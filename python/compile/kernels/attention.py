"""Layer-1 Pallas kernel: chunked-prefill / decode attention over a slotted KV cache.

This is HyGen's compute hot-spot expressed for the TPU execution model
(see DESIGN.md §Hardware-Adaptation for the CUDA->TPU mapping):

  * the iteration batch is laid out as ``[B, C]`` -- ``B`` sequence slots,
    each contributing up to ``C`` new tokens this iteration (``C == 1`` for a
    pure decode slot, ``C`` up to the chunk budget for a prefill chunk).
    This is exactly Sarathi-style iteration-level chunked prefill.
  * each grid program ``(b, h)`` owns one (slot, head) pair; its Q tile
    ``[C, D]`` and the slot's full K/V cache stripes ``[T, D]`` are staged
    HBM->VMEM by ``BlockSpec`` index maps -- the declarative analogue of the
    cooperative threadblock loads a CUDA kernel would issue.
  * softmax uses the online (running max / running denominator) formulation
    over K tiles of ``block_k`` so the working set stays in VMEM and the two
    matmuls (QK^T, PV) are MXU-shaped.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path and real-TPU
performance is estimated analytically (see DESIGN.md §5).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # large-negative instead of -inf: keeps exp() NaN-free


def _attention_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    """One (slot, head) program: online-softmax attention of C queries vs T keys.

    pos_ref: [1, 1] i32  -- first position of this slot's new tokens
    q_ref:   [1, C, 1, D]  query tile (already RoPE-rotated)
    k_ref:   [1, T, 1, D]  slot's key cache stripe for this head
    v_ref:   [1, T, 1, D]  slot's value cache stripe
    o_ref:   [1, C, 1, D]  output tile
    """
    q = q_ref[0, :, 0, :]  # [C, D]
    c, d = q.shape
    t = k_ref.shape[1]
    pos0 = pos_ref[0, 0]
    q_pos = pos0 + jax.lax.iota(jnp.int32, c)  # position of each query token
    scale = 1.0 / math.sqrt(d)

    m = jnp.full((c,), NEG_INF, dtype=jnp.float32)  # running max
    l = jnp.zeros((c,), dtype=jnp.float32)  # running denominator
    acc = jnp.zeros((c, d), dtype=jnp.float32)  # running numerator

    # Static loop over K tiles: T and block_k are compile-time constants, so
    # this unrolls into a fixed HBM->VMEM schedule (the BlockSpec already
    # staged the full stripe; the tile loop keeps the MXU operands small).
    for kb in range(t // block_k):
        k = k_ref[0, kb * block_k : (kb + 1) * block_k, 0, :]  # [block_k, D]
        v = v_ref[0, kb * block_k : (kb + 1) * block_k, 0, :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kv_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = kv_pos[None, :] <= q_pos[:, None]
        s = jnp.where(causal, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m = m_new

    o_ref[0, :, 0, :] = acc / l[:, None]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def chunked_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos_base: jax.Array,
    *,
    block_k: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Attention of the iteration's new tokens against the slotted KV cache.

    Args:
      q:        [B, C, H, D] new-token queries (RoPE already applied).
      k_cache:  [B, T, H, D] per-slot key cache; positions
                ``[pos_base[b], pos_base[b] + C)`` hold this iteration's keys.
      v_cache:  [B, T, H, D] value cache, same layout.
      pos_base: [B] int32, first new-token position per slot.
      block_k:  K-tile size for the online softmax (multiple of lane width).

    Returns: [B, C, H, D] attention outputs. Padding queries (beyond a
    slot's ``n_new``) produce garbage rows the model never reads.
    """
    b, c, h, d = q.shape
    t = k_cache.shape[1]
    if t % block_k != 0:
        raise ValueError(f"T={t} must be a multiple of block_k={block_k}")
    pos2 = pos_base.reshape(b, 1).astype(jnp.int32)
    kernel = functools.partial(_attention_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((1, c, 1, d), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, t, 1, d), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, t, 1, d), lambda bi, hi: (bi, 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, d), lambda bi, hi: (bi, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, h, d), jnp.float32),
        interpret=interpret,
    )(pos2, q, k_cache, v_cache)


def vmem_bytes(c: int, t: int, d: int, block_k: int) -> int:
    """Estimated VMEM working set of one (slot, head) program, in bytes.

    Used by the §Perf analysis: q tile + staged K/V stripes + accumulators.
    """
    f32 = 4
    q_tile = c * d * f32
    kv_stripes = 2 * t * d * f32
    tiles = 2 * block_k * d * f32
    acc = (c * d + 2 * c) * f32
    scores = c * block_k * f32
    return q_tile + kv_stripes + tiles + acc + scores


def mxu_flops(c: int, t: int, d: int) -> int:
    """MXU FLOPs of one (slot, head) program: QK^T + PV."""
    return 2 * c * t * d * 2
