"""Pure-jnp oracle for the Pallas chunked-attention kernel.

No Pallas, no tiling, no online softmax -- a direct masked-softmax
implementation that the kernel is tested against (pytest + hypothesis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos_base: jax.Array,
) -> jax.Array:
    """Reference attention; same contract as ``attention.chunked_attention``.

    q:        [B, C, H, D]
    k_cache:  [B, T, H, D]
    v_cache:  [B, T, H, D]
    pos_base: [B] int32
    returns:  [B, C, H, D]
    """
    b, c, h, d = q.shape
    t = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    # [B, H, C, T] scores
    s = jnp.einsum("bchd,bthd->bhct", q, k_cache) * scale
    q_pos = pos_base[:, None].astype(jnp.int32) + jnp.arange(c, dtype=jnp.int32)[None, :]
    kv_pos = jnp.arange(t, dtype=jnp.int32)
    causal = kv_pos[None, None, :] <= q_pos[:, :, None]  # [B, C, T]
    s = jnp.where(causal[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhct,bthd->bchd", p, v_cache)
    return o.astype(jnp.float32)
