"""Layer-2 JAX model: a tiny byte-level decoder transformer with a slotted KV
cache, driven one iteration (= one mixed chunked-prefill/decode batch) at a
time.

This is the compute substrate the Rust coordinator schedules onto. The step
function has exactly the contract HyGen's scheduler needs:

    step(tokens[B, C], pos_base[B], n_new[B], cache_k, cache_v)
        -> (logits[B, C, V], cache_k', cache_v')

* ``B`` sequence slots (one per running request), ``C`` new tokens per slot
  this iteration. A decode slot contributes 1 token; a prefill slot
  contributes a chunk of up to ``C`` tokens (Sarathi-style chunked prefill).
* ``pos_base[b]`` is the slot's current sequence length (where the new
  tokens start); ``n_new[b] <= C`` is how many of the C are real. Padding
  rows write garbage K/V *beyond* ``pos_base + n_new``, which is never read
  (attention masks by position) and is overwritten by the next chunk.
* caches are ``[L, B, T, H, D]`` and travel through the step as inputs and
  outputs so the Rust runtime can keep them as XLA literals between calls.

Model params are created from a fixed seed at AOT time and *baked into the
HLO as constants* -- the Rust side only ever ships tokens/positions/caches.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import chunked_attention
from .kernels.ref import attention_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny-transformer hyperparameters (byte-level vocab)."""

    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    head_dim: int = 32
    n_layers: int = 2
    d_ff: int = 256
    max_seq: int = 256
    rope_theta: float = 10000.0

    def __post_init__(self):
        assert self.n_heads * self.head_dim == self.d_model


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    """He-style init of all weights as a flat dict of arrays."""
    ks = jax.random.split(key, 2 + cfg.n_layers)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def dense(k, fan_in, shape):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(
            jnp.float32
        )

    params = {
        "embed": dense(ks[0], 1.0, (v, d)) * 0.02 * jnp.sqrt(1.0),
        "lm_head": dense(ks[1], d, (d, v)),
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[2 + i], 7)
        params["layers"].append(
            {
                "attn_norm": jnp.ones((d,), jnp.float32),
                "wq": dense(lk[0], d, (d, d)),
                "wk": dense(lk[1], d, (d, d)),
                "wv": dense(lk[2], d, (d, d)),
                "wo": dense(lk[3], d, (d, d)),
                "mlp_norm": jnp.ones((d,), jnp.float32),
                "w_gate": dense(lk[4], d, (d, f)),
                "w_up": dense(lk[5], d, (d, f)),
                "w_down": dense(lk[6], f, (f, d)),
            }
        )
    return params


def _rms_norm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [B, C, H, D]; positions: [B, C] int32."""
    b, c, h, d = x.shape
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, C, half]
    cos = jnp.cos(ang)[:, :, None, :]  # [B, C, 1, half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _update_cache(cache: jax.Array, new: jax.Array, pos_base: jax.Array) -> jax.Array:
    """Write [B, C, H, D] new K/V into [B, T, H, D] cache at pos_base[b].

    Whole-chunk dynamic_update_slice per slot: rows past ``n_new`` land as
    garbage beyond the live region; they are masked out of attention and
    overwritten by the next chunk starting exactly at pos_base + n_new.
    """

    def write_one(cache_b, new_b, start):
        return jax.lax.dynamic_update_slice(cache_b, new_b, (start, 0, 0))

    return jax.vmap(write_one)(cache, new, pos_base)


@functools.partial(
    jax.jit, static_argnames=("cfg", "use_pallas", "interpret")
)
def step(
    params: dict,
    tokens: jax.Array,
    pos_base: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    *,
    cfg: ModelConfig,
    use_pallas: bool = True,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One engine iteration over a mixed prefill/decode batch.

    tokens:   [B, C] int32 new token ids (padding rows arbitrary).
    pos_base: [B] int32 current length of each slot.
    cache_k/v: [L, B, T, H, D] f32.
    Returns (logits [B, C, V], new cache_k, new cache_v).
    """
    b, c = tokens.shape
    h, d = cfg.n_heads, cfg.head_dim
    positions = pos_base[:, None].astype(jnp.int32) + jnp.arange(c, dtype=jnp.int32)

    x = params["embed"][tokens]  # [B, C, d_model]
    new_ks, new_vs = [], []
    for li, layer in enumerate(params["layers"]):
        xn = _rms_norm(x, layer["attn_norm"])
        q = (xn @ layer["wq"]).reshape(b, c, h, d)
        k = (xn @ layer["wk"]).reshape(b, c, h, d)
        v = (xn @ layer["wv"]).reshape(b, c, h, d)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        ck = _update_cache(cache_k[li], k, pos_base)
        cv = _update_cache(cache_v[li], v, pos_base)
        new_ks.append(ck)
        new_vs.append(cv)
        if use_pallas:
            o = chunked_attention(q, ck, cv, pos_base, interpret=interpret)
        else:
            o = attention_ref(q, ck, cv, pos_base)
        x = x + o.reshape(b, c, cfg.d_model) @ layer["wo"]
        xn = _rms_norm(x, layer["mlp_norm"])
        x = x + (jax.nn.silu(xn @ layer["w_gate"]) * (xn @ layer["w_up"])) @ layer[
            "w_down"
        ]

    x = _rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]  # [B, C, V]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def _param_layout(cfg: ModelConfig):
    """Deterministic (name, shape) order used to (un)flatten the weights.

    The same order defines ``artifacts/params.bin``: one little-endian f32
    blob the Rust runtime loads at startup and ships as the step function's
    first argument. (jax >= 0.5 lifts closed-over arrays to module
    parameters rather than baking them as HLO constants, so the weights are
    an *explicit* input -- which also matches how a real serving engine
    loads checkpoints.)
    """
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    layout = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        layout += [
            (f"layers.{i}.attn_norm", (d,)),
            (f"layers.{i}.wq", (d, d)),
            (f"layers.{i}.wk", (d, d)),
            (f"layers.{i}.wv", (d, d)),
            (f"layers.{i}.wo", (d, d)),
            (f"layers.{i}.mlp_norm", (d,)),
            (f"layers.{i}.w_gate", (d, f)),
            (f"layers.{i}.w_up", (d, f)),
            (f"layers.{i}.w_down", (f, d)),
        ]
    layout += [("final_norm", (d,)), ("lm_head", (d, v))]
    return layout


def num_params(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in _param_layout(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def flatten_params(params: dict, cfg: ModelConfig) -> jax.Array:
    """Flatten the params dict into one f32 vector per ``_param_layout``."""
    flat = {}
    flat["embed"] = params["embed"]
    for i, layer in enumerate(params["layers"]):
        for k, vv in layer.items():
            flat[f"layers.{i}.{k}"] = vv
    flat["final_norm"] = params["final_norm"]
    flat["lm_head"] = params["lm_head"]
    return jnp.concatenate(
        [flat[name].reshape(-1) for name, _ in _param_layout(cfg)]
    ).astype(jnp.float32)


def unflatten_params(flat: jax.Array, cfg: ModelConfig) -> dict:
    """Inverse of ``flatten_params`` (traced inside the lowered step fn)."""
    out: dict = {"layers": [dict() for _ in range(cfg.n_layers)]}
    off = 0
    for name, shape in _param_layout(cfg):
        n = 1
        for s in shape:
            n *= s
        arr = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        off += n
        if name.startswith("layers."):
            _, idx, key = name.split(".")
            out["layers"][int(idx)][key] = arr
        else:
            out[name] = arr
    return out


def make_step_fn(cfg: ModelConfig, *, use_pallas: bool = True):
    """Build fn(flat_params, tokens, pos_base, cache_k, cache_v) for AOT.

    This is the function ``aot.py`` lowers; its 5-array signature is the
    runtime ABI between the artifacts and the Rust engine.
    """

    def fn(flat_params, tokens, pos_base, cache_k, cache_v):
        params = unflatten_params(flat_params, cfg)
        return step(
            params,
            tokens,
            pos_base,
            cache_k,
            cache_v,
            cfg=cfg,
            use_pallas=use_pallas,
        )

    return fn


def empty_cache(cfg: ModelConfig, batch: int) -> Tuple[jax.Array, jax.Array]:
    shape = (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)
