"""AOT pipeline: lower the L2 step function to HLO *text* artifacts.

The Rust runtime (rust/src/runtime) loads these with
``HloModuleProto::from_text_file`` and compiles them on the PJRT CPU client.

HLO text -- NOT ``lowered.compile().serialize()`` and NOT the serialized
HloModuleProto -- is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (what the published
``xla = 0.1.6`` crate binds) rejects with ``proto.id() <= INT_MAX``. The
text parser reassigns ids, so text round-trips cleanly (see
rust/src/runtime/mod.rs).

One artifact is emitted per (B, C) shape bucket -- XLA executables have
static shapes, so the Rust engine pads each iteration batch up to the
nearest bucket. Model weights come from a fixed seed and are baked into
the HLO as constants: Python never runs at serving time, and the Rust
side ships only tokens/positions/caches.

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

import numpy as np

from .model import (
    ModelConfig,
    empty_cache,
    flatten_params,
    init_params,
    make_step_fn,
    num_params,
)

# (batch slots, chunk tokens per slot) buckets the Rust engine can pick from.
BUCKETS = [(1, 1), (1, 32), (4, 1), (4, 8), (4, 32), (8, 1), (8, 8), (8, 32)]
SEED = 0


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(cfg: ModelConfig, b: int, c: int) -> str:
    """Lower one (B, C) shape bucket of the step fn to HLO text.

    ABI (5 inputs / 3-tuple output) consumed by rust/src/runtime:
      in:  flat_params f32[P], tokens s32[B,C], pos_base s32[B],
           cache_k f32[L,B,T,H,D], cache_v f32[L,B,T,H,D]
      out: (logits f32[B,C,V], cache_k', cache_v')
    """
    fn = make_step_fn(cfg, use_pallas=True)
    flat = jax.ShapeDtypeStruct((num_params(cfg),), jnp.float32)
    tokens = jax.ShapeDtypeStruct((b, c), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    ck, cv = empty_cache(cfg, b)
    cache = jax.ShapeDtypeStruct(ck.shape, ck.dtype)
    return to_hlo_text(jax.jit(fn).lower(flat, tokens, pos, cache, cache))


def input_fingerprint() -> str:
    """Hash of the compile-path sources, so `make artifacts` can skip."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for rel in ["model.py", "aot.py", "kernels/attention.py", "kernels/ref.py"]:
        with open(os.path.join(here, rel), "rb") as f:
            h.update(f.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default=None, help="e.g. '1x1,8x32' to restrict")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    buckets = BUCKETS
    if args.buckets:
        buckets = [tuple(map(int, s.split("x"))) for s in args.buckets.split(",")]

    cfg = ModelConfig()
    params = init_params(jax.random.PRNGKey(SEED), cfg)
    flat = np.asarray(flatten_params(params, cfg), dtype="<f4")
    with open(os.path.join(args.out_dir, "params.bin"), "wb") as f:
        f.write(flat.tobytes())
    print(f"wrote params.bin: {flat.size} f32 ({flat.nbytes} bytes)")

    manifest = {
        "seed": SEED,
        "fingerprint": input_fingerprint(),
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "num_params": int(flat.size),
        },
        "artifacts": [],
    }
    for b, c in buckets:
        name = f"step_b{b}_c{c}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_bucket(cfg, b, c)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({"batch": b, "chunk": c, "file": name})
        print(f"wrote {name}: {len(text)} chars")

    # Cross-language fixture: greedy-decode a fixed prompt with the jax
    # model; the Rust integration test must reproduce these exact token ids
    # through the PJRT path (L1+L2+L3 consistency proof).
    fixture = make_fixture(cfg, params)
    with open(os.path.join(args.out_dir, "expected_tokens.json"), "w") as f:
        json.dump(fixture, f)
    print(f"wrote expected_tokens.json ({len(fixture['output_tokens'])} tokens)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(buckets)} buckets)")


def make_fixture(cfg: ModelConfig, params: dict, prompt: str = "Hello, HyGen!", n_out: int = 12):
    """Greedy generation fixture for the Rust integration test."""
    from .model import step

    tokens = [b for b in prompt.encode()]
    ck, cv = empty_cache(cfg, 1)
    t = jnp.asarray([tokens], jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    logits, ck, cv = step(params, t, pos, ck, cv, cfg=cfg)
    out = [int(jnp.argmax(logits[0, len(tokens) - 1]))]
    for i in range(n_out - 1):
        t = jnp.asarray([[out[-1]]], jnp.int32)
        pos = jnp.asarray([len(tokens) + i], jnp.int32)
        logits, ck, cv = step(params, t, pos, ck, cv, cfg=cfg)
        out.append(int(jnp.argmax(logits[0, 0])))
    return {"prompt": prompt, "prompt_tokens": tokens, "output_tokens": out}


if __name__ == "__main__":
    main()
