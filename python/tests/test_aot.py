"""AOT pipeline tests: lowering emits parseable HLO text with the exact
5-input / 3-output ABI the Rust runtime expects, and the params flattening
round-trips."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import input_fingerprint, lower_bucket, to_hlo_text
from compile.model import (
    ModelConfig,
    empty_cache,
    flatten_params,
    init_params,
    num_params,
    step,
    unflatten_params,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(max_seq=64, n_layers=1)


def _entry_section(text: str) -> str:
    idx = text.index("ENTRY")
    return text[idx:]


def test_lower_small_bucket_emits_hlo_text():
    text = lower_bucket(CFG, b=1, c=1)
    assert "HloModule" in text
    entry = _entry_section(text)
    # exactly 5 inputs: flat_params, tokens, pos_base, cache_k, cache_v
    for i in range(5):
        assert f"parameter({i})" in entry
    assert "parameter(5)" not in entry


def test_lowered_signature_shapes():
    text = lower_bucket(CFG, b=2, c=4)
    assert f"f32[{num_params(CFG)}]" in text  # flat params
    assert "s32[2,4]" in text  # tokens
    assert "s32[2]" in text  # pos_base
    assert f"f32[1,2,64,{CFG.n_heads},{CFG.head_dim}]" in text  # caches


def test_params_flatten_roundtrip():
    params = init_params(jax.random.PRNGKey(0), CFG)
    flat = flatten_params(params, CFG)
    assert flat.shape == (num_params(CFG),)
    back = unflatten_params(flat, CFG)
    np.testing.assert_array_equal(np.asarray(back["embed"]), np.asarray(params["embed"]))
    np.testing.assert_array_equal(
        np.asarray(back["layers"][0]["w_down"]),
        np.asarray(params["layers"][0]["w_down"]),
    )
    np.testing.assert_array_equal(
        np.asarray(back["lm_head"]), np.asarray(params["lm_head"])
    )


def test_flat_step_matches_dict_step():
    """The AOT'd flat-params path computes the same logits as the direct one."""
    params = init_params(jax.random.PRNGKey(0), CFG)
    flat = flatten_params(params, CFG)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 4)), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    ck, cv = empty_cache(CFG, 2)
    l1, k1, v1 = step(params, tokens, pos, ck, cv, cfg=CFG)
    l2, k2, v2 = step(unflatten_params(flat, CFG), tokens, pos, ck, cv, cfg=CFG)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-6)


def test_hlo_text_round_trips_through_plain_jit():
    """The interchange helper works on arbitrary jitted fns, not just step."""
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text


def test_fingerprint_is_stable_and_short():
    fp1, fp2 = input_fingerprint(), input_fingerprint()
    assert fp1 == fp2
    assert len(fp1) == 16
