"""L1 correctness: Pallas chunked-attention kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute layer -- hypothesis
sweeps shapes, cache fills, and positions; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import chunked_attention, mxu_flops, vmem_bytes
from compile.kernels.ref import attention_ref

jax.config.update("jax_platform_name", "cpu")


def _rand_case(rng, b, c, h, d, t):
    q = rng.standard_normal((b, c, h, d), dtype=np.float32)
    k = rng.standard_normal((b, t, h, d), dtype=np.float32)
    v = rng.standard_normal((b, t, h, d), dtype=np.float32)
    # pos_base must leave room for the C new tokens: pos + C <= T
    pos = rng.integers(0, t - c + 1, size=(b,)).astype(np.int32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(pos)


def _check(q, k, v, pos, block_k):
    out = chunked_attention(q, k, v, pos, block_k=block_k)
    ref = attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestKernelVsRef:
    def test_basic(self):
        rng = np.random.default_rng(0)
        _check(*_rand_case(rng, 4, 8, 4, 32, 128), block_k=64)

    def test_decode_shape(self):
        """C=1 pure-decode batch."""
        rng = np.random.default_rng(1)
        _check(*_rand_case(rng, 8, 1, 4, 32, 256), block_k=64)

    def test_prefill_from_zero(self):
        rng = np.random.default_rng(2)
        q, k, v, _ = _rand_case(rng, 2, 32, 4, 32, 64)
        pos = jnp.zeros((2,), jnp.int32)
        _check(q, k, v, pos, block_k=32)

    def test_single_slot_single_head(self):
        rng = np.random.default_rng(3)
        _check(*_rand_case(rng, 1, 4, 1, 16, 32), block_k=16)

    def test_block_k_full_t(self):
        """block_k == T degenerates to one tile."""
        rng = np.random.default_rng(4)
        _check(*_rand_case(rng, 2, 4, 2, 16, 64), block_k=64)

    def test_block_k_indivisible_raises(self):
        rng = np.random.default_rng(5)
        q, k, v, pos = _rand_case(rng, 1, 2, 1, 8, 64)
        with pytest.raises(ValueError):
            chunked_attention(q, k, v, pos, block_k=48)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 5),
        c=st.sampled_from([1, 2, 4, 8]),
        h=st.integers(1, 4),
        logd=st.integers(3, 5),
        t_mult=st.integers(1, 4),
        block_k=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, b, c, h, logd, t_mult, block_k, seed):
        d = 2**logd
        t = block_k * t_mult
        if t < c:
            t = block_k * ((c + block_k - 1) // block_k)
        rng = np.random.default_rng(seed)
        _check(*_rand_case(rng, b, c, h, d, t), block_k=block_k)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_scale_invariance_of_mask(self, seed):
        """Garbage K/V beyond every query's position must not leak into out."""
        rng = np.random.default_rng(seed)
        b, c, h, d, t = 2, 4, 2, 16, 64
        q, k, v, pos = _rand_case(rng, b, c, h, d, t)
        out1 = chunked_attention(q, k, v, pos, block_k=32)
        # poison all cache rows strictly beyond the last query position
        last = np.asarray(pos) + c  # first untouched row per slot
        k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
        for bi in range(b):
            k2[bi, last[bi] :] = 1e4
            v2[bi, last[bi] :] = -1e4
        out2 = chunked_attention(q, jnp.asarray(k2), jnp.asarray(v2), pos, block_k=32)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


class TestRoofline:
    """Sanity of the roofline estimators (they feed the analytic cost models; DESIGN.md §5)."""

    def test_vmem_fits_budget(self):
        # production bucket: C=32, T=256, D=32, block_k=64 per (slot, head)
        assert vmem_bytes(32, 256, 32, 64) < 16 * 1024 * 1024

    def test_mxu_flops_positive_and_scales(self):
        assert mxu_flops(32, 256, 32) == 2 * mxu_flops(16, 256, 32)
