"""L2 correctness: the step function's serving invariants.

These are the invariants the Rust engine relies on:
  * pallas path == ref-attention path,
  * chunked prefill == monolithic prefill (Sarathi equivalence),
  * incremental decode == full-sequence forward,
  * padding slots/rows never perturb live slots.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import ModelConfig, empty_cache, init_params, step

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(max_seq=64, n_layers=2)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


def run_step(tokens, pos_base, ck, cv, use_pallas=True):
    return step(
        PARAMS,
        jnp.asarray(tokens, jnp.int32),
        jnp.asarray(pos_base, jnp.int32),
        ck,
        cv,
        cfg=CFG,
        use_pallas=use_pallas,
    )


def test_pallas_matches_ref_path():
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, CFG.vocab, size=(2, 8))
    ck, cv = empty_cache(CFG, 2)
    lp, ckp, cvp = run_step(tokens, [0, 0], ck, cv, use_pallas=True)
    lr, ckr, cvr = run_step(tokens, [0, 0], ck, cv, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ckp), np.asarray(ckr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cvp), np.asarray(cvr), rtol=1e-5, atol=1e-5)


def test_chunked_prefill_equals_monolithic():
    """Prefilling 16 tokens as 2x8-chunks must equal one 16-token prefill."""
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, CFG.vocab, size=(1, 16))
    ck, cv = empty_cache(CFG, 1)
    logits_full, _, _ = run_step(prompt, [0], ck, cv)

    ck2, cv2 = empty_cache(CFG, 1)
    _, ck2, cv2 = run_step(prompt[:, :8], [0], ck2, cv2)
    logits_chunk2, _, _ = run_step(prompt[:, 8:], [8], ck2, cv2)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, 8:]),
        np.asarray(logits_chunk2),
        rtol=1e-4,
        atol=1e-4,
    )


def test_incremental_decode_equals_forward():
    """Last-token logits from token-by-token decode == full forward pass."""
    rng = np.random.default_rng(2)
    seq = rng.integers(0, CFG.vocab, size=(1, 12))
    ck, cv = empty_cache(CFG, 1)
    logits_full, _, _ = run_step(seq, [0], ck, cv)

    ck2, cv2 = empty_cache(CFG, 1)
    _, ck2, cv2 = run_step(seq[:, :4], [0], ck2, cv2)  # prefill 4
    outs = []
    for i in range(4, 12):  # decode one at a time
        lg, ck2, cv2 = run_step(seq[:, i : i + 1], [i], ck2, cv2)
        outs.append(np.asarray(lg)[0, 0])
    np.testing.assert_allclose(
        np.asarray(logits_full)[0, 4:], np.stack(outs), rtol=1e-4, atol=1e-4
    )


def test_padding_slot_does_not_perturb_live_slot():
    """Slot 1's content must not change slot 0's logits (batch isolation)."""
    rng = np.random.default_rng(3)
    t0 = rng.integers(0, CFG.vocab, size=(8,))
    pad_a = rng.integers(0, CFG.vocab, size=(8,))
    pad_b = rng.integers(0, CFG.vocab, size=(8,))
    ck, cv = empty_cache(CFG, 2)
    la, _, _ = run_step(np.stack([t0, pad_a]), [0, 0], ck, cv)
    lb, _, _ = run_step(np.stack([t0, pad_b]), [0, 0], ck, cv)
    np.testing.assert_allclose(np.asarray(la[0]), np.asarray(lb[0]), rtol=1e-6)


def test_padding_rows_within_chunk_do_not_perturb():
    """Rows beyond n_new are padding; changing them must not affect the
    logits of real rows (positions mask them out)."""
    rng = np.random.default_rng(4)
    real = rng.integers(0, CFG.vocab, size=(4,))
    ck, cv = empty_cache(CFG, 1)
    a = np.concatenate([real, rng.integers(0, CFG.vocab, size=(4,))])[None, :]
    b = np.concatenate([real, rng.integers(0, CFG.vocab, size=(4,))])[None, :]
    la, _, _ = run_step(a, [0], ck, cv)
    lb, _, _ = run_step(b, [0], ck, cv)
    np.testing.assert_allclose(np.asarray(la[0, :4]), np.asarray(lb[0, :4]), rtol=1e-6)


def test_cache_garbage_overwritten_by_next_chunk():
    """Padding K/V written past n_new is overwritten when the next chunk
    starts at pos_base + n_new: two chunked runs with different padding
    converge to identical caches over the live region."""
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, CFG.vocab, size=(10,))
    pad1 = rng.integers(0, CFG.vocab, size=(2,))
    pad2 = rng.integers(0, CFG.vocab, size=(2,))

    def run(pad):
        ck, cv = empty_cache(CFG, 1)
        chunk1 = np.concatenate([prompt[:6], pad])[None, :]  # n_new=6, C=8
        _, ck, cv = run_step(chunk1, [0], ck, cv)
        lg, ck, cv = run_step(prompt[None, 6:10], [6], ck, cv)  # next at pos 6
        return np.asarray(lg), np.asarray(ck)[:, :, :10], np.asarray(cv)[:, :, :10]

    l1, k1, v1 = run(pad1)
    l2, k2, v2 = run(pad2)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    np.testing.assert_allclose(k1, k2, rtol=1e-6)
    np.testing.assert_allclose(v1, v2, rtol=1e-6)


def test_determinism():
    rng = np.random.default_rng(6)
    tokens = rng.integers(0, CFG.vocab, size=(2, 4))
    ck, cv = empty_cache(CFG, 2)
    l1, _, _ = run_step(tokens, [0, 0], ck, cv)
    l2, _, _ = run_step(tokens, [0, 0], ck, cv)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_logit_shapes():
    ck, cv = empty_cache(CFG, 4)
    lg, ck2, cv2 = run_step(np.zeros((4, 8)), [0, 0, 0, 0], ck, cv)
    assert lg.shape == (4, 8, CFG.vocab)
    assert ck2.shape == ck.shape and cv2.shape == cv.shape
