# HyGen build entry points. The Rust crate is the primary artifact
# (`cargo build --release` / `cargo test -q` work without any of this);
# `make artifacts` produces the AOT HLO artifacts the PJRT execution path
# (`--features pjrt`) loads at startup.

.PHONY: all artifacts test lint bench bench-sched bench-replay cluster multi-slo chaos overload microbench clean

all:
	cargo build --release

# AOT-lower the Layer-2 JAX step function (with the Layer-1 Pallas kernel
# inside) to HLO text + weights + manifest under artifacts/.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

test:
	cargo build --release && cargo test -q

# In-repo static analysis (DESIGN.md §9): determinism, alloc-free,
# panic-free, and config-doc invariants over rust/src/. Blocking in CI.
lint:
	cargo run --release -- lint

# Regenerate both tracked perf-trajectory files
# (BENCH_sched.json + BENCH_e2e.json + BENCH_prefix.csv).
bench: bench-sched bench-replay

# Scheduling-overhead trajectory (10k-request mixed trace + scaling probe)
# -> BENCH_sched.json
bench-sched:
	cargo run --release -- bench-sched

# End-to-end replay trajectory (multi-scale mixed-trace replay +
# zero-allocation steady-decode probe with live cache churn + O(1)
# block-recycling probe + prefix shape sweep)
# -> BENCH_e2e.json + BENCH_prefix.csv
bench-replay:
	cargo run --release -- bench-replay

# Multi-replica router comparison on the mixed + mooncake-prefix
# workloads (1/2/4/8 replicas x round-robin/jsq/slo-headroom/
# prefix-affinity, with the slo-headroom-vs-round-robin and
# prefix-affinity-vs-slo-headroom acceptance gates)
# -> artifacts/cluster_compare.csv
cluster:
	cargo run --release -- cluster-sim --check

# N-class SLO registry comparison: the calibrated 4-class trace (chat /
# completion / summarize / batch) under the 2-class and 4-class
# registries across 1/2/4 replicas -> artifacts/multi_slo.csv
multi-slo:
	cargo run --release -- multi-slo

# Chaos-test the cluster fault tolerance: seeded kill/restart schedules
# per router policy next to a fault-free baseline, with the zero-loss
# conservation gate -> artifacts/chaos_compare.csv
chaos:
	cargo run --release -- chaos

# Ramp open-loop QPS past single-replica capacity through the serving
# admission ladder (brown-out 429s, bounded queues, deadline 504s), with
# the exact conservation gate -> artifacts/overload.csv
overload:
	cargo run --release -- overload

# In-tree Bencher micro-benchmarks (scheduler, PSM, predictor, figures,
# sched_trace, replay bench targets).
microbench:
	cargo bench

clean:
	cargo clean
	rm -rf artifacts results
