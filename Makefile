# HyGen build entry points. The Rust crate is the primary artifact
# (`cargo build --release` / `cargo test -q` work without any of this);
# `make artifacts` produces the AOT HLO artifacts the PJRT execution path
# (`--features pjrt`) loads at startup.

.PHONY: all artifacts test bench bench-sched clean

all:
	cargo build --release

# AOT-lower the Layer-2 JAX step function (with the Layer-1 Pallas kernel
# inside) to HLO text + weights + manifest under artifacts/.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

test:
	cargo build --release && cargo test -q

bench:
	cargo bench

# Scheduling-overhead trajectory (10k-request mixed trace + scaling probe)
# -> BENCH_sched.json
bench-sched:
	cargo run --release -- bench-sched

clean:
	cargo clean
	rm -rf artifacts results
