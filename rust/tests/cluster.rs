//! Cluster-layer contracts:
//!
//! * **exactly-one placement** — over random traces, policies, and
//!   replica counts, every admitted request lives on exactly one live
//!   replica (or, for deferred offline work, in the shared backlog), and
//!   per-engine invariants hold after the run;
//! * **JSQ minimality** — `JoinShortestQueue` never picks a replica with
//!   a strictly longer queue than another live replica;
//! * **router totality** — every policy returns an in-range index for
//!   arbitrary snapshot vectors, preferring live replicas while any
//!   exist;
//! * **chaos conservation** — under seeded random kill/restart schedules,
//!   every admitted request is finished, resident, backlogged, or failed
//!   exactly once (`lost == 0`), with per-engine invariants checked after
//!   every step; a failing case logs its replay seed.
//!
//! (`tests/determinism.rs` holds the byte-identity contract for the
//! `cluster-sim` and `chaos` CSVs.)

use hygen::cluster::router::{JoinShortestQueue, Router, RouterPolicy};
use hygen::cluster::sim::{ClusterSim, FaultSchedule};
use hygen::cluster::ReplicaSnapshot;
use hygen::coordinator::predictor::LatencyPredictor;
use hygen::coordinator::queues::OfflinePolicy;
use hygen::coordinator::request::Class;
use hygen::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
use hygen::coordinator::state::EngineState;
use hygen::engine::Engine;
use hygen::sim::costmodel::CostModel;
use hygen::sim::SimBackend;
use hygen::util::prop::{check, Gen};
use hygen::workload::trace::{Trace, TraceEvent};

fn engines(n: usize, budget: Option<f64>, seed: u64) -> Vec<Engine<SimBackend>> {
    (0..n)
        .map(|i| {
            // Full A100-class KV pool: the properties probe routing, not
            // memory pressure (tight pools have their own unit tests).
            let blocks = CostModel::a100_llama7b().num_blocks(16);
            let state = EngineState::new(OfflinePolicy::Fcfs, blocks, 16, seed + i as u64);
            let sched = HybridScheduler::new(
                SchedulerConfig { latency_budget_ms: budget, ..Default::default() },
                LatencyPredictor::default_seed(),
            );
            let mut e = Engine::new(
                sched,
                state,
                SimBackend::new(CostModel::a100_llama7b(), seed + i as u64),
            );
            e.state.keep_finished = false;
            e
        })
        .collect()
}

fn random_trace(g: &mut Gen) -> Trace {
    let n = g.usize(5, 60);
    let mut events = Vec::with_capacity(n + 1);
    for _ in 0..n {
        let online = g.bool();
        events.push(TraceEvent {
            arrival_s: g.f64(0.0, 5.0),
            class: if online { Class::ONLINE } else { Class::OFFLINE },
            prompt_len: g.usize(8, 400),
            output_len: g.usize(1, 24),
            prompt: Vec::new().into(),
        });
    }
    // A final online event after every other arrival keeps the cluster
    // replaying until the whole trace is admitted (the run stops once the
    // online portion completes, so an all-offline tail would otherwise
    // never be admitted — by design, not a conservation bug).
    events.push(TraceEvent {
        arrival_s: 5.5,
        class: Class::ONLINE,
        prompt_len: 32,
        output_len: 4,
        prompt: Vec::new().into(),
    });
    Trace::new(events)
}

fn random_snaps(g: &mut Gen) -> Vec<ReplicaSnapshot> {
    let n = g.usize(1, 8);
    let mut snaps: Vec<ReplicaSnapshot> = (0..n)
        .map(|_| {
            let mut s = ReplicaSnapshot {
                free_kv_tokens: g.usize(0, 10_000),
                predicted_iter_ms: g.f64(0.0, 80.0),
                latency_budget_ms: if g.bool() { 40.0 } else { f64::INFINITY },
                failed: g.bool(),
                ..ReplicaSnapshot::default()
            };
            s.waiting[0] = g.usize(0, 20);
            s.waiting[1] = g.usize(0, 40);
            s.running[0] = g.usize(0, 20);
            s.running[1] = g.usize(0, 20);
            s.preempted[1] = g.usize(0, 5);
            s
        })
        .collect();
    // Keep at least one live replica in most cases.
    if g.bool() {
        snaps[0].failed = false;
    }
    snaps
}

#[test]
fn prop_every_admitted_request_lands_on_exactly_one_replica() {
    check("cluster conservation", 40, |g: &mut Gen| {
        let policy = *g.pick(&RouterPolicy::ALL);
        let n = g.usize(1, 5);
        let budget = if g.bool() { Some(40.0) } else { None };
        let trace = random_trace(g);
        let mut sim = ClusterSim::new(engines(n, budget, g.seed), policy.build(), 0.5);
        let r = sim.run(&trace, 400.0).unwrap();
        // Conservation: every admitted event is finished on a replica,
        // still resident on a replica, or held in the shared backlog —
        // never duplicated, never lost.
        let mut on_replicas = 0usize;
        for e in &sim.engines {
            e.state.check_invariants().unwrap();
            on_replicas +=
                e.state.num_running() + e.state.total_waiting() + e.state.total_preempted();
        }
        let finished = r.aggregate.online_finished + r.aggregate.offline_finished;
        assert_eq!(
            finished + on_replicas + r.backlog_left,
            trace.len(),
            "policy {} with {} replicas",
            policy.name(),
            n
        );
        // Each placement went to exactly one replica: the dispatch tally
        // matches the events that left the backlog (reclaims re-count).
        assert_eq!(r.dispatched - r.reclaimed, trace.len() - r.backlog_left);
        assert_eq!(sim.routed.iter().sum::<usize>(), r.dispatched);
        // The full online trace must be served (replicas are live).
        assert_eq!(r.aggregate.online_finished, trace.num_online());
    });
}

#[test]
fn prop_chaos_conserves_every_request() {
    check("chaos conservation", 25, |g: &mut Gen| {
        let policy = *g.pick(&RouterPolicy::ALL);
        let n = g.usize(2, 5);
        let budget = if g.bool() { Some(40.0) } else { None };
        let trace = random_trace(g);
        // Seeded random kill/restart schedule over the trace span; some
        // kills stay permanent, some replicas revive a moment later.
        let mut schedule = FaultSchedule::new();
        for _ in 0..g.usize(1, 4) {
            let replica = g.usize(0, n);
            let t_kill = g.f64(0.2, 5.0);
            schedule = schedule.kill(replica, t_kill);
            if g.bool() {
                schedule = schedule.restart(replica, t_kill + g.f64(0.1, 2.0));
            }
        }
        let mut sim = ClusterSim::new(engines(n, budget, g.seed), policy.build(), 0.5)
            .with_faults(schedule);
        sim.check_invariants_each_step = true;
        let r = sim.run(&trace, 400.0).unwrap();
        // Conservation under faults: every admitted event is finished,
        // still resident on a replica, held in the shared backlog, or
        // failed fast with a reported error — exactly one of the four,
        // never duplicated, never silently dropped.
        let mut on_replicas = 0usize;
        for e in &sim.engines {
            e.state.check_invariants().unwrap();
            on_replicas +=
                e.state.num_running() + e.state.total_waiting() + e.state.total_preempted();
        }
        let finished = r.aggregate.online_finished + r.aggregate.offline_finished;
        assert_eq!(
            finished + on_replicas + r.backlog_left + r.failed_503,
            r.admitted,
            "policy {} with {} replicas",
            policy.name(),
            n
        );
        assert_eq!(r.lost, 0, "policy {} with {} replicas", policy.name(), n);
        // 503s are an online-only outcome, so the online tally can never
        // exceed the trace's online population.
        assert!(r.aggregate.online_finished + r.failed_503 <= trace.num_online());
        assert!(r.admitted <= trace.len());
    });
}

#[test]
fn prop_jsq_never_picks_a_strictly_longer_queue() {
    check("jsq minimality", 300, |g: &mut Gen| {
        let snaps = random_snaps(g);
        let mut jsq = JoinShortestQueue;
        let picked = jsq.route_online(&snaps);
        assert!(picked < snaps.len());
        if snaps.iter().any(|s| !s.failed) {
            assert!(!snaps[picked].failed, "JSQ must prefer live replicas");
            let min_depth =
                snaps.iter().filter(|s| !s.failed).map(|s| s.total_depth()).min().unwrap();
            assert_eq!(
                snaps[picked].total_depth(),
                min_depth,
                "picked a strictly longer queue: {snaps:?}"
            );
        }
        if let Some(off) = jsq.route_offline(&snaps) {
            assert!(off < snaps.len());
        }
    });
}

#[test]
fn prop_routers_return_valid_live_indices() {
    check("router totality", 300, |g: &mut Gen| {
        let snaps = random_snaps(g);
        for policy in RouterPolicy::ALL {
            let mut router = policy.build();
            for _ in 0..3 {
                let i = router.route_online(&snaps);
                assert!(i < snaps.len(), "{}", policy.name());
                if snaps.iter().any(|s| !s.failed) {
                    assert!(!snaps[i].failed, "{} routed to a failed replica", policy.name());
                }
                if let Some(j) = router.route_offline(&snaps) {
                    assert!(j < snaps.len(), "{}", policy.name());
                    if snaps.iter().any(|s| !s.failed) {
                        assert!(
                            !snaps[j].failed,
                            "{} placed offline on a failed replica",
                            policy.name()
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn slo_headroom_beats_round_robin_on_a_skewed_burst() {
    // A deterministic end-to-end sanity of the routing signal: a burst of
    // heavy online prompts arrives back-to-back. Round-robin alternates
    // blindly; SLO-headroom observes the census and spreads by predicted
    // load, so the online trace finishes no later (and the worst replica
    // queue stays shorter).
    let burst: Vec<TraceEvent> = (0..24)
        .map(|i| TraceEvent {
            arrival_s: 0.01 * i as f64,
            class: Class::ONLINE,
            // alternate huge/tiny prompts: count-even splits are
            // token-skewed
            prompt_len: if i % 2 == 0 { 1800 } else { 16 },
            output_len: 8,
            prompt: Vec::new().into(),
        })
        .collect();
    let trace = Trace::new(burst);
    let run = |policy: RouterPolicy| {
        let mut sim = ClusterSim::new(engines(2, Some(40.0), 9), policy.build(), 0.5);
        sim.run(&trace, 400.0).unwrap()
    };
    let rr = run(RouterPolicy::RoundRobin);
    let slo = run(RouterPolicy::SloHeadroom);
    assert_eq!(rr.aggregate.online_finished, 24);
    assert_eq!(slo.aggregate.online_finished, 24);
    assert!(
        slo.duration_s <= rr.duration_s * 1.05,
        "slo-headroom must not finish the burst later: {} vs {}",
        slo.duration_s,
        rr.duration_s
    );
}
