//! Determinism contracts of the experiment harness:
//!
//! * the same seed must produce an identical `Report` across two full
//!   replays (every RNG is engine-owned and seeded);
//! * the parallel figure runner (`figures -j N`) must produce CSVs
//!   **byte-identical** to the serial run — parallelism only changes
//!   wallclock, never content. Checked here on scaled-down shapes of
//!   fig 6 (policy panel) and fig 10 (QPS × metric sweep), the two
//!   figures whose internal grids run as parallel jobs; CI re-checks the
//!   full `--quick` shapes through the CLI;
//! * the `cluster-sim` grid must be byte-identical for a fixed seed and
//!   for any `-j` (CI re-checks the `--quick` shape through the CLI by
//!   comparing two full runs);
//! * the `chaos` grid must stay byte-identical under fault injection —
//!   kills, migrations, and reroutes are part of the deterministic replay,
//!   not a source of nondeterminism — and a kill + restart must be
//!   *restart-equivalent*: the faulted run accounts for exactly the same
//!   online population as the clean run (finished + failed-fast), with
//!   nothing lost.

use hygen::baselines::{SimSetup, System};
use hygen::cluster::router::RouterPolicy;
use hygen::cluster::sim::{ClusterSim, FaultSchedule};
use hygen::experiments::{chaos, cluster_sim, figures, multi_slo, Ctx};
use hygen::sim::costmodel::CostModel;
use hygen::workload::azure::{self, AzureTraceConfig};
use hygen::workload::datasets::{self, Dataset};

/// A deliberately tiny ctx so the figure determinism check stays
/// test-suite-sized (the horizons/backlogs only need to be big enough to
/// produce non-trivial tables).
fn tiny_ctx(jobs: usize) -> Ctx {
    Ctx {
        horizon_s: 40.0,
        trace_s: 25.0,
        profile_steps: 2,
        offline_frac: 0.02,
        jobs,
        ..Ctx::default()
    }
}

#[test]
fn same_seed_identical_report() {
    let run = || {
        let setup = SimSetup::new(CostModel::a100_llama7b()).with_seed(3);
        let online = azure::generate(
            &AzureTraceConfig { duration_s: 30.0, mean_qps: 2.0, ..Default::default() },
            3,
        );
        let offline = datasets::generate(Dataset::ArxivSummarization, 200, 3);
        let workload = online.merged(offline);
        setup
            .run(System::HyGen { latency_budget_ms: 40.0 }, &workload, 90.0)
            .unwrap()
            .report
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must reproduce the report bit-for-bit");
}

#[test]
fn different_seed_differs() {
    let run = |seed: u64| {
        let setup = SimSetup::new(CostModel::a100_llama7b()).with_seed(seed);
        let online = azure::generate(
            &AzureTraceConfig { duration_s: 30.0, mean_qps: 2.0, ..Default::default() },
            seed,
        );
        let offline = datasets::generate(Dataset::ArxivSummarization, 200, seed);
        setup
            .run(System::HyGen { latency_budget_ms: 40.0 }, &online.merged(offline), 90.0)
            .unwrap()
            .report
    };
    assert_ne!(run(3), run(4), "the seed must actually steer the run");
}

fn figure_csvs(id: &str, jobs: usize) -> Vec<String> {
    let ctx = tiny_ctx(jobs);
    figures::run_figure(&ctx, id)
        .unwrap_or_else(|e| panic!("figure {id} with jobs={jobs}: {e:#}"))
        .iter()
        .map(|t| t.to_csv())
        .collect()
}

#[test]
fn fig6_parallel_output_is_byte_identical() {
    let serial = figure_csvs("6", 1);
    let parallel = figure_csvs("6", 2);
    assert!(!serial.is_empty() && serial.iter().all(|c| c.lines().count() > 1));
    assert_eq!(serial, parallel, "fig6 CSV bytes must not depend on -j");
}

#[test]
fn fig10_parallel_output_is_byte_identical() {
    let serial = figure_csvs("10", 1);
    let parallel = figure_csvs("10", 2);
    assert!(!serial.is_empty() && serial.iter().all(|c| c.lines().count() > 1));
    assert_eq!(serial, parallel, "fig10 CSV bytes must not depend on -j");
}

fn cluster_csv(seed: u64, jobs: usize) -> String {
    let cfg = cluster_sim::ClusterSimConfig {
        replica_counts: vec![1, 2],
        policies: RouterPolicy::ALL.to_vec(),
        online_qps: 2.0,
        trace_s: 10.0,
        offline_n: 30,
        latency_budget_ms: 40.0,
        rebalance_interval_s: 0.5,
        max_clock_s: 200.0,
        seed,
        jobs,
    };
    cluster_sim::table(&cluster_sim::run_grid(&cfg).unwrap()).to_csv()
}

#[test]
fn cluster_sim_output_is_byte_identical_for_a_seed() {
    let a = cluster_csv(7, 1);
    let b = cluster_csv(7, 1);
    assert!(a.lines().count() > 6, "grid produced rows:\n{a}");
    assert_eq!(a, b, "same seed must reproduce the cluster-sim CSV byte-for-byte");
    let parallel = cluster_csv(7, 3);
    assert_eq!(a, parallel, "cluster-sim CSV bytes must not depend on -j");
    let other = cluster_csv(8, 1);
    assert_ne!(a, other, "the seed must actually steer the grid");
}

fn multi_slo_csv(seed: u64, jobs: usize) -> String {
    let cfg = multi_slo::MultiSloConfig {
        replica_counts: vec![1, 2],
        chat_qps: 1.0,
        trace_s: 6.0,
        batch_n: 16,
        summarize_n: 10,
        latency_budget_ms: 40.0,
        rebalance_interval_s: 0.5,
        max_clock_s: 120.0,
        seed,
        jobs,
    };
    multi_slo::table(&multi_slo::run_grid(&cfg).unwrap()).to_csv()
}

#[test]
fn multi_slo_output_is_byte_identical_for_a_seed() {
    let a = multi_slo_csv(11, 1);
    let b = multi_slo_csv(11, 1);
    assert!(a.lines().count() > 6, "grid produced rows:\n{a}");
    assert_eq!(a, b, "same seed must reproduce the multi-slo CSV byte-for-byte");
    let parallel = multi_slo_csv(11, 3);
    assert_eq!(a, parallel, "multi-slo CSV bytes must not depend on -j");
    let other = multi_slo_csv(12, 1);
    assert_ne!(a, other, "the seed must actually steer the grid");
}

fn chaos_csv(seed: u64, jobs: usize) -> String {
    let cfg = chaos::ChaosConfig {
        replicas: 2,
        policies: RouterPolicy::ALL.to_vec(),
        schedules: 2,
        kills_per_schedule: 1,
        online_qps: 2.0,
        trace_s: 10.0,
        offline_n: 30,
        latency_budget_ms: 40.0,
        rebalance_interval_s: 0.5,
        max_clock_s: 200.0,
        seed,
        jobs,
    };
    chaos::table(&chaos::run_grid(&cfg).unwrap()).to_csv()
}

#[test]
fn chaos_output_is_byte_identical_for_a_seed() {
    let a = chaos_csv(7, 1);
    let b = chaos_csv(7, 1);
    assert!(a.lines().count() > 6, "grid produced rows:\n{a}");
    assert_eq!(a, b, "same seed must reproduce the chaos CSV byte-for-byte");
    let parallel = chaos_csv(7, 3);
    assert_eq!(a, parallel, "chaos CSV bytes must not depend on -j");
    let other = chaos_csv(8, 1);
    assert_ne!(a, other, "the seed must actually steer the grid");
}

#[test]
fn kill_plus_restart_is_restart_equivalent_to_a_clean_run() {
    // A kill + restart must not change *what* the cluster owes the trace:
    // the faulted run accounts for exactly the online population the
    // clean run serves — every online request finished or failed fast,
    // none lost, none finished twice.
    use hygen::coordinator::queues::OfflinePolicy;
    use hygen::coordinator::scheduler::SchedulerConfig;

    let seed = 5;
    let online = azure::generate(
        &AzureTraceConfig { duration_s: 20.0, mean_qps: 2.0, ..Default::default() },
        seed,
    );
    let offline = datasets::generate(Dataset::ArxivSummarization, 40, seed);
    let trace = online.merged(offline);
    let run = |faults: FaultSchedule| {
        let engines: Vec<_> = (0..2)
            .map(|i| {
                let setup = SimSetup::with_seed_predictor(CostModel::a100_llama7b())
                    .with_policy(OfflinePolicy::Psm)
                    .with_seed(seed + i as u64);
                let mut e = setup.build_with_config(SchedulerConfig {
                    latency_budget_ms: Some(40.0),
                    ..SchedulerConfig::default()
                });
                e.state.keep_finished = false;
                e
            })
            .collect();
        let mut sim = ClusterSim::new(engines, RouterPolicy::RoundRobin.build(), 0.5)
            .with_faults(faults);
        sim.check_invariants_each_step = true;
        sim.run(&trace, 400.0).unwrap()
    };
    let clean = run(FaultSchedule::new());
    let faulted = run(FaultSchedule::new().kill(1, 4.0).restart(1, 6.0));
    assert_eq!(clean.lost, 0);
    assert_eq!(faulted.lost, 0, "kill+restart lost a request");
    assert_eq!(clean.aggregate.online_finished, trace.num_online());
    assert_eq!(clean.failed_503, 0);
    assert_eq!(faulted.fault_restarts, 1);
    assert_eq!(
        faulted.aggregate.online_finished + faulted.failed_503,
        trace.num_online(),
        "the faulted run must account for the same online population"
    );
    // The same faulted schedule replays bit-identically.
    let again = run(FaultSchedule::new().kill(1, 4.0).restart(1, 6.0));
    assert_eq!(faulted.aggregate, again.aggregate);
    assert_eq!(faulted.rerouted, again.rerouted);
    assert_eq!(faulted.migrated, again.migrated);
}
