//! Property tests on scheduler/coordinator invariants (routing, batching,
//! budgets, preemption, memory) using the in-repo prop harness.

use hygen::coordinator::batch::Features;
use hygen::coordinator::predictor::LatencyPredictor;
use hygen::coordinator::queues::OfflinePolicy;
use hygen::coordinator::request::{Class, Request};
use hygen::coordinator::scheduler::{HybridScheduler, PreemptionMode, SchedulerConfig};
use hygen::coordinator::state::EngineState;
use hygen::util::prop::{check, Gen};

fn random_state(g: &mut Gen) -> EngineState {
    let blocks = g.usize(32, 1024);
    let policy = *g.pick(&[
        OfflinePolicy::Fcfs,
        OfflinePolicy::Psm,
        OfflinePolicy::PsmFair { utility_ratio: 0.5 },
    ]);
    let mut st = EngineState::new(policy, blocks, 16, g.u64(0, 1 << 32));
    let n = g.usize(0, 30);
    for i in 0..n {
        let class = if g.bool() { Class::ONLINE } else { Class::OFFLINE };
        let plen = g.usize(1, 600);
        let prompt: Vec<u32> = if g.bool() {
            // family-structured prompts exercise the trie
            let fam = g.u64(0, 5) as u32;
            (0..plen as u32)
                .map(|k| if k < 32 { fam * 1000 + k } else { i as u32 * 7919 + k })
                .collect()
        } else {
            (0..plen as u32).map(|k| i as u32 * 104729 + k).collect()
        };
        st.enqueue(
            Request::new(i as u64, class, g.f64(0.0, 10.0), plen, g.usize(1, 64))
                .with_prompt(prompt),
        );
    }
    st
}

fn random_config(g: &mut Gen) -> SchedulerConfig {
    SchedulerConfig {
        latency_budget_ms: if g.bool() { Some(g.f64(5.0, 200.0)) } else { None },
        chunk_tokens: g.usize(16, 2048),
        max_chunk_per_request: *g.pick(&[8usize, 32, 512, usize::MAX]),
        max_running: g.usize(1, 64),
        preemption: if g.bool() { PreemptionMode::Preserve } else { PreemptionMode::Discard },
        enable_offline: g.bool(),
        offline_qps_cap: if g.bool() { Some(g.f64(0.1, 10.0)) } else { None },
        watermark_blocks: g.usize(0, 4),
    }
}

/// Apply a batch like the engine would; progress goes through the
/// census-maintaining [`EngineState`] transitions (mutating `Request`
/// phases directly would drift the phase counts the scheduler relies on).
fn apply(st: &mut EngineState, batch: &hygen::coordinator::batch::Batch) {
    let mut done = Vec::new();
    for e in &batch.entries {
        let finished = if e.is_prefill {
            // The chunk that completes the prompt also emits the first
            // output token, mirroring Engine::apply.
            st.advance_prefill(e.id, e.n_tokens) && st.advance_decode(e.id)
        } else {
            st.advance_decode(e.id)
        };
        if finished {
            done.push(e.id);
        }
    }
    for id in done {
        st.finish(id);
    }
}

/// Drive a random workload through many schedule/apply rounds.
fn drive(
    g: &mut Gen,
    rounds: usize,
    mut inspect: impl FnMut(&HybridScheduler, &EngineState, &hygen::coordinator::batch::Batch),
) {
    let mut st = random_state(g);
    let cfg = random_config(g);
    let mut sched = HybridScheduler::new(cfg, LatencyPredictor::default_seed());
    for round in 0..rounds {
        let now = round as f64 * 0.02;
        let batch = sched.schedule_owned(&mut st, now);
        inspect(&sched, &st, &batch);
        apply(&mut st, &batch);
        // The full structural invariants (no dual membership, queue/table
        // disjointness, phase-census consistency) must hold after *every*
        // schedule+apply iteration, for every random workload and config.
        if let Err(e) = st.check_invariants() {
            panic!("invariant violated after round {round}: {e}");
        }
    }
}

#[test]
fn prop_state_invariants_hold_under_random_workloads() {
    check("state invariants", 150, |g| {
        drive(g, 40, |_s, st, _b| {
            st.check_invariants().unwrap();
        });
    });
}

#[test]
fn prop_batch_never_exceeds_budgets() {
    check("budget compliance", 150, |g| {
        drive(g, 30, |s, _st, b| {
            // chunk budget: scheduled prefill tokens never exceed the
            // iteration token budget (decodes ride along, matching the
            // scheduler's `c` accounting).
            let prefill_tokens: usize =
                b.entries.iter().filter(|e| e.is_prefill).map(|e| e.n_tokens).sum();
            assert!(
                prefill_tokens <= s.cfg.chunk_tokens,
                "prefill {prefill_tokens} > chunk {}",
                s.cfg.chunk_tokens
            );
            for e in &b.entries {
                if e.is_prefill {
                    assert!(e.n_tokens <= s.cfg.max_chunk_per_request);
                    assert!(e.n_tokens > 0);
                }
            }
            assert!(b.len() <= s.cfg.max_running, "batch larger than slot bound");
        });
    });
}

#[test]
fn prop_latency_budget_respected_on_offline_only_workloads() {
    check("latency budget", 100, |g| {
        // All-offline workloads: nothing may bypass the budget.
        let blocks = g.usize(256, 2048);
        let mut st = EngineState::new(OfflinePolicy::Fcfs, blocks, 16, 1);
        for i in 0..g.usize(1, 40) {
            let plen = g.usize(16, 1500);
            st.enqueue(
                Request::new(i as u64, Class::OFFLINE, 0.0, plen, g.usize(1, 32))
                    .with_prompt((0..plen as u32).collect::<Vec<u32>>()),
            );
        }
        let budget = g.f64(8.0, 80.0);
        let mut sched = HybridScheduler::new(
            SchedulerConfig {
                latency_budget_ms: Some(budget),
                chunk_tokens: 1 << 20,
                ..Default::default()
            },
            LatencyPredictor::default_seed(),
        );
        for round in 0..10 {
            let b = sched.schedule_owned(&mut st, round as f64);
            assert!(
                sched.last_stats.predicted_ms <= budget + 1e-6,
                "predicted {} > budget {budget}",
                sched.last_stats.predicted_ms
            );
            apply(&mut st, &b);
        }
    });
}

#[test]
fn prop_no_request_lost_or_duplicated() {
    check("request conservation", 150, |g| {
        let mut st = random_state(g);
        let total = st.total_waiting();
        let cfg = random_config(g);
        let mut sched = HybridScheduler::new(cfg, LatencyPredictor::default_seed());
        for round in 0..60 {
            let b = sched.schedule_owned(&mut st, round as f64 * 0.02);
            apply(&mut st, &b);
            // conservation: queued + running + preempted + finished == total
            let now =
                st.total_waiting() + st.num_running() + st.total_preempted() + st.finished.len();
            assert_eq!(now, total, "requests lost/duplicated at round {round}");
            // no id in two running/preempted sets at once
            let mut seen = std::collections::HashSet::new();
            for id in st
                .runs
                .iter()
                .flat_map(|set| set.iter())
                .chain(st.preempted_by_class.iter().flat_map(|p| p.iter().copied()))
            {
                assert!(seen.insert(id), "id {id} in two sets");
            }
            st.check_invariants().unwrap_or_else(|e| panic!("round {round}: {e}"));
        }
    });
}

#[test]
fn prop_only_offline_requests_are_preempted() {
    check("preemption direction", 100, |g| {
        drive(g, 40, |_s, st, _b| {
            // The default registry's top tier (online) is never preempted.
            assert!(st.preempted(Class::ONLINE).is_empty());
            for id in st.preempted(Class::OFFLINE) {
                assert_eq!(st.requests[id].class, Class::OFFLINE);
            }
        });
    });
}

#[test]
fn prop_disable_offline_schedules_online_only() {
    check("pure-online mode", 80, |g| {
        let mut st = random_state(g);
        let mut cfg = random_config(g);
        cfg.enable_offline = false;
        let mut sched = HybridScheduler::new(cfg, LatencyPredictor::default_seed());
        for round in 0..20 {
            let b = sched.schedule_owned(&mut st, round as f64 * 0.02);
            assert!(b.entries.iter().all(|e| e.class.is_online()));
            apply(&mut st, &b);
        }
    });
}

#[test]
fn prop_max_prefill_tokens_always_within_budget() {
    check("predictor inversion", 300, |g| {
        // Random (even partially non-physical) coefficients: the
        // verification loop must still never exceed the budget.
        let mut coef = [0.0; 7];
        for c in coef.iter_mut() {
            *c = g.f64(-0.01, 0.3);
        }
        coef[3] = g.f64(0.0, 1e-4); // sp^2 >= 0
        let p = LatencyPredictor { coef };
        let mut f = Features::default();
        for _ in 0..g.usize(0, 5) {
            f.add_prefill(g.usize(1, 1024));
        }
        for _ in 0..g.usize(0, 32) {
            f.add_decode();
        }
        let budget = g.f64(0.0, 50.0);
        let cap = g.usize(1, 4096);
        let (l, t_req) =
            p.max_prefill_tokens(&f, budget, cap, g.usize(1, 1 << 16), g.usize(1, 1 << 16));
        assert!(l <= cap);
        if l > 0 {
            assert!(t_req <= budget + 1e-9, "t_req {t_req} > budget {budget}");
            let real = (p.predict(&f.with_prefill(l)) - p.predict(&f)).max(0.0);
            assert!(real <= budget + 1e-9, "real marginal {real} > budget {budget}");
        }
    });
}
