//! End-to-end simulation tests: the paper's qualitative results must hold
//! on the calibrated cost models (these are the cheap, always-on versions
//! of the figure harnesses).

use hygen::baselines::{SimSetup, System};
use hygen::coordinator::queues::OfflinePolicy;
use hygen::coordinator::request::{Slo, SloMetric};
use hygen::experiments::{hygen_profiled, online_baseline, Ctx};
use hygen::sim::costmodel::CostModel;
use hygen::workload::azure::{self, AzureTraceConfig};
use hygen::workload::datasets::{self, Dataset};
use hygen::workload::mooncake::{self, MooncakeTraceConfig};

fn ctx() -> Ctx {
    Ctx { horizon_s: 150.0, trace_s: 90.0, profile_steps: 5, ..Default::default() }
}

fn azure_online(qps: f64, seed: u64) -> hygen::workload::trace::Trace {
    azure::generate(
        &AzureTraceConfig { duration_s: 90.0, mean_qps: qps, ..Default::default() },
        seed,
    )
}

#[test]
fn hygen_meets_slo_while_colocating() {
    let ctx = ctx();
    let setup = SimSetup::new(CostModel::a100_llama7b());
    let online = azure_online(2.0, 0);
    let offline = datasets::generate(Dataset::ArxivSummarization, 800, 0);
    let workload = online.clone().merged(offline);
    let base = online_baseline(&setup, &online, &ctx).unwrap();
    let slo = Slo::from_tolerance(SloMetric::P99Tbt, base.p99_tbt_ms, 0.2);
    let (prof, report) = hygen_profiled(&setup, &workload, &slo, &ctx).unwrap();
    assert!(
        report.p99_tbt_ms <= slo.limit_ms * 1.05,
        "p99 tbt {} > slo {}",
        report.p99_tbt_ms,
        slo.limit_ms
    );
    assert!(report.offline_tps > 0.0, "co-location must add offline throughput");
    assert!(prof.budget_ms > 0.0);
}

#[test]
fn hygen_beats_pure_online_total_throughput() {
    // Fig. 4 headline: co-location multiplies total throughput.
    let ctx = ctx();
    let setup = SimSetup::new(CostModel::a100_llama7b());
    let online = azure_online(1.0, 1);
    let offline = datasets::generate(Dataset::ArxivSummarization, 800, 1);
    let workload = online.clone().merged(offline);
    let base = online_baseline(&setup, &online, &ctx).unwrap();
    let r = setup
        .run(System::HyGen { latency_budget_ms: 60.0 }, &workload, ctx.horizon_s)
        .unwrap()
        .report;
    assert!(
        r.total_tps > 2.0 * base.total_tps,
        "hygen {} !>> online-only {}",
        r.total_tps,
        base.total_tps
    );
}

#[test]
fn sarathi_pp_violates_what_hygen_holds() {
    // Fig. 3's contrast: same workload, same SLO — Sarathi++ (no latency
    // budget) violates where profiled HyGen complies.
    let ctx = ctx();
    let setup = SimSetup::new(CostModel::a100_llama7b());
    let online = azure_online(2.0, 2);
    let offline = datasets::generate(Dataset::ArxivSummarization, 800, 2);
    let workload = online.clone().merged(offline);
    let base = online_baseline(&setup, &online, &ctx).unwrap();
    let slo = Slo::from_tolerance(SloMetric::MeanTbt, base.mean_tbt_ms, 0.1);
    let spp = setup.run(System::SarathiPlusPlus, &workload, ctx.horizon_s).unwrap().report;
    let (_prof, hygen) = hygen_profiled(&setup, &workload, &slo, &ctx).unwrap();
    assert!(spp.mean_tbt_ms > slo.limit_ms, "sarathi++ should violate: {}", spp.mean_tbt_ms);
    assert!(hygen.mean_tbt_ms <= slo.limit_ms * 1.05, "hygen must comply: {}", hygen.mean_tbt_ms);
}

#[test]
fn psm_beats_fcfs_on_prefix_heavy_offline() {
    // Fig. 6 shape.
    let offline = datasets::generate(Dataset::Mmlu, 4000, 3);
    let run = |policy| {
        let setup = SimSetup::new(CostModel::a100_llama7b()).with_policy(policy);
        setup
            .run_draining(System::SarathiOffline { chunk_tokens: 1024 }, &offline, 120.0)
            .unwrap()
            .report
            .offline_qps
    };
    let fcfs = run(OfflinePolicy::Fcfs);
    let psm = run(OfflinePolicy::Psm);
    assert!(psm > 1.3 * fcfs, "psm {psm} !>> fcfs {fcfs}");
}

#[test]
fn offline_throughput_shrinks_with_online_load() {
    // Fig. 17 shape: more online QPS -> less residual capacity.
    let ctx = ctx();
    let setup = SimSetup::new(CostModel::a100_llama7b());
    let offline = datasets::generate(Dataset::ArxivSummarization, 800, 4);
    let mut last = f64::INFINITY;
    for qps in [0.5, 2.0, 4.0] {
        let online = azure_online(qps, 5);
        let workload = online.merged(offline.clone());
        let r = setup
            .run(System::HyGen { latency_budget_ms: 25.0 }, &workload, ctx.horizon_s)
            .unwrap()
            .report;
        assert!(
            r.offline_tps < last * 1.1,
            "offline tps should not grow with online load: {} after {last}",
            r.offline_tps
        );
        last = r.offline_tps;
    }
}

#[test]
fn mooncake_trace_served_on_mistral() {
    // Fig. 14 smoke: the Mooncake + Mistral combination runs end to end.
    let online = mooncake::generate(
        &MooncakeTraceConfig { duration_s: 60.0, mean_qps: 0.8, ..Default::default() },
        6,
    );
    let offline = datasets::generate(Dataset::ArxivSummarization, 300, 6);
    let setup = SimSetup::new(CostModel::a100_mistral7b());
    let r = setup
        .run(System::HyGen { latency_budget_ms: 40.0 }, &online.merged(offline), 120.0)
        .unwrap();
    assert!(r.finished_online > 10);
    assert!(r.report.offline_tps > 0.0);
}

#[test]
fn a5000_small_model_served() {
    // Fig. 15 smoke.
    let online = azure::generate(
        &AzureTraceConfig {
            duration_s: 60.0,
            mean_qps: 2.0,
            max_prompt: 2000,
            ..Default::default()
        },
        7,
    );
    let offline = datasets::generate(Dataset::CnnDailyMail, 500, 7);
    let setup = SimSetup::new(CostModel::a5000_sheared27b());
    let r = setup
        .run(System::HyGen { latency_budget_ms: 30.0 }, &online.merged(offline), 120.0)
        .unwrap();
    assert!(r.finished_online > 20);
    assert!(r.report.offline_tps > 0.0);
}

#[test]
fn tp_pp_run_completes_with_lower_latency_than_serial() {
    // Fig. 9 structural check: the TP2/PP2 cost model serves the same
    // workload with lower TBT than a hypothetical serial 34B.
    let online = azure_online(0.4, 8);
    let offline = datasets::generate(Dataset::ArxivSummarization, 200, 8);
    let workload = online.merged(offline);
    let par = SimSetup::new(CostModel::a40x4_yi34b_tp2pp2());
    let serial = SimSetup::new(CostModel::a40x4_yi34b_tp2pp2().with_parallelism(1, 1));
    let rp = par.run(System::SarathiPlusPlus, &workload, 120.0).unwrap().report;
    let rs = serial.run(System::SarathiPlusPlus, &workload, 120.0).unwrap().report;
    assert!(rp.mean_tbt_ms < rs.mean_tbt_ms, "{} !< {}", rp.mean_tbt_ms, rs.mean_tbt_ms);
}

#[test]
fn predictor_degradation_is_tolerated() {
    // Fig. 16 shape: a 20%-noisy predictor still serves with bounded SLO
    // damage (the profiler's macro budget absorbs micro errors).
    let ctx = ctx();
    let online = azure_online(1.5, 9);
    let offline = datasets::generate(Dataset::ArxivSummarization, 500, 9);
    let workload = online.clone().merged(offline);
    let accurate = SimSetup::new(CostModel::a100_llama7b());
    let base = online_baseline(&accurate, &online, &ctx).unwrap();
    let slo = Slo::from_tolerance(SloMetric::P99Tbt, base.p99_tbt_ms, 0.2);
    let mut rng = hygen::util::rng::Rng::new(10);
    let degraded_predictor = accurate.predictor.degraded(0.2, &mut rng);
    let degraded = SimSetup::new(CostModel::a100_llama7b()).with_predictor(degraded_predictor);
    let (_p, r) = hygen_profiled(&degraded, &workload, &slo, &ctx).unwrap();
    assert!(
        r.p99_tbt_ms <= slo.limit_ms * 1.1,
        "degraded predictor broke the SLO badly: {} vs {}",
        r.p99_tbt_ms,
        slo.limit_ms
    );
    assert!(r.offline_tps > 0.0);
}
