//! The allocation-free-loop contract, asserted for real: this test
//! binary registers the counting allocator as its global allocator and
//! drives the steady-state decode probe (`bench_replay::steady_probe`) —
//! after warmup, a window of engine iterations (schedule → execute →
//! apply → metrics) must perform **zero** heap allocations.
//!
//! The probe runs with the flight recorder enabled (its default), so the
//! gate also proves tracing is allocation-free: the recorder's ring and
//! histograms are preallocated, and the probe reports how many trace
//! events landed inside the measured window.
//!
//! The window is not pure decode: the probe's churn companion drives
//! prefix-cache hits (resurrections) *and* evictions through the block
//! manager every iteration, so the zero-allocation contract is asserted
//! over the cache's recycle paths too.
//!
//! This file holds exactly one test so no concurrent test thread can
//! allocate inside the measured window (the counter is process-global).

use hygen::experiments::bench_replay::steady_probe;
use hygen::util::alloc::{alloc_count, counting_active, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn steady_decode_iterations_do_not_allocate() {
    assert!(counting_active(), "counting allocator must be registered in this binary");
    let before = alloc_count();
    let probe = steady_probe(64, 100).expect("probe runs");
    assert!(alloc_count() > before, "setup itself allocates; the counter is live");
    assert_eq!(probe.iterations, 100);
    assert!(probe.ns_per_iter > 0.0);
    assert!(
        probe.trace_events >= probe.iterations,
        "tracing must be live inside the window ({} events over {} iterations) — \
         a zero-alloc pass with tracing off would not test the recorder",
        probe.trace_events,
        probe.iterations
    );
    assert!(
        probe.cache_hits >= probe.iterations && probe.cache_evictions >= probe.iterations,
        "cache churn must be live inside the window ({} hits / {} evictions over {} \
         iterations) — a zero-alloc pass with an idle cache would not test recycling",
        probe.cache_hits,
        probe.cache_evictions,
        probe.iterations
    );
    assert_eq!(
        probe.allocs_total, 0,
        "steady-state decode iterations allocated {} times over {} iterations \
         with tracing enabled and live cache churn (contract: zero once scratch \
         buffers are warm)",
        probe.allocs_total, probe.iterations
    );
}
