//! Request-lifecycle conservation under hostile clients and a flaky
//! replica, asserted against a **live** `Server`:
//!
//! Seeded random cases mix normal clients, clients that time out and
//! hang up early, clients that disconnect mid-request, an over-capacity
//! burst against a tiny admission queue, and a mid-run injected replica
//! kill (the backend errors for a window; the supervisor restarts it).
//! After the dust settles, the front-end ledger must balance **exactly**:
//!
//! ```text
//! admitted = finished_200 + rejected_429 + timed_out_504 + failed_503
//! ```
//!
//! with `resident = 0` — every admitted request resolved exactly once
//! (no silent drop, no double completion), no matter how its client
//! behaved. Clients additionally verify they never receive two HTTP
//! responses on one connection, and that the 200s they observed are a
//! subset of the server's `finished_200` count (a disconnected client's
//! finish still counts server-side; the reverse would be a double
//! completion).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use hygen::cluster::replica::SupervisorConfig;
use hygen::cluster::router::RouterPolicy;
use hygen::coordinator::batch::Batch;
use hygen::coordinator::predictor::LatencyPredictor;
use hygen::coordinator::queues::OfflinePolicy;
use hygen::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
use hygen::coordinator::state::EngineState;
use hygen::engine::{Engine, ExecutionBackend};
use hygen::server::{OverloadConfig, Server, DEFAULT_DRAIN};
use hygen::util::json::Json;
use hygen::util::prop::{check, Gen};

/// Echo-style token generator with a real per-iteration delay (so queues
/// form) and a test-controlled kill switch: while the switch is set,
/// every `execute` errors, the engine thread dies, and the supervisor
/// restarts it — the injected "replica kill".
struct FlakyBackend {
    kill: Arc<AtomicBool>,
    delay: Duration,
}

impl ExecutionBackend for FlakyBackend {
    fn execute(&mut self, batch: &Batch, state: &mut EngineState) -> anyhow::Result<f64> {
        anyhow::ensure!(!self.kill.load(Ordering::SeqCst), "injected replica kill");
        std::thread::sleep(self.delay);
        for e in &batch.entries {
            let req = state.req_mut(e.id);
            let emit =
                if e.is_prefill { req.prefilled + e.n_tokens >= req.prompt_len } else { true };
            if emit {
                let n = req.output_tokens.len();
                let tok = req.prompt.get(n).copied().unwrap_or(b'!' as u32);
                req.output_tokens.push(tok);
            }
        }
        Ok(0.0005)
    }
}

fn start_server(kills: &[Arc<AtomicBool>], overload: OverloadConfig) -> Server {
    let factories: Vec<_> = kills
        .iter()
        .map(|k| {
            let k = Arc::clone(k);
            move || -> anyhow::Result<Engine<FlakyBackend>> {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(
                    sched,
                    state,
                    FlakyBackend { kill: Arc::clone(&k), delay: Duration::from_millis(3) },
                ))
            }
        })
        .collect();
    Server::start_cluster_with_registry(
        "127.0.0.1:0",
        factories,
        RouterPolicy::RoundRobin.build(),
        8,
        DEFAULT_DRAIN,
        Arc::new(hygen::coordinator::classes::ClassRegistry::default_two()),
        // Fast recovery so the injected kill window never exhausts the
        // restart budget.
        SupervisorConfig {
            max_restarts: 20,
            backoff_initial: Duration::from_millis(50),
            backoff_cap: Duration::from_millis(500),
        },
        overload,
    )
    .unwrap()
}

fn completions_raw(prompt: &str, class: &str, max_tokens: usize) -> String {
    let body =
        format!(r#"{{"prompt": "{prompt}", "max_tokens": {max_tokens}, "class": "{class}"}}"#);
    format!(
        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
}

/// Full request/response exchange; returns the raw response text.
fn http(addr: std::net::SocketAddr, raw: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn scrape_ledger(addr: std::net::SocketAddr) -> Json {
    let resp = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
    let body = resp.split("\r\n\r\n").nth(1).unwrap_or("");
    Json::parse(body).unwrap()
}

fn counter(m: &Json, key: &str) -> u64 {
    m.get(key).as_u64().unwrap_or_else(|| panic!("metrics missing {key}: {m}"))
}

/// What one client thread did and saw.
enum ClientOutcome {
    /// Full exchange: HTTP status observed, plus how many `HTTP/1.1`
    /// response heads arrived on the one connection (must be 1).
    Status(u16, usize),
    /// Hung up before any (full) response.
    Abandoned,
}

fn run_client(addr: std::net::SocketAddr, behavior: usize, raw: &str) -> ClientOutcome {
    match behavior {
        // Client-side timeout: give up long before the server's deadline
        // and hang up; the server must still resolve the request.
        0 => {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(raw.as_bytes()).unwrap();
            s.set_read_timeout(Some(Duration::from_millis(30))).unwrap();
            let mut buf = [0u8; 256];
            let _ = s.read(&mut buf);
            ClientOutcome::Abandoned
        }
        // Mid-request disconnect: send half the bytes and vanish. The
        // server never sees a full request, so nothing is admitted.
        1 => {
            let mut s = TcpStream::connect(addr).unwrap();
            let _ = s.write_all(&raw.as_bytes()[..raw.len() / 2]);
            ClientOutcome::Abandoned
        }
        // Well-behaved client: full exchange.
        _ => {
            let resp = http(addr, raw);
            let status: u16 = resp
                .strip_prefix("HTTP/1.1 ")
                .and_then(|r| r.get(..3))
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let heads = resp.matches("HTTP/1.1 ").count();
            ClientOutcome::Status(status, heads)
        }
    }
}

#[test]
fn lifecycle_ledger_balances_under_chaos() {
    check("lifecycle conservation", 3, |g: &mut Gen| {
        let kills: Vec<Arc<AtomicBool>> =
            (0..2).map(|_| Arc::new(AtomicBool::new(false))).collect();
        let overload = OverloadConfig {
            queue_cap: 3,
            request_timeout: Duration::from_millis(400),
            retry_budget: 1,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(150),
            ..OverloadConfig::default()
        };
        let server = start_server(&kills, overload);
        let addr = server.addr;

        let n_clients = g.usize(24, 40);
        let mut handles = Vec::with_capacity(n_clients);
        for i in 0..n_clients {
            // First third arrives as an unstaggered over-capacity burst;
            // the rest trickle in.
            let delay_ms = if i < n_clients / 3 { 0 } else { g.usize(0, 80) as u64 };
            let behavior = g.usize(0, 6); // 0: timeout, 1: disconnect, 2+: normal
            let class = if g.usize(0, 4) == 0 { "offline" } else { "online" };
            let raw = completions_raw(&g.word(3..9), class, g.usize(1, 40));
            handles.push(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(delay_ms));
                run_client(addr, behavior, &raw)
            }));
        }
        // Mid-run, kill replica 0 for a window: its backend errors, the
        // engine thread dies, the supervisor restarts it.
        std::thread::sleep(Duration::from_millis(30));
        kills[0].store(true, Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(120));
        kills[0].store(false, Ordering::SeqCst);

        let mut observed_200 = 0u64;
        let mut abandoned = 0u64;
        for h in handles {
            match h.join().unwrap() {
                ClientOutcome::Status(status, heads) => {
                    assert_eq!(heads, 1, "client saw {heads} responses on one connection");
                    assert!(
                        matches!(status, 200 | 429 | 503 | 504),
                        "unexpected status {status}"
                    );
                    if status == 200 {
                        observed_200 += 1;
                    }
                }
                ClientOutcome::Abandoned => abandoned += 1,
            }
        }

        // Settle: every admitted request resolves within its deadline (+
        // the server's grace); poll until the ledger shows none resident.
        let deadline = Instant::now() + Duration::from_secs(10);
        let m = loop {
            let m = scrape_ledger(addr);
            if counter(&m, "resident") == 0 {
                break m;
            }
            assert!(Instant::now() < deadline, "requests stuck resident: {m}");
            std::thread::sleep(Duration::from_millis(50));
        };

        let admitted = counter(&m, "admitted");
        let finished = counter(&m, "finished_200");
        let rejected = counter(&m, "rejected_429");
        let timed_out = counter(&m, "timed_out_504");
        let failed = counter(&m, "failed_503");
        assert_eq!(
            admitted,
            finished + rejected + timed_out + failed,
            "conservation ledger broken (abandoned clients: {abandoned}): {m}"
        );
        assert!(admitted <= n_clients as u64, "admitted more than offered: {m}");
        assert!(
            observed_200 <= finished,
            "clients saw {observed_200} successes but the server finished {finished} \
             — a finish was double-counted or lost: {m}"
        );
        assert!(finished > 0, "nothing finished — the case exercised nothing: {m}");
        // Lifecycle counters must all be published, even when zero.
        for key in ["retries", "breaker_open_total"] {
            let _ = counter(&m, key);
        }
        assert_eq!(
            m.get("shed_by_class").as_arr().map(|a| a.len()),
            Some(2),
            "per-class shed counters must match the registry: {m}"
        );
        server.shutdown();
    });
}
