//! Seeded property suite for the intrusive-list `BlockManager` (the
//! prefix-cache recycling core). Random interleavings of
//! `allocate_tagged` / `grow` / `release` against small pools — sized so
//! evictions, resurrections, and out-of-memory rejections all fire —
//! with the full invariant set re-checked after **every** operation:
//!
//! 1. refcount conservation — `used_blocks + free_blocks == num_blocks`,
//!    recounted from per-block views, not the manager's own counters;
//! 2. every prefix-cache entry points at a block whose inline hash
//!    matches the entry's key (the map and the block array never drift);
//! 3. no block is simultaneously on a free/LRU list and referenced by a
//!    sequence (`refcount > 0` xor `listed`);
//! 4. the free/LRU lists are well-formed partitions: walking every tier
//!    bucket plus the untracked list visits each free block exactly
//!    once, LRU members are hashed refcount-0 blocks in their tier's
//!    bucket, untracked members are unhashed.
//!
//! LRU order itself (release order = eviction order within a bucket,
//! resurrection moves a family to the MRU end) is pinned by the
//! deterministic scenarios at the bottom — the random walk cannot know
//! which physical block a shared hash resolves to once entries shadow.
//!
//! Tests enumerate their own hash universe (every chain hash they ever
//! passed in) so cache contents are checked without iterating the
//! manager's maps.

use std::collections::HashSet;

use hygen::coordinator::block_manager::{synthetic_chain, BlockManager, EvictionPolicy};
use hygen::coordinator::classes::MAX_CLASSES;
use hygen::util::rng::Rng;

const BLOCK_SIZE: usize = 4;

/// Re-derive every invariant from read-only probes. `universe` is every
/// hash any chain ever contained (superset of live cache keys).
fn check_invariants(bm: &BlockManager, universe: &[u64], ctx: &str) {
    let n = bm.num_blocks();
    // Per-block recount: listed xor referenced, and the counts add up.
    let mut listed = 0usize;
    let mut referenced = 0usize;
    for b in 0..n as u32 {
        let v = bm.block_view(b).expect("block id in range");
        assert!(
            (v.refcount > 0) != v.listed,
            "{ctx}: block {b} refcount={} listed={} — must be exactly one",
            v.refcount,
            v.listed
        );
        if v.listed {
            listed += 1;
            if v.untracked {
                assert!(v.hash.is_none(), "{ctx}: untracked block {b} carries a hash");
            } else {
                assert!(v.hash.is_some(), "{ctx}: LRU-listed block {b} has no hash");
            }
        } else {
            referenced += 1;
        }
    }
    assert_eq!(listed, bm.free_blocks(), "{ctx}: free_blocks drifted from per-block recount");
    assert_eq!(referenced, bm.used_blocks(), "{ctx}: used_blocks drifted from per-block recount");
    assert_eq!(listed + referenced, n, "{ctx}: conservation used + free == num_blocks");

    // Cache entries resolve to blocks that still carry the same hash.
    let mut distinct: HashSet<u64> = HashSet::new();
    let mut cached = 0usize;
    for &h in universe {
        if !distinct.insert(h) {
            continue;
        }
        if let Some(b) = bm.cache_lookup(h) {
            cached += 1;
            let v = bm.block_view(b).expect("cached block id in range");
            assert_eq!(
                v.hash,
                Some(h),
                "{ctx}: cache entry {h:#x} points at block {b} whose hash is {:?}",
                v.hash
            );
        }
    }
    assert_eq!(
        cached,
        bm.cache_entries(),
        "{ctx}: cache holds entries outside the test's hash universe"
    );

    // The lists partition the free blocks exactly.
    let mut walk = Vec::new();
    let mut seen: HashSet<u32> = HashSet::new();
    let mut total = 0usize;
    for bucket in 0..MAX_CLASSES {
        bm.lru_order(bucket, &mut walk);
        for &b in &walk {
            assert!(seen.insert(b), "{ctx}: block {b} on two free lists");
            let v = bm.block_view(b).expect("listed block id in range");
            assert_eq!(v.refcount, 0, "{ctx}: LRU block {b} still referenced");
            assert!(v.hash.is_some() && !v.untracked);
            assert_eq!(
                (v.tier as usize).min(MAX_CLASSES - 1),
                bucket,
                "{ctx}: block {b} filed under bucket {bucket} but tagged tier {}",
                v.tier
            );
        }
        total += walk.len();
    }
    bm.untracked_order(&mut walk);
    for &b in &walk {
        assert!(seen.insert(b), "{ctx}: block {b} on two free lists");
        let v = bm.block_view(b).expect("listed block id in range");
        assert_eq!(v.refcount, 0, "{ctx}: untracked block {b} still referenced");
    }
    total += walk.len();
    assert_eq!(total, bm.free_blocks(), "{ctx}: list walks disagree with free_blocks");
}

/// One random interleaving, invariants re-checked after every op.
fn random_walk(seed: u64, num_blocks: usize, ops: usize, policy: EvictionPolicy) {
    let mut rng = Rng::new(seed);
    let mut bm = BlockManager::new(num_blocks, BLOCK_SIZE);
    bm.set_eviction_policy(policy);
    let mut universe: Vec<u64> = Vec::new();
    // (id, chain) for live sequences; ids are never reused so shadowed
    // cache entries genuinely occur.
    let mut live: Vec<(u64, Vec<u64>)> = Vec::new();
    let mut next_id = 1u64;

    for op in 0..ops {
        let ctx = format!("seed {seed} policy {policy:?} op {op}");
        match rng.range(0, 10) {
            // allocate: shared-prefix families force hits/resurrections,
            // unique tails force fresh blocks and evictions.
            0..=4 => {
                let group = rng.range(1, 7);
                let total_blocks = rng.range_usize(1, 7);
                let shared = rng.range_usize(0, total_blocks + 1);
                let chain = synthetic_chain(group, shared, next_id, total_blocks);
                universe.extend_from_slice(&chain);
                let tokens = total_blocks * BLOCK_SIZE - rng.range_usize(0, BLOCK_SIZE);
                let class = rng.range_usize(0, MAX_CLASSES);
                let tier = rng.range(0, 4) as u8;
                let before = (bm.free_blocks(), bm.num_seqs(), bm.cache_entries());
                match bm.allocate_tagged(next_id, tokens, &chain, class, tier) {
                    Some(cached) => {
                        assert!(cached <= tokens, "{ctx}: cached tokens exceed request");
                        assert!(bm.is_allocated(next_id));
                        assert_eq!(bm.tokens_of(next_id), tokens);
                        live.push((next_id, chain));
                    }
                    None => {
                        // Rejection must be a no-op.
                        assert_eq!(
                            (bm.free_blocks(), bm.num_seqs(), bm.cache_entries()),
                            before,
                            "{ctx}: failed allocate mutated state"
                        );
                    }
                }
                next_id += 1;
            }
            // grow a live sequence (decode append), possibly refused.
            5..=6 if !live.is_empty() => {
                let i = rng.range_usize(0, live.len());
                let id = live[i].0;
                let target = bm.tokens_of(id) + rng.range_usize(1, 3 * BLOCK_SIZE);
                let before = bm.tokens_of(id);
                if bm.grow(id, target) {
                    assert_eq!(bm.tokens_of(id), target, "{ctx}: grow lost tokens");
                } else {
                    assert_eq!(bm.tokens_of(id), before, "{ctx}: failed grow mutated tokens");
                }
            }
            // release a live sequence.
            _ if !live.is_empty() => {
                let i = rng.range_usize(0, live.len());
                let (id, _) = live.swap_remove(i);
                bm.release(id);
                assert!(!bm.is_allocated(id));
            }
            _ => {}
        }
        check_invariants(&bm, &universe, &ctx);
    }
    // Drain: after releasing everything, every block is free again and
    // the invariants still hold with an all-cached pool.
    for (id, _) in live.drain(..) {
        bm.release(id);
    }
    assert_eq!(bm.free_blocks(), num_blocks, "seed {seed}: leaked blocks after drain");
    check_invariants(&bm, &universe, &format!("seed {seed} drained"));
}

#[test]
fn random_interleavings_hold_invariants_tier_lru() {
    for seed in 0..12u64 {
        random_walk(0xB10C_0000 + seed, 24, 160, EvictionPolicy::TierLru);
    }
}

#[test]
fn random_interleavings_hold_invariants_lru() {
    for seed in 0..12u64 {
        random_walk(0x1B10_0000 + seed, 24, 160, EvictionPolicy::Lru);
    }
}

#[test]
fn tiny_pool_is_eviction_heavy_and_safe() {
    // 6 blocks and 6-block requests: nearly every admission must evict
    // or be refused; the walk exercises the full/empty edges.
    for seed in 0..8u64 {
        random_walk(0x71FF_0000 + seed, 6, 120, EvictionPolicy::TierLru);
    }
}

/// LRU order within a bucket is release order, and resurrection moves a
/// family to the MRU end — eviction takes the stalest family first.
#[test]
fn lru_order_tracks_release_and_resurrection() {
    let mut bm = BlockManager::new(16, BLOCK_SIZE);
    let chains: Vec<Vec<u64>> = (1..=3).map(|g| synthetic_chain(g, 2, 0, 2)).collect();
    for (i, c) in chains.iter().enumerate() {
        bm.allocate_tagged(i as u64, 2 * BLOCK_SIZE, c, 0, 0).expect("fits");
    }
    let block_of = |bm: &BlockManager, h: u64| bm.cache_lookup(h).expect("cached");
    // Release A, B, C in order: bucket 0 reads [A.., B.., C..] LRU→MRU.
    for i in 0..3u64 {
        bm.release(i);
    }
    let mut order = Vec::new();
    bm.lru_order(0, &mut order);
    assert_eq!(order.len(), 6, "three 2-block families released");
    assert_eq!(order[0], block_of(&bm, chains[0][0]), "A released first = LRU head");
    assert_eq!(order[4], block_of(&bm, chains[2][0]), "C released last = MRU end");
    // Resurrect A (a pure cache hit) and re-release: A moves behind C.
    let cached = bm.allocate_tagged(10, 2 * BLOCK_SIZE, &chains[0], 0, 0).expect("fits");
    assert_eq!(cached, 2 * BLOCK_SIZE, "fully served from cache");
    bm.release(10);
    bm.lru_order(0, &mut order);
    assert_eq!(order[0], block_of(&bm, chains[1][0]), "B is now the eviction frontier");
    assert_eq!(order[4], block_of(&bm, chains[0][0]), "resurrected A moved to MRU end");
}

/// TierLru spends low-tier blocks first; plain Lru ignores tiers and
/// takes the globally stalest release.
#[test]
fn eviction_policy_orders_victims() {
    let mk = || {
        let mut bm = BlockManager::new(4, BLOCK_SIZE);
        let hot = synthetic_chain(1, 2, 0, 2); // tier 2, released FIRST (stalest)
        let cold = synthetic_chain(2, 2, 0, 2); // tier 0, released second
        bm.allocate_tagged(1, 2 * BLOCK_SIZE, &hot, 1, 2).expect("fits");
        bm.release(1);
        bm.allocate_tagged(2, 2 * BLOCK_SIZE, &cold, 0, 0).expect("fits");
        bm.release(2);
        (bm, hot, cold)
    };
    // TierLru: the tier-0 family is evicted even though tier-2 is staler.
    let (mut bm, hot, cold) = mk();
    bm.allocate(3, 2 * BLOCK_SIZE, &[]).expect("evicts to fit");
    assert!(bm.cache_lookup(hot[0]).is_some(), "tier-2 family survives under tier-lru");
    assert!(bm.cache_lookup(cold[0]).is_none(), "tier-0 family evicted first");
    // Lru: the stalest release (the tier-2 family) goes first.
    let (mut bm, hot, cold) = mk();
    bm.set_eviction_policy(EvictionPolicy::Lru);
    bm.allocate(3, 2 * BLOCK_SIZE, &[]).expect("evicts to fit");
    assert!(bm.cache_lookup(hot[0]).is_none(), "stalest family evicted under lru");
    assert!(bm.cache_lookup(cold[0]).is_some(), "fresher family survives");
}
