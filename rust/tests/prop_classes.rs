//! SLO-class registry contracts:
//!
//! * **two-phase equivalence** — under the default two-class registry the
//!   tier-loop scheduler reproduces the pre-registry two-phase schedule
//!   *batch-for-batch*: a literal translation of the old
//!   online-phase/offline-phase code (kept here as the reference
//!   implementation) and the production scheduler are driven over random
//!   workloads and must emit identical batches at every round;
//! * **tier ordering** — on multi-class registries, emitted batches are
//!   tier-descending, the top tier is never preempted, and a class's
//!   preempted set only grows when strictly-higher-tier work exists (or
//!   the class self-preempted during its own pass);
//! * **no budget starvation up-tier** — with two charged classes sharing
//!   a tight budget, the higher tier's backlog finishes no slower than
//!   the lower tier's at every round.

use hygen::coordinator::batch::{Batch, BatchEntry, Features};
use hygen::coordinator::classes::{AdmissionPolicy, ClassRegistry, ClassSpec};
use hygen::coordinator::predictor::LatencyPredictor;
use hygen::coordinator::queues::OfflinePolicy;
use hygen::coordinator::request::{Class, Phase, Request, RequestId};
use hygen::coordinator::scheduler::{
    HybridScheduler, PreemptionMode, RateLimiter, SchedulerConfig,
};
use hygen::coordinator::state::EngineState;
use hygen::util::prop::{check, Gen};
use std::sync::Arc;

// ------------------------------------------------------------------ shared

fn apply(st: &mut EngineState, batch: &Batch) {
    let mut done: Vec<RequestId> = Vec::new();
    for e in &batch.entries {
        let finished = if e.is_prefill {
            st.advance_prefill(e.id, e.n_tokens) && st.advance_decode(e.id)
        } else {
            st.advance_decode(e.id)
        };
        if finished {
            done.push(e.id);
        }
    }
    for id in done {
        st.finish(id);
    }
}

fn prompt_for(id: u64, len: usize, family: Option<u32>) -> Vec<u32> {
    (0..len as u32)
        .map(|k| match family {
            Some(fam) if k < 32 => fam * 1000 + k,
            _ => id as u32 * 7919 + k,
        })
        .collect()
}

// ---------------------------------------------- reference two-phase (frozen)

/// Literal translation of the pre-registry two-phase scheduler (§4.1,
/// Alg. 1–2 hard-coded to online/offline), expressed against the
/// class-indexed state API. This is the frozen behavioral baseline the
/// tier-loop scheduler must match exactly under the default registry.
struct TwoPhaseReference {
    cfg: SchedulerConfig,
    predictor: LatencyPredictor,
    offline_limiter: Option<RateLimiter>,
}

impl TwoPhaseReference {
    fn new(cfg: SchedulerConfig, predictor: LatencyPredictor) -> TwoPhaseReference {
        let offline_limiter = cfg.offline_qps_cap.map(RateLimiter::new);
        TwoPhaseReference { cfg, predictor, offline_limiter }
    }

    fn phase_ids(state: &EngineState, class: Class, phase: Phase) -> Vec<RequestId> {
        state
            .running(class)
            .iter()
            .filter(|&id| state.requests[&id].phase == phase)
            .collect()
    }

    fn schedule(&mut self, state: &mut EngineState, now: f64) -> Batch {
        let mut batch = Batch::new();
        let mut t = self.cfg.latency_budget_ms.unwrap_or(f64::INFINITY);
        if t.is_finite() {
            t -= self.predictor.predict(&Features::default());
        }
        let mut c = self.cfg.chunk_tokens;
        let mut feats = Features::default();
        self.online_phase(state, &mut batch, &mut feats, &mut t, &mut c);
        if self.cfg.enable_offline {
            self.offline_phase(state, now, &mut batch, &mut feats, &mut t, &mut c);
        }
        batch
    }

    fn online_phase(
        &mut self,
        state: &mut EngineState,
        batch: &mut Batch,
        feats: &mut Features,
        t: &mut f64,
        c: &mut usize,
    ) {
        let discard = self.cfg.preemption == PreemptionMode::Discard;
        // 1. Online decodes: unconditional; preempt offline for memory.
        for id in Self::phase_ids(state, Class::ONLINE, Phase::Decode) {
            let need = state.requests[&id].context_len() + 1;
            let mut ok = state.blocks.grow(id, need);
            while !ok {
                if state.preempt_last_offline(discard).is_none() {
                    break;
                }
                ok = state.blocks.grow(id, need);
            }
            if !ok {
                continue;
            }
            let t_req = self.predictor.decode_cost(feats);
            *t -= t_req;
            feats.add_decode();
            batch.push(BatchEntry {
                id,
                class: Class::ONLINE,
                n_tokens: 1,
                is_prefill: false,
                predicted_ms: t_req,
            });
        }
        // 2. Online prefill continuations.
        for id in Self::phase_ids(state, Class::ONLINE, Phase::Prefill) {
            if *c == 0 {
                break;
            }
            let want = state.requests[&id].prefill_remaining();
            let cap = want.min(self.cfg.max_chunk_per_request);
            let (l, t_req) = self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, cap);
            if l == 0 {
                break;
            }
            *t -= t_req;
            *c -= l;
            feats.add_prefill(l);
            batch.push(BatchEntry {
                id,
                class: Class::ONLINE,
                n_tokens: l,
                is_prefill: true,
                predicted_ms: t_req,
            });
        }
        // 3. Online admissions from the FCFS queue.
        while *c > 0 && state.num_running() < self.cfg.max_running {
            let Some(next) = state.queue_mut(Class::ONLINE).peek_next() else { break };
            let prompt_len = next.prompt_len;
            let watermark = self.cfg.watermark_blocks * state.blocks.block_size();
            let mut free = state.blocks.free_tokens().saturating_sub(watermark);
            while free < prompt_len {
                if state.preempt_last_offline(discard).is_none() {
                    break;
                }
                free = state.blocks.free_tokens().saturating_sub(watermark);
            }
            if free < prompt_len {
                break;
            }
            let mut req = state.queue_mut(Class::ONLINE).pop_next().expect("peeked");
            let chain = state.prompt_chain(&req);
            let cached = match state.blocks.allocate(req.id, prompt_len.max(1), &chain) {
                Some(cached) => cached,
                None => {
                    state.queue_mut(Class::ONLINE).requeue_unscheduled(req);
                    break;
                }
            };
            req.prefilled = cached.min(prompt_len.saturating_sub(1));
            let want = req.prefill_remaining().min(self.cfg.max_chunk_per_request);
            let (l, t_req) = self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, want);
            if l == 0 {
                state.blocks.release(req.id);
                req.prefilled = 0;
                state.queue_mut(Class::ONLINE).requeue_unscheduled(req);
                break;
            }
            *t -= t_req;
            *c -= l;
            feats.add_prefill(l);
            req.phase = Phase::Prefill;
            batch.push(BatchEntry {
                id: req.id,
                class: Class::ONLINE,
                n_tokens: l,
                is_prefill: true,
                predicted_ms: t_req,
            });
            state.insert_running(req);
        }
    }

    fn offline_phase(
        &mut self,
        state: &mut EngineState,
        now: f64,
        batch: &mut Batch,
        feats: &mut Features,
        t: &mut f64,
        c: &mut usize,
    ) {
        let discard = self.cfg.preemption == PreemptionMode::Discard;
        // 1. Offline decodes within the residual budget.
        for id in Self::phase_ids(state, Class::OFFLINE, Phase::Decode) {
            if !state.running(Class::OFFLINE).contains(id) {
                continue;
            }
            let t_req = self.predictor.decode_cost(feats);
            if t_req > *t {
                break;
            }
            let need = state.requests[&id].context_len() + 1;
            let mut ok = state.blocks.grow(id, need);
            while !ok {
                match state.running(Class::OFFLINE).last() {
                    Some(last) if last != id => {
                        state.preempt_last_offline(discard);
                        ok = state.blocks.grow(id, need);
                    }
                    _ => break,
                }
            }
            if !ok {
                break;
            }
            *t -= t_req;
            feats.add_decode();
            batch.push(BatchEntry {
                id,
                class: Class::OFFLINE,
                n_tokens: 1,
                is_prefill: false,
                predicted_ms: t_req,
            });
        }
        // 2. Offline prefill continuations.
        for id in Self::phase_ids(state, Class::OFFLINE, Phase::Prefill) {
            if *c == 0 || *t <= 0.0 {
                break;
            }
            let want =
                state.requests[&id].prefill_remaining().min(self.cfg.max_chunk_per_request);
            let (l, t_req) = self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, want);
            if l == 0 {
                break;
            }
            *t -= t_req;
            *c -= l;
            feats.add_prefill(l);
            batch.push(BatchEntry {
                id,
                class: Class::OFFLINE,
                n_tokens: l,
                is_prefill: true,
                predicted_ms: t_req,
            });
        }
        // 3. Resume preempted offline requests, FIFO.
        while let Some(&id) = state.preempted(Class::OFFLINE).front() {
            if state.num_running() >= self.cfg.max_running || *t <= 0.0 {
                break;
            }
            let req = &state.requests[&id];
            let ctx = req.context_len().max(1);
            let chain = state.prompt_chain(req);
            if state.blocks.allocate(id, ctx, &chain).is_none() {
                break;
            }
            let resumed_phase =
                state.resume_front_preempted().expect("front() guard guarantees a head");
            if resumed_phase == Phase::Decode {
                let t_req = self.predictor.decode_cost(feats);
                let need = state.requests[&id].context_len() + 1;
                if t_req <= *t && state.blocks.grow(id, need) {
                    *t -= t_req;
                    feats.add_decode();
                    batch.push(BatchEntry {
                        id,
                        class: Class::OFFLINE,
                        n_tokens: 1,
                        is_prefill: false,
                        predicted_ms: t_req,
                    });
                }
            } else {
                let want =
                    state.requests[&id].prefill_remaining().min(self.cfg.max_chunk_per_request);
                let (l, t_req) =
                    self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, want);
                if l > 0 {
                    *t -= t_req;
                    *c -= l;
                    feats.add_prefill(l);
                    batch.push(BatchEntry {
                        id,
                        class: Class::OFFLINE,
                        n_tokens: l,
                        is_prefill: true,
                        predicted_ms: t_req,
                    });
                }
            }
        }
        // 4. New offline admissions in queue-policy order.
        while *c > 0 && *t > 0.0 && state.num_running() < self.cfg.max_running {
            let Some(next) = state.queue_mut(Class::OFFLINE).peek_next() else { break };
            let prompt_len = next.prompt_len;
            let watermark = self.cfg.watermark_blocks * state.blocks.block_size();
            let free = state.blocks.free_tokens().saturating_sub(watermark);
            if free < prompt_len {
                break;
            }
            if let Some(lim) = &mut self.offline_limiter {
                if !lim.admit(now) {
                    break;
                }
            }
            let mut req = state.queue_mut(Class::OFFLINE).pop_next().expect("peeked");
            let chain = state.prompt_chain(&req);
            let cached = match state.blocks.allocate(req.id, prompt_len.max(1), &chain) {
                Some(cached) => cached,
                None => {
                    state.queue_mut(Class::OFFLINE).requeue_unscheduled(req);
                    break;
                }
            };
            let reuse = if state.prefix_caching {
                cached.max(req.shared_prefix_len.min(prompt_len))
            } else {
                0
            };
            req.prefilled = reuse.min(prompt_len.saturating_sub(1));
            let want = req.prefill_remaining().min(self.cfg.max_chunk_per_request);
            let (l, t_req) = self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, want);
            if l == 0 {
                state.blocks.release(req.id);
                req.prefilled = 0;
                state.queue_mut(Class::OFFLINE).requeue_unscheduled(req);
                break;
            }
            *t -= t_req;
            *c -= l;
            feats.add_prefill(l);
            req.phase = Phase::Prefill;
            batch.push(BatchEntry {
                id: req.id,
                class: Class::OFFLINE,
                n_tokens: l,
                is_prefill: true,
                predicted_ms: t_req,
            });
            state.insert_running(req);
        }
    }
}

// -------------------------------------------------------------- equivalence

fn random_config(g: &mut Gen) -> SchedulerConfig {
    SchedulerConfig {
        latency_budget_ms: if g.bool() { Some(g.f64(5.0, 200.0)) } else { None },
        chunk_tokens: g.usize(16, 2048),
        max_chunk_per_request: *g.pick(&[8usize, 32, 512, usize::MAX]),
        max_running: g.usize(1, 64),
        preemption: if g.bool() { PreemptionMode::Preserve } else { PreemptionMode::Discard },
        enable_offline: g.bool(),
        offline_qps_cap: if g.bool() { Some(g.f64(0.1, 10.0)) } else { None },
        watermark_blocks: g.usize(0, 4),
    }
}

/// Build one two-class workload twice (identical construction) so the
/// production scheduler and the reference evolve separate but initially
/// identical states.
fn twin_states(g: &mut Gen) -> (EngineState, EngineState) {
    let blocks = g.usize(32, 1024);
    let policy = *g.pick(&[
        OfflinePolicy::Fcfs,
        OfflinePolicy::Psm,
        OfflinePolicy::PsmFair { utility_ratio: 0.5 },
    ]);
    let seed = g.u64(0, 1 << 32);
    let mut a = EngineState::new(policy, blocks, 16, seed);
    let mut b = EngineState::new(policy, blocks, 16, seed);
    for i in 0..g.usize(0, 30) {
        let class = if g.bool() { Class::ONLINE } else { Class::OFFLINE };
        let plen = g.usize(1, 600);
        let family = if g.bool() { Some(g.u64(0, 5) as u32) } else { None };
        let prompt = prompt_for(i as u64, plen, family);
        let arrival = g.f64(0.0, 10.0);
        let out = g.usize(1, 64);
        a.enqueue(Request::new(i as u64, class, arrival, plen, out).with_prompt(prompt.clone()));
        b.enqueue(Request::new(i as u64, class, arrival, plen, out).with_prompt(prompt));
    }
    (a, b)
}

#[test]
fn prop_default_registry_reproduces_two_phase_schedule() {
    check("two-phase equivalence", 120, |g| {
        let cfg = random_config(g);
        let (mut st_new, mut st_ref) = twin_states(g);
        let mut tiered = HybridScheduler::new(cfg.clone(), LatencyPredictor::default_seed());
        let mut reference = TwoPhaseReference::new(cfg, LatencyPredictor::default_seed());
        for round in 0..40 {
            let now = round as f64 * 0.02;
            let b_new = tiered.schedule_owned(&mut st_new, now);
            let b_ref = reference.schedule(&mut st_ref, now);
            assert_eq!(
                b_new.entries, b_ref.entries,
                "tier-loop batch diverged from the two-phase reference at round {round}"
            );
            apply(&mut st_new, &b_new);
            apply(&mut st_ref, &b_ref);
            st_new.check_invariants().unwrap_or_else(|e| panic!("round {round}: {e}"));
        }
    });
}

// ------------------------------------------------------------ tier ordering

fn spec(name: &str, tier: u8, admission: AdmissionPolicy, bypass: bool) -> ClassSpec {
    ClassSpec {
        name: name.into(),
        tier,
        ttft_slo_ms: if bypass { Some(800.0) } else { None },
        tbt_slo_ms: None,
        latency_budget: if bypass { None } else { Some(1.0) },
        preempt_priority: tier * 10,
        admission,
        starvation_age_s: None,
    }
}

fn three_class_state(g: &mut Gen) -> EngineState {
    let reg = Arc::new(
        ClassRegistry::new(vec![
            spec("chat", 2, AdmissionPolicy::Fcfs, true),
            spec("mid", 1, AdmissionPolicy::Fcfs, false),
            spec("bulk", 0, AdmissionPolicy::LongestPrefix, false),
        ])
        .unwrap(),
    );
    let blocks = g.usize(32, 1024);
    let mut st =
        EngineState::with_registry(reg, OfflinePolicy::Psm, blocks, 16, g.u64(0, 1 << 32));
    for i in 0..g.usize(0, 36) {
        let class = Class(g.u64(0, 3) as u16);
        let plen = g.usize(1, 500);
        let family = if g.bool() { Some(g.u64(0, 4) as u32) } else { None };
        st.enqueue(
            Request::new(i as u64, class, g.f64(0.0, 10.0), plen, g.usize(1, 48))
                .with_prompt(prompt_for(i as u64, plen, family)),
        );
    }
    st
}

#[test]
fn prop_batches_are_tier_descending_and_top_tier_never_preempted() {
    check("tier ordering", 120, |g| {
        let mut st = three_class_state(g);
        let cfg = SchedulerConfig {
            latency_budget_ms: if g.bool() { Some(g.f64(8.0, 120.0)) } else { None },
            chunk_tokens: g.usize(32, 2048),
            max_running: g.usize(1, 48),
            watermark_blocks: g.usize(0, 4),
            preemption: if g.bool() { PreemptionMode::Preserve } else { PreemptionMode::Discard },
            ..SchedulerConfig::default()
        };
        let mut sched = HybridScheduler::new(cfg, LatencyPredictor::default_seed());
        for round in 0..30 {
            // Work present per class *before* the round (for the
            // preemption-direction check below).
            let registry = Arc::clone(&st.registry);
            let had_work: Vec<bool> = registry
                .ids()
                .map(|c| !st.queue(c).is_empty() || !st.running(c).is_empty())
                .collect();
            let preempted_before: Vec<usize> =
                registry.ids().map(|c| st.preempted(c).len()).collect();

            let b = sched.schedule_owned(&mut st, round as f64 * 0.02);

            // (1) Batches are tier-descending.
            let tiers: Vec<u8> =
                b.entries.iter().map(|e| registry.spec(e.class).tier).collect();
            assert!(
                tiers.windows(2).all(|w| w[0] >= w[1]),
                "batch not tier-descending at round {round}: {tiers:?}"
            );
            // (2) The top tier is never preempted.
            assert!(st.preempted(Class(0)).is_empty(), "top tier preempted");
            // (3) A class's preempted set only grows when strictly
            //     higher-tier work existed, or the class scheduled its own
            //     work this round (self-preemption inside its pass).
            for c in registry.ids() {
                let grew = st.preempted(c).len() > preempted_before[c.index()];
                if grew {
                    let my_tier = registry.spec(c).tier;
                    let higher = registry
                        .ids()
                        .any(|o| registry.spec(o).tier > my_tier && had_work[o.index()]);
                    let own_pass = b.entries.iter().any(|e| e.class == c);
                    assert!(
                        higher || own_pass,
                        "class {} preempted with no higher-tier work at round {round}",
                        c.index()
                    );
                }
            }
            apply(&mut st, &b);
            st.check_invariants().unwrap_or_else(|e| panic!("round {round}: {e}"));
        }
    });
}

#[test]
fn higher_tier_backlog_never_finishes_slower_under_a_shared_budget() {
    // Two charged classes under one tight budget: the tier loop feeds the
    // higher tier first, so its backlog completion count dominates the
    // lower tier's at every round — the "no budget starvation up-tier"
    // contract.
    let reg = Arc::new(
        ClassRegistry::new(vec![
            spec("hi", 1, AdmissionPolicy::Fcfs, false),
            spec("lo", 0, AdmissionPolicy::Fcfs, false),
        ])
        .unwrap(),
    );
    let mut st = EngineState::with_registry(reg, OfflinePolicy::Fcfs, 1 << 14, 16, 0);
    for i in 0..20u64 {
        st.enqueue(
            Request::new(i, Class(0), 0.0, 128, 8)
                .with_prompt(prompt_for(i, 128, None)),
        );
        st.enqueue(
            Request::new(100 + i, Class(1), 0.0, 128, 8)
                .with_prompt(prompt_for(100 + i, 128, None)),
        );
    }
    let mut sched = HybridScheduler::new(
        SchedulerConfig {
            latency_budget_ms: Some(18.0),
            chunk_tokens: 1 << 16,
            ..SchedulerConfig::default()
        },
        LatencyPredictor::default_seed(),
    );
    for round in 0..400 {
        let b = sched.schedule_owned(&mut st, round as f64 * 0.02);
        if b.is_empty() && !st.has_pending() {
            break;
        }
        apply(&mut st, &b);
        let hi_done = st.finished.iter().filter(|r| r.class == Class(0)).count();
        let lo_done = st.finished.iter().filter(|r| r.class == Class(1)).count();
        assert!(
            hi_done >= lo_done,
            "lower tier outran the higher tier at round {round}: {lo_done} > {hi_done}"
        );
        st.check_invariants().unwrap();
    }
    assert!(
        st.finished.iter().any(|r| r.class == Class(0)),
        "the higher tier made progress"
    );
}
