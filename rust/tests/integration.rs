//! Integration tests over the real PJRT runtime + backend.
//!
//! The whole target is gated on the `pjrt` cargo feature (the default
//! build has no PJRT runtime). With the feature on, the tests additionally
//! need `make artifacts` to have run (they are skipped with a message
//! otherwise, so `cargo test --features pjrt` stays green on a fresh
//! checkout).
#![cfg(feature = "pjrt")]

use hygen::coordinator::queues::OfflinePolicy;
use hygen::coordinator::request::{Class, Request};
use hygen::engine::pjrt_backend::build_real_engine;
use hygen::runtime::{tokenizer, PjrtRuntime};
use hygen::util::json::Json;
use hygen::workload::trace::{Trace, TraceEvent};

const ARTIFACTS: &str = "artifacts";

fn default_registry() -> std::sync::Arc<hygen::coordinator::classes::ClassRegistry> {
    std::sync::Arc::new(hygen::coordinator::classes::ClassRegistry::default_two())
}

fn have_artifacts() -> bool {
    std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn runtime_loads_all_buckets() {
    require_artifacts!();
    let rt = PjrtRuntime::load(ARTIFACTS).unwrap();
    assert!(rt.buckets().len() >= 4);
    assert!(rt.buckets().contains(&(8, 32)));
    assert_eq!(rt.dims.vocab, 256);
    assert_eq!(rt.pick_bucket(3, 5), Some((4, 8)));
}

#[test]
fn step_executes_and_shapes_match() {
    require_artifacts!();
    let rt = PjrtRuntime::load(ARTIFACTS).unwrap();
    let (ck, cv) = rt.empty_caches(1);
    let tokens = vec![72i32; 1]; // 'H'
    let out = rt.step(1, 1, &tokens, &[0], &ck, &cv).unwrap();
    assert_eq!(out.logits.len(), 256);
    let tok = rt.argmax(&out, 0, 0);
    assert!(tok < 256);
}

#[test]
fn step_rejects_out_of_range_positions() {
    require_artifacts!();
    let rt = PjrtRuntime::load(ARTIFACTS).unwrap();
    let (ck, cv) = rt.empty_caches(1);
    let max = rt.dims.max_seq as i32;
    assert!(rt.step(1, 1, &[0], &[max], &ck, &cv).is_err());
    assert!(rt.step(1, 1, &[0], &[-1], &ck, &cv).is_err());
    assert!(rt.step(1, 1, &[0, 0], &[0], &ck, &cv).is_err(), "bad token count");
}

/// THE cross-layer consistency check: greedy generation through the Rust
/// PJRT path must reproduce the jax reference generation token-for-token
/// (fixture produced by python/compile/aot.py at artifact-build time).
#[test]
fn greedy_generation_matches_jax_reference() {
    require_artifacts!();
    let fixture_text =
        std::fs::read_to_string(format!("{ARTIFACTS}/expected_tokens.json")).unwrap();
    let fixture = Json::parse(&fixture_text).unwrap();
    let prompt: Vec<u32> = fixture
        .get("prompt_tokens")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap() as u32)
        .collect();
    let expected: Vec<u32> = fixture
        .get("output_tokens")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap() as u32)
        .collect();

    let mut engine =
        build_real_engine(ARTIFACTS, None, OfflinePolicy::Fcfs, default_registry(), 0).unwrap();
    let id = engine.fresh_id();
    let req = Request::new(id, Class::ONLINE, 0.0, prompt.len(), expected.len())
        .with_prompt(prompt);
    engine.submit(req);
    while engine.has_work() {
        engine.step().unwrap();
    }
    assert_eq!(engine.state.finished.len(), 1);
    let got = &engine.state.finished[0].output_tokens;
    assert_eq!(got, &expected, "rust PJRT generation != jax reference");
}

#[test]
fn chunked_prefill_equals_monolithic_through_pjrt() {
    require_artifacts!();
    // Generate with a prompt long enough to be chunked (> max_chunk).
    let run = |max_chunk: usize| -> Vec<u32> {
        let mut engine =
            build_real_engine(ARTIFACTS, None, OfflinePolicy::Fcfs, default_registry(), 0).unwrap();
        engine.scheduler.cfg.max_chunk_per_request =
            max_chunk.min(engine.scheduler.cfg.max_chunk_per_request);
        let prompt = tokenizer::encode(
            "This prompt is deliberately longer than one chunk bucket so that \
             the scheduler must split it across iterations.",
        );
        let id = engine.fresh_id();
        engine.submit(
            Request::new(id, Class::ONLINE, 0.0, prompt.len(), 6).with_prompt(prompt),
        );
        while engine.has_work() {
            engine.step().unwrap();
        }
        engine.state.finished[0].output_tokens.clone()
    };
    let chunked = run(8); // forces many chunks
    let monolithic = run(32);
    assert_eq!(chunked, monolithic, "chunked prefill must be numerically invisible");
}

#[test]
fn colocated_batch_serves_online_and_offline() {
    require_artifacts!();
    let mut engine =
        build_real_engine(ARTIFACTS, None, OfflinePolicy::Psm, default_registry(), 0).unwrap();
    let mut events = Vec::new();
    for i in 0..3 {
        events.push(TraceEvent {
            arrival_s: i as f64 * 0.001,
            class: Class::ONLINE,
            prompt_len: 24,
            output_len: 4,
            prompt: tokenizer::encode(&format!("online request number {i} body")).into(),
        });
    }
    for i in 0..4 {
        let p = tokenizer::encode(&format!("Summarize the following: doc {i}"));
        events.push(TraceEvent {
            arrival_s: 0.0,
            class: Class::OFFLINE,
            prompt_len: p.len(),
            output_len: 3,
            prompt: p.into(),
        });
    }
    let r = engine.run_trace(&Trace::new(events), 300.0, true).unwrap();
    assert_eq!(r.finished_online, 3);
    assert_eq!(r.finished_offline, 4);
    assert!(r.report.mean_ttft_ms > 0.0);
    assert!(engine.backend.steps > 0);
    engine.state.check_invariants().unwrap();
}

#[test]
fn deterministic_generation_across_runs() {
    require_artifacts!();
    let run = || {
        let mut engine =
            build_real_engine(ARTIFACTS, None, OfflinePolicy::Fcfs, default_registry(), 0).unwrap();
        let prompt = tokenizer::encode("determinism check");
        let id = engine.fresh_id();
        engine.submit(
            Request::new(id, Class::ONLINE, 0.0, prompt.len(), 8).with_prompt(prompt),
        );
        while engine.has_work() {
            engine.step().unwrap();
        }
        engine.state.finished[0].output_tokens.clone()
    };
    assert_eq!(run(), run());
}
