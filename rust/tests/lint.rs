//! Golden tests for `hygen lint` (the in-repo static-analysis pass,
//! DESIGN.md §9): `tests/lint_fixtures/` seeds one violation per rule
//! class and the diagnostics are pinned exactly — file, line, and rule —
//! so a rule that silently stops firing fails here, not in review. The
//! committed tree itself must lint clean (same gate CI runs via
//! `cargo run --release -- lint`).

use std::path::PathBuf;

use hygen::analysis::{lint_repo, lint_tree};

fn fixture_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("lint_fixtures")
}

#[test]
fn fixtures_trip_every_rule_class() {
    let root = fixture_root();
    let report =
        lint_tree(&root.join("src"), Some(&root), "fixtures/").expect("fixture lint runs");
    assert_eq!(report.files_scanned, 4);

    let got: Vec<(&str, u32, &str)> =
        report.diagnostics.iter().map(|d| (d.file.as_str(), d.line, d.rule)).collect();
    let expected: Vec<(&str, u32, &str)> = vec![
        ("README.md", 4, "config-doc"),
        ("fixtures/clockwork.rs", 4, "wallclock"),
        ("fixtures/clockwork.rs", 8, "annotation"),
        ("fixtures/clockwork.rs", 10, "rng"),
        ("fixtures/clockwork.rs", 14, "annotation"),
        ("fixtures/config/mod.rs", 4, "config-doc"),
        ("fixtures/coordinator/scheduler.rs", 7, "map-iter"),
        ("fixtures/coordinator/scheduler.rs", 10, "map-iter"),
        ("fixtures/coordinator/scheduler.rs", 17, "panic"),
        ("fixtures/coordinator/scheduler.rs", 17, "panic"),
        ("fixtures/hotpath.rs", 6, "alloc"),
        ("fixtures/hotpath.rs", 11, "alloc"),
    ];
    assert_eq!(got, expected, "full diagnostics: {:#?}", report.diagnostics);
}

#[test]
fn fixture_diagnostics_name_the_construct() {
    let root = fixture_root();
    let report =
        lint_tree(&root.join("src"), Some(&root), "fixtures/").expect("fixture lint runs");
    let msgs_for = |rule: &str| -> String {
        report
            .diagnostics
            .iter()
            .filter(|d| d.rule == rule)
            .map(|d| d.msg.as_str())
            .collect::<Vec<_>>()
            .join("\n")
    };
    let alloc = msgs_for("alloc");
    assert!(alloc.contains("Vec::new"), "{alloc}");
    assert!(alloc.contains("via helper"), "transitive chain must be named: {alloc}");
    assert!(msgs_for("rng").contains("thread_rng"));
    assert!(msgs_for("config-doc").contains("mystery_knob"), "undocumented knob named");
    assert!(msgs_for("config-doc").contains("phantom_knob"), "unparsed doc key named");

    // `file:line: rule(name): message` — the format CI logs and editors
    // jump on.
    let rendered = report.diagnostics[0].to_string();
    assert!(rendered.starts_with("README.md:4: rule(config-doc):"), "{rendered}");
}

/// The gate itself: the committed tree carries zero violations, so any
/// change that introduces one fails tier-1 even before the dedicated
/// `hygen lint` CI step runs.
#[test]
fn committed_tree_is_clean() {
    let repo_root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives one level under the repo root")
        .to_path_buf();
    assert!(repo_root.join("rust").join("src").is_dir(), "unexpected repo layout");
    let report = lint_repo(&repo_root).expect("lint runs on the committed tree");
    assert!(
        report.is_clean(),
        "committed tree must lint clean:\n{}",
        report
            .diagnostics
            .iter()
            .map(ToString::to_string)
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files_scanned >= 50, "scanned only {} files", report.files_scanned);
}
