//! Fixture: parses a knob the fixture docs do not mention.

pub fn parse(j: &Json) -> Option<f64> {
    j.get("mystery_knob").and_then(Json::as_f64)
}
