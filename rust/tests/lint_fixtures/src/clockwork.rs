//! Fixture: seeded `wallclock`, `rng`, and `annotation` violations.

pub fn stamp() -> u64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos() as u64
}

// lint: allow(rng)
pub fn roll() -> u32 {
    let mut rng = thread_rng();
    rng.next()
}

// lint: allwo(wallclock, reason=typo)
pub fn later() {}
