//! Fixture: seeded `map-iter` and `panic` violations. Never compiled —
//! only lexed by `tests/lint.rs`.

use std::collections::HashMap;
pub fn snapshot(batch: &HashMap<u64, u64>) -> u64 {
    let mut sum = 0;
    for (_, v) in batch.iter() {
        sum += *v;
    }
    for k in &batch {
        sum += *k.1;
    }
    sum
}

pub fn pick(xs: &[u64], opt: Option<u64>) -> u64 {
    xs[0] + opt.unwrap()
}
