//! Fixture: seeded `alloc` violations inside an `alloc-free` root, one
//! direct and one transitive.

// lint: alloc-free
pub fn hot() -> usize {
    let ids = vec![1u32, 2, 3];
    helper() + ids.len()
}

fn helper() -> usize {
    let s: Vec<u8> = Vec::new();
    s.len()
}
