//! Build-surface smoke tests: the example targets must keep compiling
//! (they live outside the crate directory and are easy to orphan when the
//! manifest changes), and the default config must survive a round trip
//! through the in-tree JSON substrate.

use hygen::config::{Config, ServeConfig};
use hygen::util::json::Json;

#[test]
fn config_defaults_roundtrip_through_util_json() {
    let c = Config::default();
    let text = c.to_json().to_pretty();
    let parsed = Json::parse(&text).expect("serialized config must reparse");
    let c2 = ServeConfig::from_json(&parsed).expect("reparsed config must validate");
    assert_eq!(c2.artifacts_dir, c.artifacts_dir);
    assert_eq!(c2.bind, c.bind);
    assert_eq!(c2.latency_budget_ms, c.latency_budget_ms);
    assert_eq!(c2.policy, c.policy);
    assert_eq!(c2.http_workers, c.http_workers);
    assert_eq!(c2.seed, c.seed);
    // Compact form parses to the same document as the pretty form.
    assert_eq!(Json::parse(&c.to_json().to_string()).unwrap(), parsed);
}

/// The examples live outside the crate directory, so they are easy to
/// orphan when the manifest changes: a deleted `[[example]]` entry makes
/// `cargo build --examples` quietly stop building the file. Guard both
/// directions — every expected target is declared and its source exists,
/// and the declared set actually compiles.
#[test]
fn every_example_target_compiles() {
    let manifest_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let manifest = std::fs::read_to_string(manifest_dir.join("Cargo.toml")).unwrap();
    for name in ["quickstart", "colocation_serving", "psm_demo", "slo_sweep"] {
        assert!(
            manifest.contains(&format!("name = \"{name}\"")),
            "example `{name}` missing from rust/Cargo.toml"
        );
        let src = manifest_dir.join("../examples").join(format!("{name}.rs"));
        assert!(src.exists(), "example source missing: {}", src.display());
    }
    let cargo = std::env::var("CARGO").unwrap_or_else(|_| "cargo".to_string());
    let status = std::process::Command::new(cargo)
        .args(["build", "--examples", "--quiet"])
        .current_dir(manifest_dir)
        .status()
        .expect("failed to spawn cargo");
    assert!(status.success(), "`cargo build --examples` failed: {status}");
}
