//! Property tests on the PSM prefix trie, the fairness extension, and the
//! block manager (Alg. 3/4 invariants + memory-safety invariants).

use hygen::coordinator::block_manager::{chain_hashes, BlockManager};
use hygen::coordinator::fairness::FairPsm;
use hygen::coordinator::psm::{lcp, PrefixTree};
use hygen::util::prop::{check, Gen};

fn random_prompt(g: &mut Gen) -> Vec<u32> {
    // family-structured prompts: shared template + unique suffix
    let fam = g.u64(0, 6) as u32;
    let shared = g.usize(0, 40);
    let unique = g.usize(1, 40);
    let tag = g.u64(0, 1 << 30) as u32;
    (0..shared as u32)
        .map(|k| fam * 10_000 + k)
        .chain((0..unique as u32).map(|k| tag.wrapping_mul(2654435761).wrapping_add(k)))
        .collect()
}

#[test]
fn prop_trie_drains_exactly_once_each() {
    check("trie drain", 200, |g| {
        let mut t = PrefixTree::new();
        let n = g.usize(1, 60);
        let prompts: Vec<Vec<u32>> = (0..n).map(|_| random_prompt(g)).collect();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(i as u64, p);
        }
        assert_eq!(t.len(), n);
        let mut seen = std::collections::HashSet::new();
        while let Some(id) = t.pop_next() {
            assert!(seen.insert(id), "id {id} popped twice");
        }
        assert_eq!(seen.len(), n, "every request popped exactly once");
        assert!(t.is_empty());
    });
}

#[test]
fn prop_dfs_order_adjacent_lcp_dominates_arrival_order() {
    check("dfs maximizes adjacent sharing", 100, |g| {
        let n = g.usize(8, 60);
        let prompts: Vec<Vec<u32>> = (0..n).map(|_| random_prompt(g)).collect();
        let mut t = PrefixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(i as u64, p);
        }
        let order = t.dfs_order();
        let dfs_sharing: usize = order
            .windows(2)
            .map(|w| lcp(&prompts[w[0] as usize], &prompts[w[1] as usize]))
            .sum();
        let arrival_sharing: usize =
            prompts.windows(2).map(|w| lcp(&w[0], &w[1])).sum();
        assert!(
            dfs_sharing >= arrival_sharing,
            "DFS adjacent sharing {dfs_sharing} < arrival {arrival_sharing}"
        );
    });
}

#[test]
fn prop_dfs_order_is_sorted_order() {
    check("dfs == lexicographic", 100, |g| {
        // DFS over a trie with token-ordered edges == lexicographic sort.
        let n = g.usize(1, 50);
        let prompts: Vec<Vec<u32>> = (0..n).map(|_| random_prompt(g)).collect();
        let mut t = PrefixTree::new();
        for (i, p) in prompts.iter().enumerate() {
            t.insert(i as u64, p);
        }
        let order = t.dfs_order();
        for w in order.windows(2) {
            let a = &prompts[w[0] as usize];
            let b = &prompts[w[1] as usize];
            assert!(a <= b, "DFS order not lexicographic: {a:?} > {b:?}");
        }
    });
}

#[test]
fn prop_trie_interleaved_ops_stay_consistent() {
    check("trie interleaved", 150, |g| {
        let mut t = PrefixTree::new();
        let mut live = std::collections::HashSet::new();
        let mut next_id = 0u64;
        for _ in 0..g.usize(10, 120) {
            match g.usize(0, 3) {
                0 => {
                    let p = random_prompt(g);
                    t.insert(next_id, &p);
                    live.insert(next_id);
                    next_id += 1;
                }
                1 => {
                    if let Some(id) = t.pop_next() {
                        assert!(live.remove(&id));
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let id = *live.iter().next().unwrap();
                        assert!(t.remove(id));
                        live.remove(&id);
                    }
                }
            }
            assert_eq!(t.len(), live.len());
            if let Some(id) = t.peek_next() {
                assert!(live.contains(&id));
            }
        }
    });
}

#[test]
fn prop_fair_psm_sync_and_no_loss() {
    check("fair psm sync", 150, |g| {
        let u = g.f64(0.0, 1.0);
        let mut f = FairPsm::new(u, g.u64(0, 1 << 40));
        let n = g.usize(1, 80);
        for i in 0..n {
            f.insert(i as u64, &random_prompt(g), g.f64(0.0, 100.0));
        }
        let mut popped = std::collections::HashSet::new();
        while let Some(id) = f.pop_next() {
            assert!(popped.insert(id));
            assert_eq!(f.trie.len(), f.fresh.len(), "structures out of sync");
        }
        assert_eq!(popped.len(), n);
    });
}

#[test]
fn prop_fair_psm_bounded_staleness_at_low_u() {
    check("bounded staleness", 40, |g| {
        // With u <= 0.5 the stalest request is picked with prob >= 0.5 per
        // pop; over a 120-pop window the oldest must surface w.h.p.
        let mut f = FairPsm::new(0.3, g.u64(0, 1 << 40));
        f.insert(0, &random_prompt(g), 0.0); // the oldest
        for i in 1..120u64 {
            f.insert(i, &random_prompt(g), 1.0 + i as f64);
        }
        let mut found = false;
        for _ in 0..60 {
            if f.pop_next() == Some(0) {
                found = true;
                break;
            }
        }
        assert!(found, "oldest request starved under u=0.3");
    });
}

#[test]
fn prop_block_manager_conservation() {
    check("block conservation", 200, |g| {
        let num_blocks = g.usize(8, 128);
        let mut bm = BlockManager::new(num_blocks, 16);
        let mut live: Vec<u64> = Vec::new();
        let mut next = 0u64;
        for _ in 0..g.usize(10, 150) {
            match g.usize(0, 3) {
                0 => {
                    let tokens = g.usize(1, 400);
                    let chain: Vec<u64> = if g.bool() {
                        let toks: Vec<u32> =
                            (0..tokens as u32).map(|k| (k / 64) * 7 + g.u64(0, 3) as u32).collect();
                        chain_hashes(&toks, 16)
                    } else {
                        vec![]
                    };
                    if bm.allocate(next, tokens, &chain).is_some() {
                        live.push(next);
                    }
                    next += 1;
                }
                1 => {
                    if !live.is_empty() {
                        let idx = g.usize(0, live.len());
                        bm.release(live.swap_remove(idx));
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = g.usize(0, live.len());
                        let id = live[idx];
                        let grown = bm.tokens_of(id) + g.usize(1, 32);
                        let _ = bm.grow(id, grown);
                    }
                }
            }
            assert!(bm.used_blocks() + bm.free_blocks() == num_blocks, "blocks leaked");
            assert_eq!(bm.num_seqs(), live.len());
        }
        for id in live {
            bm.release(id);
        }
        assert_eq!(bm.used_blocks(), 0, "all blocks returned after release");
    });
}

#[test]
fn prop_prefix_sharing_never_exceeds_actual_lcp() {
    check("lcp honesty", 150, |g| {
        use hygen::coordinator::queues::{OfflinePolicy, OfflineQueue};
        use hygen::coordinator::request::{Class, Request};
        let mut q = OfflineQueue::new(OfflinePolicy::Psm, g.u64(0, 1 << 30));
        let n = g.usize(2, 50);
        let prompts: Vec<Vec<u32>> = (0..n).map(|_| random_prompt(g)).collect();
        for (i, p) in prompts.iter().enumerate() {
            q.push(
                Request::new(i as u64, Class::OFFLINE, i as f64, p.len(), 4)
                    .with_prompt(p.clone()),
            );
        }
        let mut prev: Option<std::sync::Arc<[u32]>> = None;
        while let Some(r) = q.pop_next() {
            if let Some(p) = &prev {
                assert_eq!(
                    r.shared_prefix_len,
                    lcp(p, &r.prompt),
                    "reported sharing must equal the true LCP"
                );
            } else {
                assert_eq!(r.shared_prefix_len, 0);
            }
            prev = Some(r.prompt.clone());
        }
    });
}
