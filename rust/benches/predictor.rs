//! Latency-predictor micro-benchmarks — the paper's claims are ~15 ms
//! training on 80k samples and ~18 µs per prediction per iteration.

use hygen::coordinator::batch::Features;
use hygen::coordinator::predictor::{LatencyPredictor, Sample};
use hygen::util::bench::{black_box, Bencher};
use hygen::util::rng::Rng;

fn samples(n: usize, seed: u64) -> Vec<Sample> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut f = Features::default();
            for _ in 0..rng.range(0, 3) {
                f.add_prefill(rng.range_usize(16, 2048));
            }
            for _ in 0..rng.range(0, 64) {
                f.add_decode();
            }
            let y = 5.0 + 0.08 * f.sp + 1.5e-5 * f.sp * f.sp + 0.2 * f.nd;
            Sample { features: f, latency_ms: y * (1.0 + 0.02 * rng.normal()) }
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    let train = samples(80_000, 0);
    b.bench("predictor/fit 80k samples (paper ~15ms)", || {
        LatencyPredictor::fit(black_box(&train))
    });
    let p = LatencyPredictor::fit(&train);
    let f = Features::default().with_prefill(512).with_decode().with_decode();
    b.bench("predictor/predict (paper ~18us per iter)", || p.predict(black_box(&f)));
    b.bench("predictor/decode_cost", || p.decode_cost(black_box(&f)));
    b.bench("predictor/max_prefill_tokens", || {
        p.max_prefill_tokens(black_box(&f), 30.0, 2048, 100_000, 1024)
    });
    let test = samples(8_000, 1);
    b.bench("predictor/evaluate_mape 8k", || p.evaluate_mape(black_box(&test)));
}
