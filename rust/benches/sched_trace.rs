//! End-to-end scheduling-overhead bench: drives the synthetic mixed trace
//! through `Engine::run_trace` on the sim backend and emits
//! `BENCH_sched.json` — the same harness as `hygen bench-sched`, exposed
//! as a bench target so `cargo bench` records the trajectory too.
//!
//! Env knobs: `BENCH_SCHED_FULL=1` for the 10 k-request shape (default is
//! the quick CI shape), `BENCH_SCHED_OUT` to override the output path.

use hygen::experiments::bench_sched::{run_and_save, BenchConfig};

fn main() {
    let cfg = if std::env::var("BENCH_SCHED_FULL").is_ok() {
        BenchConfig::full()
    } else {
        BenchConfig::quick()
    };
    let out = std::env::var("BENCH_SCHED_OUT").unwrap_or_else(|_| "BENCH_sched.json".into());
    if let Err(e) = run_and_save(&cfg, &out) {
        eprintln!("bench-sched failed: {e:#}");
        std::process::exit(1);
    }
}
