//! End-to-end replay-throughput bench: the same harness as
//! `hygen bench-replay`, exposed as a bench target so `cargo bench`
//! records the trajectory too. Registers the counting allocator so the
//! allocation columns (and the zero-allocation steady-state contract)
//! are measured for real.
//!
//! Env knobs: `BENCH_REPLAY_FULL=1` for the multi-scale trajectory shape
//! (default is the quick CI shape), `BENCH_REPLAY_OUT` to override the
//! output path.

use hygen::experiments::bench_replay::{check_gates, run_and_save, ReplayConfig};
use hygen::util::alloc::CountingAlloc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let cfg = if std::env::var("BENCH_REPLAY_FULL").is_ok() {
        ReplayConfig::full()
    } else {
        ReplayConfig::quick()
    };
    let out = std::env::var("BENCH_REPLAY_OUT").unwrap_or_else(|_| "BENCH_e2e.json".into());
    if let Err(e) = run_and_save(&cfg, &out).and_then(|outcome| check_gates(&outcome)) {
        eprintln!("bench-replay failed: {e:#}");
        std::process::exit(1);
    }
}
