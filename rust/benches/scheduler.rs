//! Scheduler hot-path benchmarks: per-iteration scheduling cost (the
//! paper's O(n) claim; scheduling must be negligible vs ~10ms batches).

use hygen::coordinator::predictor::LatencyPredictor;
use hygen::coordinator::queues::OfflinePolicy;
use hygen::coordinator::request::{Class, Request};
use hygen::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
use hygen::coordinator::state::EngineState;
use hygen::util::bench::{black_box, Bencher};
use hygen::util::rng::Rng;

/// A steady-state engine: many running decodes + waiting queues.
fn steady_state(n_running: usize, n_queued: usize, policy: OfflinePolicy) -> EngineState {
    let mut st = EngineState::new(policy, 1 << 16, 16, 0);
    let mut rng = Rng::new(7);
    for i in 0..n_running {
        let id = i as u64;
        let mut r = Request::new(id, if i % 2 == 0 { Class::ONLINE } else { Class::OFFLINE }, 0.0, 256, 64)
            .with_prompt((0..256u32).map(|k| k + id as u32 * 977).collect::<Vec<u32>>());
        r.prefilled = 256;
        r.generated = 1 + (i % 8);
        r.phase = hygen::coordinator::request::Phase::Decode;
        st.blocks.allocate(id, r.context_len(), &[]).unwrap();
        st.insert_running(r);
    }
    for i in 0..n_queued {
        let id = (10_000 + i) as u64;
        let len = rng.range_usize(64, 2048);
        let req = Request::new(id, Class::OFFLINE, i as f64 * 0.01, len, 32)
            .with_prompt((0..len as u32).map(|k| k + id as u32 * 131).collect::<Vec<u32>>());
        st.queue_mut(Class::OFFLINE).push(req);
    }
    st
}

fn main() {
    let mut b = Bencher::new();
    for (n_running, n_queued) in [(8, 16), (64, 256), (256, 1024)] {
        for policy in [OfflinePolicy::Fcfs, OfflinePolicy::Psm] {
            let mut st = steady_state(n_running, n_queued, policy);
            let mut sched = HybridScheduler::new(
                SchedulerConfig {
                    latency_budget_ms: Some(40.0),
                    chunk_tokens: 512,
                    max_running: n_running, // no admissions: pure steady-state cost
                    ..Default::default()
                },
                LatencyPredictor::default_seed(),
            );
            let mut now = 0.0;
            // Reused iteration batch, exactly like the engine's hot loop.
            let mut batch = hygen::coordinator::batch::Batch::new();
            b.bench(
                &format!("schedule/steady r={n_running} q={n_queued} [{}]", policy.name()),
                || {
                    now += 0.01;
                    sched.schedule(&mut st, now, &mut batch);
                    black_box(batch.len())
                },
            );
        }
    }

    // Admission-heavy iteration (queue drains into the batch).
    let mut sched = HybridScheduler::new(
        SchedulerConfig {
            latency_budget_ms: Some(100.0),
            chunk_tokens: 4096,
            ..Default::default()
        },
        LatencyPredictor::default_seed(),
    );
    b.bench("schedule/admission burst 64 offline", || {
        let mut st = steady_state(0, 64, OfflinePolicy::Psm);
        black_box(sched.schedule_owned(&mut st, 0.0).len())
    });
}
