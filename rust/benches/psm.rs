//! PSM prefix-tree benchmarks — the paper's complexity claims (App. A.4):
//! O(L) insert/remove, O(1) amortized next-request.

use hygen::coordinator::psm::PrefixTree;
use hygen::coordinator::queues::{OfflinePolicy, OfflineQueue};
use hygen::coordinator::request::{Class, Request};
use hygen::util::bench::{black_box, Bencher};
use hygen::util::rng::Rng;

fn prompts(n: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let family = rng.range(0, 57);
            let mut p: Vec<u32> = (0..320u32).map(|k| (family as u32) << 16 | k).collect();
            p.extend((0..rng.range_usize(16, 256)).map(|k| (i * 1000 + k) as u32));
            p
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    let ps = prompts(4096, 0);

    b.bench("psm/insert into 4k-request trie (O(L))", || {
        // build once per ~many iters would skew; measure insert+remove pair
        // against a prebuilt trie to keep size constant.
        let mut t = PrefixTree::new();
        for (i, p) in ps.iter().take(64).enumerate() {
            t.insert(i as u64, p);
        }
        black_box(t.len())
    });

    let mut tree = PrefixTree::new();
    for (i, p) in ps.iter().enumerate() {
        tree.insert(i as u64, p);
    }
    let mut i = 0u64;
    b.bench("psm/insert+remove steady-state", || {
        let id = 1_000_000 + i;
        tree.insert(id, &ps[(i % 4096) as usize]);
        tree.remove(id);
        i += 1;
    });

    b.bench("psm/peek_next amortized O(1)", || black_box(tree.peek_next()));

    b.bench("psm/full drain of 4k requests (DFS order)", || {
        let mut t = PrefixTree::new();
        for (i, p) in ps.iter().enumerate() {
            t.insert(i as u64, p);
        }
        let mut n = 0;
        while t.pop_next().is_some() {
            n += 1;
        }
        black_box(n)
    });

    // Queue-level comparison: pop cost incl. LCP accounting.
    for policy in [OfflinePolicy::Fcfs, OfflinePolicy::Psm, OfflinePolicy::PsmFair { utility_ratio: 0.9 }] {
        b.bench(&format!("queue/push+pop 256 [{}]", policy.name()), || {
            let mut q = OfflineQueue::new(policy, 1);
            for (i, p) in ps.iter().take(256).enumerate() {
                q.push(
                    Request::new(i as u64, Class::OFFLINE, i as f64, p.len(), 4)
                        .with_prompt(p.clone()),
                );
            }
            let mut n = 0;
            while q.pop_next().is_some() {
                n += 1;
            }
            black_box(n)
        });
    }
}
