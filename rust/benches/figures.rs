//! End-to-end benchmark rows — one per paper table/figure family — each
//! timing a scaled-down regeneration of that experiment on the simulation
//! backend. (`cargo run --release -- figures all` produces the full-size
//! CSVs; these rows track the harness cost and guard against regressions
//! in the end-to-end path.)

use hygen::baselines::{SimSetup, System};
use hygen::coordinator::queues::OfflinePolicy;
use hygen::sim::costmodel::CostModel;
use hygen::sim::profile_and_fit;
use hygen::util::bench::{black_box, Bencher};
use hygen::workload::azure::{self, AzureTraceConfig};
use hygen::workload::datasets::{self, Dataset};
use hygen::workload::mooncake::{self, MooncakeTraceConfig};

fn main() {
    let mut b = Bencher::new();

    // fig1/13 — trace synthesis
    b.bench("fig1/azure 1h trace synthesis", || {
        black_box(
            azure::generate(&AzureTraceConfig::default(), 0).len(),
        )
    });
    b.bench("fig13/mooncake 1h trace synthesis", || {
        black_box(mooncake::generate(&MooncakeTraceConfig::default(), 0).len())
    });

    // fig5/16 — predictor profiling + fit
    b.bench("fig5/profile+fit 20k samples", || {
        black_box(profile_and_fit(&CostModel::a100_llama7b(), 0, 20_000).2)
    });

    // fig3/4/7..17 — one end-to-end co-location run (60 s horizon)
    let setup = SimSetup::new(CostModel::a100_llama7b());
    let online = azure::generate(
        &AzureTraceConfig { duration_s: 45.0, mean_qps: 2.0, ..Default::default() },
        0,
    );
    let offline = datasets::generate(Dataset::ArxivSummarization, 300, 0);
    let workload = online.clone().merged(offline.clone());
    b.bench("fig3/hygen 60s co-location run", || {
        black_box(
            setup
                .run(System::HyGen { latency_budget_ms: 30.0 }, &workload, 60.0)
                .unwrap()
                .report
                .total_tps,
        )
    });
    b.bench("fig4/sarathi++ 60s run", || {
        black_box(setup.run(System::SarathiPlusPlus, &workload, 60.0).unwrap().report.total_tps)
    });
    b.bench("fig4/sarathi-offline 60s run", || {
        black_box(
            setup
                .run_draining(System::SarathiOffline { chunk_tokens: 1024 }, &offline, 60.0)
                .unwrap()
                .report
                .offline_tps,
        )
    });

    // fig6 — PSM policy run on prefix-heavy offline
    let mmlu = datasets::generate(Dataset::Mmlu, 1500, 0);
    for policy in [OfflinePolicy::Fcfs, OfflinePolicy::Psm] {
        let s = SimSetup::new(CostModel::a100_llama7b()).with_policy(policy);
        b.bench(&format!("fig6/mmlu 60s run [{}]", policy.name()), || {
            black_box(
                s.run_draining(System::HyGen { latency_budget_ms: 60.0 }, &mmlu, 60.0)
                    .unwrap()
                    .report
                    .offline_qps,
            )
        });
    }

    // fig9 — TP/PP cost model
    b.bench("fig9/yi34b tp2pp2 60s run", || {
        let s = SimSetup::new(CostModel::a40x4_yi34b_tp2pp2());
        black_box(
            s.run(System::HyGen { latency_budget_ms: 80.0 }, &workload, 60.0)
                .unwrap()
                .report
                .total_tps,
        )
    });
}
