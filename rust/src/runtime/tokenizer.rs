//! Byte-level tokenizer for the tiny AOT model (vocab = 256 = raw bytes).
//!
//! Deliberately trivial: the reproduction's contribution is scheduling,
//! not tokenization — byte-level keeps the Python and Rust sides exactly
//! consistent with zero shared vocabulary files.

/// Encode UTF-8 text as byte token ids.
pub fn encode(text: &str) -> Vec<u32> {
    text.bytes().map(|b| b as u32).collect()
}

/// Decode byte token ids back to text (lossy on invalid UTF-8).
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "Summarize: the quick brown fox.";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo → wörld";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn out_of_range_ids_masked() {
        assert_eq!(decode(&[72, 105, 256 + 33]), "Hi!"); // 289 & 0xFF = '!'
    }

    #[test]
    fn empty() {
        assert_eq!(encode(""), Vec::<u32>::new());
        assert_eq!(decode(&[]), "");
    }
}
