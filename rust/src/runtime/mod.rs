//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `params.bin` + `manifest.json`, produced once by `make artifacts`) and
//! executes the Layer-2 step function on the PJRT CPU client via the `xla`
//! crate. Python never runs here — this is the request path.
//!
//! Everything that touches the `xla` crate is gated behind the `pjrt`
//! cargo feature; the default build ships only [`tokenizer`] and
//! [`ModelDims`] so the crate compiles on machines without a PJRT plugin
//! (the calibrated `sim` backend is the default execution path).
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py).

pub mod tokenizer;

#[cfg(feature = "pjrt")]
use crate::util::json::Json;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

/// Model dimensions from `manifest.json` (must match the AOT'd weights).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub num_params: usize,
}

/// One compiled (batch-slots, chunk-tokens) shape bucket.
#[cfg(feature = "pjrt")]
struct Bucket {
    exe: xla::PjRtLoadedExecutable,
}

/// The loaded runtime: PJRT client + per-bucket executables + weights.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub dims: ModelDims,
    params: xla::Literal,
    buckets: HashMap<(usize, usize), Bucket>,
    bucket_keys: Vec<(usize, usize)>,
}

/// Output of one step execution.
#[cfg(feature = "pjrt")]
pub struct StepOutput {
    /// Row-major logits `[B, C, V]`.
    pub logits: Vec<f32>,
    pub b: usize,
    pub c: usize,
    /// Updated caches, to be fed to the next step.
    pub cache_k: xla::Literal,
    pub cache_v: xla::Literal,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Load every artifact listed in `<dir>/manifest.json` and compile it
    /// on a fresh PJRT CPU client.
    pub fn load(dir: &str) -> Result<PjrtRuntime> {
        let dir = Path::new(dir);
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let manifest = Json::parse(&manifest_text).map_err(|e| anyhow!("manifest: {e}"))?;
        let m = manifest.get("model");
        let geti = |k: &str| -> Result<usize> {
            m.get(k)
                .as_u64()
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("manifest model.{k} missing"))
        };
        let dims = ModelDims {
            vocab: geti("vocab")?,
            d_model: geti("d_model")?,
            n_heads: geti("n_heads")?,
            head_dim: geti("head_dim")?,
            n_layers: geti("n_layers")?,
            d_ff: geti("d_ff")?,
            max_seq: geti("max_seq")?,
            num_params: geti("num_params")?,
        };

        let params_bytes = std::fs::read(dir.join("params.bin"))
            .with_context(|| "reading params.bin")?;
        if params_bytes.len() != dims.num_params * 4 {
            bail!(
                "params.bin has {} bytes, manifest says {} f32",
                params_bytes.len(),
                dims.num_params
            );
        }
        let params_f32: Vec<f32> = params_bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let params = xla::Literal::vec1(&params_f32);

        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut buckets = HashMap::new();
        let mut bucket_keys = Vec::new();
        let arts = manifest
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest.artifacts missing"))?;
        for a in arts {
            let b = a.get("batch").as_u64().ok_or_else(|| anyhow!("artifact.batch"))? as usize;
            let c = a.get("chunk").as_u64().ok_or_else(|| anyhow!("artifact.chunk"))? as usize;
            let file = a.get("file").as_str().ok_or_else(|| anyhow!("artifact.file"))?;
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compile {file}: {e:?}"))?;
            buckets.insert((b, c), Bucket { exe });
            bucket_keys.push((b, c));
        }
        if buckets.is_empty() {
            bail!("no artifacts in manifest");
        }
        bucket_keys.sort();
        Ok(PjrtRuntime { client, dims, params, buckets, bucket_keys })
    }

    /// Available (B, C) shape buckets, sorted.
    pub fn buckets(&self) -> &[(usize, usize)] {
        &self.bucket_keys
    }

    /// Smallest bucket with `batch >= b` and `chunk >= c` (padding target).
    pub fn pick_bucket(&self, b: usize, c: usize) -> Option<(usize, usize)> {
        self.bucket_keys
            .iter()
            .copied()
            .filter(|&(bb, cc)| bb >= b && cc >= c)
            .min_by_key(|&(bb, cc)| bb * 1_000_000 + cc)
    }

    /// Fresh zeroed KV caches for bucket batch size `b`.
    pub fn empty_caches(&self, b: usize) -> (xla::Literal, xla::Literal) {
        let d = &self.dims;
        let n = d.n_layers * b * d.max_seq * d.n_heads * d.head_dim;
        let zeros = vec![0f32; n];
        let shape = [
            d.n_layers as i64,
            b as i64,
            d.max_seq as i64,
            d.n_heads as i64,
            d.head_dim as i64,
        ];
        let k = xla::Literal::vec1(&zeros).reshape(&shape).expect("shape");
        let v = xla::Literal::vec1(&zeros).reshape(&shape).expect("shape");
        (k, v)
    }

    /// Execute one step on bucket `(b, c)`.
    ///
    /// * `tokens` — `b*c` i32 token ids, row-major (padding arbitrary).
    /// * `pos_base` — `b` i32 first-new-token positions. Callers must keep
    ///   every slot's live rows `<= max_seq - c` so padding writes cannot
    ///   clamp into live data (see pjrt_backend).
    pub fn step(
        &self,
        b: usize,
        c: usize,
        tokens: &[i32],
        pos_base: &[i32],
        cache_k: &xla::Literal,
        cache_v: &xla::Literal,
    ) -> Result<StepOutput> {
        let bucket = self
            .buckets
            .get(&(b, c))
            .ok_or_else(|| anyhow!("no artifact for bucket ({b},{c})"))?;
        if tokens.len() != b * c || pos_base.len() != b {
            bail!("bad step inputs: tokens {} pos {}", tokens.len(), pos_base.len());
        }
        for (slot, &p) in pos_base.iter().enumerate() {
            if p < 0 || p as usize + c > self.dims.max_seq {
                bail!("slot {slot}: pos_base {p} + chunk {c} exceeds max_seq {}", self.dims.max_seq);
            }
        }
        let tokens_lit = xla::Literal::vec1(tokens).reshape(&[b as i64, c as i64])?;
        let pos_lit = xla::Literal::vec1(pos_base);
        let args: [&xla::Literal; 5] = [&self.params, &tokens_lit, &pos_lit, cache_k, cache_v];
        let result = bucket.exe.execute::<&xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let (logits_lit, ck, cv) = out.to_tuple3()?;
        let logits = logits_lit.to_vec::<f32>()?;
        debug_assert_eq!(logits.len(), b * c * self.dims.vocab);
        Ok(StepOutput { logits, b, c, cache_k: ck, cache_v: cv })
    }

    /// Greedy argmax over the logits row `(slot, row)`.
    pub fn argmax(&self, out: &StepOutput, slot: usize, row: usize) -> u32 {
        let v = self.dims.vocab;
        let base = (slot * out.c + row) * v;
        let row = &out.logits[base..base + v];
        let mut best = 0usize;
        let mut bestv = f32::NEG_INFINITY;
        for (i, &x) in row.iter().enumerate() {
            if x > bestv {
                bestv = x;
                best = i;
            }
        }
        best as u32
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need artifacts live in rust/tests/integration.rs
    // (they require `make artifacts` and a PJRT client). Here: pure logic.

    #[test]
    fn pick_bucket_logic() {
        // Build the lookup structure without a client by testing the
        // selection math on a bare sorted key list.
        let keys = vec![(1, 1), (1, 32), (4, 8), (8, 1), (8, 32)];
        let pick = |b: usize, c: usize| {
            keys.iter()
                .copied()
                .filter(|&(bb, cc)| bb >= b && cc >= c)
                .min_by_key(|&(bb, cc)| bb * 1_000_000 + cc)
        };
        assert_eq!(pick(1, 1), Some((1, 1)));
        assert_eq!(pick(2, 4), Some((4, 8)));
        assert_eq!(pick(5, 1), Some((8, 1)));
        assert_eq!(pick(8, 9), Some((8, 32)));
        assert_eq!(pick(9, 1), None);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn load_fails_cleanly_without_artifacts() {
        let err = match super::PjrtRuntime::load("/nonexistent-dir") {
            Ok(_) => panic!("load must fail"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("manifest"), "{err:#}");
    }
}
