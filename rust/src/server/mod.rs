//! HTTP serving front end: the leader process of a HyGen deployment.
//!
//! Architecture (the paper's Fig. 2, generalized to N replicas):
//! connection handling on a thread pool; one *engine thread per replica*
//! owning that replica's scheduler, queues, and backend
//! ([`crate::cluster::replica`]); `std::sync::mpsc` message queues
//! between them — the same message-passing structure as the paper's
//! asynchronous two-queue workflow (Appendix A.1). A
//! [`Router`](crate::cluster::router::Router) picks the replica for every
//! submission from the replicas' published census snapshots.
//!
//! API:
//! * `POST /v1/completions` `{"prompt": str, "max_tokens": n,
//!   "class": "online"|"offline"}` → `{"text", "tokens", "latency_ms", ...}`
//! * `GET /metrics` → serving report (JSON). Single replica: the flat
//!   per-engine report. Multi-replica: `{"replicas": [...], "aggregate"}`
//!   where additive fields are summed and latency percentiles take the
//!   worst replica (the cluster meets an SLO only if its slowest replica
//!   does).
//! * `GET /health` → `{"status":"ok"}`
//!
//! Shutdown drains: accepted requests keep executing until they finish or
//! the drain deadline passes (then they fail with 503), instead of being
//! dropped mid-flight.

pub mod http;

use crate::cluster::replica::{Job, ReplicaShared, Supervisor, SupervisorConfig};
use crate::cluster::router::{Router, RouterPolicy};
use crate::coordinator::classes::ClassRegistry;
use crate::coordinator::request::Class;
use crate::engine::{Engine, ExecutionBackend};
use crate::runtime::tokenizer;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use http::{read_request, write_response};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub use crate::cluster::replica::Completion;

/// Default graceful-drain deadline on shutdown.
pub const DEFAULT_DRAIN: Duration = Duration::from_secs(5);

/// Shared front-end state: the replica ports, the routing policy, and
/// the SLO-class registry (resolves request `class` names and decides
/// interactive-vs-elastic routing).
struct ClusterState {
    replicas: Vec<ReplicaPort>,
    router: Mutex<Box<dyn Router>>,
    registry: Arc<ClassRegistry>,
}

struct ReplicaPort {
    tx: Sender<Job>,
    shared: Arc<ReplicaShared>,
}

impl ClusterState {
    fn all_failed(&self) -> bool {
        self.replicas.iter().all(|r| r.shared.failed.load(Ordering::SeqCst))
    }
}

pub struct Server {
    pub addr: std::net::SocketAddr,
    /// Engine replicas behind this server.
    pub replicas: usize,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    replica_handles: Vec<Supervisor>,
}

impl Server {
    /// Start a classic single-engine server (round-robin over one
    /// replica). The engine is *constructed on* a dedicated engine thread
    /// by `factory` — PJRT handles are not `Send`, so they must never
    /// cross threads; handlers talk to the engine thread via a message
    /// queue only. The factory must be callable repeatedly: the replica's
    /// supervisor re-runs it to restart a failed engine.
    pub fn start<B, F>(bind: &str, factory: F, workers: usize) -> anyhow::Result<Server>
    where
        B: ExecutionBackend + 'static,
        F: Fn() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        Self::start_cluster(
            bind,
            vec![factory],
            RouterPolicy::RoundRobin.build(),
            workers,
            DEFAULT_DRAIN,
        )
    }

    /// Start serving with one engine thread per factory and `router`
    /// deciding which replica serves each submission, under the default
    /// two-class registry and restart policy.
    pub fn start_cluster<B, F>(
        bind: &str,
        factories: Vec<F>,
        router: Box<dyn Router>,
        workers: usize,
        drain: Duration,
    ) -> anyhow::Result<Server>
    where
        B: ExecutionBackend + 'static,
        F: Fn() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        Self::start_cluster_with_registry(
            bind,
            factories,
            router,
            workers,
            drain,
            Arc::new(ClassRegistry::default_two()),
            SupervisorConfig::default(),
        )
    }

    /// Start serving under an explicit SLO-class registry. Submissions
    /// carry a `class` name resolved against it; each engine factory must
    /// build its [`EngineState`](crate::coordinator::state::EngineState)
    /// over the *same* registry or class-indexed enqueues will be
    /// rejected. Each replica runs under a [`Supervisor`] with the given
    /// restart policy: a persistently failing engine is rebuilt by its
    /// factory with capped exponential backoff, and the replica publishes
    /// itself `failed` (routers skip it) until the restart lands.
    #[allow(clippy::too_many_arguments)]
    pub fn start_cluster_with_registry<B, F>(
        bind: &str,
        factories: Vec<F>,
        router: Box<dyn Router>,
        workers: usize,
        drain: Duration,
        registry: Arc<ClassRegistry>,
        supervisor: SupervisorConfig,
    ) -> anyhow::Result<Server>
    where
        B: ExecutionBackend + 'static,
        F: Fn() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        anyhow::ensure!(!factories.is_empty(), "server needs at least one replica");
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        let mut replica_handles = Vec::with_capacity(factories.len());
        for (i, factory) in factories.into_iter().enumerate() {
            let spawned = Supervisor::spawn(
                format!("hygen-engine-{i}"),
                factory,
                Arc::clone(&stop),
                drain,
                supervisor,
            );
            match spawned {
                Ok(r) => replica_handles.push(r),
                Err(e) => {
                    // Tear down the replicas that did start.
                    stop.store(true, Ordering::SeqCst);
                    for r in &mut replica_handles {
                        r.join();
                    }
                    return Err(e.context(format!("replica {i} failed to start")));
                }
            }
        }
        let state = Arc::new(ClusterState {
            replicas: replica_handles
                .iter()
                .map(|r| ReplicaPort { tx: r.tx.clone(), shared: Arc::clone(&r.shared) })
                .collect(),
            router: Mutex::new(router),
            registry,
        });

        let accept_thread = {
            let stop = Arc::clone(&stop);
            let pool = ThreadPool::new(workers);
            std::thread::Builder::new().name("hygen-accept".into()).spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            let state = Arc::clone(&state);
                            pool.execute(move || {
                                let _ = handle_connection(&mut stream, &state);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                // pool drops here, joining workers; the workers' pending
                // replies are produced by the replica threads' drain.
            })?
        };

        Ok(Server {
            addr,
            replicas: replica_handles.len(),
            stop,
            accept_thread: Some(accept_thread),
            replica_handles,
        })
    }

    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for r in &mut self.replica_handles {
            r.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // A dropped-without-shutdown server must not leak the accept and
        // engine threads (and with them the bound port) — join like
        // `shutdown()` does.
        self.stop_and_join();
    }
}

/// Additive `/metrics` fields summed across replicas; the remaining
/// latency fields take the per-replica worst (see the module docs).
const SUM_FIELDS: [&str; 7] = [
    "online_finished",
    "offline_finished",
    "online_tps",
    "offline_tps",
    "total_tps",
    "online_qps",
    "offline_qps",
];

/// `/metrics` fields where the aggregate is the worst replica: latency
/// percentiles/means (an SLO holds cluster-wide only if it holds on the
/// slowest replica) and the observation window.
const WORST_FIELDS: [&str; 7] = [
    "mean_ttft_ms",
    "p50_ttft_ms",
    "p99_ttft_ms",
    "mean_tbt_ms",
    "p50_tbt_ms",
    "p99_tbt_ms",
    "duration_s",
];

/// Per-class block fields that sum across replicas; the rest of the
/// block (latency means/percentiles) takes the per-replica worst.
const CLASS_SUM_FIELDS: [&str; 3] = ["finished", "tps", "qps"];
const CLASS_WORST_FIELDS: [&str; 6] = [
    "mean_ttft_ms",
    "p50_ttft_ms",
    "p99_ttft_ms",
    "mean_tbt_ms",
    "p50_tbt_ms",
    "p99_tbt_ms",
];

/// Aggregate the replicas' `classes` arrays element-wise (class `i` with
/// class `i`): additive fields summed, latency fields worst-replica.
fn aggregate_class_blocks(reports: &[Json]) -> Json {
    let n = reports
        .iter()
        .filter_map(|r| r.get("classes").as_arr().map(|a| a.len()))
        .max()
        .unwrap_or(0);
    let block = |r: &Json, i: usize| r.get("classes").as_arr().and_then(|a| a.get(i).cloned());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let blocks: Vec<Json> = reports.iter().filter_map(|r| block(r, i)).collect();
        let mut pairs: Vec<(&str, Json)> = vec![("class", Json::from(i))];
        for field in CLASS_SUM_FIELDS {
            let total: f64 = blocks.iter().filter_map(|b| b.get(field).as_f64()).sum();
            pairs.push((field, Json::from(total)));
        }
        for field in CLASS_WORST_FIELDS {
            let worst =
                blocks.iter().filter_map(|b| b.get(field).as_f64()).fold(0.0f64, f64::max);
            pairs.push((field, Json::from(worst)));
        }
        out.push(Json::obj(pairs));
    }
    Json::Arr(out)
}

/// Aggregate per-replica report JSONs into the multi-replica `/metrics`
/// payload. `fleet` carries supervision counters (restarts, generations)
/// that live beside the engine reports rather than inside them.
fn aggregate_metrics(reports: &[Json], fleet: Vec<(&'static str, Json)>) -> Json {
    let mut agg: Vec<(&str, Json)> = Vec::new();
    for field in SUM_FIELDS {
        let total: f64 = reports.iter().filter_map(|r| r.get(field).as_f64()).sum();
        agg.push((field, Json::from(total)));
    }
    for field in WORST_FIELDS {
        let worst = reports
            .iter()
            .filter_map(|r| r.get(field).as_f64())
            .fold(0.0f64, f64::max);
        agg.push((field, Json::from(worst)));
    }
    agg.push(("classes", aggregate_class_blocks(reports)));
    let mut top = vec![
        ("replicas", Json::Arr(reports.to_vec())),
        ("aggregate", Json::obj(agg)),
    ];
    top.extend(fleet);
    Json::obj(top)
}

/// Supervision counters for the multi-replica `/metrics` payload:
/// per-replica restart attempts and engine generations, plus the fleet
/// total (these are front-end state, not engine report fields — the
/// aggregate drift guard stays exact).
fn fleet_fields(state: &ClusterState) -> Vec<(&'static str, Json)> {
    let restarts: Vec<usize> = state
        .replicas
        .iter()
        .map(|r| r.shared.restarts.load(Ordering::Relaxed))
        .collect();
    let generations: Vec<Json> = state
        .replicas
        .iter()
        .map(|r| Json::from(r.shared.generation.load(Ordering::Relaxed)))
        .collect();
    vec![
        ("total_restarts", Json::from(restarts.iter().sum::<usize>())),
        ("restarts", Json::Arr(restarts.into_iter().map(Json::from).collect())),
        ("generations", Json::Arr(generations)),
    ]
}

fn handle_connection(
    stream: &mut std::net::TcpStream,
    state: &ClusterState,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let req = match read_request(stream) {
        Ok(r) => r,
        Err(_) => return write_response(stream, 400, "application/json", b"{\"error\":\"bad request\"}"),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => write_response(stream, 200, "application/json", b"{\"status\":\"ok\"}"),
        ("GET", "/metrics") => {
            let body = if state.replicas.len() == 1 {
                let body = state.replicas[0].shared.metrics_json.lock().unwrap().clone();
                if body.is_empty() {
                    "{}".to_string()
                } else {
                    body
                }
            } else {
                let reports: Vec<Json> = state
                    .replicas
                    .iter()
                    .map(|r| {
                        let text = r.shared.metrics_json.lock().unwrap().clone();
                        Json::parse(&text).unwrap_or(Json::Obj(Default::default()))
                    })
                    .collect();
                aggregate_metrics(&reports, fleet_fields(state)).to_pretty()
            };
            write_response(stream, 200, "application/json", body.as_bytes())
        }
        ("POST", "/v1/completions") => {
            if state.all_failed() {
                return write_response(
                    stream,
                    503,
                    "application/json",
                    b"{\"error\":\"backend failed\"}",
                );
            }
            let parsed = Json::parse(&String::from_utf8_lossy(&req.body));
            let Ok(j) = parsed else {
                return write_response(stream, 400, "application/json", b"{\"error\":\"bad json\"}");
            };
            let Some(prompt) = j.get("prompt").as_str() else {
                return write_response(stream, 400, "application/json", b"{\"error\":\"missing prompt\"}");
            };
            let max_tokens = j.get("max_tokens").as_u64().unwrap_or(16) as usize;
            // Resolve the class name against the registry (default:
            // the flagship class). Unknown names are an explicit client
            // error, not a silent interactive upgrade.
            let class = match j.get("class").as_str() {
                None => Class::ONLINE,
                Some(name) => match state.registry.by_name(name) {
                    Some(c) => c,
                    None => {
                        return write_response(
                            stream,
                            400,
                            "application/json",
                            b"{\"error\":\"unknown class\"}",
                        )
                    }
                },
            };
            // Route from the published census snapshots. Elastic
            // submissions need a reply channel too, so a deferring router
            // falls back to its interactive placement. A single replica
            // skips the snapshot copies and the router lock entirely —
            // the classic one-engine server pays no routing overhead.
            let target = if state.replicas.len() == 1 {
                0
            } else {
                let snaps: Vec<_> =
                    state.replicas.iter().map(|r| r.shared.routing_snapshot()).collect();
                let mut router = state.router.lock().unwrap();
                let i = if state.registry.spec(class).elastic() {
                    router
                        .route_offline(&snaps)
                        .unwrap_or_else(|| router.route_online(&snaps))
                } else {
                    router.route_online(&snaps)
                };
                i.min(state.replicas.len() - 1)
            };
            let port = &state.replicas[target];
            if port.shared.failed.load(Ordering::SeqCst) {
                return write_response(
                    stream,
                    503,
                    "application/json",
                    b"{\"error\":\"backend failed\"}",
                );
            }
            let (reply_tx, reply_rx) = channel();
            let job = Job {
                prompt: tokenizer::encode(prompt),
                max_tokens: max_tokens.clamp(1, 1024),
                class,
                reply: reply_tx,
            };
            port.shared.note_submitted(class);
            if port.tx.send(job).is_err() {
                // The replica thread is gone (panic or exit) without
                // flagging itself: mark it failed so routers stop
                // selecting it instead of 503-ing every routed request
                // while healthy replicas idle.
                port.shared.failed.store(true, Ordering::SeqCst);
                return write_response(stream, 503, "application/json", b"{\"error\":\"engine down\"}");
            }
            match reply_rx.recv_timeout(Duration::from_secs(120)) {
                Ok(Ok(c)) => {
                    let body = Json::obj(vec![
                        ("id", c.id.into()),
                        ("replica", target.into()),
                        ("text", c.text.into()),
                        ("num_tokens", c.tokens.len().into()),
                        ("latency_ms", c.latency_ms.into()),
                    ]);
                    write_response(stream, 200, "application/json", body.to_string().as_bytes())
                }
                Ok(Err(e)) => {
                    let body = format!("{{\"error\":\"{}\"}}", e.message());
                    write_response(stream, 503, "application/json", body.as_bytes())
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // The replica thread exited (shutdown race): that is
                    // an explicit refusal, not a request timeout.
                    write_response(
                        stream,
                        503,
                        "application/json",
                        b"{\"error\":\"server stopping\"}",
                    )
                }
                Err(RecvTimeoutError::Timeout) => {
                    write_response(stream, 500, "application/json", b"{\"error\":\"timeout\"}")
                }
            }
        }
        ("POST", _) | ("GET", _) => write_response(stream, 404, "application/json", b"{\"error\":\"not found\"}"),
        _ => write_response(stream, 405, "application/json", b"{\"error\":\"method\"}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::replica::JobError;
    use crate::coordinator::batch::Batch;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
    use crate::coordinator::state::EngineState;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    /// Echo-ish backend: generates deterministic tokens without PJRT.
    struct EchoBackend;
    impl ExecutionBackend for EchoBackend {
        fn execute(&mut self, batch: &Batch, state: &mut EngineState) -> anyhow::Result<f64> {
            for e in &batch.entries {
                let req = state.req_mut(e.id);
                let emit = if e.is_prefill {
                    req.prefilled + e.n_tokens >= req.prompt_len
                } else {
                    true
                };
                if emit {
                    let n = req.output_tokens.len();
                    let tok = req.prompt.get(n).copied().unwrap_or(b'!' as u32);
                    req.output_tokens.push(tok);
                }
            }
            Ok(0.0005)
        }
    }

    fn echo_engine() -> anyhow::Result<Engine<EchoBackend>> {
        let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
        let sched = HybridScheduler::new(
            SchedulerConfig { latency_budget_ms: None, ..Default::default() },
            LatencyPredictor::default_seed(),
        );
        Ok(Engine::new(sched, state, EchoBackend))
    }

    fn http(addr: std::net::SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn start_echo_server() -> Server {
        Server::start("127.0.0.1:0", echo_engine, 2).unwrap()
    }

    fn completions_request_class(prompt: &str, class: &str) -> String {
        let body = format!(r#"{{"prompt": "{prompt}", "max_tokens": 3, "class": "{class}"}}"#);
        format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
    }

    #[test]
    fn health_and_metrics_endpoints() {
        let server = start_echo_server();
        let r = http(server.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""), "{r}");
        let r = http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"));
        server.shutdown();
    }

    #[test]
    fn completion_roundtrip() {
        let server = start_echo_server();
        let body = r#"{"prompt": "abcd", "max_tokens": 3, "class": "online"}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let r = http(server.addr, &raw);
        assert!(r.contains("200 OK"), "{r}");
        // Echo backend repeats the prompt: 3 tokens -> "abc"
        assert!(r.contains("\"text\":\"abc\""), "{r}");
        assert!(r.contains("\"num_tokens\":3"), "{r}");
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let server = start_echo_server();
        let addr = server.addr;
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let body = format!(r#"{{"prompt": "req{i}xx", "max_tokens": 2}}"#);
                    let raw = format!(
                        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                        body.len(),
                        body
                    );
                    http(addr, &raw)
                })
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert!(r.contains("200 OK"), "{r}");
        }
        server.shutdown();
    }

    #[test]
    fn multi_replica_serves_and_aggregates_metrics() {
        let server = Server::start_cluster(
            "127.0.0.1:0",
            vec![echo_engine, echo_engine, echo_engine],
            RouterPolicy::RoundRobin.build(),
            4,
            DEFAULT_DRAIN,
        )
        .unwrap();
        assert_eq!(server.replicas, 3);
        let addr = server.addr;
        let handles: Vec<_> = (0..9)
            .map(|i| {
                std::thread::spawn(move || {
                    http(addr, &completions_request_class(&format!("req{i}"), "online"))
                })
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert!(r.contains("200 OK"), "{r}");
            assert!(r.contains("\"replica\":"), "{r}");
        }
        // Offline submissions work through the fallback placement too.
        let r = http(addr, &completions_request_class("zzzz", "offline"));
        assert!(r.contains("200 OK"), "{r}");
        // Wait out a publish interval so every replica has a report up.
        std::thread::sleep(Duration::from_millis(450));
        let m = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("200 OK"), "{m}");
        assert!(m.contains("\"aggregate\""), "{m}");
        assert!(m.contains("\"replicas\""), "{m}");
        assert!(m.contains("\"p50_tbt_ms\""), "{m}");
        // Fleet supervision counters ride beside the engine reports: a
        // healthy cluster shows zero restarts and generation-0 replicas.
        assert!(m.contains("\"total_restarts\""), "{m}");
        assert!(m.contains("\"restarts\""), "{m}");
        assert!(m.contains("\"generations\""), "{m}");
        server.shutdown();
    }

    /// Backend that takes real wallclock per step, so in-flight work
    /// straddles `shutdown()`.
    struct SlowBackend;
    impl ExecutionBackend for SlowBackend {
        fn execute(&mut self, batch: &Batch, state: &mut EngineState) -> anyhow::Result<f64> {
            std::thread::sleep(Duration::from_millis(3));
            for e in &batch.entries {
                let req = state.req_mut(e.id);
                let emit =
                    if e.is_prefill { req.prefilled + e.n_tokens >= req.prompt_len } else { true };
                if emit {
                    req.output_tokens.push(b'z' as u32);
                }
            }
            Ok(0.003)
        }
    }

    #[test]
    fn shutdown_drains_inflight_requests() {
        let server = Server::start_cluster(
            "127.0.0.1:0",
            vec![|| {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, SlowBackend))
            }],
            RouterPolicy::SloHeadroom.build(),
            2,
            DEFAULT_DRAIN,
        )
        .unwrap();
        let addr = server.addr;
        // ~30 decode steps x 3 ms: the request is still in flight when
        // shutdown starts.
        let body = r#"{"prompt": "abcd", "max_tokens": 30}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let client = std::thread::spawn(move || http(addr, &raw));
        std::thread::sleep(Duration::from_millis(25));
        server.shutdown();
        let r = client.join().unwrap();
        assert!(r.contains("200 OK"), "accepted request must complete across stop(): {r}");
        assert!(r.contains("\"num_tokens\":30"), "{r}");
    }

    #[test]
    fn drain_deadline_fails_stragglers_instead_of_hanging() {
        let server = Server::start_cluster(
            "127.0.0.1:0",
            vec![|| {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, SlowBackend))
            }],
            RouterPolicy::RoundRobin.build(),
            2,
            Duration::from_millis(40),
        )
        .unwrap();
        let addr = server.addr;
        // 1024 decode steps x 3 ms >> the 40 ms drain deadline.
        let body = r#"{"prompt": "abcd", "max_tokens": 1024}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let client = std::thread::spawn(move || http(addr, &raw));
        std::thread::sleep(Duration::from_millis(25));
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5), "drain deadline must bound shutdown");
        let r = client.join().unwrap();
        assert!(r.contains("503"), "straggler fails explicitly: {r}");
        assert!(r.contains("server stopping"), "{r}");
    }

    /// Backend that fails every execution (persistent hardware fault).
    struct FailBackend;
    impl ExecutionBackend for FailBackend {
        fn execute(&mut self, _batch: &Batch, _state: &mut EngineState) -> anyhow::Result<f64> {
            anyhow::bail!("injected backend failure")
        }
    }

    fn completions_request(prompt: &str) -> String {
        let body = format!(r#"{{"prompt": "{prompt}", "max_tokens": 2}}"#);
        format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
    }

    #[test]
    fn failing_backend_errors_requests_without_livelock() {
        let server = Server::start(
            "127.0.0.1:0",
            || {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, FailBackend))
            },
            2,
        )
        .unwrap();
        // First request reaches the engine, the backend fails, and the
        // inflight reply channel must carry the error back promptly — not
        // spin until the 120 s handler timeout.
        let t0 = std::time::Instant::now();
        let r = http(server.addr, &completions_request("abcd"));
        assert!(r.contains("503"), "{r}");
        assert!(r.contains("backend failed"), "{r}");
        assert!(t0.elapsed() < Duration::from_secs(10), "reply was not prompt");
        // The engine aborted its work: the process stays responsive and
        // subsequent completions are refused with 503 up front.
        let r = http(server.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""), "{r}");
        let r = http(server.addr, &completions_request("efgh"));
        assert!(r.contains("503"), "{r}");
        let r = http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        server.shutdown();
    }

    #[test]
    fn drop_joins_threads_and_frees_port() {
        let server = start_echo_server();
        let addr = server.addr;
        drop(server); // no explicit shutdown()
        // Drop must join the accept thread and release the listener: the
        // port is immediately rebindable and nothing serves on it.
        let listener = std::net::TcpListener::bind(addr)
            .expect("port still bound after Server::drop");
        drop(listener);
    }

    #[test]
    fn rejects_bad_requests() {
        let server = start_echo_server();
        let r = http(server.addr, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"));
        let raw = "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\nnotjson";
        let r = http(server.addr, raw);
        assert!(r.contains("400"), "{r}");
        let raw = "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 2\r\n\r\n{}";
        let r = http(server.addr, raw);
        assert!(r.contains("missing prompt"), "{r}");
        server.shutdown();
    }

    #[test]
    fn unknown_class_name_is_a_client_error() {
        let server = start_echo_server();
        let r = http(server.addr, &completions_request_class("abcd", "mystery"));
        assert!(r.contains("400"), "{r}");
        assert!(r.contains("unknown class"), "{r}");
        // Registry names keep working.
        let r = http(server.addr, &completions_request_class("abcd", "offline"));
        assert!(r.contains("200 OK"), "{r}");
        server.shutdown();
    }

    #[test]
    fn aggregate_merges_per_class_blocks_element_wise() {
        let a = Json::parse(
            r#"{"total_tps": 1.0, "classes": [
                {"class": 0, "finished": 2, "tps": 5.0, "p99_ttft_ms": 10.0},
                {"class": 1, "finished": 1, "tps": 3.0, "p99_ttft_ms": 0.0}
            ]}"#,
        )
        .unwrap();
        let b = Json::parse(
            r#"{"total_tps": 2.0, "classes": [
                {"class": 0, "finished": 4, "tps": 7.0, "p99_ttft_ms": 25.0}
            ]}"#,
        )
        .unwrap();
        let m = aggregate_metrics(&[a, b], Vec::new());
        let classes = m.get("aggregate").get("classes").as_arr().unwrap();
        assert_eq!(classes.len(), 2, "max class count across replicas");
        assert_eq!(classes[0].get("finished").as_f64(), Some(6.0), "additive summed");
        assert_eq!(classes[0].get("tps").as_f64(), Some(12.0));
        assert_eq!(classes[0].get("p99_ttft_ms").as_f64(), Some(25.0), "latency = worst");
        assert_eq!(classes[1].get("finished").as_f64(), Some(1.0), "missing block = absent");
    }

    #[test]
    fn aggregate_metrics_sums_and_takes_worst() {
        let a = Json::parse(
            r#"{"online_finished": 2, "total_tps": 10.5, "p99_tbt_ms": 12.0, "p50_ttft_ms": 3.0}"#,
        )
        .unwrap();
        let b = Json::parse(
            r#"{"online_finished": 3, "total_tps": 4.5, "p99_tbt_ms": 30.0, "p50_ttft_ms": 1.0}"#,
        )
        .unwrap();
        let m = aggregate_metrics(&[a, b], Vec::new());
        let agg = m.get("aggregate");
        assert_eq!(agg.get("online_finished").as_f64(), Some(5.0));
        assert_eq!(agg.get("total_tps").as_f64(), Some(15.0));
        assert_eq!(agg.get("p99_tbt_ms").as_f64(), Some(30.0));
        assert_eq!(agg.get("p50_ttft_ms").as_f64(), Some(3.0));
        assert_eq!(m.get("replicas").as_arr().map(|a| a.len()), Some(2));
    }

    #[test]
    fn aggregate_covers_every_report_field() {
        // Drift guard for the stringly-typed SUM_FIELDS/WORST_FIELDS
        // lists: every field Report serializes must appear in the
        // multi-replica aggregate (a new Report field that is added to
        // neither list fails here, not silently in production).
        let report = crate::coordinator::metrics::Metrics::new(1.0).report(Some(1.0)).to_json();
        let m = aggregate_metrics(&[report.clone(), report.clone()], Vec::new());
        let agg = m.get("aggregate").as_obj().unwrap();
        for key in report.as_obj().unwrap().keys() {
            assert!(agg.contains_key(key), "aggregate missing report field '{key}'");
        }
    }

    #[test]
    fn job_error_messages() {
        assert_eq!(JobError::BackendFailed.message(), "backend failed");
        assert_eq!(JobError::DrainTimeout.message(), "server stopping");
    }
}
