//! HTTP serving front end: the leader process of a HyGen instance.
//!
//! Architecture (the paper's Fig. 2, one instance): connection handling on
//! a thread pool; a single *engine thread* owning the scheduler, queues,
//! and backend; `std::sync::mpsc` message queues between them — the same
//! message-passing structure as the paper's asynchronous two-queue
//! workflow (Appendix A.1).
//!
//! API:
//! * `POST /v1/completions` `{"prompt": str, "max_tokens": n,
//!   "class": "online"|"offline"}` → `{"text", "tokens", "latency_ms", ...}`
//! * `GET /metrics` → aggregate serving report (JSON)
//! * `GET /health` → `{"status":"ok"}`

pub mod http;

use crate::coordinator::request::{Class, Request, RequestId};
use crate::engine::{Engine, ExecutionBackend};
use crate::runtime::tokenizer;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use http::{read_request, write_response};
use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A submission travelling from a connection handler to the engine thread.
struct Job {
    prompt: Vec<u32>,
    max_tokens: usize,
    class: Class,
    reply: Sender<Completion>,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub text: String,
    pub tokens: Vec<u32>,
    /// Negative = the request failed (backend error); see
    /// [`Completion::failed`].
    pub latency_ms: f64,
}

impl Completion {
    /// Error marker sent when the execution backend failed.
    fn failed() -> Completion {
        Completion { id: 0, text: String::new(), tokens: vec![], latency_ms: -1.0 }
    }

    fn is_failed(&self) -> bool {
        self.latency_ms < 0.0
    }
}

/// Shared server state published by the engine thread.
#[derive(Default)]
struct Shared {
    metrics_json: Mutex<String>,
    /// Set by the engine thread after a persistent backend failure: the
    /// engine aborted its work and new completions are refused with 503
    /// (health/metrics stay up for observability).
    engine_failed: AtomicBool,
}

pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    engine_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving on `bind`. The engine is *constructed on* a dedicated
    /// engine thread by `factory` — PJRT handles are not `Send`, so they
    /// must never cross threads; handlers talk to the engine thread via a
    /// message queue only.
    pub fn start<B, F>(bind: &str, factory: F, workers: usize) -> anyhow::Result<Server>
    where
        B: ExecutionBackend + 'static,
        F: FnOnce() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(Shared::default());
        let (tx, rx) = channel::<Job>();

        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let engine_thread = {
            let stop = Arc::clone(&stop);
            let shared = Arc::clone(&shared);
            std::thread::Builder::new().name("hygen-engine".into()).spawn(move || {
                let engine = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(engine, rx, stop, shared)
            })?
        };
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;

        let accept_thread = {
            let stop = Arc::clone(&stop);
            let shared = Arc::clone(&shared);
            let pool = ThreadPool::new(workers);
            std::thread::Builder::new().name("hygen-accept".into()).spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            let tx = tx.clone();
                            let shared = Arc::clone(&shared);
                            pool.execute(move || {
                                let _ = handle_connection(&mut stream, &tx, &shared);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                // pool drops here, joining workers
            })?
        };

        Ok(Server { addr, stop, accept_thread: Some(accept_thread), engine_thread: Some(engine_thread) })
    }

    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // A dropped-without-shutdown server must not leak the accept and
        // engine threads (and with them the bound port) — join like
        // `shutdown()` does.
        self.stop_and_join();
    }
}

fn engine_loop<B: ExecutionBackend>(
    mut engine: Engine<B>,
    rx: Receiver<Job>,
    stop: Arc<AtomicBool>,
    shared: Arc<Shared>,
) {
    let start = Instant::now();
    let mut inflight: HashMap<RequestId, (Sender<Completion>, Instant)> = HashMap::new();
    engine.state.keep_finished = true;
    let mut last_publish = Instant::now();
    while !stop.load(Ordering::SeqCst) {
        // ingest
        loop {
            match rx.try_recv() {
                Ok(job) => {
                    if shared.engine_failed.load(Ordering::SeqCst) {
                        // Backend already declared dead: refuse instead of
                        // queueing work that can never execute (jobs racing
                        // the handler's own engine_failed check land here).
                        let _ = job.reply.send(Completion::failed());
                        continue;
                    }
                    let id = engine.fresh_id();
                    let now = start.elapsed().as_secs_f64();
                    let req = Request::new(id, job.class, now, job.prompt.len(), job.max_tokens)
                        .with_prompt(job.prompt);
                    inflight.insert(id, (job.reply, Instant::now()));
                    engine.submit(req);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return,
            }
        }
        if engine.has_work() {
            match engine.step() {
                Err(_) => {
                    // Execution error: fail all inflight requests AND tear
                    // the engine's in-flight work down (release blocks,
                    // empty the queues/running sets). Leaving it intact
                    // re-schedules the same doomed batch every loop — a
                    // 100% CPU livelock with no reply channels left to
                    // observe it.
                    for (_, (reply, _)) in inflight.drain() {
                        let _ = reply.send(Completion::failed());
                    }
                    engine.abort_all();
                    shared.engine_failed.store(true, Ordering::SeqCst);
                }
                Ok(0) => {
                    // Work exists but nothing is schedulable right now
                    // (e.g. a queued prompt waiting on KV memory): back
                    // off instead of re-running the scheduler at 100% CPU
                    // until something changes.
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(_) => {}
            }
            // deliver completions
            for req in engine.state.finished.drain(..) {
                if let Some((reply, t0)) = inflight.remove(&req.id) {
                    let _ = reply.send(Completion {
                        id: req.id,
                        text: tokenizer::decode(&req.output_tokens),
                        tokens: req.output_tokens,
                        latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                    });
                }
            }
        } else {
            std::thread::sleep(Duration::from_millis(1));
        }
        if last_publish.elapsed() > Duration::from_millis(200) {
            let report = engine.metrics.report(Some(start.elapsed().as_secs_f64()));
            *shared.metrics_json.lock().unwrap() = report.to_json().to_pretty();
            last_publish = Instant::now();
        }
    }
}

fn handle_connection(
    stream: &mut std::net::TcpStream,
    tx: &Sender<Job>,
    shared: &Shared,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let req = match read_request(stream) {
        Ok(r) => r,
        Err(_) => return write_response(stream, 400, "application/json", b"{\"error\":\"bad request\"}"),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => write_response(stream, 200, "application/json", b"{\"status\":\"ok\"}"),
        ("GET", "/metrics") => {
            let body = shared.metrics_json.lock().unwrap().clone();
            let body = if body.is_empty() { "{}".to_string() } else { body };
            write_response(stream, 200, "application/json", body.as_bytes())
        }
        ("POST", "/v1/completions") => {
            if shared.engine_failed.load(Ordering::SeqCst) {
                return write_response(
                    stream,
                    503,
                    "application/json",
                    b"{\"error\":\"backend failed\"}",
                );
            }
            let parsed = Json::parse(&String::from_utf8_lossy(&req.body));
            let Ok(j) = parsed else {
                return write_response(stream, 400, "application/json", b"{\"error\":\"bad json\"}");
            };
            let Some(prompt) = j.get("prompt").as_str() else {
                return write_response(stream, 400, "application/json", b"{\"error\":\"missing prompt\"}");
            };
            let max_tokens = j.get("max_tokens").as_u64().unwrap_or(16) as usize;
            let class = match j.get("class").as_str().unwrap_or("online") {
                "offline" => Class::Offline,
                _ => Class::Online,
            };
            let (reply_tx, reply_rx) = channel();
            let job = Job {
                prompt: tokenizer::encode(prompt),
                max_tokens: max_tokens.clamp(1, 1024),
                class,
                reply: reply_tx,
            };
            if tx.send(job).is_err() {
                return write_response(stream, 503, "application/json", b"{\"error\":\"engine down\"}");
            }
            match reply_rx.recv_timeout(Duration::from_secs(120)) {
                Ok(c) if !c.is_failed() => {
                    let body = Json::obj(vec![
                        ("id", c.id.into()),
                        ("text", c.text.into()),
                        ("num_tokens", c.tokens.len().into()),
                        ("latency_ms", c.latency_ms.into()),
                    ]);
                    write_response(stream, 200, "application/json", body.to_string().as_bytes())
                }
                Ok(_) => write_response(stream, 503, "application/json", b"{\"error\":\"backend failed\"}"),
                Err(_) => write_response(stream, 500, "application/json", b"{\"error\":\"timeout\"}"),
            }
        }
        ("POST", _) | ("GET", _) => write_response(stream, 404, "application/json", b"{\"error\":\"not found\"}"),
        _ => write_response(stream, 405, "application/json", b"{\"error\":\"method\"}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batch::Batch;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
    use crate::coordinator::state::EngineState;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    /// Echo-ish backend: generates deterministic tokens without PJRT.
    struct EchoBackend;
    impl ExecutionBackend for EchoBackend {
        fn execute(&mut self, batch: &Batch, state: &mut EngineState) -> anyhow::Result<f64> {
            for e in &batch.entries {
                let req = state.req_mut(e.id);
                let emit = if e.is_prefill {
                    req.prefilled + e.n_tokens >= req.prompt_len
                } else {
                    true
                };
                if emit {
                    let n = req.output_tokens.len();
                    let tok = req.prompt.get(n).copied().unwrap_or(b'!' as u32);
                    req.output_tokens.push(tok);
                }
            }
            Ok(0.0005)
        }
    }

    fn http(addr: std::net::SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn start_echo_server() -> Server {
        Server::start(
            "127.0.0.1:0",
            || {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, EchoBackend))
            },
            2,
        )
        .unwrap()
    }

    #[test]
    fn health_and_metrics_endpoints() {
        let server = start_echo_server();
        let r = http(server.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""), "{r}");
        let r = http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"));
        server.shutdown();
    }

    #[test]
    fn completion_roundtrip() {
        let server = start_echo_server();
        let body = r#"{"prompt": "abcd", "max_tokens": 3, "class": "online"}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let r = http(server.addr, &raw);
        assert!(r.contains("200 OK"), "{r}");
        // Echo backend repeats the prompt: 3 tokens -> "abc"
        assert!(r.contains("\"text\":\"abc\""), "{r}");
        assert!(r.contains("\"num_tokens\":3"), "{r}");
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let server = start_echo_server();
        let addr = server.addr;
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let body = format!(r#"{{"prompt": "req{i}xx", "max_tokens": 2}}"#);
                    let raw = format!(
                        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                        body.len(),
                        body
                    );
                    http(addr, &raw)
                })
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert!(r.contains("200 OK"), "{r}");
        }
        server.shutdown();
    }

    /// Backend that fails every execution (persistent hardware fault).
    struct FailBackend;
    impl ExecutionBackend for FailBackend {
        fn execute(&mut self, _batch: &Batch, _state: &mut EngineState) -> anyhow::Result<f64> {
            anyhow::bail!("injected backend failure")
        }
    }

    fn completions_request(prompt: &str) -> String {
        let body = format!(r#"{{"prompt": "{prompt}", "max_tokens": 2}}"#);
        format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
    }

    #[test]
    fn failing_backend_errors_requests_without_livelock() {
        let server = Server::start(
            "127.0.0.1:0",
            || {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, FailBackend))
            },
            2,
        )
        .unwrap();
        // First request reaches the engine, the backend fails, and the
        // inflight reply channel must carry the error back promptly — not
        // spin until the 120 s handler timeout.
        let t0 = std::time::Instant::now();
        let r = http(server.addr, &completions_request("abcd"));
        assert!(r.contains("503"), "{r}");
        assert!(r.contains("backend failed"), "{r}");
        assert!(t0.elapsed() < Duration::from_secs(10), "reply was not prompt");
        // The engine aborted its work: the process stays responsive and
        // subsequent completions are refused with 503 up front.
        let r = http(server.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""), "{r}");
        let r = http(server.addr, &completions_request("efgh"));
        assert!(r.contains("503"), "{r}");
        let r = http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        server.shutdown();
    }

    #[test]
    fn drop_joins_threads_and_frees_port() {
        let server = start_echo_server();
        let addr = server.addr;
        drop(server); // no explicit shutdown()
        // Drop must join the accept thread and release the listener: the
        // port is immediately rebindable and nothing serves on it.
        let listener = std::net::TcpListener::bind(addr)
            .expect("port still bound after Server::drop");
        drop(listener);
    }

    #[test]
    fn rejects_bad_requests() {
        let server = start_echo_server();
        let r = http(server.addr, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"));
        let raw = "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\nnotjson";
        let r = http(server.addr, raw);
        assert!(r.contains("400"), "{r}");
        let raw = "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 2\r\n\r\n{}";
        let r = http(server.addr, raw);
        assert!(r.contains("missing prompt"), "{r}");
        server.shutdown();
    }
}
