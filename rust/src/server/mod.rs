//! HTTP serving front end: the leader process of a HyGen deployment.
//!
//! Architecture (the paper's Fig. 2, generalized to N replicas):
//! connection handling on a thread pool; one *engine thread per replica*
//! owning that replica's scheduler, queues, and backend
//! ([`crate::cluster::replica`]); `std::sync::mpsc` message queues
//! between them — the same message-passing structure as the paper's
//! asynchronous two-queue workflow (Appendix A.1). A
//! [`Router`](crate::cluster::router::Router) picks the replica for every
//! submission from the replicas' published census snapshots.
//!
//! API:
//! * `POST /v1/completions` `{"prompt": str, "max_tokens": n,
//!   "class": "online"|"offline"}` → `{"text", "tokens", "latency_ms", ...}`
//! * `GET /metrics` → serving report (JSON). Single replica: the flat
//!   per-engine report. Multi-replica: `{"replicas": [...], "aggregate"}`
//!   where additive fields are summed and latency percentiles take the
//!   worst replica (the cluster meets an SLO only if its slowest replica
//!   does).
//! * `GET /health` → `{"status":"ok"}`
//! * `GET /trace?n=K` → the latest published flight-recorder dump
//!   (lifecycle + scheduler-decision events, see `crate::obs`),
//!   optionally truncated to the last K events. Single replica: the flat
//!   recorder dump; multi-replica: `{"replicas": [...]}`.
//!
//! Latency aggregation note: when every replica's report carries the
//! bounded latency histograms (`ttft_hist`/`tbt_hist`, PR 9+), per-class
//! aggregate percentiles come from the bucket-wise *merged* distribution
//! — pooled quantiles, not the worst replica's. Flat legacy payloads
//! (and the top-level summary fields, which have no histogram) keep the
//! conservative worst-replica rule.
//!
//! Shutdown drains: accepted requests keep executing until they finish or
//! the drain deadline passes (then they fail with 503), instead of being
//! dropped mid-flight.

pub mod http;

use crate::cluster::replica::{Job, JobError, ReplicaShared, Supervisor, SupervisorConfig};
use crate::cluster::router::{Router, RouterPolicy};
use crate::cluster::ReplicaSnapshot;
use crate::coordinator::classes::{ClassRegistry, ClassSpec, MAX_CLASSES};
use crate::coordinator::request::Class;
use crate::engine::{Engine, ExecutionBackend};
use crate::obs::histogram::{Histogram, SignedHistogram};
use crate::runtime::tokenizer;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use http::{read_request, write_response, write_response_with_headers};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub use crate::cluster::replica::Completion;

/// Default graceful-drain deadline on shutdown.
pub const DEFAULT_DRAIN: Duration = Duration::from_secs(5);

/// Overload policy: bounded admission, deadline shedding, retry
/// re-routing, and the brown-out ladder. Built from flat config keys by
/// [`ClusterConfig::overload_config`](crate::config::ClusterConfig::overload_config);
/// the defaults here are the documented config defaults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadConfig {
    /// Per-class waiting-queue bound on the routed replica. A request
    /// whose class already has this many waiting requests there is
    /// rejected with 429 + `Retry-After` instead of deepening the queue.
    pub queue_cap: usize,
    /// Hard per-request wallclock backstop. The effective deadline is
    /// the tighter of this and the class SLO envelope
    /// ([`effective_deadline`]).
    pub request_timeout: Duration,
    /// How many times an interactive request that failed *before any
    /// token was delivered* may be re-routed to another live replica
    /// (0 = never retry).
    pub retry_budget: usize,
    /// Consecutive per-replica errors that open its circuit breaker.
    pub breaker_threshold: usize,
    /// How long an open breaker hides the replica from routing before a
    /// half-open probe is allowed through.
    pub breaker_cooldown: Duration,
    /// Brown-out rung 1: aggregate headroom (ms) below which elastic
    /// (no-TTFT-SLO) classes are shed with 429.
    pub brownout_offline_headroom_ms: f64,
    /// Brown-out rung 2: headroom below which every class except the
    /// top tier is shed.
    pub brownout_shed_headroom_ms: f64,
    /// Brown-out rung 3: headroom below which even top-tier interactive
    /// work is shed — total admission stop.
    pub brownout_online_headroom_ms: f64,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        OverloadConfig {
            queue_cap: 256,
            request_timeout: Duration::from_secs(120),
            retry_budget: 2,
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_secs(1),
            brownout_offline_headroom_ms: 5.0,
            brownout_shed_headroom_ms: 2.0,
            brownout_online_headroom_ms: 0.5,
        }
    }
}

impl OverloadConfig {
    /// The brown-out ladder decision: should a request of this class be
    /// shed at the given aggregate headroom? Pure so the overload
    /// experiment and unit tests exercise exactly the serving policy.
    /// Infinite headroom (SLO-unaware deployment: no latency budget
    /// configured) never browns out — the ladder is an SLO-protection
    /// mechanism, not a load limit.
    pub fn brownout_sheds(&self, headroom_ms: f64, elastic: bool, top_tier: bool) -> bool {
        if !headroom_ms.is_finite() {
            return false;
        }
        if headroom_ms < self.brownout_online_headroom_ms {
            return true;
        }
        if headroom_ms < self.brownout_shed_headroom_ms && !top_tier {
            return true;
        }
        headroom_ms < self.brownout_offline_headroom_ms && elastic
    }
}

/// The `Retry-After` seconds advertised with a 429: proportional to how
/// deep past the SLO knee the cluster is (each 250 ms of negative
/// headroom adds a second), clamped to [1, 30] so clients neither
/// stampede back instantly nor give up.
pub fn retry_after_secs(headroom_ms: f64) -> u64 {
    if !headroom_ms.is_finite() || headroom_ms >= 0.0 {
        1
    } else {
        ((-headroom_ms / 250.0).ceil() as u64 + 1).min(30)
    }
}

/// The effective deadline for one request: the tighter of the global
/// `request_timeout` backstop and the class SLO envelope — TTFT SLO plus
/// TBT SLO per generated token, scaled by the class tolerance and a 4x
/// service slack so deadline shedding fires on pathological waits, not
/// on ordinary queueing jitter. Classes with no SLO at all (elastic
/// batch work) get the backstop only.
pub fn effective_deadline(cfg: &OverloadConfig, spec: &ClassSpec, max_tokens: usize) -> Duration {
    const SLACK: f64 = 4.0;
    if spec.ttft_slo_ms.is_none() && spec.tbt_slo_ms.is_none() {
        return cfg.request_timeout;
    }
    let envelope_ms = (spec.ttft_slo_ms.unwrap_or(0.0)
        + spec.tbt_slo_ms.unwrap_or(0.0) * max_tokens as f64)
        * SLACK
        * spec.budget_tolerance().max(1.0);
    let envelope = Duration::from_secs_f64((envelope_ms / 1e3).max(0.001));
    envelope.min(cfg.request_timeout)
}

/// Per-replica consecutive-error circuit breaker. Closed (routable) →
/// open after `breaker_threshold` consecutive errors (hidden from
/// routing for `breaker_cooldown`) → half-open (cooldown elapsed: one
/// probe request may route here; success closes, failure re-opens).
#[derive(Debug, Default)]
struct Breaker {
    consecutive: usize,
    open_until: Option<Instant>,
}

impl Breaker {
    fn is_open(&self, now: Instant) -> bool {
        self.open_until.is_some_and(|t| now < t)
    }
}

/// Front-end request-lifecycle ledger. Every admitted request increments
/// `admitted` exactly once and exactly one terminal counter, so at any
/// quiescent instant `admitted = finished_200 + rejected_429 +
/// timed_out_504 + failed_503` and the in-flight remainder is
/// `resident` — `/metrics` exposes all of them and the overload
/// experiment asserts the conservation exactly.
#[derive(Debug, Default)]
struct FrontendStats {
    admitted: AtomicUsize,
    finished: AtomicUsize,
    rejected_429: AtomicUsize,
    timed_out_504: AtomicUsize,
    failed_503: AtomicUsize,
    retries: AtomicUsize,
    breaker_open_total: AtomicUsize,
    /// Per-class 429 breakdown (brown-out + queue-cap sheds).
    shed_by_class: [AtomicUsize; MAX_CLASSES],
}

/// Shared front-end state: the replica ports, the routing policy, the
/// SLO-class registry (resolves request `class` names and decides
/// interactive-vs-elastic routing), the overload policy, and the
/// lifecycle ledger.
struct ClusterState {
    replicas: Vec<ReplicaPort>,
    router: Mutex<Box<dyn Router>>,
    registry: Arc<ClassRegistry>,
    overload: OverloadConfig,
    stats: FrontendStats,
}

struct ReplicaPort {
    tx: Sender<Job>,
    shared: Arc<ReplicaShared>,
    breaker: Mutex<Breaker>,
}

impl ClusterState {
    fn all_failed(&self) -> bool {
        self.replicas.iter().all(|r| r.shared.failed.load(Ordering::SeqCst))
    }

    fn breaker_on_success(&self, target: usize) {
        let mut b = self.replicas[target].breaker.lock().unwrap();
        b.consecutive = 0;
        b.open_until = None;
    }

    fn breaker_on_error(&self, target: usize) {
        let mut b = self.replicas[target].breaker.lock().unwrap();
        b.consecutive += 1;
        if b.consecutive >= self.overload.breaker_threshold {
            let now = Instant::now();
            // Count closed/half-open -> open transitions only: a failed
            // half-open probe re-opens (and re-counts), but piling more
            // errors onto an already-open breaker does not.
            if !b.is_open(now) {
                self.stats.breaker_open_total.fetch_add(1, Ordering::Relaxed);
            }
            b.open_until = Some(now + self.overload.breaker_cooldown);
        }
    }
}

pub struct Server {
    pub addr: std::net::SocketAddr,
    /// Engine replicas behind this server.
    pub replicas: usize,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    replica_handles: Vec<Supervisor>,
}

impl Server {
    /// Start a classic single-engine server (round-robin over one
    /// replica). The engine is *constructed on* a dedicated engine thread
    /// by `factory` — PJRT handles are not `Send`, so they must never
    /// cross threads; handlers talk to the engine thread via a message
    /// queue only. The factory must be callable repeatedly: the replica's
    /// supervisor re-runs it to restart a failed engine.
    pub fn start<B, F>(bind: &str, factory: F, workers: usize) -> anyhow::Result<Server>
    where
        B: ExecutionBackend + 'static,
        F: Fn() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        Self::start_cluster(
            bind,
            vec![factory],
            RouterPolicy::RoundRobin.build(),
            workers,
            DEFAULT_DRAIN,
        )
    }

    /// Start serving with one engine thread per factory and `router`
    /// deciding which replica serves each submission, under the default
    /// two-class registry and restart policy.
    pub fn start_cluster<B, F>(
        bind: &str,
        factories: Vec<F>,
        router: Box<dyn Router>,
        workers: usize,
        drain: Duration,
    ) -> anyhow::Result<Server>
    where
        B: ExecutionBackend + 'static,
        F: Fn() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        Self::start_cluster_with_registry(
            bind,
            factories,
            router,
            workers,
            drain,
            Arc::new(ClassRegistry::default_two()),
            SupervisorConfig::default(),
            OverloadConfig::default(),
        )
    }

    /// Start serving under an explicit SLO-class registry. Submissions
    /// carry a `class` name resolved against it; each engine factory must
    /// build its [`EngineState`](crate::coordinator::state::EngineState)
    /// over the *same* registry or class-indexed enqueues will be
    /// rejected. Each replica runs under a [`Supervisor`] with the given
    /// restart policy: a persistently failing engine is rebuilt by its
    /// factory with capped exponential backoff, and the replica publishes
    /// itself `failed` (routers skip it) until the restart lands.
    /// `overload` sets the admission/deadline/retry/brown-out policy
    /// (see [`OverloadConfig`]).
    #[allow(clippy::too_many_arguments)]
    pub fn start_cluster_with_registry<B, F>(
        bind: &str,
        factories: Vec<F>,
        router: Box<dyn Router>,
        workers: usize,
        drain: Duration,
        registry: Arc<ClassRegistry>,
        supervisor: SupervisorConfig,
        overload: OverloadConfig,
    ) -> anyhow::Result<Server>
    where
        B: ExecutionBackend + 'static,
        F: Fn() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        anyhow::ensure!(!factories.is_empty(), "server needs at least one replica");
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        let mut replica_handles = Vec::with_capacity(factories.len());
        for (i, factory) in factories.into_iter().enumerate() {
            let spawned = Supervisor::spawn(
                format!("hygen-engine-{i}"),
                factory,
                Arc::clone(&stop),
                drain,
                supervisor,
            );
            match spawned {
                Ok(r) => replica_handles.push(r),
                Err(e) => {
                    // Tear down the replicas that did start.
                    stop.store(true, Ordering::SeqCst);
                    for r in &mut replica_handles {
                        r.join();
                    }
                    return Err(e.context(format!("replica {i} failed to start")));
                }
            }
        }
        let state = Arc::new(ClusterState {
            replicas: replica_handles
                .iter()
                .map(|r| ReplicaPort {
                    tx: r.tx.clone(),
                    shared: Arc::clone(&r.shared),
                    breaker: Mutex::new(Breaker::default()),
                })
                .collect(),
            router: Mutex::new(router),
            registry,
            overload,
            stats: FrontendStats::default(),
        });

        let accept_thread = {
            let stop = Arc::clone(&stop);
            let pool = ThreadPool::new(workers);
            std::thread::Builder::new().name("hygen-accept".into()).spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            let state = Arc::clone(&state);
                            pool.execute(move || {
                                let _ = handle_connection(&mut stream, &state);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                // pool drops here, joining workers; the workers' pending
                // replies are produced by the replica threads' drain.
            })?
        };

        Ok(Server {
            addr,
            replicas: replica_handles.len(),
            stop,
            accept_thread: Some(accept_thread),
            replica_handles,
        })
    }

    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for r in &mut self.replica_handles {
            r.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // A dropped-without-shutdown server must not leak the accept and
        // engine threads (and with them the bound port) — join like
        // `shutdown()` does.
        self.stop_and_join();
    }
}

/// Additive `/metrics` fields summed across replicas; the remaining
/// latency fields take the per-replica worst (see the module docs).
const SUM_FIELDS: [&str; 7] = [
    "online_finished",
    "offline_finished",
    "online_tps",
    "offline_tps",
    "total_tps",
    "online_qps",
    "offline_qps",
];

/// `/metrics` fields where the aggregate is the worst replica: latency
/// percentiles/means (an SLO holds cluster-wide only if it holds on the
/// slowest replica) and the observation window.
const WORST_FIELDS: [&str; 7] = [
    "mean_ttft_ms",
    "p50_ttft_ms",
    "p99_ttft_ms",
    "mean_tbt_ms",
    "p50_tbt_ms",
    "p99_tbt_ms",
    "duration_s",
];

/// Per-class block fields that sum across replicas; the rest of the
/// block (latency means/percentiles) takes the per-replica worst.
/// Prefix-cache counters are replica-additive by construction (each
/// replica's block manager counts its own admissions).
const CLASS_SUM_FIELDS: [&str; 8] = [
    "finished",
    "tps",
    "qps",
    "cache_hit_blocks",
    "cache_miss_blocks",
    "cache_evictions",
    "cache_resurrections",
    "cached_tokens",
];
const CLASS_WORST_FIELDS: [&str; 6] = [
    "mean_ttft_ms",
    "p50_ttft_ms",
    "p99_ttft_ms",
    "mean_tbt_ms",
    "p50_tbt_ms",
    "p99_tbt_ms",
];

/// Bucket-wise merge of one histogram field across report blocks —
/// `None` unless every block carries it, so legacy/flat payloads fall
/// back to worst-replica aggregation.
fn merge_hists(blocks: &[Json], key: &str) -> Option<Histogram> {
    let mut merged = Histogram::new();
    for b in blocks {
        merged.merge(&Histogram::from_json(b.get(key))?);
    }
    Some(merged)
}

/// Merge the replicas' signed predictor-error histogram arrays
/// shape-bucket by shape-bucket. `None` unless every report carries the
/// array.
fn merge_predictor_error(reports: &[Json]) -> Option<Json> {
    let mut merged: Vec<(u64, SignedHistogram)> = Vec::new();
    for r in reports {
        for (i, e) in r.get("predictor_error").as_arr()?.iter().enumerate() {
            let h = SignedHistogram::from_json(e)?;
            if merged.len() <= i {
                merged.push((e.get("shape").as_u64().unwrap_or(i as u64), SignedHistogram::new()));
            }
            merged[i].1.merge(&h);
        }
    }
    let arr = merged
        .into_iter()
        .map(|(shape, h)| {
            let mut j = h.to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("shape".to_string(), Json::from(shape));
            }
            j
        })
        .collect();
    Some(Json::Arr(arr))
}

/// Aggregate the replicas' `classes` arrays element-wise (class `i` with
/// class `i`): additive fields summed; latency fields come from the
/// merged histograms (pooled quantiles) when every replica reports them,
/// else the per-replica worst.
fn aggregate_class_blocks(reports: &[Json]) -> Json {
    let n = reports
        .iter()
        .filter_map(|r| r.get("classes").as_arr().map(|a| a.len()))
        .max()
        .unwrap_or(0);
    let block = |r: &Json, i: usize| r.get("classes").as_arr().and_then(|a| a.get(i).cloned());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let blocks: Vec<Json> = reports.iter().filter_map(|r| block(r, i)).collect();
        let mut pairs: Vec<(&str, Json)> = vec![("class", Json::from(i))];
        for field in CLASS_SUM_FIELDS {
            let total: f64 = blocks.iter().filter_map(|b| b.get(field).as_f64()).sum();
            pairs.push((field, Json::from(total)));
        }
        let ttft = merge_hists(&blocks, "ttft_hist");
        let tbt = merge_hists(&blocks, "tbt_hist");
        for field in CLASS_WORST_FIELDS {
            let pooled = match field {
                "mean_ttft_ms" => ttft.as_ref().map(Histogram::mean),
                "p50_ttft_ms" => ttft.as_ref().map(Histogram::p50),
                "p99_ttft_ms" => ttft.as_ref().map(Histogram::p99),
                "mean_tbt_ms" => tbt.as_ref().map(Histogram::mean),
                "p50_tbt_ms" => tbt.as_ref().map(Histogram::p50),
                "p99_tbt_ms" => tbt.as_ref().map(Histogram::p99),
                _ => None,
            };
            let v = pooled.unwrap_or_else(|| {
                blocks.iter().filter_map(|b| b.get(field).as_f64()).fold(0.0f64, f64::max)
            });
            pairs.push((field, Json::from(v)));
        }
        if let Some(h) = &ttft {
            pairs.push(("ttft_hist", h.to_json()));
        }
        if let Some(h) = &tbt {
            pairs.push(("tbt_hist", h.to_json()));
        }
        out.push(Json::obj(pairs));
    }
    Json::Arr(out)
}

/// Aggregate per-replica report JSONs into the multi-replica `/metrics`
/// payload. `fleet` carries supervision counters (restarts, generations)
/// that live beside the engine reports rather than inside them.
fn aggregate_metrics(reports: &[Json], fleet: Vec<(&'static str, Json)>) -> Json {
    let mut agg: Vec<(&str, Json)> = Vec::new();
    for field in SUM_FIELDS {
        let total: f64 = reports.iter().filter_map(|r| r.get(field).as_f64()).sum();
        agg.push((field, Json::from(total)));
    }
    for field in WORST_FIELDS {
        let worst = reports
            .iter()
            .filter_map(|r| r.get(field).as_f64())
            .fold(0.0f64, f64::max);
        agg.push((field, Json::from(worst)));
    }
    // Mergeable distributions ride along whenever every replica reports
    // them: bucket-wise sums give pooled (not worst-replica) quantiles.
    if let Some(h) = merge_hists(reports, "batch_latency_hist") {
        agg.push(("batch_latency_hist", h.to_json()));
    }
    if let Some(pe) = merge_predictor_error(reports) {
        agg.push(("predictor_error", pe));
    }
    agg.push(("classes", aggregate_class_blocks(reports)));
    let mut top = vec![
        ("replicas", Json::Arr(reports.to_vec())),
        ("aggregate", Json::obj(agg)),
    ];
    top.extend(fleet);
    Json::obj(top)
}

/// Supervision counters for the multi-replica `/metrics` payload:
/// per-replica restart attempts and engine generations, plus the fleet
/// total (these are front-end state, not engine report fields — the
/// aggregate drift guard stays exact).
fn fleet_fields(state: &ClusterState) -> Vec<(&'static str, Json)> {
    let restarts: Vec<usize> = state
        .replicas
        .iter()
        .map(|r| r.shared.restarts.load(Ordering::Relaxed))
        .collect();
    let generations: Vec<Json> = state
        .replicas
        .iter()
        .map(|r| Json::from(r.shared.generation.load(Ordering::Relaxed)))
        .collect();
    vec![
        ("total_restarts", Json::from(restarts.iter().sum::<usize>())),
        ("restarts", Json::Arr(restarts.into_iter().map(Json::from).collect())),
        ("generations", Json::Arr(generations)),
    ]
}

/// Request-lifecycle counters for `/metrics`. Like [`fleet_fields`],
/// these are front-end state riding beside the engine reports (both in
/// the single-replica flat payload and the multi-replica aggregate), so
/// the report-field drift guard stays exact. `resident` is derived from
/// the conservation identity, never counted independently.
fn overload_fields(state: &ClusterState) -> Vec<(&'static str, Json)> {
    let s = &state.stats;
    let admitted = s.admitted.load(Ordering::Relaxed);
    let finished = s.finished.load(Ordering::Relaxed);
    let rejected = s.rejected_429.load(Ordering::Relaxed);
    let timed_out = s.timed_out_504.load(Ordering::Relaxed);
    let failed = s.failed_503.load(Ordering::Relaxed);
    let resident = admitted.saturating_sub(finished + rejected + timed_out + failed);
    let shed: Vec<Json> = (0..state.registry.len())
        .map(|i| Json::from(s.shed_by_class[i].load(Ordering::Relaxed)))
        .collect();
    vec![
        ("admitted", Json::from(admitted)),
        ("finished_200", Json::from(finished)),
        ("rejected_429", Json::from(rejected)),
        ("timed_out_504", Json::from(timed_out)),
        ("failed_503", Json::from(failed)),
        ("resident", Json::from(resident)),
        ("retries", Json::from(s.retries.load(Ordering::Relaxed))),
        ("breaker_open_total", Json::from(s.breaker_open_total.load(Ordering::Relaxed))),
        ("shed_by_class", Json::Arr(shed)),
    ]
}

/// JSON error body with proper escaping. A raw `format!` would let a
/// quote or backslash in the message break the payload (see the
/// `error_body_escapes_message` pin test); routing through [`Json`]
/// makes injection structurally impossible.
fn error_body(message: &str) -> String {
    Json::obj(vec![("error", Json::from(message))]).to_string()
}

/// Write a 429 admission rejection with its `Retry-After` hint and
/// count it in the ledger (total + per-class shed breakdown).
fn reject_429(
    stream: &mut std::net::TcpStream,
    state: &ClusterState,
    class: Class,
    headroom_ms: f64,
) -> std::io::Result<()> {
    state.stats.rejected_429.fetch_add(1, Ordering::Relaxed);
    if let Some(c) = state.stats.shed_by_class.get(class.index()) {
        c.fetch_add(1, Ordering::Relaxed);
    }
    write_response_with_headers(
        stream,
        429,
        "application/json",
        error_body("over capacity").as_bytes(),
        &[("Retry-After", retry_after_secs(headroom_ms).to_string())],
    )
}

/// The `/trace` payload: each replica's latest published flight-recorder
/// dump, optionally truncated to the last `n` events. The dump is
/// re-published alongside `/metrics` (see
/// [`crate::cluster::replica::TRACE_PUBLISH_EVENTS`]), so this never
/// touches the engine thread.
fn trace_payload(state: &ClusterState, n: Option<usize>) -> Json {
    let one = |port: &ReplicaPort| {
        let text = port.shared.trace_json.lock().unwrap().clone();
        let mut j = Json::parse(&text).unwrap_or(Json::Obj(Default::default()));
        if let (Some(k), Json::Obj(map)) = (n, &mut j) {
            if let Some(Json::Arr(events)) = map.get_mut("events") {
                let drop = events.len().saturating_sub(k);
                events.drain(..drop);
            }
        }
        j
    };
    if state.replicas.len() == 1 {
        one(&state.replicas[0])
    } else {
        Json::obj(vec![("replicas", Json::Arr(state.replicas.iter().map(one).collect()))])
    }
}

fn handle_connection(
    stream: &mut std::net::TcpStream,
    state: &ClusterState,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let req = match read_request(stream) {
        Ok(r) => r,
        Err(_) => return write_response(stream, 400, "application/json", b"{\"error\":\"bad request\"}"),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => write_response(stream, 200, "application/json", b"{\"status\":\"ok\"}"),
        ("GET", "/metrics") => {
            let body = if state.replicas.len() == 1 {
                // Flat per-engine report with the front-end lifecycle
                // counters merged in as top-level fields.
                let text = state.replicas[0].shared.metrics_json.lock().unwrap().clone();
                let mut j = Json::parse(&text).unwrap_or(Json::Obj(Default::default()));
                if let Json::Obj(map) = &mut j {
                    for (k, v) in overload_fields(state) {
                        map.insert(k.to_string(), v);
                    }
                }
                j.to_pretty()
            } else {
                let reports: Vec<Json> = state
                    .replicas
                    .iter()
                    .map(|r| {
                        let text = r.shared.metrics_json.lock().unwrap().clone();
                        Json::parse(&text).unwrap_or(Json::Obj(Default::default()))
                    })
                    .collect();
                let mut fleet = fleet_fields(state);
                fleet.extend(overload_fields(state));
                aggregate_metrics(&reports, fleet).to_pretty()
            };
            write_response(stream, 200, "application/json", body.as_bytes())
        }
        ("GET", path) if path == "/trace" || path.starts_with("/trace?") => {
            let n = path
                .split_once('?')
                .and_then(|(_, q)| q.split('&').find_map(|kv| kv.strip_prefix("n=")))
                .and_then(|v| v.parse::<usize>().ok());
            let body = trace_payload(state, n).to_pretty();
            write_response(stream, 200, "application/json", body.as_bytes())
        }
        ("POST", "/v1/completions") => handle_completion(stream, state, &req.body),
        ("POST", _) | ("GET", _) => write_response(stream, 404, "application/json", b"{\"error\":\"not found\"}"),
        _ => write_response(stream, 405, "application/json", b"{\"error\":\"method\"}"),
    }
}

/// One attempt's terminal-vs-retryable classification (see the retry
/// loop in [`handle_completion`]).
enum Attempt {
    /// A terminal HTTP response was written; its ledger counter is
    /// already incremented.
    Done(std::io::Result<()>),
    /// The attempt failed before any token was delivered; the request
    /// may be re-routed if the retry gate allows.
    Failed(&'static str),
}

/// The `POST /v1/completions` lifecycle: parse → admit (ledger entry) →
/// brown-out ladder → route (breaker-aware) → bounded admission →
/// execute with an absolute deadline → classify, with failed attempts
/// re-routed to another live replica under a bounded retry budget.
fn handle_completion(
    stream: &mut std::net::TcpStream,
    state: &ClusterState,
    body: &[u8],
) -> std::io::Result<()> {
    let parsed = Json::parse(&String::from_utf8_lossy(body));
    let Ok(j) = parsed else {
        return write_response(stream, 400, "application/json", b"{\"error\":\"bad json\"}");
    };
    let Some(prompt) = j.get("prompt").as_str() else {
        return write_response(stream, 400, "application/json", b"{\"error\":\"missing prompt\"}");
    };
    let max_tokens = (j.get("max_tokens").as_u64().unwrap_or(16) as usize).clamp(1, 1024);
    // Resolve the class name against the registry (default: the
    // flagship class). Unknown names are an explicit client error, not
    // a silent interactive upgrade.
    let class = match j.get("class").as_str() {
        None => Class::ONLINE,
        Some(name) => match state.registry.by_name(name) {
            Some(c) => c,
            None => {
                return write_response(
                    stream,
                    400,
                    "application/json",
                    b"{\"error\":\"unknown class\"}",
                )
            }
        },
    };
    // ---- Lifecycle entry. Everything past this point is in the
    // conservation ledger: `admitted` is incremented exactly once per
    // request, and every exit below increments exactly one terminal
    // counter (200 / 429 / 503 / 504). Malformed requests above never
    // enter the ledger — they carry no work.
    state.stats.admitted.fetch_add(1, Ordering::Relaxed);
    if state.all_failed() {
        state.stats.failed_503.fetch_add(1, Ordering::Relaxed);
        return write_response(
            stream,
            503,
            "application/json",
            error_body("backend failed").as_bytes(),
        );
    }
    let spec = state.registry.spec(class);
    let elastic = spec.elastic();
    let top_tier = spec.tier == state.registry.top_tier();
    // The absolute deadline travels with the job: the engine sheds
    // expired work before building each batch (KV + batch slot freed
    // in-engine), and the handler's recv below waits only as long as
    // the deadline plus a grace period for the shed reply to arrive.
    let deadline_at = Instant::now() + effective_deadline(&state.overload, spec, max_tokens);
    let prompt_tokens = tokenizer::encode(prompt);
    let mut budget = state.overload.retry_budget;
    let mut tried: Vec<usize> = Vec::new();
    loop {
        // Fresh census every attempt: queue depths and failure flags
        // move while a reply is awaited.
        let snaps: Vec<ReplicaSnapshot> =
            state.replicas.iter().map(|r| r.shared.routing_snapshot()).collect();
        let agg_headroom = snaps
            .iter()
            .filter(|s| !s.failed)
            .map(|s| s.headroom_ms())
            .fold(f64::INFINITY, f64::min);
        // Brown-out ladder: headroom-driven admission stop, applied
        // before any queueing so shed work costs nothing downstream.
        if state.overload.brownout_sheds(agg_headroom, elastic, top_tier) {
            return reject_429(stream, state, class, agg_headroom);
        }
        // Route from the published census. Elastic submissions need a
        // reply channel too, so a deferring router falls back to its
        // interactive placement. Breaker-open and already-tried
        // replicas are masked failed for this attempt. A single replica
        // routes trivially and skips the breaker mask — with nowhere to
        // re-route, an open breaker would only turn fast errors into
        // blanket 503s.
        let target = if state.replicas.len() == 1 {
            0
        } else {
            let now = Instant::now();
            let mut masked = snaps.clone();
            for (i, s) in masked.iter_mut().enumerate() {
                if tried.contains(&i) || state.replicas[i].breaker.lock().unwrap().is_open(now) {
                    s.failed = true;
                }
            }
            if masked.iter().all(|s| s.failed) {
                // Everything is masked or down: fall back to the raw
                // census so a half-open probe can still land.
                masked = snaps.clone();
            }
            if masked.iter().all(|s| s.failed) {
                state.stats.failed_503.fetch_add(1, Ordering::Relaxed);
                return write_response(
                    stream,
                    503,
                    "application/json",
                    error_body("backend failed").as_bytes(),
                );
            }
            let mut router = state.router.lock().unwrap();
            let i = if elastic {
                router.route_offline(&masked).unwrap_or_else(|| router.route_online(&masked))
            } else {
                router.route_online(&masked)
            };
            i.min(state.replicas.len() - 1)
        };
        // Bounded admission: the routed replica's waiting queue for
        // this class is full → 429 with a headroom-derived Retry-After
        // instead of unbounded queue growth.
        if snaps[target].class_waiting(class) >= state.overload.queue_cap {
            return reject_429(stream, state, class, snaps[target].headroom_ms());
        }
        let port = &state.replicas[target];
        let (reply_tx, reply_rx) = channel();
        let job = Job {
            prompt: prompt_tokens.clone(),
            max_tokens,
            class,
            reply: reply_tx,
            deadline: Some(deadline_at),
        };
        port.shared.note_submitted(class);
        let outcome = if port.tx.send(job).is_err() {
            // The replica thread is gone (panic or exit) without
            // flagging itself: mark it failed so routers stop selecting
            // it instead of 503-ing every routed request while healthy
            // replicas idle.
            port.shared.failed.store(true, Ordering::SeqCst);
            Attempt::Failed("engine down")
        } else {
            let wait =
                deadline_at.saturating_duration_since(Instant::now()) + Duration::from_secs(1);
            match reply_rx.recv_timeout(wait) {
                Ok(Ok(c)) => {
                    state.breaker_on_success(target);
                    state.stats.finished.fetch_add(1, Ordering::Relaxed);
                    let body = Json::obj(vec![
                        ("id", c.id.into()),
                        ("replica", target.into()),
                        ("text", c.text.into()),
                        ("num_tokens", c.tokens.len().into()),
                        ("latency_ms", c.latency_ms.into()),
                    ]);
                    Attempt::Done(write_response(
                        stream,
                        200,
                        "application/json",
                        body.to_string().as_bytes(),
                    ))
                }
                Ok(Err(JobError::DeadlineExceeded)) => {
                    // The engine shed it at the deadline: KV blocks and
                    // batch slot already reclaimed. Never retried — the
                    // deadline is spent.
                    state.stats.timed_out_504.fetch_add(1, Ordering::Relaxed);
                    Attempt::Done(write_response(
                        stream,
                        504,
                        "application/json",
                        error_body(JobError::DeadlineExceeded.message()).as_bytes(),
                    ))
                }
                Ok(Err(JobError::DrainTimeout)) => {
                    // Shutdown refusal: not a replica fault, no retry.
                    state.stats.failed_503.fetch_add(1, Ordering::Relaxed);
                    Attempt::Done(write_response(
                        stream,
                        503,
                        "application/json",
                        error_body(JobError::DrainTimeout.message()).as_bytes(),
                    ))
                }
                Ok(Err(JobError::BackendFailed)) => {
                    Attempt::Failed(JobError::BackendFailed.message())
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // The replica thread exited (shutdown race): that is
                    // an explicit refusal, not a request timeout.
                    state.stats.failed_503.fetch_add(1, Ordering::Relaxed);
                    Attempt::Done(write_response(
                        stream,
                        503,
                        "application/json",
                        error_body("server stopping").as_bytes(),
                    ))
                }
                Err(RecvTimeoutError::Timeout) => {
                    // The engine missed even its in-engine shed pass
                    // (wedged thread). The request may still be live, so
                    // it must NEVER be re-routed — a retry could
                    // double-complete; the deadline shed reclaims its
                    // memory whenever the engine resumes.
                    state.stats.timed_out_504.fetch_add(1, Ordering::Relaxed);
                    Attempt::Done(write_response(
                        stream,
                        504,
                        "application/json",
                        error_body("request timed out").as_bytes(),
                    ))
                }
            }
        };
        match outcome {
            Attempt::Done(r) => return r,
            Attempt::Failed(msg) => {
                state.breaker_on_error(target);
                tried.push(target);
                // Retry gate: interactive work only (elastic work has no
                // latency promise to salvage), pre-first-token only — a
                // failure reply means the engine tore the request down
                // before delivering anything, so a re-route cannot
                // double-complete — within budget and deadline, and only
                // when a different live replica exists to route to.
                let another_alive = state.replicas.iter().enumerate().any(|(i, r)| {
                    !tried.contains(&i) && !r.shared.failed.load(Ordering::SeqCst)
                });
                if !elastic
                    && budget > 0
                    && state.replicas.len() > 1
                    && another_alive
                    && Instant::now() < deadline_at
                {
                    budget -= 1;
                    state.stats.retries.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                state.stats.failed_503.fetch_add(1, Ordering::Relaxed);
                return write_response(
                    stream,
                    503,
                    "application/json",
                    error_body(msg).as_bytes(),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::replica::JobError;
    use crate::coordinator::batch::Batch;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
    use crate::coordinator::state::EngineState;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    /// Echo-ish backend: generates deterministic tokens without PJRT.
    struct EchoBackend;
    impl ExecutionBackend for EchoBackend {
        fn execute(&mut self, batch: &Batch, state: &mut EngineState) -> anyhow::Result<f64> {
            for e in &batch.entries {
                let req = state.req_mut(e.id);
                let emit = if e.is_prefill {
                    req.prefilled + e.n_tokens >= req.prompt_len
                } else {
                    true
                };
                if emit {
                    let n = req.output_tokens.len();
                    let tok = req.prompt.get(n).copied().unwrap_or(b'!' as u32);
                    req.output_tokens.push(tok);
                }
            }
            Ok(0.0005)
        }
    }

    fn echo_engine() -> anyhow::Result<Engine<EchoBackend>> {
        let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
        let sched = HybridScheduler::new(
            SchedulerConfig { latency_budget_ms: None, ..Default::default() },
            LatencyPredictor::default_seed(),
        );
        Ok(Engine::new(sched, state, EchoBackend))
    }

    fn http(addr: std::net::SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn start_echo_server() -> Server {
        Server::start("127.0.0.1:0", echo_engine, 2).unwrap()
    }

    /// Parse the JSON body out of a raw HTTP response.
    fn body_json(resp: &str) -> Json {
        let body = resp.split("\r\n\r\n").nth(1).unwrap_or("");
        Json::parse(body).unwrap()
    }

    fn completions_request_class(prompt: &str, class: &str) -> String {
        let body = format!(r#"{{"prompt": "{prompt}", "max_tokens": 3, "class": "{class}"}}"#);
        format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
    }

    #[test]
    fn health_and_metrics_endpoints() {
        let server = start_echo_server();
        let r = http(server.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""), "{r}");
        let r = http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"));
        server.shutdown();
    }

    #[test]
    fn completion_roundtrip() {
        let server = start_echo_server();
        let body = r#"{"prompt": "abcd", "max_tokens": 3, "class": "online"}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let r = http(server.addr, &raw);
        assert!(r.contains("200 OK"), "{r}");
        // Echo backend repeats the prompt: 3 tokens -> "abc"
        assert!(r.contains("\"text\":\"abc\""), "{r}");
        assert!(r.contains("\"num_tokens\":3"), "{r}");
        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let server = start_echo_server();
        let addr = server.addr;
        let handles: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let body = format!(r#"{{"prompt": "req{i}xx", "max_tokens": 2}}"#);
                    let raw = format!(
                        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                        body.len(),
                        body
                    );
                    http(addr, &raw)
                })
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert!(r.contains("200 OK"), "{r}");
        }
        server.shutdown();
    }

    #[test]
    fn multi_replica_serves_and_aggregates_metrics() {
        let server = Server::start_cluster(
            "127.0.0.1:0",
            vec![echo_engine, echo_engine, echo_engine],
            RouterPolicy::RoundRobin.build(),
            4,
            DEFAULT_DRAIN,
        )
        .unwrap();
        assert_eq!(server.replicas, 3);
        let addr = server.addr;
        let handles: Vec<_> = (0..9)
            .map(|i| {
                std::thread::spawn(move || {
                    http(addr, &completions_request_class(&format!("req{i}"), "online"))
                })
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap();
            assert!(r.contains("200 OK"), "{r}");
            assert!(r.contains("\"replica\":"), "{r}");
        }
        // Offline submissions work through the fallback placement too.
        let r = http(addr, &completions_request_class("zzzz", "offline"));
        assert!(r.contains("200 OK"), "{r}");
        // Wait out a publish interval so every replica has a report up.
        std::thread::sleep(Duration::from_millis(450));
        let m = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("200 OK"), "{m}");
        assert!(m.contains("\"aggregate\""), "{m}");
        assert!(m.contains("\"replicas\""), "{m}");
        assert!(m.contains("\"p50_tbt_ms\""), "{m}");
        // Fleet supervision counters ride beside the engine reports: a
        // healthy cluster shows zero restarts and generation-0 replicas.
        assert!(m.contains("\"total_restarts\""), "{m}");
        assert!(m.contains("\"restarts\""), "{m}");
        assert!(m.contains("\"generations\""), "{m}");
        server.shutdown();
    }

    /// Backend that takes real wallclock per step, so in-flight work
    /// straddles `shutdown()`.
    struct SlowBackend;
    impl ExecutionBackend for SlowBackend {
        fn execute(&mut self, batch: &Batch, state: &mut EngineState) -> anyhow::Result<f64> {
            std::thread::sleep(Duration::from_millis(3));
            for e in &batch.entries {
                let req = state.req_mut(e.id);
                let emit =
                    if e.is_prefill { req.prefilled + e.n_tokens >= req.prompt_len } else { true };
                if emit {
                    req.output_tokens.push(b'z' as u32);
                }
            }
            Ok(0.003)
        }
    }

    #[test]
    fn shutdown_drains_inflight_requests() {
        let server = Server::start_cluster(
            "127.0.0.1:0",
            vec![|| {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, SlowBackend))
            }],
            RouterPolicy::SloHeadroom.build(),
            2,
            DEFAULT_DRAIN,
        )
        .unwrap();
        let addr = server.addr;
        // ~30 decode steps x 3 ms: the request is still in flight when
        // shutdown starts.
        let body = r#"{"prompt": "abcd", "max_tokens": 30}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let client = std::thread::spawn(move || http(addr, &raw));
        std::thread::sleep(Duration::from_millis(25));
        server.shutdown();
        let r = client.join().unwrap();
        assert!(r.contains("200 OK"), "accepted request must complete across stop(): {r}");
        assert!(r.contains("\"num_tokens\":30"), "{r}");
    }

    #[test]
    fn drain_deadline_fails_stragglers_instead_of_hanging() {
        let server = Server::start_cluster(
            "127.0.0.1:0",
            vec![|| {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, SlowBackend))
            }],
            RouterPolicy::RoundRobin.build(),
            2,
            Duration::from_millis(40),
        )
        .unwrap();
        let addr = server.addr;
        // 1024 decode steps x 3 ms >> the 40 ms drain deadline.
        let body = r#"{"prompt": "abcd", "max_tokens": 1024}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let client = std::thread::spawn(move || http(addr, &raw));
        std::thread::sleep(Duration::from_millis(25));
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5), "drain deadline must bound shutdown");
        let r = client.join().unwrap();
        assert!(r.contains("503"), "straggler fails explicitly: {r}");
        assert!(r.contains("server stopping"), "{r}");
    }

    /// Backend that fails every execution (persistent hardware fault).
    struct FailBackend;
    impl ExecutionBackend for FailBackend {
        fn execute(&mut self, _batch: &Batch, _state: &mut EngineState) -> anyhow::Result<f64> {
            anyhow::bail!("injected backend failure")
        }
    }

    fn completions_request(prompt: &str) -> String {
        let body = format!(r#"{{"prompt": "{prompt}", "max_tokens": 2}}"#);
        format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
    }

    #[test]
    fn failing_backend_errors_requests_without_livelock() {
        let server = Server::start(
            "127.0.0.1:0",
            || {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, FailBackend))
            },
            2,
        )
        .unwrap();
        // First request reaches the engine, the backend fails, and the
        // inflight reply channel must carry the error back promptly — not
        // spin until the 120 s handler timeout.
        let t0 = std::time::Instant::now();
        let r = http(server.addr, &completions_request("abcd"));
        assert!(r.contains("503"), "{r}");
        assert!(r.contains("backend failed"), "{r}");
        assert!(t0.elapsed() < Duration::from_secs(10), "reply was not prompt");
        // The engine aborted its work: the process stays responsive and
        // subsequent completions are refused with 503 up front.
        let r = http(server.addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""), "{r}");
        let r = http(server.addr, &completions_request("efgh"));
        assert!(r.contains("503"), "{r}");
        let r = http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        server.shutdown();
    }

    #[test]
    fn drop_joins_threads_and_frees_port() {
        let server = start_echo_server();
        let addr = server.addr;
        drop(server); // no explicit shutdown()
        // Drop must join the accept thread and release the listener: the
        // port is immediately rebindable and nothing serves on it.
        let listener = std::net::TcpListener::bind(addr)
            .expect("port still bound after Server::drop");
        drop(listener);
    }

    #[test]
    fn rejects_bad_requests() {
        let server = start_echo_server();
        let r = http(server.addr, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"));
        let raw = "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\nnotjson";
        let r = http(server.addr, raw);
        assert!(r.contains("400"), "{r}");
        let raw = "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 2\r\n\r\n{}";
        let r = http(server.addr, raw);
        assert!(r.contains("missing prompt"), "{r}");
        server.shutdown();
    }

    #[test]
    fn unknown_class_name_is_a_client_error() {
        let server = start_echo_server();
        let r = http(server.addr, &completions_request_class("abcd", "mystery"));
        assert!(r.contains("400"), "{r}");
        assert!(r.contains("unknown class"), "{r}");
        // Registry names keep working.
        let r = http(server.addr, &completions_request_class("abcd", "offline"));
        assert!(r.contains("200 OK"), "{r}");
        server.shutdown();
    }

    #[test]
    fn aggregate_merges_per_class_blocks_element_wise() {
        let a = Json::parse(
            r#"{"total_tps": 1.0, "classes": [
                {"class": 0, "finished": 2, "tps": 5.0, "p99_ttft_ms": 10.0,
                 "cache_hit_blocks": 8, "cached_tokens": 128},
                {"class": 1, "finished": 1, "tps": 3.0, "p99_ttft_ms": 0.0}
            ]}"#,
        )
        .unwrap();
        let b = Json::parse(
            r#"{"total_tps": 2.0, "classes": [
                {"class": 0, "finished": 4, "tps": 7.0, "p99_ttft_ms": 25.0,
                 "cache_hit_blocks": 3, "cached_tokens": 48}
            ]}"#,
        )
        .unwrap();
        let m = aggregate_metrics(&[a, b], Vec::new());
        let classes = m.get("aggregate").get("classes").as_arr().unwrap();
        assert_eq!(classes.len(), 2, "max class count across replicas");
        assert_eq!(classes[0].get("finished").as_f64(), Some(6.0), "additive summed");
        assert_eq!(classes[0].get("tps").as_f64(), Some(12.0));
        assert_eq!(classes[0].get("p99_ttft_ms").as_f64(), Some(25.0), "latency = worst");
        assert_eq!(classes[0].get("cache_hit_blocks").as_f64(), Some(11.0), "cache counters sum");
        assert_eq!(classes[0].get("cached_tokens").as_f64(), Some(176.0));
        assert_eq!(classes[1].get("finished").as_f64(), Some(1.0), "missing block = absent");
    }

    #[test]
    fn aggregate_metrics_sums_and_takes_worst() {
        let a = Json::parse(
            r#"{"online_finished": 2, "total_tps": 10.5, "p99_tbt_ms": 12.0, "p50_ttft_ms": 3.0}"#,
        )
        .unwrap();
        let b = Json::parse(
            r#"{"online_finished": 3, "total_tps": 4.5, "p99_tbt_ms": 30.0, "p50_ttft_ms": 1.0}"#,
        )
        .unwrap();
        let m = aggregate_metrics(&[a, b], Vec::new());
        let agg = m.get("aggregate");
        assert_eq!(agg.get("online_finished").as_f64(), Some(5.0));
        assert_eq!(agg.get("total_tps").as_f64(), Some(15.0));
        assert_eq!(agg.get("p99_tbt_ms").as_f64(), Some(30.0));
        assert_eq!(agg.get("p50_ttft_ms").as_f64(), Some(3.0));
        assert_eq!(m.get("replicas").as_arr().map(|a| a.len()), Some(2));
    }

    #[test]
    fn aggregate_covers_every_report_field() {
        // Drift guard for the stringly-typed SUM_FIELDS/WORST_FIELDS
        // lists: every field Report serializes must appear in the
        // multi-replica aggregate (a new Report field that is added to
        // neither list fails here, not silently in production).
        let report = crate::coordinator::metrics::Metrics::new(1.0).report(Some(1.0)).to_json();
        let m = aggregate_metrics(&[report.clone(), report.clone()], Vec::new());
        let agg = m.get("aggregate").as_obj().unwrap();
        for key in report.as_obj().unwrap().keys() {
            assert!(agg.contains_key(key), "aggregate missing report field '{key}'");
        }
    }

    #[test]
    fn job_error_messages() {
        assert_eq!(JobError::BackendFailed.message(), "backend failed");
        assert_eq!(JobError::DrainTimeout.message(), "server stopping");
        assert_eq!(JobError::DeadlineExceeded.message(), "request timed out");
    }

    #[test]
    fn error_body_escapes_message() {
        // Pin test for the JSON-injection fix: a message containing a
        // quote must yield a parseable body with the message intact, not
        // a truncated/injected payload.
        let body = error_body(r#"engine said "no" \ twice"#);
        let j = Json::parse(&body).expect("error body must stay valid JSON");
        assert_eq!(j.get("error").as_str(), Some(r#"engine said "no" \ twice"#));
    }

    #[test]
    fn brownout_ladder_degrades_by_class() {
        let cfg = OverloadConfig::default(); // rungs at 5.0 / 2.0 / 0.5 ms
        // Plenty of headroom: nobody sheds.
        assert!(!cfg.brownout_sheds(100.0, true, false));
        // Rung 1: elastic classes shed, interactive tiers keep going.
        assert!(cfg.brownout_sheds(4.0, true, false));
        assert!(!cfg.brownout_sheds(4.0, false, false));
        assert!(!cfg.brownout_sheds(4.0, false, true));
        // Rung 2: everything below the top tier sheds.
        assert!(cfg.brownout_sheds(1.0, false, false));
        assert!(!cfg.brownout_sheds(1.0, false, true));
        // Rung 3: total admission stop.
        assert!(cfg.brownout_sheds(0.1, false, true));
        // SLO-unaware deployments (infinite headroom) never brown out.
        assert!(!cfg.brownout_sheds(f64::INFINITY, true, false));
    }

    #[test]
    fn retry_after_scales_with_negative_headroom() {
        assert_eq!(retry_after_secs(f64::INFINITY), 1);
        assert_eq!(retry_after_secs(3.0), 1);
        assert_eq!(retry_after_secs(-100.0), 2);
        assert_eq!(retry_after_secs(-1000.0), 5);
        assert_eq!(retry_after_secs(-1e9), 30, "clamped");
    }

    #[test]
    fn effective_deadline_takes_tighter_of_slo_and_backstop() {
        let cfg = OverloadConfig::default();
        let reg = ClassRegistry::default_two();
        let online = reg.spec(Class::ONLINE);
        // (1000 + 100 * 10) * 4 = 8 s envelope, under the 120 s backstop.
        assert_eq!(effective_deadline(&cfg, online, 10), Duration::from_secs(8));
        // Elastic class: no SLO envelope, backstop applies.
        assert_eq!(effective_deadline(&cfg, reg.spec(Class::OFFLINE), 10), cfg.request_timeout);
        // A tight backstop wins over a roomy envelope.
        let tight = OverloadConfig { request_timeout: Duration::from_millis(200), ..cfg };
        assert_eq!(effective_deadline(&tight, online, 10), Duration::from_millis(200));
    }

    #[test]
    fn timed_out_request_returns_504_and_frees_engine_capacity() {
        // A request that overruns `request_timeout_s` must come back as
        // 504 (not 500), be shed *in-engine* (KV blocks and batch slot
        // reclaimed), and leave the replica serving.
        let server = Server::start_cluster_with_registry(
            "127.0.0.1:0",
            vec![|| {
                let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                Ok(Engine::new(sched, state, SlowBackend))
            }],
            RouterPolicy::RoundRobin.build(),
            2,
            DEFAULT_DRAIN,
            Arc::new(ClassRegistry::default_two()),
            SupervisorConfig::default(),
            OverloadConfig {
                request_timeout: Duration::from_millis(200),
                ..OverloadConfig::default()
            },
        )
        .unwrap();
        // 1024 decode steps x 3 ms >> the 200 ms timeout.
        let body = r#"{"prompt": "abcd", "max_tokens": 1024}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let r = http(server.addr, &raw);
        assert!(r.contains("504"), "timeout must be 504, got: {r}");
        assert!(r.contains("request timed out"), "{r}");
        // The engine shed the work: census drains to empty (blocks and
        // batch slot released), instead of the dead request squatting
        // until its 1024 tokens would have finished (~3 s).
        let shared = Arc::clone(&server.replica_handles[0].shared);
        let t0 = std::time::Instant::now();
        while shared.routing_snapshot().total_depth() > 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(2),
                "timed-out request still resident in the engine census"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        // And the replica keeps serving within the same timeout budget.
        let r = http(server.addr, &completions_request("wxyz"));
        assert!(r.contains("200 OK"), "replica must serve after a shed: {r}");
        // Ledger: one admitted request timed out, one finished.
        let m = body_json(&http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert_eq!(m.get("timed_out_504").as_u64(), Some(1), "{m}");
        assert_eq!(m.get("finished_200").as_u64(), Some(1), "{m}");
        assert_eq!(m.get("resident").as_u64(), Some(0), "{m}");
        server.shutdown();
    }

    #[test]
    fn full_queue_rejects_429_with_retry_after() {
        // queue_cap = 0 makes every admission find a "full" queue: the
        // request is rejected up front with 429 + Retry-After and counted
        // in the ledger, and nothing reaches the engine.
        let server = Server::start_cluster_with_registry(
            "127.0.0.1:0",
            vec![echo_engine],
            RouterPolicy::RoundRobin.build(),
            2,
            DEFAULT_DRAIN,
            Arc::new(ClassRegistry::default_two()),
            SupervisorConfig::default(),
            OverloadConfig { queue_cap: 0, ..OverloadConfig::default() },
        )
        .unwrap();
        let r = http(server.addr, &completions_request("abcd"));
        assert!(r.contains("429"), "{r}");
        assert!(r.contains("Retry-After: 1"), "{r}");
        assert!(r.contains("over capacity"), "{r}");
        let m = body_json(&http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert_eq!(m.get("admitted").as_u64(), Some(1), "{m}");
        assert_eq!(m.get("rejected_429").as_u64(), Some(1), "{m}");
        assert_eq!(m.get("resident").as_u64(), Some(0), "{m}");
        // Class 0 took the shed; class 1 is untouched.
        let shed = m.get("shed_by_class").as_arr().unwrap();
        assert_eq!(shed[0].as_u64(), Some(1), "{m}");
        assert_eq!(shed[1].as_u64(), Some(0), "{m}");
        server.shutdown();
    }

    #[test]
    fn metrics_expose_lifecycle_counters_in_both_modes() {
        const KEYS: [&str; 9] = [
            "admitted",
            "finished_200",
            "rejected_429",
            "timed_out_504",
            "failed_503",
            "resident",
            "retries",
            "breaker_open_total",
            "shed_by_class",
        ];
        let single = start_echo_server();
        let m = http(single.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        for k in KEYS {
            assert!(m.contains(&format!("\"{k}\"")), "single-replica /metrics missing {k}: {m}");
        }
        single.shutdown();
        let multi = Server::start_cluster(
            "127.0.0.1:0",
            vec![echo_engine, echo_engine],
            RouterPolicy::RoundRobin.build(),
            2,
            DEFAULT_DRAIN,
        )
        .unwrap();
        let m = http(multi.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        for k in KEYS {
            assert!(m.contains(&format!("\"{k}\"")), "multi-replica /metrics missing {k}: {m}");
        }
        multi.shutdown();
    }

    /// Backend whose first-built instance fails every execution and later
    /// instances echo — replica 0 starts broken, replica 1 (and any
    /// supervisor-restarted engine) is healthy.
    struct FirstBrokenBackend {
        fail: bool,
    }
    impl ExecutionBackend for FirstBrokenBackend {
        fn execute(&mut self, batch: &Batch, state: &mut EngineState) -> anyhow::Result<f64> {
            if self.fail {
                anyhow::bail!("injected backend failure");
            }
            for e in &batch.entries {
                let req = state.req_mut(e.id);
                let emit =
                    if e.is_prefill { req.prefilled + e.n_tokens >= req.prompt_len } else { true };
                if emit {
                    let n = req.output_tokens.len();
                    let tok = req.prompt.get(n).copied().unwrap_or(b'!' as u32);
                    req.output_tokens.push(tok);
                }
            }
            Ok(0.0005)
        }
    }

    #[test]
    fn failed_attempt_reroutes_to_live_replica() {
        static BUILDS: AtomicUsize = AtomicUsize::new(0);
        fn first_broken_engine() -> anyhow::Result<Engine<FirstBrokenBackend>> {
            let fail = BUILDS.fetch_add(1, Ordering::SeqCst) == 0;
            let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
            let sched = HybridScheduler::new(
                SchedulerConfig { latency_budget_ms: None, ..Default::default() },
                LatencyPredictor::default_seed(),
            );
            Ok(Engine::new(sched, state, FirstBrokenBackend { fail }))
        }
        let server = Server::start_cluster(
            "127.0.0.1:0",
            vec![first_broken_engine, first_broken_engine],
            RouterPolicy::RoundRobin.build(),
            2,
            DEFAULT_DRAIN,
        )
        .unwrap();
        // Round-robin sends the first request to replica 0, whose backend
        // fails before any token is delivered; the front end re-routes it
        // to replica 1 under the retry budget and the client sees 200.
        let r = http(server.addr, &completions_request_class("abcd", "online"));
        assert!(r.contains("200 OK"), "failed attempt must be rerouted: {r}");
        let m = body_json(&http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert_eq!(m.get("retries").as_u64(), Some(1), "{m}");
        assert_eq!(m.get("finished_200").as_u64(), Some(1), "{m}");
        assert_eq!(m.get("failed_503").as_u64(), Some(0), "{m}");
        server.shutdown();
    }

    #[test]
    fn aggregate_pools_latency_histograms_across_replicas() {
        // Regression for the "worst replica" latency merge: two replicas
        // with disjoint latency populations (one fast at ~10 ms, one slow
        // at ~100 ms). The worst-replica rule would report the cluster
        // p50 as the slow replica's ~100 ms; the pooled distribution's
        // median sits in the fast population. p99 must still see the
        // slow tail.
        let mk = |ms: f64| {
            let mut h = Histogram::new();
            for _ in 0..100 {
                h.observe(ms);
            }
            h
        };
        let block = |h: &Histogram| {
            Json::obj(vec![
                ("class", Json::from(0u64)),
                ("finished", Json::from(100u64)),
                ("tps", Json::from(1.0)),
                ("qps", Json::from(1.0)),
                ("mean_ttft_ms", Json::from(h.mean())),
                ("p50_ttft_ms", Json::from(h.p50())),
                ("p99_ttft_ms", Json::from(h.p99())),
                ("mean_tbt_ms", Json::from(0.0)),
                ("p50_tbt_ms", Json::from(0.0)),
                ("p99_tbt_ms", Json::from(0.0)),
                ("ttft_hist", h.to_json()),
                ("tbt_hist", Histogram::new().to_json()),
            ])
        };
        let fast = mk(10.0);
        let slow = mk(100.0);
        let a = Json::obj(vec![("classes", Json::Arr(vec![block(&fast)]))]);
        let b = Json::obj(vec![("classes", Json::Arr(vec![block(&slow)]))]);
        let m = aggregate_metrics(&[a, b], Vec::new());
        let classes = m.get("aggregate").get("classes").as_arr().unwrap();
        let p50 = classes[0].get("p50_ttft_ms").as_f64().unwrap();
        let p99 = classes[0].get("p99_ttft_ms").as_f64().unwrap();
        assert!(p50 < 50.0, "pooled p50 sits in the fast population, got {p50}");
        assert!(p50 >= 9.0, "p50 stays within a bucket of the fast mode, got {p50}");
        assert!(p99 > 50.0, "pooled p99 still sees the slow tail, got {p99}");
        assert!(
            classes[0].get("ttft_hist").get("count").as_u64() == Some(200),
            "merged histogram exported for downstream aggregation: {m}"
        );
        // Flat legacy payloads (no histograms) keep the worst-replica rule
        // — pinned separately in aggregate_metrics_sums_and_takes_worst.
    }

    fn echo_engine_with_budget() -> anyhow::Result<Engine<EchoBackend>> {
        let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
        let sched = HybridScheduler::new(
            SchedulerConfig { latency_budget_ms: Some(40.0), ..Default::default() },
            LatencyPredictor::default_seed(),
        );
        Ok(Engine::new(sched, state, EchoBackend))
    }

    #[test]
    fn brownout_429_paths_carry_retry_after() {
        // Rung 1: an impossible offline-headroom bar sheds every elastic
        // request while interactive work keeps flowing. The budgeted
        // engine makes headroom finite so the ladder engages at all.
        let server = Server::start_cluster_with_registry(
            "127.0.0.1:0",
            vec![echo_engine_with_budget],
            RouterPolicy::RoundRobin.build(),
            2,
            DEFAULT_DRAIN,
            Arc::new(ClassRegistry::default_two()),
            SupervisorConfig::default(),
            OverloadConfig {
                brownout_offline_headroom_ms: f64::INFINITY,
                ..OverloadConfig::default()
            },
        )
        .unwrap();
        let r = http(server.addr, &completions_request_class("abcd", "offline"));
        assert!(r.contains("429"), "rung-1 brown-out sheds elastic work: {r}");
        assert!(r.contains("Retry-After:"), "rung-1 429 must carry Retry-After: {r}");
        let r = http(server.addr, &completions_request_class("abcd", "online"));
        assert!(r.contains("200 OK"), "rung 1 leaves interactive admission open: {r}");
        server.shutdown();
        // Rung 3: total admission stop — even top-tier interactive work
        // sheds, and that 429 carries Retry-After too.
        let server = Server::start_cluster_with_registry(
            "127.0.0.1:0",
            vec![echo_engine_with_budget],
            RouterPolicy::RoundRobin.build(),
            2,
            DEFAULT_DRAIN,
            Arc::new(ClassRegistry::default_two()),
            SupervisorConfig::default(),
            OverloadConfig {
                brownout_online_headroom_ms: f64::INFINITY,
                ..OverloadConfig::default()
            },
        )
        .unwrap();
        let r = http(server.addr, &completions_request_class("abcd", "online"));
        assert!(r.contains("429"), "rung-3 brown-out stops all admission: {r}");
        assert!(r.contains("Retry-After:"), "rung-3 429 must carry Retry-After: {r}");
        let m = body_json(&http(server.addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert_eq!(m.get("rejected_429").as_u64(), Some(1), "{m}");
        server.shutdown();
    }

    #[test]
    fn trace_endpoint_serves_flight_recorder() {
        let server = start_echo_server();
        let r = http(server.addr, &completions_request("abcd"));
        assert!(r.contains("200 OK"), "{r}");
        // Wait out a publish interval so the recorder dump is up.
        std::thread::sleep(Duration::from_millis(450));
        let t = http(server.addr, "GET /trace HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(t.contains("200 OK"), "{t}");
        let j = body_json(&t);
        let events = j.get("events").as_arr().expect("trace carries an event list").to_vec();
        assert!(!events.is_empty(), "{t}");
        assert!(
            events.iter().any(|e| e.get("kind").as_str() == Some("admit")),
            "lifecycle starts with an admit: {t}"
        );
        assert!(
            events.iter().any(|e| e.get("kind").as_str() == Some("finish")),
            "completed request leaves a finish record: {t}"
        );
        // ?n=K truncates to the most recent K events.
        let t = http(server.addr, "GET /trace?n=1 HTTP/1.1\r\nHost: x\r\n\r\n");
        let j = body_json(&t);
        assert_eq!(j.get("events").as_arr().map(|a| a.len()), Some(1), "{t}");
        server.shutdown();
    }
}
