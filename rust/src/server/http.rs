//! Minimal HTTP/1.1 request/response handling over `std::net` (the
//! offline registry has no tokio/hyper). Enough for the serving front
//! end: one request per connection, Content-Length bodies, JSON payloads.

use std::io::{Read, Write};
use std::net::TcpStream;

#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Read one HTTP/1.1 request (bounded: 64 KiB headers, 4 MiB body).
pub fn read_request(stream: &mut TcpStream) -> std::io::Result<HttpRequest> {
    let mut buf = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    // headers
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > 64 * 1024 {
            return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "headers too large"));
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof in headers"));
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let header_text = String::from_utf8_lossy(&buf[..header_end]).into_owned();
    let mut lines = header_text.lines();
    let request_line = lines
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    for line in lines {
        let lower = line.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    if content_length > 4 * 1024 * 1024 {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "body too large"));
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            break;
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(HttpRequest { method, path, body })
}

pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write_response_with_headers(stream, status, content_type, body, &[])
}

/// Like [`write_response`], with extra response headers (e.g. the
/// `Retry-After` a 429 admission rejection carries).
pub fn write_response_with_headers(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
    extra_headers: &[(&str, String)],
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    };
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (name, value) in extra_headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn roundtrip(raw: &[u8]) -> std::io::Result<HttpRequest> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let t = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(&raw).unwrap();
            c.flush().unwrap();
            // keep the socket open until the server read everything
            std::thread::sleep(std::time::Duration::from_millis(50));
        });
        let (mut stream, _) = listener.accept().unwrap();
        let req = read_request(&mut stream);
        t.join().unwrap();
        req
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world";
        let req = roundtrip(raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.body, b"hello world");
    }

    #[test]
    fn parses_get_without_body() {
        let req = roundtrip(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert!(req.body.is_empty());
    }

    #[test]
    fn case_insensitive_content_length() {
        let raw = b"POST / HTTP/1.1\r\ncontent-length: 2\r\n\r\nok";
        assert_eq!(roundtrip(raw).unwrap().body, b"ok");
    }
}
