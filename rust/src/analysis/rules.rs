//! Per-file rule engines: annotation hygiene, wallclock, unseeded RNG,
//! map iteration, panic-freedom, and config-doc coverage.

use std::path::Path;

use super::config::{
    path_in, MAP_ITER_METHODS, MAP_ITER_SCOPE, PANIC_SCOPE, UNSEEDED_RNG_IDENTS,
    WALLCLOCK_ALLOWED,
};
use super::lexer::{AnnKind, Tok, Token};
use super::{Diagnostic, SourceFile};

/// Words that may legally precede `[` without it being an index
/// expression (slice patterns, array types after casts, ...).
const NON_INDEX_KEYWORDS: &[&str] = &[
    "let", "in", "return", "if", "else", "match", "mut", "ref", "move", "as", "break",
    "continue", "use", "where", "for", "while", "loop", "impl", "fn", "struct", "enum",
    "type", "trait", "mod", "unsafe", "dyn", "static", "const", "pub", "crate", "super",
    "yield", "await", "box",
];

fn ident<'a>(toks: &'a [Token], i: usize) -> Option<&'a str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct(toks: &[Token], i: usize, c: char) -> bool {
    toks.get(i).map(|t| &t.tok) == Some(&Tok::Punct(c))
}

pub fn check_file(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    check_annotations(sf, out);
    if !path_in(&sf.rel, WALLCLOCK_ALLOWED) {
        check_wallclock(sf, out);
    }
    check_rng(sf, out);
    if path_in(&sf.rel, MAP_ITER_SCOPE) {
        check_map_iter(sf, out);
    }
    if path_in(&sf.rel, PANIC_SCOPE) {
        check_panic(sf, out);
    }
}

/// Malformed `// lint:` comments and reason-less allows are violations:
/// a typo must not silently disable a rule.
fn check_annotations(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    for a in &sf.lexed.annotations {
        match &a.kind {
            AnnKind::Malformed(text) => out.push(Diagnostic {
                file: sf.display.clone(),
                line: a.line,
                rule: "annotation",
                msg: format!(
                    "malformed lint annotation `lint: {text}`; expected `alloc-free` or \
                     `allow(<rule>, reason=...)`"
                ),
            }),
            AnnKind::Allow { rule, has_reason: false } => out.push(Diagnostic {
                file: sf.display.clone(),
                line: a.line,
                rule: "annotation",
                msg: format!(
                    "`allow({rule})` without a reason suppresses nothing; write \
                     `allow({rule}, reason=...)`"
                ),
            }),
            _ => {}
        }
    }
}

fn check_wallclock(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    let toks = &sf.lexed.tokens;
    for i in 0..toks.len() {
        let hit = match ident(toks, i) {
            Some("Instant") => {
                punct(toks, i + 1, ':')
                    && punct(toks, i + 2, ':')
                    && ident(toks, i + 3) == Some("now")
            }
            Some("SystemTime") => true,
            _ => false,
        };
        if !hit {
            continue;
        }
        let line = toks[i].line;
        if sf.items.is_test_line(line) || sf.allowed("wallclock", line, i) {
            continue;
        }
        out.push(Diagnostic {
            file: sf.display.clone(),
            line,
            rule: "wallclock",
            msg: "wallclock read outside an allowlisted timing module breaks seeded \
                  reproducibility; use the virtual clock or annotate the measured t0 site"
                .to_string(),
        });
    }
}

fn check_rng(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    let toks = &sf.lexed.tokens;
    for i in 0..toks.len() {
        let Some(w) = ident(toks, i) else { continue };
        if !UNSEEDED_RNG_IDENTS.contains(&w) {
            continue;
        }
        let line = toks[i].line;
        if sf.items.is_test_line(line) || sf.allowed("rng", line, i) {
            continue;
        }
        out.push(Diagnostic {
            file: sf.display.clone(),
            line,
            rule: "rng",
            msg: format!("unseeded randomness (`{w}`); use the seeded xoshiro in util/rng.rs"),
        });
    }
}

/// Names in this file declared with a `HashMap`/`HashSet` type
/// (`name: HashMap<..>` in fields, params, or let bindings).
fn map_typed_names(toks: &[Token]) -> Vec<String> {
    let mut names = Vec::new();
    for i in 0..toks.len() {
        let Some(name) = ident(toks, i) else { continue };
        // `name :` but not `name ::`
        if !punct(toks, i + 1, ':') || punct(toks, i + 2, ':') {
            continue;
        }
        let mut j = i + 2;
        let mut steps = 0;
        while steps < 8 {
            match toks.get(j).map(|t| &t.tok) {
                Some(Tok::Ident(w)) if w == "HashMap" || w == "HashSet" => {
                    names.push(name.to_string());
                    break;
                }
                Some(Tok::Ident(_)) | Some(Tok::Punct(':')) | Some(Tok::Punct('&')) => {
                    j += 1;
                    steps += 1;
                }
                _ => break,
            }
        }
    }
    names.sort();
    names.dedup();
    names
}

fn check_map_iter(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    let toks = &sf.lexed.tokens;
    let maps = map_typed_names(toks);
    if maps.is_empty() {
        return;
    }
    let is_map = |w: &str| maps.iter().any(|m| m == w);
    let mut flag = |i: usize, name: &str, how: &str, out: &mut Vec<Diagnostic>| {
        let line = toks[i].line;
        if sf.items.is_test_line(line) || sf.allowed("map-iter", line, i) {
            return;
        }
        out.push(Diagnostic {
            file: sf.display.clone(),
            line,
            rule: "map-iter",
            msg: format!(
                "iteration over hash-ordered `{name}` ({how}) feeds batches/snapshots/CSVs \
                 in nondeterministic order; use a BTreeMap/slab or sort first"
            ),
        });
    };
    for i in 0..toks.len() {
        // `name.iter()` / `.keys()` / ...
        if let Some(name) = ident(toks, i) {
            if is_map(name) && punct(toks, i + 1, '.') {
                if let Some(m) = ident(toks, i + 2) {
                    if MAP_ITER_METHODS.contains(&m) && punct(toks, i + 3, '(') {
                        flag(i + 2, name, &format!(".{m}()"), out);
                    }
                }
            }
        }
        // `for x in &name {` / `for x in name {`
        if ident(toks, i) == Some("in") {
            let mut last: Option<(usize, &str)> = None;
            let mut j = i + 1;
            let mut steps = 0;
            while steps < 8 {
                match toks.get(j).map(|t| &t.tok) {
                    Some(Tok::Punct('{')) => {
                        if let Some((k, name)) = last {
                            if is_map(name) {
                                flag(k, name, "for-in", out);
                            }
                        }
                        break;
                    }
                    Some(Tok::Ident(w)) if w != "mut" => last = Some((j, w.as_str())),
                    Some(Tok::Punct('&')) | Some(Tok::Punct('.')) | Some(Tok::Ident(_)) => {}
                    _ => break,
                }
                j += 1;
                steps += 1;
            }
        }
    }
}

fn check_panic(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    let toks = &sf.lexed.tokens;
    let mut flag = |i: usize, msg: String, out: &mut Vec<Diagnostic>| {
        let line = toks[i].line;
        if sf.items.is_test_line(line) || sf.allowed("panic", line, i) {
            return;
        }
        out.push(Diagnostic { file: sf.display.clone(), line, rule: "panic", msg });
    };
    for i in 0..toks.len() {
        match &toks[i].tok {
            Tok::Punct('.') => {
                if let Some(m) = ident(toks, i + 1) {
                    if (m == "unwrap" || m == "expect") && punct(toks, i + 2, '(') {
                        flag(
                            i + 1,
                            format!(
                                "`.{m}()` in a hot path can kill a serving loop; return a \
                                 typed error, log to the anomalies ledger, or annotate"
                            ),
                            out,
                        );
                    }
                }
            }
            Tok::Ident(w)
                if (w == "panic"
                    || w == "unreachable"
                    || w == "todo"
                    || w == "unimplemented")
                    && punct(toks, i + 1, '!') =>
            {
                flag(i, format!("`{w}!` in a hot path"), out);
            }
            Tok::Punct('[') if i > 0 => {
                let indexy = match &toks[i - 1].tok {
                    Tok::Ident(w) => !NON_INDEX_KEYWORDS.contains(&w.as_str()),
                    Tok::Punct(')') | Tok::Punct(']') => true,
                    _ => false,
                };
                if indexy {
                    flag(
                        i,
                        "indexing can panic on out-of-range input; use `.get()` or \
                         annotate the invariant that bounds it"
                            .to_string(),
                        out,
                    );
                }
            }
            _ => {}
        }
    }
}

/// Config-doc coverage: every flat-JSON knob parsed in `config/mod.rs`
/// must be documented (as `` `key` ``) in README.md or DESIGN.md, and
/// every knob listed in a doc's `<!-- lint: config-keys -->` region
/// must be parsed.
pub fn check_config_doc(sources: &[SourceFile], repo_root: &Path, out: &mut Vec<Diagnostic>) {
    let Some(cfg) = sources.iter().find(|s| s.rel == "config/mod.rs") else { return };
    let toks = &cfg.lexed.tokens;
    let mut parsed: Vec<(String, u32)> = Vec::new();
    for i in 0..toks.len() {
        let Some(w) = ident(toks, i) else { continue };
        if (w == "get" || w == "num_field" || w == "int_field") && punct(toks, i + 1, '(') {
            if let Some(Tok::Str(key)) = toks.get(i + 2).map(|t| &t.tok) {
                if !key.is_empty()
                    && !cfg.items.is_test_line(toks[i].line)
                    && !parsed.iter().any(|(k, _)| k == key)
                {
                    parsed.push((key.clone(), toks[i].line));
                }
            }
        }
    }

    let readme = std::fs::read_to_string(repo_root.join("README.md")).unwrap_or_default();
    let design = std::fs::read_to_string(repo_root.join("DESIGN.md")).unwrap_or_default();
    for (key, line) in &parsed {
        let tick = format!("`{key}`");
        if !readme.contains(&tick) && !design.contains(&tick) {
            out.push(Diagnostic {
                file: cfg.display.clone(),
                line: *line,
                rule: "config-doc",
                msg: format!(
                    "config knob \"{key}\" is parsed here but documented in neither \
                     README.md nor DESIGN.md"
                ),
            });
        }
    }

    for (name, text) in [("README.md", readme.as_str()), ("DESIGN.md", design.as_str())] {
        let mut in_region = false;
        for (idx, line) in text.lines().enumerate() {
            if line.contains("<!-- lint: config-keys -->") {
                in_region = true;
                continue;
            }
            if line.contains("<!-- lint: end-config-keys -->") {
                in_region = false;
                continue;
            }
            if !in_region {
                continue;
            }
            let mut parts = line.split('`');
            let (Some(_), Some(key)) = (parts.next(), parts.next()) else { continue };
            let valid_key = !key.is_empty()
                && key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_');
            if valid_key && !parsed.iter().any(|(k, _)| k == key) {
                out.push(Diagnostic {
                    file: name.to_string(),
                    line: idx as u32 + 1,
                    rule: "config-doc",
                    msg: format!(
                        "doc lists config knob \"{key}\" but rust/src/config/mod.rs does \
                         not parse it"
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::items;
    use super::super::lexer;
    use super::*;

    fn file(rel: &str, src: &str) -> SourceFile {
        let lexed = lexer::lex(src);
        let items = items::build(&lexed);
        SourceFile { rel: rel.to_string(), display: rel.to_string(), lexed, items }
    }

    fn run(rel: &str, src: &str) -> Vec<Diagnostic> {
        let sf = file(rel, src);
        let mut out = Vec::new();
        check_file(&sf, &mut out);
        out
    }

    #[test]
    fn wallclock_flagged_outside_allowlist() {
        let src = "fn f() { let t0 = std::time::Instant::now(); }";
        let d = run("coordinator/foo.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "wallclock");
        assert!(run("server/mod.rs", src).is_empty(), "allowlisted module");
        let annotated = "fn f() {\n\
             let t0 = std::time::Instant::now(); // lint: allow(wallclock, reason=bench t0)\n}";
        assert!(run("coordinator/foo.rs", annotated).is_empty());
    }

    #[test]
    fn map_iteration_flagged_in_scope() {
        let src = "
struct S { reqs: HashMap<u64, u32> }
impl S {
    fn ids(&self) -> Vec<u64> { self.reqs.keys().copied().collect() }
    fn ok(&self) -> Option<&u32> { self.reqs.get(&1) }
}
fn g(m: &HashMap<u64, u32>) { for (_k, _v) in m { } }
";
        let d = run("coordinator/foo.rs", src);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().all(|x| x.rule == "map-iter"));
        assert!(run("util/foo.rs", src).is_empty(), "outside the scope");
    }

    #[test]
    fn panic_constructs_flagged_in_hot_files() {
        let src = "
fn f(v: &[u32], i: usize) -> u32 {
    let a = v.get(i).unwrap();
    let b = v[i];
    if i > 100 { panic!(\"too big\") }
    *a + b
}
#[cfg(test)]
mod tests {
    #[test]
    fn t() { super::f(&[1], 0); assert_eq!((&[1u32])[0], 1); }
}
";
        let d = run("coordinator/state.rs", src);
        assert_eq!(d.len(), 3, "{d:?}");
        assert!(d.iter().all(|x| x.rule == "panic"));
        assert!(run("coordinator/queues.rs", src).is_empty(), "not a panic-scope file");
    }

    #[test]
    fn fn_level_allow_suppresses() {
        let src = "
// lint: allow(panic, reason=index bounded by registry validation)
fn f(v: &[u32]) -> u32 { v[0] }
";
        assert!(run("coordinator/state.rs", src).is_empty());
    }

    #[test]
    fn reasonless_allow_reported_and_ignored() {
        let src = "
// lint: allow(panic)
fn f(v: &[u32]) -> u32 { v[0] }
";
        let d = run("coordinator/state.rs", src);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().any(|x| x.rule == "annotation"));
        assert!(d.iter().any(|x| x.rule == "panic"));
    }

    #[test]
    fn slice_patterns_and_types_not_flagged() {
        let src = "
fn f(v: &[u32; 4]) -> [u32; 2] {
    let [a, b, ..] = v;
    let arr = [*a, *b];
    arr
}
";
        assert!(run("coordinator/state.rs", src).is_empty());
    }

    #[test]
    fn rng_flagged_everywhere() {
        let d = run("util/foo.rs", "fn f() { let r = thread_rng(); }");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "rng");
    }
}
