//! Minimal Rust lexer for the in-repo lint (`hygen lint`).
//!
//! Produces a line-numbered token stream with comments stripped and
//! literal *contents* dropped (a string literal becomes one opaque
//! token), plus every `// lint:` marker comment found in the file. This
//! is deliberately not a full Rust lexer — it only needs to be exact
//! about the constructs that could hide or fake a rule match in a plain
//! text scan: nested block comments, raw/byte strings, escapes, and the
//! char-literal-vs-lifetime ambiguity of `'`.

/// One lexed token.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// Ordinary string literal, contents preserved (the config-doc rule
    /// reads knob names out of `j.get("...")` calls).
    Str(String),
    /// Any other literal (raw string, char, byte, number); contents
    /// dropped.
    Lit,
    /// A lifetime such as `'a` (kept distinct so `'` handling is exact).
    Lifetime,
    /// Single punctuation character.
    Punct(char),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// 1-based source line.
    pub line: u32,
    pub tok: Tok,
}

/// One `// lint: ...` marker comment.
#[derive(Debug, Clone, PartialEq)]
pub struct Annotation {
    pub line: u32,
    pub kind: AnnKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum AnnKind {
    /// `// lint: alloc-free` — marks the next `fn` as a root of the
    /// alloc-free rule's transitive check.
    AllocFree,
    /// `// lint: allow(<rule>, reason=...)`. `has_reason` records
    /// whether a non-empty reason was given; an allow without one does
    /// not suppress anything and is itself reported.
    Allow { rule: String, has_reason: bool },
    /// Unparseable `// lint:` comment — reported as a violation so a
    /// typo cannot silently disable a rule.
    Malformed(String),
}

/// Lexer output for one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    /// `// lint:` annotations in line order.
    pub annotations: Vec<Annotation>,
}

/// Lex one file. Never fails: unterminated constructs simply consume
/// the rest of the input (rustc will reject such a file anyway).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut out = Lexed::default();
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            _ if c.is_ascii_whitespace() => i += 1,
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                let text = &src[start..i];
                // `// lint: ...` (doc comments `///` never match: the
                // char after `//` must not be `/` or `!`).
                let body = &text[2..];
                if !body.starts_with('/') && !body.starts_with('!') {
                    if let Some(rest) = body.trim_start().strip_prefix("lint:") {
                        out.annotations
                            .push(Annotation { line, kind: parse_annotation(rest.trim()) });
                    }
                }
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                i += 2;
                let mut depth = 1u32;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                let text = skip_string(b, &mut i, &mut line);
                out.tokens.push(Token { line, tok: Tok::Str(text) });
            }
            b'\'' => {
                let next = b.get(i + 1).copied().unwrap_or(0);
                let lifetime = (next.is_ascii_alphabetic() || next == b'_')
                    && b.get(i + 2) != Some(&b'\'');
                if lifetime {
                    i += 1;
                    while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                        i += 1;
                    }
                    out.tokens.push(Token { line, tok: Tok::Lifetime });
                } else {
                    // Char literal: 'a', '\n', '\u{1F600}', or a
                    // multi-byte UTF-8 scalar.
                    i += 1;
                    if b.get(i) == Some(&b'\\') {
                        i += 2; // skip the escape lead + escaped char
                    }
                    while i < b.len() && b[i] != b'\'' {
                        if b[i] == b'\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                    i += 1; // closing quote
                    out.tokens.push(Token { line, tok: Tok::Lit });
                }
            }
            _ if c.is_ascii_digit() => {
                i += 1;
                loop {
                    if i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                        i += 1;
                    } else if b.get(i) == Some(&b'.')
                        && b.get(i + 1).is_some_and(u8::is_ascii_digit)
                    {
                        i += 2;
                    } else {
                        break;
                    }
                }
                out.tokens.push(Token { line, tok: Tok::Lit });
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                let word = &src[start..i];
                // Raw / byte string prefixes lex as an ident glued to
                // the opening quote: r"..", r#".."#, b"..", br#".."#,
                // b'x'.
                match word {
                    "r" | "br" if matches!(b.get(i), Some(&b'"') | Some(&b'#')) => {
                        let mut hashes = 0usize;
                        let mut j = i;
                        while b.get(j) == Some(&b'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if b.get(j) == Some(&b'"') {
                            i = j + 1;
                            skip_raw_string(b, &mut i, &mut line, hashes);
                            out.tokens.push(Token { line, tok: Tok::Lit });
                        } else {
                            // `r#ident` raw identifier or stray `#`.
                            out.tokens.push(Token { line, tok: Tok::Ident(word.to_string()) });
                        }
                    }
                    "b" if b.get(i) == Some(&b'"') => {
                        skip_string(b, &mut i, &mut line);
                        out.tokens.push(Token { line, tok: Tok::Lit });
                    }
                    _ => out.tokens.push(Token { line, tok: Tok::Ident(word.to_string()) }),
                }
            }
            _ => {
                // Multi-byte UTF-8 in code position only appears inside
                // literals/comments, all handled above; treat any other
                // byte as punctuation.
                out.tokens.push(Token { line, tok: Tok::Punct(c as char) });
                i += 1;
            }
        }
    }
    out
}

/// Skip a `"`-delimited string starting at `b[*i]` (the opening quote or
/// just before the contents when called for `b"`), returning its
/// contents with escape sequences left raw.
fn skip_string(b: &[u8], i: &mut usize, line: &mut u32) -> String {
    debug_assert_eq!(b[*i], b'"');
    *i += 1;
    let start = *i;
    let mut end = *i;
    while *i < b.len() {
        match b[*i] {
            b'\\' => {
                if b.get(*i + 1) == Some(&b'\n') {
                    *line += 1;
                }
                *i += 2;
            }
            b'"' => {
                end = *i;
                *i += 1;
                break;
            }
            b'\n' => {
                *line += 1;
                *i += 1;
            }
            _ => *i += 1,
        }
        end = *i;
    }
    String::from_utf8_lossy(&b[start..end.min(b.len())]).into_owned()
}

/// Skip a raw string body; `*i` points just past the opening `"`.
fn skip_raw_string(b: &[u8], i: &mut usize, line: &mut u32, hashes: usize) {
    while *i < b.len() {
        if b[*i] == b'\n' {
            *line += 1;
            *i += 1;
            continue;
        }
        if b[*i] == b'"' {
            let mut k = 0usize;
            while k < hashes && b.get(*i + 1 + k) == Some(&b'#') {
                k += 1;
            }
            if k == hashes {
                *i += 1 + hashes;
                return;
            }
        }
        *i += 1;
    }
}

fn parse_annotation(text: &str) -> AnnKind {
    if text == "alloc-free" {
        return AnnKind::AllocFree;
    }
    if let Some(open) = text.strip_prefix("allow(") {
        if let Some(close) = open.rfind(')') {
            let inner = &open[..close];
            let (rule, rest) = match inner.split_once(',') {
                Some((r, rest)) => (r.trim(), rest.trim()),
                None => (inner.trim(), ""),
            };
            let has_reason =
                rest.strip_prefix("reason=").is_some_and(|r| !r.trim().is_empty());
            if !rule.is_empty() && rule.chars().all(|c| c.is_ascii_alphanumeric() || c == '-') {
                return AnnKind::Allow { rule: rule.to_string(), has_reason };
            }
        }
    }
    AnnKind::Malformed(text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_tokens() {
        let src = r##"
            // Instant::now in a comment
            /* HashMap /* nested */ still comment */
            let s = "Instant::now inside a string";
            let r = r#"unwrap() in a raw string"#;
            let c = '"'; // a quote char must not open a string
            let real = foo();
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|s| s == "Instant" || s == "HashMap" || s == "unwrap"));
        assert!(ids.iter().any(|s| s == "real"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { let c = 'x'; x }";
        let lx = lex(src);
        let lifetimes = lx.tokens.iter().filter(|t| t.tok == Tok::Lifetime).count();
        let lits = lx.tokens.iter().filter(|t| t.tok == Tok::Lit).count();
        assert_eq!(lifetimes, 3);
        assert_eq!(lits, 1);
    }

    #[test]
    fn string_contents_kept_for_config_rule() {
        let lx = lex(r#"j.get("latency_budget_ms")"#);
        assert!(lx
            .tokens
            .iter()
            .any(|t| t.tok == Tok::Str("latency_budget_ms".to_string())));
    }

    #[test]
    fn annotations_parse() {
        let src = "\n// lint: alloc-free\nfn f() {}\n\
                   x(); // lint: allow(panic, reason=bounded by registry)\n\
                   // lint: allow(panic)\n\
                   // lint: allwo(panic, reason=typo)\n";
        let lx = lex(src);
        assert_eq!(lx.annotations.len(), 4);
        assert_eq!(lx.annotations[0].kind, AnnKind::AllocFree);
        assert_eq!(lx.annotations[0].line, 2);
        assert_eq!(
            lx.annotations[1].kind,
            AnnKind::Allow { rule: "panic".into(), has_reason: true }
        );
        assert_eq!(
            lx.annotations[2].kind,
            AnnKind::Allow { rule: "panic".into(), has_reason: false }
        );
        assert!(matches!(lx.annotations[3].kind, AnnKind::Malformed(_)));
    }

    #[test]
    fn doc_comments_are_not_annotations() {
        let lx = lex("/// lint: alloc-free\n//! lint: alloc-free\nfn f() {}\n");
        assert!(lx.annotations.is_empty());
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "let a = \"two\nlines\";\n/* one\ntwo */\nlet b = 1;";
        let lx = lex(src);
        let b_line = lx
            .tokens
            .iter()
            .find(|t| t.tok == Tok::Ident("b".into()))
            .map(|t| t.line)
            .unwrap();
        assert_eq!(b_line, 5);
    }
}
