//! The `alloc` rule: functions annotated `// lint: alloc-free` must not
//! reach an allocating construct, transitively within the crate — the
//! static complement of the `CountingAlloc` runtime probe (which proves
//! the steady-state decode loop allocates nothing, but only for the
//! inputs a bench happens to replay).
//!
//! Call edges are resolved *by name, only when unambiguous*: a call
//! `foo(..)` or `.foo(..)` follows into `fn foo` when exactly one
//! non-test function with that name exists in the crate. Ambiguous or
//! external names are skipped — this rule is deliberately best-effort
//! on reachability and exact on the constructs themselves. The banned
//! list targets constructs that allocate fresh storage per call
//! (`Vec::new` + push warm-up is the runtime probe's amortized domain):
//! container constructors, `vec!`/`format!`, `.clone()`/`.collect()`/
//! `.to_vec()`/`.to_string()`/`.to_owned()`, and `Box::new`. The
//! refcount-bump path forms `Arc::clone(&x)`/`Rc::clone(&x)` stay legal
//! (that idiom exists precisely to signal "not a deep clone").
//!
//! A function annotated `// lint: allow(alloc, reason=...)` is treated
//! as audited and not descended into; a line-level allow suppresses one
//! construct (e.g. the cold anomaly-ledger `format!` in an otherwise
//! hot transition).

use std::collections::BTreeMap;

use super::lexer::Tok;
use super::{Diagnostic, SourceFile};

const CONTAINERS: &[&str] = &[
    "Vec", "String", "Box", "HashMap", "HashSet", "BTreeMap", "BTreeSet", "VecDeque", "Arc",
    "Rc",
];
const CTORS: &[&str] = &["new", "with_capacity", "from"];
const ALLOC_METHODS: &[&str] = &["clone", "to_vec", "to_string", "to_owned", "collect"];
const CALL_KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "move", "fn", "in", "let", "else",
    "Some", "Ok", "Err", "None",
];

pub fn check(sources: &[SourceFile], out: &mut Vec<Diagnostic>) {
    // Crate-wide fn-name index over non-test fns: name -> (file, fn).
    let mut index: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    for (fi, sf) in sources.iter().enumerate() {
        for (gi, f) in sf.items.fns.iter().enumerate() {
            if !f.in_test {
                index.entry(f.name.as_str()).or_default().push((fi, gi));
            }
        }
    }

    let mut visited: Vec<(usize, usize)> = Vec::new();
    for (fi, sf) in sources.iter().enumerate() {
        for (gi, f) in sf.items.fns.iter().enumerate() {
            if f.alloc_free && !f.in_test {
                let mut path = vec![qualified(sources, fi, gi)];
                scan_fn(sources, &index, fi, gi, &mut visited, &mut path, out);
            }
        }
    }
}

fn qualified(sources: &[SourceFile], fi: usize, gi: usize) -> String {
    let f = &sources[fi].items.fns[gi];
    match &f.owner {
        Some(o) => format!("{o}::{}", f.name),
        None => f.name.clone(),
    }
}

fn scan_fn(
    sources: &[SourceFile],
    index: &BTreeMap<&str, Vec<(usize, usize)>>,
    fi: usize,
    gi: usize,
    visited: &mut Vec<(usize, usize)>,
    path: &mut Vec<String>,
    out: &mut Vec<Diagnostic>,
) {
    if visited.contains(&(fi, gi)) {
        return;
    }
    visited.push((fi, gi));
    let sf = &sources[fi];
    let f = &sf.items.fns[gi];
    // An audited function stops the descent.
    if f.allows.iter().any(|r| r == "alloc") {
        return;
    }
    let toks = &sf.lexed.tokens;
    let (lo, hi) = f.body;
    let root = path.first().cloned().unwrap_or_default();
    let via = if path.len() > 1 {
        format!(" (reached from alloc-free `{root}` via {})", path[1..].join(" -> "))
    } else {
        String::new()
    };

    let mut i = lo;
    while i <= hi && i < toks.len() {
        let line = toks[i].line;
        match &toks[i].tok {
            // `Vec::new`, `Box::new`, `String::from`, ...
            Tok::Ident(c) if CONTAINERS.contains(&c.as_str()) => {
                if punct(sf, i + 1, ':') && punct(sf, i + 2, ':') {
                    if let Some(m) = ident(sf, i + 3) {
                        if CTORS.contains(&m) && !sf.allowed("alloc", line, i) {
                            out.push(diag(sf, line, format!("`{c}::{m}` allocates{via}")));
                        }
                    }
                }
            }
            // `vec![..]`, `format!(..)`
            Tok::Ident(m) if (m == "vec" || m == "format") && punct(sf, i + 1, '!') => {
                if !sf.allowed("alloc", line, i) {
                    out.push(diag(sf, line, format!("`{m}!` allocates{via}")));
                }
            }
            // `.clone()`, `.collect::<..>()`, `.to_vec()`, ...
            Tok::Punct('.') => {
                if let Some(m) = ident(sf, i + 1) {
                    if ALLOC_METHODS.contains(&m)
                        && (punct(sf, i + 2, '(') || punct(sf, i + 2, ':'))
                        && !sf.allowed("alloc", toks[i + 1].line, i + 1)
                    {
                        out.push(diag(sf, toks[i + 1].line, format!("`.{m}()` allocates{via}")));
                    }
                }
            }
            _ => {}
        }
        // Call edges: `name(..)` with exactly one crate-wide definition.
        if let Some(name) = ident(sf, i) {
            if punct(sf, i + 1, '(') && !CALL_KEYWORDS.contains(&name) {
                if let Some(defs) = index.get(name) {
                    if let [(tfi, tgi)] = defs[..] {
                        if (tfi, tgi) != (fi, gi) {
                            path.push(qualified(sources, tfi, tgi));
                            scan_fn(sources, index, tfi, tgi, visited, path, out);
                            path.pop();
                        }
                    }
                }
            }
        }
        i += 1;
    }
}

fn ident(sf: &SourceFile, i: usize) -> Option<&str> {
    match sf.lexed.tokens.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct(sf: &SourceFile, i: usize, c: char) -> bool {
    sf.lexed.tokens.get(i).map(|t| &t.tok) == Some(&Tok::Punct(c))
}

fn diag(sf: &SourceFile, line: u32, msg: String) -> Diagnostic {
    Diagnostic { file: sf.display.clone(), line, rule: "alloc", msg }
}

#[cfg(test)]
mod tests {
    use super::super::{items, lexer, SourceFile};
    use super::*;

    fn file(rel: &str, src: &str) -> SourceFile {
        let lexed = lexer::lex(src);
        let items = items::build(&lexed);
        SourceFile { rel: rel.to_string(), display: rel.to_string(), lexed, items }
    }

    fn run(srcs: &[(&str, &str)]) -> Vec<Diagnostic> {
        let sources: Vec<SourceFile> = srcs.iter().map(|(r, s)| file(r, s)).collect();
        let mut out = Vec::new();
        check(&sources, &mut out);
        out.sort();
        out
    }

    #[test]
    fn direct_and_transitive_allocation_flagged() {
        let d = run(&[(
            "a.rs",
            "
// lint: alloc-free
fn hot() { helper(); }
fn helper() { let v = Vec::new(); let _ = v.clone(); }
fn cold() { let _s = format!(\"untouched\"); }
",
        )]);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d[0].msg.contains(".clone()"));
        assert!(d[1].msg.contains("Vec::new"));
        assert!(d[1].msg.contains("via helper"), "{}", d[1].msg);
    }

    #[test]
    fn arc_clone_path_form_is_legal() {
        let d = run(&[(
            "a.rs",
            "
// lint: alloc-free
fn hot(x: &Arc<u32>) { let _y = Arc::clone(x); }
",
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn ambiguous_callee_not_followed() {
        let d = run(&[(
            "a.rs",
            "
// lint: alloc-free
fn hot() { twice(); }
struct A; struct B;
impl A { fn twice(&self) { let _ = vec![1]; } }
impl B { fn twice(&self) { let _ = vec![2]; } }
",
        )]);
        assert!(d.is_empty(), "two defs of `twice` -> skipped: {d:?}");
    }

    #[test]
    fn line_allow_and_audited_fn() {
        let d = run(&[(
            "a.rs",
            "
// lint: alloc-free
fn hot() {
    // lint: allow(alloc, reason=cold anomaly path)
    let _ = format!(\"anomaly\");
    audited();
}
// lint: allow(alloc, reason=audited by hand)
fn audited() { let _ = Vec::new(); }
",
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn cycles_terminate() {
        let d = run(&[(
            "a.rs",
            "
// lint: alloc-free
fn ping() { pong(); }
fn pong() { ping(); let _ = Box::new(1); }
",
        )]);
        assert_eq!(d.len(), 1);
        assert!(d[0].msg.contains("Box::new"));
    }
}
