//! Item-level structure over the token stream: `fn` items with body
//! spans and attached `// lint:` annotations, `impl`-block owners, and
//! `#[cfg(test)]` / `#[test]` regions (excluded from every rule).

use super::lexer::{AnnKind, Lexed, Tok};

/// One `fn` item with a body.
#[derive(Debug)]
pub struct FnItem {
    pub name: String,
    /// Owning type for methods in an `impl` block (`Engine` for
    /// `Engine::apply`); `None` for free functions.
    pub owner: Option<String>,
    /// Line of the `fn` keyword.
    pub line: u32,
    /// Token-index range of the body, inclusive of both braces.
    pub body: (usize, usize),
    /// Marked `// lint: alloc-free` — a root of the alloc rule.
    pub alloc_free: bool,
    /// Function-scoped `allow(<rule>, reason=...)` rule names (only
    /// allows that carried a reason).
    pub allows: Vec<String>,
    /// Inside a `#[cfg(test)]` region or under a `#[test]` attribute.
    pub in_test: bool,
}

/// Structure extracted from one lexed file.
#[derive(Debug, Default)]
pub struct FileItems {
    pub fns: Vec<FnItem>,
    /// Line ranges (inclusive) covered by `#[cfg(test)]` items and
    /// `#[test]` functions.
    pub test_regions: Vec<(u32, u32)>,
}

impl FileItems {
    pub fn is_test_line(&self, line: u32) -> bool {
        self.test_regions.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// The innermost function whose body token range contains `tok_idx`.
    pub fn enclosing_fn(&self, tok_idx: usize) -> Option<&FnItem> {
        self.fns
            .iter()
            .filter(|f| f.body.0 <= tok_idx && tok_idx <= f.body.1)
            .max_by_key(|f| f.body.0)
    }
}

/// Words that can precede `fn` in an item header (walked over when
/// attaching annotations above the item).
const FN_QUALIFIERS: &[&str] = &["pub", "crate", "super", "in", "unsafe", "const", "async", "extern", "default"];

pub fn build(lx: &Lexed) -> FileItems {
    let toks = &lx.tokens;
    let mut out = FileItems::default();

    // ---- test regions: #[cfg(test)] items and #[test] fns ----
    let mut i = 0usize;
    while i < toks.len() {
        if is_test_attr_at(toks, i) {
            // Find what the attribute covers: skip any further
            // attributes, then scan to the item's opening `{` (or `;`
            // for an item with no body).
            let mut j = skip_attrs(toks, i);
            let start_line = tok_line(toks, i);
            let mut paren = 0i32;
            while j < toks.len() {
                match &toks[j].tok {
                    Tok::Punct('(') | Tok::Punct('[') => paren += 1,
                    Tok::Punct(')') | Tok::Punct(']') => paren -= 1,
                    Tok::Punct('{') if paren == 0 => break,
                    Tok::Punct(';') if paren == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            if j < toks.len() && toks[j].tok == Tok::Punct('{') {
                let close = match_brace(toks, j);
                out.test_regions.push((start_line, tok_line(toks, close)));
                // Keep scanning *inside* the region: nested `#[test]`
                // fns get their own (overlapping) regions.
                i = j + 1;
                continue;
            }
        }
        i += 1;
    }

    // ---- impl owners + fn items ----
    // Stack of (brace_depth_at_open, owner_name) for impl blocks.
    let mut impl_stack: Vec<(i32, String)> = Vec::new();
    let mut depth: i32 = 0;
    let mut i = 0usize;
    while i < toks.len() {
        match &toks[i].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if let Some(&(d, _)) = impl_stack.last() {
                    if depth < d {
                        impl_stack.pop();
                    }
                }
            }
            Tok::Ident(w) if w == "impl" || w == "trait" => {
                if let Some((owner, body_open)) = parse_impl_header(toks, i) {
                    impl_stack.push((depth + 1, owner));
                    depth += 1;
                    i = body_open + 1;
                    continue;
                }
            }
            Tok::Ident(w) if w == "fn" => {
                if let Some(item) = parse_fn(lx, toks, i, &impl_stack, &out) {
                    let skip_to = item.body.1;
                    out.fns.push(item);
                    // Do NOT skip the body: nested fns/closures stay
                    // visible, and brace depth must keep counting.
                    let _ = skip_to;
                }
            }
            _ => {}
        }
        i += 1;
    }
    out
}

fn tok_line(toks: &[super::lexer::Token], i: usize) -> u32 {
    toks.get(i).map_or(u32::MAX, |t| t.line)
}

fn ident_at<'a>(toks: &'a [super::lexer::Token], i: usize) -> Option<&'a str> {
    match toks.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

/// `#[cfg(test)]` or `#[test]` or `#[cfg_attr(..test..)]`? Only the
/// first two — `cfg_attr` gating is per-runner, not a test region.
fn is_test_attr_at(toks: &[super::lexer::Token], i: usize) -> bool {
    if toks.get(i).map(|t| &t.tok) != Some(&Tok::Punct('#'))
        || toks.get(i + 1).map(|t| &t.tok) != Some(&Tok::Punct('['))
    {
        return false;
    }
    match ident_at(toks, i + 2) {
        Some("test") => toks.get(i + 3).map(|t| &t.tok) == Some(&Tok::Punct(']')),
        Some("cfg") => {
            toks.get(i + 3).map(|t| &t.tok) == Some(&Tok::Punct('('))
                && ident_at(toks, i + 4) == Some("test")
                && toks.get(i + 5).map(|t| &t.tok) == Some(&Tok::Punct(')'))
        }
        _ => false,
    }
}

/// Starting at a `#` token, skip consecutive `#[...]` groups; returns
/// the index just past them.
fn skip_attrs(toks: &[super::lexer::Token], mut i: usize) -> usize {
    while toks.get(i).map(|t| &t.tok) == Some(&Tok::Punct('#'))
        && toks.get(i + 1).map(|t| &t.tok) == Some(&Tok::Punct('['))
    {
        let mut depth = 0i32;
        let mut j = i + 1;
        while j < toks.len() {
            match toks[j].tok {
                Tok::Punct('[') => depth += 1,
                Tok::Punct(']') => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        i = j + 1;
    }
    i
}

/// Index of the `}` matching the `{` at `open`.
fn match_brace(toks: &[super::lexer::Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < toks.len() {
        match toks[j].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    toks.len() - 1
}

/// Parse `impl<...> Type {` / `impl Trait for Type {`; returns the
/// implemented type's name and the index of the body `{`.
fn parse_impl_header(
    toks: &[super::lexer::Token],
    impl_idx: usize,
) -> Option<(String, usize)> {
    let mut angle = 0i32;
    let mut j = impl_idx + 1;
    let mut first_ident: Option<String> = None;
    let mut after_for: Option<String> = None;
    let mut saw_for = false;
    let mut saw_where = false;
    let mut prev_dash = false;
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct('{') if angle == 0 => {
                let owner = after_for.or(first_ident)?;
                return Some((owner, j));
            }
            Tok::Punct(';') if angle == 0 => return None,
            Tok::Punct('<') => angle += 1,
            Tok::Punct('>') => {
                if prev_dash {
                    // `->` in a where-clause `Fn() -> T` bound.
                } else {
                    angle -= 1;
                }
            }
            Tok::Ident(w) if w == "for" && angle == 0 => saw_for = true,
            Tok::Ident(w) if w == "where" && angle == 0 => saw_where = true,
            Tok::Ident(w) => {
                if angle == 0 && !saw_where {
                    // Keep the LAST path segment (`state::EngineState`
                    // -> `EngineState`); a single `:` is a trait bound
                    // (`trait T: Send`), not a path.
                    let prev_colon = j > 1
                        && toks[j - 1].tok == Tok::Punct(':')
                        && toks[j - 2].tok == Tok::Punct(':');
                    if saw_for {
                        if after_for.is_none() || prev_colon {
                            after_for = Some(w.clone());
                        }
                    } else if first_ident.is_none() || prev_colon {
                        first_ident = Some(w.clone());
                    }
                }
            }
            _ => {}
        }
        prev_dash = toks[j].tok == Tok::Punct('-');
        j += 1;
    }
    None
}

fn parse_fn(
    lx: &Lexed,
    toks: &[super::lexer::Token],
    fn_idx: usize,
    impl_stack: &[(i32, String)],
    so_far: &FileItems,
) -> Option<FnItem> {
    let name = ident_at(toks, fn_idx + 1)?.to_string();
    // Find the body `{` (or bail at `;` — trait method declaration).
    let mut j = fn_idx + 2;
    let mut paren = 0i32;
    let mut angle = 0i32;
    let mut prev_dash = false;
    let body_open = loop {
        match toks.get(j).map(|t| &t.tok)? {
            Tok::Punct('(') | Tok::Punct('[') => paren += 1,
            Tok::Punct(')') | Tok::Punct(']') => paren -= 1,
            Tok::Punct('<') if paren == 0 => angle += 1,
            Tok::Punct('>') if paren == 0 && !prev_dash => angle -= 1,
            Tok::Punct('{') if paren == 0 => break j,
            Tok::Punct(';') if paren == 0 && angle <= 0 => return None,
            _ => {}
        }
        prev_dash = toks[j].tok == Tok::Punct('-');
        j += 1;
    };
    let body_close = match_brace(toks, body_open);

    // Walk back over qualifiers and attributes to the start of the item
    // header, so annotations directly above it (and above its
    // attributes / doc comments) attach to this fn.
    let mut head = fn_idx;
    loop {
        if head == 0 {
            break;
        }
        let prev = &toks[head - 1].tok;
        match prev {
            Tok::Ident(w) if FN_QUALIFIERS.contains(&w.as_str()) => head -= 1,
            Tok::Punct(')') => {
                // `pub(crate)` — walk to the matching `(`.
                let mut k = head - 1;
                let mut depth = 0i32;
                loop {
                    match toks[k].tok {
                        Tok::Punct(')') => depth += 1,
                        Tok::Punct('(') => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    if k == 0 {
                        break;
                    }
                    k -= 1;
                }
                // Only part of the header if preceded by `pub`.
                if k >= 1 && ident_at(toks, k - 1) == Some("pub") {
                    head = k;
                } else {
                    break;
                }
            }
            Tok::Punct(']') => {
                // An attribute `#[...]` — walk to its `#`.
                let mut k = head - 1;
                let mut depth = 0i32;
                loop {
                    match toks[k].tok {
                        Tok::Punct(']') => depth += 1,
                        Tok::Punct('[') => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    if k == 0 {
                        break;
                    }
                    k -= 1;
                }
                if k >= 1 && toks[k - 1].tok == Tok::Punct('#') {
                    head = k - 1;
                } else {
                    break;
                }
            }
            _ => break,
        }
    }
    // Annotations in the line gap between the previous token and the fn
    // keyword belong to this item.
    let gap_start = if head == 0 { 0 } else { tok_line(toks, head - 1) };
    let fn_line = tok_line(toks, fn_idx);
    let mut alloc_free = false;
    let mut allows = Vec::new();
    for ann in &lx.annotations {
        if ann.line > gap_start && ann.line <= fn_line {
            match &ann.kind {
                AnnKind::AllocFree => alloc_free = true,
                AnnKind::Allow { rule, has_reason: true } => allows.push(rule.clone()),
                _ => {}
            }
        }
    }

    let owner = impl_stack.last().map(|(_, o)| o.clone());
    Some(FnItem {
        name,
        owner,
        line: fn_line,
        body: (body_open, body_close),
        alloc_free,
        allows,
        in_test: so_far.is_test_line(fn_line),
    })
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    #[test]
    fn fns_and_owners() {
        let src = "
struct S;
impl S {
    pub fn a(&self) -> usize { 1 }
    fn b() {}
}
impl Default for S {
    fn default() -> S { S }
}
fn free() {}
";
        let lx = lex(src);
        let items = build(&lx);
        let names: Vec<(String, Option<String>)> =
            items.fns.iter().map(|f| (f.name.clone(), f.owner.clone())).collect();
        assert_eq!(
            names,
            vec![
                ("a".into(), Some("S".into())),
                ("b".into(), Some("S".into())),
                ("default".into(), Some("S".into())),
                ("free".into(), None),
            ]
        );
    }

    #[test]
    fn cfg_test_region_detected() {
        let src = "
fn live() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() { live(); }
}
";
        let lx = lex(src);
        let items = build(&lx);
        assert_eq!(items.test_regions.len(), 2, "mod region + inner #[test] fn");
        let t = items.fns.iter().find(|f| f.name == "t").unwrap();
        assert!(t.in_test);
        let live = items.fns.iter().find(|f| f.name == "live").unwrap();
        assert!(!live.in_test);
    }

    #[test]
    fn annotations_attach_through_attrs_and_docs() {
        let src = "
// lint: alloc-free
/// Doc line.
#[inline]
pub fn hot() {}

// lint: allow(panic, reason=index bounded)
fn risky() {}

// lint: allow(panic)
fn reasonless() {}
";
        let lx = lex(src);
        let items = build(&lx);
        let hot = items.fns.iter().find(|f| f.name == "hot").unwrap();
        assert!(hot.alloc_free);
        let risky = items.fns.iter().find(|f| f.name == "risky").unwrap();
        assert_eq!(risky.allows, vec!["panic".to_string()]);
        let r = items.fns.iter().find(|f| f.name == "reasonless").unwrap();
        assert!(r.allows.is_empty(), "allow without reason must not suppress");
    }

    #[test]
    fn trait_decl_without_body_skipped() {
        let src = "trait T { fn sig(&self) -> usize; fn with_default(&self) -> usize { 0 } }";
        let lx = lex(src);
        let items = build(&lx);
        assert_eq!(items.fns.len(), 1);
        assert_eq!(items.fns[0].name, "with_default");
        assert_eq!(items.fns[0].owner, Some("T".into()));
    }

    #[test]
    fn enclosing_fn_is_innermost() {
        let src = "fn outer() { fn inner() { x(); } }";
        let lx = lex(src);
        let items = build(&lx);
        let x_idx = lx
            .tokens
            .iter()
            .position(|t| t.tok == Tok::Ident("x".into()))
            .unwrap();
        assert_eq!(items.enclosing_fn(x_idx).unwrap().name, "inner");
    }
}
