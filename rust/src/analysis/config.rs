//! Rule scopes and allowlists for `hygen lint`.
//!
//! Paths are relative to `rust/src/` with forward slashes; an entry
//! ending in `/` matches a whole module directory. Changing a scope is
//! a reviewed code change, not a config file — the allowlists are part
//! of the crate on purpose.

/// Modules where wallclock reads (`Instant::now` / `SystemTime`) are the
/// point: real-time serving front ends, the bench harness, and the
/// launcher. Everything else must run on the virtual clock (or carry a
/// justified `// lint: allow(wallclock, reason=...)` at a measured `t0`
/// site).
pub const WALLCLOCK_ALLOWED: &[&str] = &[
    "util/bench.rs",
    "server/",
    "cluster/replica.rs",
    "engine/pjrt_backend.rs",
    "experiments/bench_sched.rs",
    "experiments/bench_replay.rs",
    "main.rs",
];

/// Modules whose output feeds batches, snapshots, or CSVs: `HashMap` /
/// `HashSet` *iteration* here is a determinism hazard (arbitrary,
/// seed-dependent order). Storage and point lookups stay fine.
pub const MAP_ITER_SCOPE: &[&str] = &["coordinator/", "cluster/", "experiments/", "workload/"];

/// Hot-path files where `unwrap()` / `expect()` / `panic!` / indexing
/// must be absent or individually justified: a panic here kills a
/// serving loop, not a CLI run.
pub const PANIC_SCOPE: &[&str] = &[
    "coordinator/scheduler.rs",
    "coordinator/state.rs",
    "engine/mod.rs",
    "cluster/replica.rs",
];

/// Identifiers that mean "unseeded randomness" — the crate's only RNG
/// is the seeded xoshiro in `util/rng.rs`, so these must never appear.
pub const UNSEEDED_RNG_IDENTS: &[&str] = &["thread_rng", "from_entropy", "OsRng", "getrandom"];

/// `HashMap`/`HashSet` methods that observe iteration order.
pub const MAP_ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "keys", "values", "values_mut", "drain", "into_iter", "retain"];

/// Does `rel` (a `rust/src/`-relative path) fall under any prefix in
/// `list`?
pub fn path_in(rel: &str, list: &[&str]) -> bool {
    list.iter().any(|p| {
        if let Some(dir) = p.strip_suffix('/') {
            rel == dir || rel.starts_with(p)
        } else {
            rel == *p
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_matching() {
        assert!(path_in("server/mod.rs", WALLCLOCK_ALLOWED));
        assert!(path_in("util/bench.rs", WALLCLOCK_ALLOWED));
        assert!(!path_in("util/bench_extra.rs", WALLCLOCK_ALLOWED));
        assert!(!path_in("coordinator/scheduler.rs", WALLCLOCK_ALLOWED));
        assert!(path_in("coordinator/scheduler.rs", PANIC_SCOPE));
        assert!(path_in("workload/azure.rs", MAP_ITER_SCOPE));
        assert!(!path_in("util/json.rs", MAP_ITER_SCOPE));
    }
}
