//! `hygen lint` — an in-repo, dependency-free static-analysis pass over
//! the crate's own sources, enforcing at the source level the invariants
//! the runtime gates (byte-identical CSVs at any `-j`, the
//! `CountingAlloc` zero-steady-alloc probe, the conservation ledgers)
//! can only observe after the fact:
//!
//! * **determinism** — no `HashMap`/`HashSet` iteration in modules that
//!   feed batches, snapshots, or CSVs; no `Instant::now`/`SystemTime`
//!   outside allowlisted timing modules; no unseeded RNG anywhere
//!   (rules `map-iter`, `wallclock`, `rng`);
//! * **alloc-free** — functions annotated `// lint: alloc-free` must not
//!   reach an allocating construct transitively within the crate
//!   (rule `alloc`);
//! * **panic-free** — no `unwrap()`/`expect()`/`panic!`/indexing in the
//!   scheduler/engine/cluster hot paths except via a justified
//!   annotation (rule `panic`);
//! * **config-doc coverage** — every flat-JSON knob parsed in
//!   `config/mod.rs` is documented, and every knob the docs list is
//!   actually parsed (rule `config-doc`).
//!
//! Violations are suppressed only by `// lint: allow(<rule>,
//! reason=...)` on the same or preceding line, or directly above the
//! enclosing `fn`. An allow without a reason suppresses nothing and is
//! itself reported, as is any malformed `// lint:` comment (rule
//! `annotation`). `#[cfg(test)]` regions are exempt from every rule.
//!
//! See DESIGN.md §"Enforced invariants" for the rule catalog and how to
//! add a rule.

pub mod config;
pub mod items;
pub mod lexer;

mod alloc;
mod rules;

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint violation, rendered as `file:line: rule(<name>): message`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    /// Repo-relative path (`rust/src/...`, `README.md`).
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: rule({}): {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One lexed + item-parsed source file.
pub struct SourceFile {
    /// Path relative to the scanned source root, forward slashes
    /// (`coordinator/scheduler.rs`).
    pub rel: String,
    /// `rel` with the on-disk prefix, as shown in diagnostics
    /// (`rust/src/coordinator/scheduler.rs`).
    pub display: String,
    pub lexed: lexer::Lexed,
    pub items: items::FileItems,
}

impl SourceFile {
    /// Is the violation of `rule` at token `tok_idx` (line `line`)
    /// suppressed by an annotation?
    pub fn allowed(&self, rule: &str, line: u32, tok_idx: usize) -> bool {
        let line_ok = self.lexed.annotations.iter().any(|a| {
            matches!(&a.kind, lexer::AnnKind::Allow { rule: r, has_reason: true }
                if r == rule && (a.line == line || a.line + 1 == line))
        });
        line_ok
            || self
                .items
                .enclosing_fn(tok_idx)
                .is_some_and(|f| f.allows.iter().any(|r| r == rule))
    }
}

/// Result of one lint run.
pub struct LintReport {
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Lint the repository at `repo_root` (the directory holding
/// `rust/src/`, README.md, and DESIGN.md).
pub fn lint_repo(repo_root: &Path) -> anyhow::Result<LintReport> {
    lint_tree(&repo_root.join("rust").join("src"), Some(repo_root), "rust/src/")
}

/// Lint an arbitrary source tree (used by the fixture tests).
/// `docs_root` enables the config-doc rule; `display_prefix` is
/// prepended to relative paths in diagnostics.
pub fn lint_tree(
    src_root: &Path,
    docs_root: Option<&Path>,
    display_prefix: &str,
) -> anyhow::Result<LintReport> {
    let mut paths: Vec<(String, PathBuf)> = Vec::new();
    walk(src_root, src_root, &mut paths)?;
    paths.sort();
    let sources: Vec<SourceFile> = paths
        .into_iter()
        .map(|(rel, path)| -> anyhow::Result<SourceFile> {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
            let lexed = lexer::lex(&text);
            let items = items::build(&lexed);
            let display = format!("{display_prefix}{rel}");
            Ok(SourceFile { rel, display, lexed, items })
        })
        .collect::<anyhow::Result<_>>()?;

    let mut diags = Vec::new();
    for sf in &sources {
        rules::check_file(sf, &mut diags);
    }
    alloc::check(&sources, &mut diags);
    if let Some(root) = docs_root {
        rules::check_config_doc(&sources, root, &mut diags);
    }
    diags.sort();
    diags.dedup();
    Ok(LintReport { diagnostics: diags, files_scanned: sources.len() })
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<(String, PathBuf)>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

/// Locate the repo root from an arbitrary working directory: the first
/// of `.`, `..`, `../..` containing `rust/src`.
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    for _ in 0..3 {
        if dir.join("rust").join("src").is_dir() {
            return Some(dir);
        }
        dir = dir.join("..");
    }
    None
}
