//! Configuration system: JSON config files + CLI overrides for the
//! launcher. A config file holds everything needed to reproduce a serving
//! deployment or a simulation run.

use crate::coordinator::queues::OfflinePolicy;
use crate::util::json::Json;

/// The crate's top-level config type (alias kept so docs and tests can
/// refer to `config::Config` generically).
pub type Config = ServeConfig;

/// Configuration of a real serving instance (`hygen serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    pub bind: String,
    /// Per-iteration latency budget (ms); None = SLO-unaware.
    pub latency_budget_ms: Option<f64>,
    pub policy: OfflinePolicy,
    pub http_workers: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".into(),
            bind: "127.0.0.1:8077".into(),
            latency_budget_ms: None,
            policy: OfflinePolicy::Psm,
            http_workers: 4,
            seed: 0,
        }
    }
}

impl ServeConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<ServeConfig> {
        let d = ServeConfig::default();
        let policy_name = j.get("policy").as_str().unwrap_or("psm");
        let utility = j.get("utility_ratio").as_f64().unwrap_or(0.9);
        let policy = OfflinePolicy::parse(policy_name, utility)
            .ok_or_else(|| anyhow::anyhow!("unknown policy '{policy_name}'"))?;
        Ok(ServeConfig {
            artifacts_dir: j
                .get("artifacts_dir")
                .as_str()
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            bind: j.get("bind").as_str().unwrap_or(&d.bind).to_string(),
            latency_budget_ms: j.get("latency_budget_ms").as_f64(),
            policy,
            http_workers: j.get("http_workers").as_u64().unwrap_or(4) as usize,
            seed: j.get("seed").as_u64().unwrap_or(0),
        })
    }

    pub fn load(path: &str) -> anyhow::Result<ServeConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("artifacts_dir", Json::from(self.artifacts_dir.as_str())),
            ("bind", Json::from(self.bind.as_str())),
            ("policy", Json::from(self.policy.name())),
            ("http_workers", Json::from(self.http_workers)),
            ("seed", Json::from(self.seed)),
        ];
        if let Some(b) = self.latency_budget_ms {
            pairs.push(("latency_budget_ms", Json::from(b)));
        }
        if let OfflinePolicy::PsmFair { utility_ratio } = self.policy {
            pairs.push(("utility_ratio", Json::from(utility_ratio)));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let c = ServeConfig::default();
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.bind, c.bind);
        assert_eq!(c2.policy, c.policy);
        assert_eq!(c2.latency_budget_ms, None);
    }

    #[test]
    fn parses_fair_policy_with_ratio() {
        let j = Json::parse(r#"{"policy": "psm-fair", "utility_ratio": 0.7, "latency_budget_ms": 25}"#)
            .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.policy, OfflinePolicy::PsmFair { utility_ratio: 0.7 });
        assert_eq!(c.latency_budget_ms, Some(25.0));
    }

    #[test]
    fn rejects_unknown_policy() {
        let j = Json::parse(r#"{"policy": "magic"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }
}
