//! Configuration system: JSON config files + CLI overrides for the
//! launcher. A config file holds everything needed to reproduce a serving
//! deployment or a simulation run.

use crate::cluster::autoscale::AutoscaleConfig;
use crate::cluster::replica::SupervisorConfig;
use crate::cluster::router::{PrefixAffinity, Router, RouterPolicy};
use crate::coordinator::block_manager::EvictionPolicy;
use crate::coordinator::classes::ClassRegistry;
use crate::coordinator::queues::OfflinePolicy;
use crate::server::OverloadConfig;
use crate::util::json::Json;

/// The crate's top-level config type (alias kept so docs and tests can
/// refer to `config::Config` generically).
pub type Config = ServeConfig;

/// Multi-replica deployment shape (`hygen serve --replicas N`).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Engine replicas behind the router (1 = the classic single-engine
    /// instance).
    pub replicas: usize,
    pub router: RouterPolicy,
    /// KV prefix-cache eviction order (`tier-lru` = sacrifice
    /// harvest-class prefixes first, LRU within a tier; `lru` = global
    /// least-recently-released).
    pub kv_eviction: EvictionPolicy,
    /// `prefix-affinity` router weight: how many milliseconds of SLO
    /// headroom one cached prefix token is worth when scoring replicas
    /// (0 = affinity degenerates to slo-headroom).
    pub affinity_weight: f64,
    /// Offline rebalance / census refresh cadence (seconds) — the tick at
    /// which the cluster re-places shared offline work in simulation.
    pub rebalance_interval_s: f64,
    /// Graceful-drain deadline on shutdown (seconds): in-flight requests
    /// keep executing this long before being failed.
    pub drain_s: f64,
    /// Supervisor gives up on a replica after this many restart attempts.
    pub max_restarts: usize,
    /// First restart backoff (ms); doubles per attempt.
    pub backoff_initial_ms: f64,
    /// Restart backoff ceiling (ms).
    pub backoff_cap_ms: f64,
    /// Autoscaler floor (live replicas).
    pub autoscale_min: usize,
    /// Autoscaler ceiling (live replicas).
    pub autoscale_max: usize,
    /// Scale up when mean live SLO headroom stays below this (ms).
    pub autoscale_up_headroom_ms: f64,
    /// Scale down when mean live SLO headroom stays above this (ms).
    pub autoscale_down_headroom_ms: f64,
    /// Consecutive rebalance ticks a scale signal must hold.
    pub autoscale_hysteresis: usize,
    /// Bounded admission: per-class waiting-queue depth (per replica)
    /// beyond which new work is rejected with 429 + `Retry-After`.
    pub queue_cap: usize,
    /// Absolute per-request deadline backstop (seconds). The effective
    /// deadline is the tighter of this and the class SLO envelope; expired
    /// work is cancelled in-engine and answered with 504.
    pub request_timeout_s: f64,
    /// Re-route attempts for an online request that failed before its
    /// first token (0 = never retry).
    pub retry_budget: usize,
    /// Consecutive job failures that open a replica's circuit breaker.
    pub breaker_threshold: usize,
    /// How long an open breaker skips its replica before the half-open
    /// probe (seconds).
    pub breaker_cooldown_s: f64,
    /// Brown-out rung 1: aggregate headroom (ms) below which elastic
    /// (offline) placement pauses.
    pub brownout_offline_headroom_ms: f64,
    /// Brown-out rung 2: aggregate headroom (ms) below which tolerant
    /// (below-top-tier) classes are shed.
    pub brownout_shed_headroom_ms: f64,
    /// Brown-out rung 3: aggregate headroom (ms) below which even online
    /// work is rejected with 429.
    pub brownout_online_headroom_ms: f64,
    /// Flight-recorder ring capacity per replica (events; 0 disables
    /// recording entirely). The ring is preallocated — steady-state
    /// tracing allocates nothing.
    pub trace_capacity: usize,
    /// Master switch for lifecycle tracing (`/trace`, `hygen
    /// trace-dump`). Disabling keeps the ring allocated but records
    /// nothing.
    pub trace_enabled: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        let sup = SupervisorConfig::default();
        let auto = AutoscaleConfig::default();
        let over = OverloadConfig::default();
        ClusterConfig {
            replicas: 1,
            router: RouterPolicy::SloHeadroom,
            kv_eviction: EvictionPolicy::TierLru,
            affinity_weight: PrefixAffinity::default().weight_ms_per_token,
            rebalance_interval_s: 1.0,
            drain_s: 5.0,
            max_restarts: sup.max_restarts,
            backoff_initial_ms: sup.backoff_initial.as_secs_f64() * 1e3,
            backoff_cap_ms: sup.backoff_cap.as_secs_f64() * 1e3,
            autoscale_min: auto.min_replicas,
            autoscale_max: auto.max_replicas,
            autoscale_up_headroom_ms: auto.up_headroom_ms,
            autoscale_down_headroom_ms: auto.down_headroom_ms,
            autoscale_hysteresis: auto.hysteresis_ticks,
            queue_cap: over.queue_cap,
            request_timeout_s: over.request_timeout.as_secs_f64(),
            retry_budget: over.retry_budget,
            breaker_threshold: over.breaker_threshold,
            breaker_cooldown_s: over.breaker_cooldown.as_secs_f64(),
            brownout_offline_headroom_ms: over.brownout_offline_headroom_ms,
            brownout_shed_headroom_ms: over.brownout_shed_headroom_ms,
            brownout_online_headroom_ms: over.brownout_online_headroom_ms,
            trace_capacity: crate::obs::DEFAULT_TRACE_CAPACITY,
            trace_enabled: true,
        }
    }
}

impl ClusterConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<ClusterConfig> {
        let d = ClusterConfig::default();
        let router = match j.get("router").as_str() {
            Some(name) => RouterPolicy::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown router '{name}'"))?,
            None => d.router,
        };
        let kv_eviction = match j.get("kv_eviction") {
            Json::Null => d.kv_eviction,
            v => {
                let name = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("kv_eviction must be a string"))?;
                EvictionPolicy::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown kv_eviction '{name}'"))?
            }
        };
        // Present-but-invalid values must error, not silently fall back
        // to defaults (an operator expecting 8 replicas must not get 1).
        let num_field = |key: &str, default: f64| -> anyhow::Result<f64> {
            match j.get(key) {
                Json::Null => Ok(default),
                v => v.as_f64().ok_or_else(|| anyhow::anyhow!("{key} must be a number")),
            }
        };
        let int_field = |key: &str, default: usize| -> anyhow::Result<usize> {
            match j.get(key) {
                Json::Null => Ok(default),
                v => Ok(v
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("{key} must be a non-negative integer"))?
                    as usize),
            }
        };
        let replicas = match j.get("replicas") {
            Json::Null => d.replicas,
            v => v
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("replicas must be a positive integer"))?
                as usize,
        };
        anyhow::ensure!(replicas >= 1, "cluster needs at least one replica");
        let affinity_weight = num_field("affinity_weight", d.affinity_weight)?;
        anyhow::ensure!(
            affinity_weight.is_finite() && affinity_weight >= 0.0,
            "affinity_weight must be a finite non-negative number"
        );
        let rebalance_interval_s = num_field("rebalance_interval_s", d.rebalance_interval_s)?;
        anyhow::ensure!(
            rebalance_interval_s.is_finite() && rebalance_interval_s > 0.0,
            "rebalance_interval_s must be a positive number"
        );
        // Duration::from_secs_f64 panics on negative/NaN input — reject
        // bad values here instead of at server startup.
        let drain_s = num_field("drain_s", d.drain_s)?;
        anyhow::ensure!(
            drain_s.is_finite() && drain_s >= 0.0,
            "drain_s must be a non-negative number"
        );
        let max_restarts = int_field("max_restarts", d.max_restarts)?;
        let backoff_initial_ms = num_field("backoff_initial_ms", d.backoff_initial_ms)?;
        anyhow::ensure!(
            backoff_initial_ms.is_finite() && backoff_initial_ms > 0.0,
            "backoff_initial_ms must be a positive number"
        );
        let backoff_cap_ms = num_field("backoff_cap_ms", d.backoff_cap_ms)?;
        anyhow::ensure!(
            backoff_cap_ms.is_finite() && backoff_cap_ms >= backoff_initial_ms,
            "backoff_cap_ms must be at least backoff_initial_ms"
        );
        let autoscale_min = int_field("autoscale_min", d.autoscale_min)?;
        anyhow::ensure!(autoscale_min >= 1, "autoscale_min must keep at least one replica");
        let autoscale_max = int_field("autoscale_max", d.autoscale_max)?;
        anyhow::ensure!(autoscale_max >= autoscale_min, "autoscale_max below autoscale_min");
        let autoscale_up_headroom_ms =
            num_field("autoscale_up_headroom_ms", d.autoscale_up_headroom_ms)?;
        let autoscale_down_headroom_ms =
            num_field("autoscale_down_headroom_ms", d.autoscale_down_headroom_ms)?;
        anyhow::ensure!(
            autoscale_up_headroom_ms < autoscale_down_headroom_ms,
            "autoscale_up_headroom_ms must sit below autoscale_down_headroom_ms"
        );
        let autoscale_hysteresis = int_field("autoscale_hysteresis", d.autoscale_hysteresis)?;
        anyhow::ensure!(
            autoscale_hysteresis >= 1,
            "autoscale_hysteresis needs at least one tick"
        );
        let queue_cap = int_field("queue_cap", d.queue_cap)?;
        anyhow::ensure!(queue_cap >= 1, "queue_cap must admit at least one request");
        // Duration::from_secs_f64 panics on negative/NaN input, and a zero
        // timeout would 504 every request at admission.
        let request_timeout_s = num_field("request_timeout_s", d.request_timeout_s)?;
        anyhow::ensure!(
            request_timeout_s.is_finite() && request_timeout_s > 0.0,
            "request_timeout_s must be a positive number"
        );
        let retry_budget = int_field("retry_budget", d.retry_budget)?;
        let breaker_threshold = int_field("breaker_threshold", d.breaker_threshold)?;
        anyhow::ensure!(
            breaker_threshold >= 1,
            "breaker_threshold needs at least one consecutive error"
        );
        let breaker_cooldown_s = num_field("breaker_cooldown_s", d.breaker_cooldown_s)?;
        anyhow::ensure!(
            breaker_cooldown_s.is_finite() && breaker_cooldown_s >= 0.0,
            "breaker_cooldown_s must be a non-negative number"
        );
        let brownout_offline_headroom_ms =
            num_field("brownout_offline_headroom_ms", d.brownout_offline_headroom_ms)?;
        let brownout_shed_headroom_ms =
            num_field("brownout_shed_headroom_ms", d.brownout_shed_headroom_ms)?;
        let brownout_online_headroom_ms =
            num_field("brownout_online_headroom_ms", d.brownout_online_headroom_ms)?;
        for (key, v) in [
            ("brownout_offline_headroom_ms", brownout_offline_headroom_ms),
            ("brownout_shed_headroom_ms", brownout_shed_headroom_ms),
            ("brownout_online_headroom_ms", brownout_online_headroom_ms),
        ] {
            anyhow::ensure!(v.is_finite(), "{key} must be a finite number");
        }
        // The ladder degrades monotonically as headroom shrinks: pause
        // offline first, shed tolerant classes next, 429 online last.
        anyhow::ensure!(
            brownout_online_headroom_ms <= brownout_shed_headroom_ms
                && brownout_shed_headroom_ms <= brownout_offline_headroom_ms,
            "brown-out thresholds must be ordered online <= shed <= offline"
        );
        let trace_capacity = int_field("trace_capacity", d.trace_capacity)?;
        let trace_enabled = match j.get("trace_enabled") {
            Json::Null => d.trace_enabled,
            v => v
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("trace_enabled must be a boolean"))?,
        };
        Ok(ClusterConfig {
            replicas,
            router,
            kv_eviction,
            affinity_weight,
            rebalance_interval_s,
            drain_s,
            max_restarts,
            backoff_initial_ms,
            backoff_cap_ms,
            autoscale_min,
            autoscale_max,
            autoscale_up_headroom_ms,
            autoscale_down_headroom_ms,
            autoscale_hysteresis,
            queue_cap,
            request_timeout_s,
            retry_budget,
            breaker_threshold,
            breaker_cooldown_s,
            brownout_offline_headroom_ms,
            brownout_shed_headroom_ms,
            brownout_online_headroom_ms,
            trace_capacity,
            trace_enabled,
        })
    }

    pub fn to_json_pairs(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("replicas", Json::from(self.replicas)),
            ("router", Json::from(self.router.name())),
            ("kv_eviction", Json::from(self.kv_eviction.name())),
            ("affinity_weight", Json::from(self.affinity_weight)),
            ("rebalance_interval_s", Json::from(self.rebalance_interval_s)),
            ("drain_s", Json::from(self.drain_s)),
            ("max_restarts", Json::from(self.max_restarts)),
            ("backoff_initial_ms", Json::from(self.backoff_initial_ms)),
            ("backoff_cap_ms", Json::from(self.backoff_cap_ms)),
            ("autoscale_min", Json::from(self.autoscale_min)),
            ("autoscale_max", Json::from(self.autoscale_max)),
            ("autoscale_up_headroom_ms", Json::from(self.autoscale_up_headroom_ms)),
            ("autoscale_down_headroom_ms", Json::from(self.autoscale_down_headroom_ms)),
            ("autoscale_hysteresis", Json::from(self.autoscale_hysteresis)),
            ("queue_cap", Json::from(self.queue_cap)),
            ("request_timeout_s", Json::from(self.request_timeout_s)),
            ("retry_budget", Json::from(self.retry_budget)),
            ("breaker_threshold", Json::from(self.breaker_threshold)),
            ("breaker_cooldown_s", Json::from(self.breaker_cooldown_s)),
            ("brownout_offline_headroom_ms", Json::from(self.brownout_offline_headroom_ms)),
            ("brownout_shed_headroom_ms", Json::from(self.brownout_shed_headroom_ms)),
            ("brownout_online_headroom_ms", Json::from(self.brownout_online_headroom_ms)),
            ("trace_capacity", Json::from(self.trace_capacity)),
            ("trace_enabled", Json::from(self.trace_enabled)),
        ]
    }

    /// Build the routing policy this config describes. Unlike the
    /// arg-less [`RouterPolicy::build`], this carries `affinity_weight`
    /// into the `prefix-affinity` router.
    pub fn build_router(&self) -> Box<dyn Router> {
        match self.router {
            RouterPolicy::PrefixAffinity => Box::new(PrefixAffinity {
                weight_ms_per_token: self.affinity_weight,
                ..PrefixAffinity::default()
            }),
            p => p.build(),
        }
    }

    /// The supervisor restart policy this config describes.
    pub fn supervisor_config(&self) -> SupervisorConfig {
        SupervisorConfig {
            max_restarts: self.max_restarts,
            backoff_initial: std::time::Duration::from_secs_f64(self.backoff_initial_ms / 1e3),
            backoff_cap: std::time::Duration::from_secs_f64(self.backoff_cap_ms / 1e3),
        }
    }

    /// The overload policy (bounded admission, deadlines, retry/breaker,
    /// brown-out ladder) this config describes.
    pub fn overload_config(&self) -> OverloadConfig {
        OverloadConfig {
            queue_cap: self.queue_cap,
            request_timeout: std::time::Duration::from_secs_f64(self.request_timeout_s),
            retry_budget: self.retry_budget,
            breaker_threshold: self.breaker_threshold,
            breaker_cooldown: std::time::Duration::from_secs_f64(self.breaker_cooldown_s),
            brownout_offline_headroom_ms: self.brownout_offline_headroom_ms,
            brownout_shed_headroom_ms: self.brownout_shed_headroom_ms,
            brownout_online_headroom_ms: self.brownout_online_headroom_ms,
        }
    }

    /// The autoscaler thresholds this config describes.
    pub fn autoscale_config(&self) -> AutoscaleConfig {
        AutoscaleConfig {
            min_replicas: self.autoscale_min,
            max_replicas: self.autoscale_max,
            up_headroom_ms: self.autoscale_up_headroom_ms,
            down_headroom_ms: self.autoscale_down_headroom_ms,
            hysteresis_ticks: self.autoscale_hysteresis,
        }
    }
}

/// Configuration of a real serving instance (`hygen serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    pub bind: String,
    /// Per-iteration latency budget (ms); None = SLO-unaware.
    pub latency_budget_ms: Option<f64>,
    pub policy: OfflinePolicy,
    pub http_workers: usize,
    pub seed: u64,
    /// Multi-replica deployment shape (replica count, router policy,
    /// rebalance cadence, drain deadline).
    pub cluster: ClusterConfig,
    /// The SLO-class registry (the `classes: [...]` key). Defaults to
    /// the paper's two-class online/offline setup.
    pub classes: ClassRegistry,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".into(),
            bind: "127.0.0.1:8077".into(),
            latency_budget_ms: None,
            policy: OfflinePolicy::Psm,
            http_workers: 4,
            seed: 0,
            cluster: ClusterConfig::default(),
            classes: ClassRegistry::default_two(),
        }
    }
}

impl ServeConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<ServeConfig> {
        let d = ServeConfig::default();
        let policy_name = j.get("policy").as_str().unwrap_or("psm");
        let utility = j.get("utility_ratio").as_f64().unwrap_or(0.9);
        let policy = OfflinePolicy::parse(policy_name, utility)
            .ok_or_else(|| anyhow::anyhow!("unknown policy '{policy_name}'"))?;
        let classes = match j.get("classes") {
            Json::Null => ClassRegistry::default_two(),
            v => ClassRegistry::from_json(v)?,
        };
        Ok(ServeConfig {
            artifacts_dir: j
                .get("artifacts_dir")
                .as_str()
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            bind: j.get("bind").as_str().unwrap_or(&d.bind).to_string(),
            latency_budget_ms: j.get("latency_budget_ms").as_f64(),
            policy,
            http_workers: j.get("http_workers").as_u64().unwrap_or(4) as usize,
            seed: j.get("seed").as_u64().unwrap_or(0),
            cluster: ClusterConfig::from_json(j)?,
            classes,
        })
    }

    pub fn load(path: &str) -> anyhow::Result<ServeConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("artifacts_dir", Json::from(self.artifacts_dir.as_str())),
            ("bind", Json::from(self.bind.as_str())),
            ("policy", Json::from(self.policy.name())),
            ("http_workers", Json::from(self.http_workers)),
            ("seed", Json::from(self.seed)),
            ("classes", self.classes.to_json()),
        ];
        pairs.extend(self.cluster.to_json_pairs());
        if let Some(b) = self.latency_budget_ms {
            pairs.push(("latency_budget_ms", Json::from(b)));
        }
        if let OfflinePolicy::PsmFair { utility_ratio } = self.policy {
            pairs.push(("utility_ratio", Json::from(utility_ratio)));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let c = ServeConfig::default();
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.bind, c.bind);
        assert_eq!(c2.policy, c.policy);
        assert_eq!(c2.latency_budget_ms, None);
        assert_eq!(c2.cluster, c.cluster);
        assert_eq!(c2.classes, c.classes);
        assert_eq!(c2.classes, ClassRegistry::default_two());
    }

    #[test]
    fn classes_key_roundtrips_and_rejects_garbage() {
        let j = Json::parse(
            r#"{"classes": [
                {"name": "chat", "tier": 2, "ttft_slo_ms": 300, "tbt_slo_ms": 50,
                 "preempt_priority": 200, "admission": "fcfs"},
                {"name": "batch", "tier": 0, "latency_budget": 4.0,
                 "admission": "rate-capped", "rate_qps": 2.0,
                 "starvation_age_s": 60}
            ]}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.classes.len(), 2);
        assert_eq!(c.classes.spec(crate::coordinator::request::ClassId(0)).name, "chat");
        assert!(c.classes.spec(crate::coordinator::request::ClassId(0)).bypasses_budget());
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.classes, c.classes);
        // A malformed classes list is an error, not a silent default.
        let bad = Json::parse(r#"{"classes": [{"tier": 1}]}"#).unwrap();
        assert!(ServeConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"classes": "two"}"#).unwrap();
        assert!(ServeConfig::from_json(&bad).is_err());
    }

    #[test]
    fn parses_fair_policy_with_ratio() {
        let j = Json::parse(r#"{"policy": "psm-fair", "utility_ratio": 0.7, "latency_budget_ms": 25}"#)
            .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.policy, OfflinePolicy::PsmFair { utility_ratio: 0.7 });
        assert_eq!(c.latency_budget_ms, Some(25.0));
    }

    #[test]
    fn rejects_unknown_policy() {
        let j = Json::parse(r#"{"policy": "magic"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn parses_cluster_shape() {
        let j = Json::parse(
            r#"{"replicas": 4, "router": "jsq", "rebalance_interval_s": 0.5, "drain_s": 2}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.replicas, 4);
        assert_eq!(c.cluster.router, RouterPolicy::JoinShortestQueue);
        assert_eq!(c.cluster.rebalance_interval_s, 0.5);
        assert_eq!(c.cluster.drain_s, 2.0);
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cluster, c.cluster);
    }

    #[test]
    fn parses_fault_tolerance_knobs() {
        let j = Json::parse(
            r#"{"max_restarts": 5, "backoff_initial_ms": 50, "backoff_cap_ms": 800,
                "autoscale_min": 2, "autoscale_max": 6,
                "autoscale_up_headroom_ms": 2, "autoscale_down_headroom_ms": 20,
                "autoscale_hysteresis": 4}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.max_restarts, 5);
        assert_eq!(c.cluster.backoff_initial_ms, 50.0);
        assert_eq!(c.cluster.backoff_cap_ms, 800.0);
        assert_eq!(c.cluster.autoscale_min, 2);
        assert_eq!(c.cluster.autoscale_max, 6);
        assert_eq!(c.cluster.autoscale_hysteresis, 4);
        // The derived sub-configs carry the same values.
        let sup = c.cluster.supervisor_config();
        assert_eq!(sup.max_restarts, 5);
        assert_eq!(sup.backoff_initial, std::time::Duration::from_millis(50));
        assert_eq!(sup.backoff_cap, std::time::Duration::from_millis(800));
        let auto = c.cluster.autoscale_config();
        assert_eq!(auto.min_replicas, 2);
        assert_eq!(auto.max_replicas, 6);
        assert_eq!(auto.up_headroom_ms, 2.0);
        assert_eq!(auto.down_headroom_ms, 20.0);
        assert_eq!(auto.hysteresis_ticks, 4);
        // Flat-JSON round trip, like the rest of the cluster shape.
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cluster, c.cluster);
    }

    #[test]
    fn rejects_bad_fault_tolerance_knobs() {
        for bad in [
            r#"{"backoff_initial_ms": 0}"#,
            r#"{"backoff_initial_ms": 100, "backoff_cap_ms": 50}"#,
            r#"{"autoscale_min": 0}"#,
            r#"{"autoscale_min": 4, "autoscale_max": 2}"#,
            r#"{"autoscale_up_headroom_ms": 30, "autoscale_down_headroom_ms": 5}"#,
            r#"{"autoscale_hysteresis": 0}"#,
            r#"{"max_restarts": "lots"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ServeConfig::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn parses_overload_knobs() {
        let j = Json::parse(
            r#"{"queue_cap": 8, "request_timeout_s": 3.5, "retry_budget": 1,
                "breaker_threshold": 2, "breaker_cooldown_s": 0.25,
                "brownout_offline_headroom_ms": 6,
                "brownout_shed_headroom_ms": 3,
                "brownout_online_headroom_ms": 1}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.queue_cap, 8);
        assert_eq!(c.cluster.request_timeout_s, 3.5);
        assert_eq!(c.cluster.retry_budget, 1);
        assert_eq!(c.cluster.breaker_threshold, 2);
        assert_eq!(c.cluster.breaker_cooldown_s, 0.25);
        // The derived sub-config carries the same values.
        let over = c.cluster.overload_config();
        assert_eq!(over.queue_cap, 8);
        assert_eq!(over.request_timeout, std::time::Duration::from_millis(3500));
        assert_eq!(over.retry_budget, 1);
        assert_eq!(over.breaker_threshold, 2);
        assert_eq!(over.breaker_cooldown, std::time::Duration::from_millis(250));
        assert_eq!(over.brownout_offline_headroom_ms, 6.0);
        assert_eq!(over.brownout_shed_headroom_ms, 3.0);
        assert_eq!(over.brownout_online_headroom_ms, 1.0);
        // Flat-JSON round trip, like the rest of the cluster shape.
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cluster, c.cluster);
    }

    #[test]
    fn rejects_bad_overload_knobs() {
        for bad in [
            r#"{"queue_cap": 0}"#,
            r#"{"queue_cap": "big"}"#,
            r#"{"request_timeout_s": 0}"#,
            r#"{"request_timeout_s": -5}"#,
            r#"{"breaker_threshold": 0}"#,
            r#"{"breaker_cooldown_s": -1}"#,
            r#"{"retry_budget": -1}"#,
            r#"{"brownout_shed_headroom_ms": 50}"#,
            r#"{"brownout_offline_headroom_ms": 1, "brownout_shed_headroom_ms": 1,
                "brownout_online_headroom_ms": 3}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ServeConfig::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn parses_trace_knobs() {
        let j = Json::parse(r#"{"trace_capacity": 128, "trace_enabled": false}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.trace_capacity, 128);
        assert!(!c.cluster.trace_enabled);
        // Defaults: tracing on, preallocated ring.
        let d = ServeConfig::default();
        assert_eq!(d.cluster.trace_capacity, crate::obs::DEFAULT_TRACE_CAPACITY);
        assert!(d.cluster.trace_enabled);
        // Zero capacity is legal (recording disabled, ring empty).
        let j = Json::parse(r#"{"trace_capacity": 0}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().cluster.trace_capacity, 0);
        // Flat-JSON round trip, like the rest of the cluster shape.
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cluster, c.cluster);
        // Present-but-mistyped values error instead of silently
        // defaulting.
        for bad in [r#"{"trace_capacity": "big"}"#, r#"{"trace_enabled": "yes"}"#] {
            let j = Json::parse(bad).unwrap();
            assert!(ServeConfig::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn parses_prefix_cache_knobs() {
        let j = Json::parse(
            r#"{"router": "prefix-affinity", "kv_eviction": "lru", "affinity_weight": 0.25}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.cluster.router, RouterPolicy::PrefixAffinity);
        assert_eq!(c.cluster.kv_eviction, EvictionPolicy::Lru);
        assert_eq!(c.cluster.affinity_weight, 0.25);
        assert_eq!(c.cluster.build_router().name(), "prefix-affinity");
        // Defaults: tier-LRU eviction, the router's stock weight.
        let d = ServeConfig::default();
        assert_eq!(d.cluster.kv_eviction, EvictionPolicy::TierLru);
        assert_eq!(d.cluster.affinity_weight, PrefixAffinity::default().weight_ms_per_token);
        assert_eq!(d.cluster.build_router().name(), d.cluster.router.name());
        // Flat-JSON round trip, like the rest of the cluster shape.
        let c2 = ServeConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cluster, c.cluster);
        // Present-but-invalid values error instead of silently defaulting.
        for bad in [
            r#"{"kv_eviction": "mru"}"#,
            r#"{"kv_eviction": 3}"#,
            r#"{"affinity_weight": -0.5}"#,
            r#"{"affinity_weight": "heavy"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ServeConfig::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn rejects_bad_cluster_shape() {
        let j = Json::parse(r#"{"router": "magic"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"replicas": 0}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"drain_s": -1}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err(), "negative drain must not panic later");
        let j = Json::parse(r#"{"rebalance_interval_s": 0}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
        // Present-but-mistyped values error instead of silently falling
        // back to the defaults.
        let j = Json::parse(r#"{"replicas": "8"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"replicas": -4}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"drain_s": "soon"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }
}
