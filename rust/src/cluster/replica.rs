//! One engine replica on its own thread behind an mpsc job queue — the
//! `server::engine_loop` message-passing shape, factored out so the HTTP
//! front end and the multi-replica cluster share it.
//!
//! The engine is *constructed on* the replica thread by the factory (PJRT
//! handles are not `Send`, so they must never cross threads). The thread:
//!
//! * ingests [`Job`]s, replying on each job's channel with an explicit
//!   `Result` — there is no in-band failure sentinel (a `Completion` with
//!   a fake request id 0 used to mean "failed", which collided with
//!   nothing only by luck);
//! * publishes a [`ReplicaSnapshot`] every loop iteration (cheap copy)
//!   and a metrics report every [`PUBLISH_INTERVAL`] for `/metrics`;
//! * on stop, **drains**: in-flight requests keep executing until they
//!   complete or the drain deadline passes, at which point the stragglers
//!   get [`JobError::DrainTimeout`] instead of a dropped channel.

use super::ReplicaSnapshot;
use crate::coordinator::classes::MAX_CLASSES;
use crate::coordinator::request::{Class, Request, RequestId};
use crate::engine::{Engine, ExecutionBackend};
use crate::obs::recorder::EventKind;
use crate::runtime::tokenizer;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// How often the replica thread refreshes its published metrics report.
pub const PUBLISH_INTERVAL: Duration = Duration::from_millis(200);

/// Flight-recorder events included in each published trace dump. A tail
/// window, not the full ring: `/trace` is a diagnostic peephole; full
/// dumps go through `hygen trace-dump`.
pub const TRACE_PUBLISH_EVENTS: usize = 256;

/// Lock a published-state mutex, recovering from poison. Both values
/// behind these mutexes (a JSON string, a plain-old-data snapshot) are
/// written atomically by single assignments, so a panic mid-write cannot
/// leave them torn — the last fully published value is always safe to
/// read, and a poisoned replica must not take the front end down with it.
fn lock_published<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A submission travelling from a connection handler to a replica thread.
pub struct Job {
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
    pub class: Class,
    /// Absolute deadline (derived from the class SLO envelope and the
    /// `request_timeout_s` knob). Work that has not completed by then is
    /// shed *in-engine* — KV blocks and batch slot freed — and the reply
    /// is [`JobError::DeadlineExceeded`]. `None` = no deadline (drain
    /// rules still apply).
    pub deadline: Option<Instant>,
    pub reply: Sender<Result<Completion, JobError>>,
}

/// A successfully served request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Replica-local request id (each replica numbers its own requests).
    pub id: RequestId,
    pub text: String,
    pub tokens: Vec<u32>,
    pub latency_ms: f64,
}

/// Why a job could not be served. Explicit on the reply channel — callers
/// never have to sniff sentinel field values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobError {
    /// The execution backend failed persistently; the replica aborted its
    /// work and refuses new jobs.
    BackendFailed,
    /// The server stopped and the drain deadline passed before this
    /// request completed.
    DrainTimeout,
    /// The request's own deadline passed before it completed; the engine
    /// shed it (blocks and batch slot freed). The front end maps this to
    /// HTTP 504.
    DeadlineExceeded,
}

impl JobError {
    pub fn message(&self) -> &'static str {
        match self {
            JobError::BackendFailed => "backend failed",
            JobError::DrainTimeout => "server stopping",
            JobError::DeadlineExceeded => "request timed out",
        }
    }
}

/// State a replica thread publishes for the front end and the router.
#[derive(Default)]
pub struct ReplicaShared {
    /// Latest metrics report (pretty JSON), refreshed every
    /// [`PUBLISH_INTERVAL`].
    pub metrics_json: Mutex<String>,
    /// Latest census snapshot (refreshed every loop iteration).
    pub snapshot: Mutex<ReplicaSnapshot>,
    /// Jobs sent toward this replica per class (incremented by submitters
    /// *before* sending). Together with the `ingested` counters this
    /// gives the router an estimate of work still in the channel, so a
    /// burst between two snapshot refreshes does not all land on the same
    /// replica — and each class's burst counts against its own census
    /// slot (elastic bursts hit the harvest buffer, not the interactive
    /// depth).
    pub submitted: [AtomicUsize; MAX_CLASSES],
    /// Jobs the engine thread has taken off the channel, per class.
    pub ingested: [AtomicUsize; MAX_CLASSES],
    /// Set after a persistent backend failure: the engine aborted its
    /// work and new completions are refused (health/metrics stay up).
    pub failed: AtomicBool,
    /// Restart attempts a [`Supervisor`] has made for this replica
    /// (0 for an unsupervised replica, and counting failed attempts).
    pub restarts: AtomicUsize,
    /// Engine incarnation: bumped on every successful supervisor
    /// restart, so routers and `/metrics` can tell "recovered" apart
    /// from "never died".
    pub generation: AtomicU64,
    /// Latest flight-recorder dump (pretty JSON), refreshed alongside
    /// `metrics_json` so `/trace` serves without touching the engine
    /// thread. Empty until the first publish.
    pub trace_json: Mutex<String>,
}

impl ReplicaShared {
    /// The published snapshot plus the not-yet-ingested job counts — the
    /// router's view of this replica.
    // lint: allow(panic, reason=loop index ranges over the fixed-size census arrays)
    pub fn routing_snapshot(&self) -> ReplicaSnapshot {
        let mut s = *lock_published(&self.snapshot);
        // Saturating: a submitter that skips the counters (tests driving
        // a replica directly) must not underflow the estimates.
        for i in 0..MAX_CLASSES {
            s.waiting[i] += self.submitted[i]
                .load(Ordering::Relaxed)
                .saturating_sub(self.ingested[i].load(Ordering::Relaxed));
        }
        s.failed = self.failed.load(Ordering::SeqCst);
        s.generation = self.generation.load(Ordering::Relaxed);
        s
    }

    /// Record a job heading toward this replica (call before sending).
    // lint: allow(panic, reason=index clamped to MAX_CLASSES - 1)
    pub fn note_submitted(&self, class: Class) {
        self.submitted[class.index().min(MAX_CLASSES - 1)].fetch_add(1, Ordering::Relaxed);
    }

    // lint: allow(panic, reason=index clamped to MAX_CLASSES - 1)
    fn note_ingested(&self, class: Class) {
        self.ingested[class.index().min(MAX_CLASSES - 1)].fetch_add(1, Ordering::Relaxed);
    }
}

/// Handle to one running replica: the job sender, the published state,
/// and the thread handle (joined by [`Replica::join`]).
pub struct Replica {
    pub tx: Sender<Job>,
    pub shared: Arc<ReplicaShared>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Replica {
    /// Spawn a replica thread. Blocks until the factory has run; a
    /// factory error is returned here rather than left to surface on the
    /// first request.
    pub fn spawn<B, F>(
        name: String,
        factory: F,
        stop: Arc<AtomicBool>,
        drain: Duration,
    ) -> anyhow::Result<Replica>
    where
        B: ExecutionBackend + 'static,
        F: FnOnce() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        let shared = Arc::new(ReplicaShared::default());
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let thread = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new().name(name).spawn(move || {
                let engine = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(engine, rx, stop, shared, drain)
            })?
        };
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("replica thread died during startup"))??;
        Ok(Replica { tx, shared, thread: Some(thread) })
    }

    /// Join the replica thread (idempotent). The caller must have set the
    /// shared stop flag first or this blocks until every submitter hangs
    /// up and the engine drains.
    pub fn join(&mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The replica iteration loop: ingest -> step -> deliver -> publish, with
/// graceful drain on stop. See the module docs for the contract.
pub fn engine_loop<B: ExecutionBackend>(
    engine: Engine<B>,
    rx: Receiver<Job>,
    stop: Arc<AtomicBool>,
    shared: Arc<ReplicaShared>,
    drain: Duration,
) {
    // Unsupervised: a persistent backend failure parks the loop in a
    // refuse-jobs state (failed flag set) instead of exiting, exactly the
    // pre-supervisor behavior.
    let _ = engine_loop_impl(engine, &rx, &stop, &shared, drain, false);
}

/// Why one engine incarnation's loop ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LoopExit {
    /// The stop flag flipped and the drain finished (or timed out).
    Stopped,
    /// Every submitter hung up with nothing in flight.
    Disconnected,
    /// The backend failed persistently (only with `exit_on_failure`; the
    /// caller — a [`Supervisor`] — owns the restart decision).
    Failed,
}

/// One engine incarnation of the replica loop. With `exit_on_failure` a
/// persistent backend failure returns [`LoopExit::Failed`] after tearing
/// the engine's work down, handing the channel back to the caller;
/// without it the loop keeps serving refusals itself (the standalone
/// [`engine_loop`] contract).
fn engine_loop_impl<B: ExecutionBackend>(
    mut engine: Engine<B>,
    rx: &Receiver<Job>,
    stop: &AtomicBool,
    shared: &ReplicaShared,
    drain: Duration,
    exit_on_failure: bool,
) -> LoopExit {
    let start = Instant::now();
    type Reply = Sender<Result<Completion, JobError>>;
    // BTreeMap so drain-failure replies go out in request-id order —
    // replica-visible behavior stays independent of hash seeding.
    // Value: (reply channel, submit instant, optional absolute deadline).
    let mut inflight: BTreeMap<RequestId, (Reply, Instant, Option<Instant>)> = BTreeMap::new();
    engine.state.keep_finished = true;
    // Stamp the recorder with this incarnation so post-restart events are
    // attributable to the new engine in merged traces.
    engine.state.recorder.generation = shared.generation.load(Ordering::Relaxed) as u32;
    let mut last_publish = Instant::now();
    let mut drain_deadline: Option<Instant> = None;
    let mut disconnected = false;
    loop {
        if drain_deadline.is_none() && stop.load(Ordering::SeqCst) {
            drain_deadline = Some(Instant::now() + drain);
        }
        // Ingest everything already queued (jobs sent before the stop
        // flag flipped were *accepted* and still participate in the
        // drain).
        loop {
            match rx.try_recv() {
                Ok(job) => {
                    shared.note_ingested(job.class);
                    if shared.failed.load(Ordering::SeqCst) {
                        // Backend already declared dead: refuse instead of
                        // queueing work that can never execute (jobs racing
                        // the handler's own failed check land here).
                        let _ = job.reply.send(Err(JobError::BackendFailed));
                        continue;
                    }
                    let id = engine.fresh_id();
                    let now = start.elapsed().as_secs_f64();
                    let req = Request::new(id, job.class, now, job.prompt.len(), job.max_tokens)
                        .with_prompt(job.prompt);
                    inflight.insert(id, (job.reply, Instant::now(), job.deadline));
                    engine.submit(req);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // Deadline shed: cancel expired work in-engine *before* the
        // scheduler builds the next batch, so a timed-out request frees
        // its KV blocks and batch slot instead of decoding for a client
        // that has already given up. Waiting, running, and preempted work
        // all shed through the same per-request abort.
        let now = Instant::now();
        let expired: Vec<RequestId> = inflight
            .iter()
            .filter(|(_, (_, _, deadline))| deadline.is_some_and(|d| now >= d))
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            if let Some((reply, _, _)) = inflight.remove(&id) {
                // Audit the shed before the abort erases the request:
                // reason 0 = deadline, context = the engine's virtual
                // clock at decision time.
                let class = match engine.state.requests.get(&id) {
                    Some(r) => r.class.index() as u16,
                    None => 0,
                };
                engine.state.recorder.record(EventKind::Shed, id, class, 0.0, engine.clock_s, 0.0);
                engine.abort_request(id);
                let _ = reply.send(Err(JobError::DeadlineExceeded));
            }
        }
        // Publish *after* ingest, before the (possibly tens-of-ms) step:
        // routers must see a burst in the queue census as soon as it is
        // ingested, or the submitted/ingested in-channel delta drops to
        // zero while the published depth still shows the pre-burst state
        // — exactly the misrouting window the counters exist to close.
        *lock_published(&shared.snapshot) = ReplicaSnapshot::of(&engine);
        if let Some(deadline) = drain_deadline {
            if inflight.is_empty() {
                break; // drained: every accepted request was answered
            }
            if Instant::now() >= deadline {
                for (_, (reply, _, _)) in std::mem::take(&mut inflight) {
                    let _ = reply.send(Err(JobError::DrainTimeout));
                }
                break;
            }
        } else if disconnected && inflight.is_empty() {
            return LoopExit::Disconnected; // every submitter hung up
        }
        if engine.has_work() {
            match engine.step() {
                Err(_) => {
                    // Execution error: fail all inflight requests AND tear
                    // the engine's in-flight work down (release blocks,
                    // empty the queues/running sets). Leaving it intact
                    // re-schedules the same doomed batch every loop — a
                    // 100% CPU livelock with no reply channels left to
                    // observe it.
                    for (_, (reply, _, _)) in std::mem::take(&mut inflight) {
                        let _ = reply.send(Err(JobError::BackendFailed));
                    }
                    engine.abort_all();
                    shared.failed.store(true, Ordering::SeqCst);
                    if exit_on_failure {
                        // Publish the post-abort state, then hand the
                        // channel back to the supervisor.
                        *lock_published(&shared.snapshot) = ReplicaSnapshot::of(&engine);
                        let report = engine.metrics.report(Some(start.elapsed().as_secs_f64()));
                        *lock_published(&shared.metrics_json) = report.to_json().to_pretty();
                        *lock_published(&shared.trace_json) =
                            engine.state.recorder.to_json(TRACE_PUBLISH_EVENTS).to_pretty();
                        return LoopExit::Failed;
                    }
                }
                Ok(0) => {
                    // Work exists but nothing is schedulable right now
                    // (e.g. a queued prompt waiting on KV memory): back
                    // off instead of re-running the scheduler at 100% CPU
                    // until something changes.
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(_) => {}
            }
            // deliver completions
            for req in engine.state.finished.drain(..) {
                if let Some((reply, t0, _)) = inflight.remove(&req.id) {
                    let _ = reply.send(Ok(Completion {
                        id: req.id,
                        text: tokenizer::decode(&req.output_tokens),
                        tokens: req.output_tokens,
                        latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                    }));
                }
            }
        } else {
            std::thread::sleep(Duration::from_millis(1));
        }
        if last_publish.elapsed() > PUBLISH_INTERVAL {
            let report = engine.metrics.report(Some(start.elapsed().as_secs_f64()));
            *lock_published(&shared.metrics_json) = report.to_json().to_pretty();
            *lock_published(&shared.trace_json) =
                engine.state.recorder.to_json(TRACE_PUBLISH_EVENTS).to_pretty();
            last_publish = Instant::now();
        }
    }
    // Jobs that raced into the channel after the final ingest pass get an
    // explicit error instead of a dropped reply channel (the handler also
    // maps a disconnected reply to 503 — belt and braces for the race).
    while let Ok(job) = rx.try_recv() {
        let _ = job.reply.send(Err(JobError::DrainTimeout));
    }
    // Final publish so a post-shutdown `/metrics` scrape (or a test)
    // observes the drained state.
    let report = engine.metrics.report(Some(start.elapsed().as_secs_f64()));
    *lock_published(&shared.metrics_json) = report.to_json().to_pretty();
    *lock_published(&shared.trace_json) =
        engine.state.recorder.to_json(TRACE_PUBLISH_EVENTS).to_pretty();
    LoopExit::Stopped
}

/// Restart policy for a supervised replica (config keys `max_restarts` /
/// `backoff_*_ms`, see `config::ClusterConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SupervisorConfig {
    /// Give up (permanently failed, refusing jobs) after this many
    /// restart attempts. Failed factory calls count as attempts too.
    pub max_restarts: usize,
    /// Backoff before the first restart attempt.
    pub backoff_initial: Duration,
    /// Backoff ceiling; the wait doubles per attempt up to here and
    /// never resets (a replica that keeps dying keeps waiting long).
    pub backoff_cap: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            max_restarts: 3,
            backoff_initial: Duration::from_millis(200),
            backoff_cap: Duration::from_secs(5),
        }
    }
}

/// A [`Replica`] that restarts its engine after persistent backend
/// failures: capped exponential backoff, a bounded number of attempts,
/// and job refusal (never a dropped reply channel) while recovering.
/// During recovery the `failed` flag stays set so routers skip the
/// replica; a successful restart clears it and bumps the published
/// generation. Same handle shape as [`Replica`] — job sender, shared
/// state, joinable thread.
pub struct Supervisor {
    pub tx: Sender<Job>,
    pub shared: Arc<ReplicaShared>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Supervisor {
    /// Spawn a supervised replica thread. The factory must be callable
    /// repeatedly — once per incarnation. Like [`Replica::spawn`], this
    /// blocks until the *first* factory call has run and returns its
    /// error rather than leaving it to surface on the first request.
    pub fn spawn<B, F>(
        name: String,
        factory: F,
        stop: Arc<AtomicBool>,
        drain: Duration,
        cfg: SupervisorConfig,
    ) -> anyhow::Result<Supervisor>
    where
        B: ExecutionBackend + 'static,
        F: Fn() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        let shared = Arc::new(ReplicaShared::default());
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let thread = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new().name(name).spawn(move || {
                let mut engine = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut restarts = 0usize;
                let mut backoff = cfg.backoff_initial;
                loop {
                    match engine_loop_impl(engine, &rx, &stop, &shared, drain, true) {
                        LoopExit::Stopped | LoopExit::Disconnected => return,
                        LoopExit::Failed => {}
                    }
                    // The incarnation died (its inflight work was already
                    // failed and torn down). Recover — or give up.
                    engine = loop {
                        if stop.load(Ordering::SeqCst) {
                            // Dying *during* shutdown is not worth a
                            // restart: refuse whatever is left and exit.
                            drain_refusing(&rx, &shared, JobError::DrainTimeout);
                            return;
                        }
                        restarts += 1;
                        shared.restarts.fetch_add(1, Ordering::Relaxed);
                        if restarts > cfg.max_restarts {
                            // Permanently failed: keep the failed flag up
                            // (routers skip us) and refuse jobs until the
                            // server stops. Health/metrics stay served
                            // from the last published state.
                            refuse_jobs(&rx, &stop, &shared, None);
                            drain_refusing(&rx, &shared, JobError::DrainTimeout);
                            return;
                        }
                        if refuse_jobs(&rx, &stop, &shared, Some(Instant::now() + backoff)) {
                            drain_refusing(&rx, &shared, JobError::DrainTimeout);
                            return;
                        }
                        backoff = backoff.saturating_mul(2).min(cfg.backoff_cap);
                        if let Ok(e) = factory() {
                            break e;
                        }
                        // A failed factory call burns an attempt and waits
                        // the (longer) backoff again.
                    };
                    shared.generation.fetch_add(1, Ordering::Relaxed);
                    shared.failed.store(false, Ordering::SeqCst);
                }
            })?
        };
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("replica thread died during startup"))??;
        Ok(Supervisor { tx, shared, thread: Some(thread) })
    }

    /// Join the supervisor thread (idempotent). Set the stop flag first.
    pub fn join(&mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Refuse jobs with [`JobError::BackendFailed`] until the stop flag flips
/// (`deadline: None`) or the deadline passes. Returns `true` when it
/// exited because of stop/disconnect (the caller should shut down).
fn refuse_jobs(
    rx: &Receiver<Job>,
    stop: &AtomicBool,
    shared: &ReplicaShared,
    deadline: Option<Instant>,
) -> bool {
    loop {
        if stop.load(Ordering::SeqCst) {
            return true;
        }
        if let Some(d) = deadline {
            if Instant::now() >= d {
                return false;
            }
        }
        match rx.recv_timeout(Duration::from_millis(5)) {
            Ok(job) => {
                shared.note_ingested(job.class);
                let _ = job.reply.send(Err(JobError::BackendFailed));
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            // Every submitter hung up: nothing left to refuse.
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return true,
        }
    }
}

/// Empty the channel, replying `err` to each queued job (shutdown path:
/// an explicit error beats a dropped reply channel).
fn drain_refusing(rx: &Receiver<Job>, shared: &ReplicaShared, err: JobError) {
    while let Ok(job) = rx.try_recv() {
        shared.note_ingested(job.class);
        let _ = job.reply.send(Err(err));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batch::Batch;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
    use crate::coordinator::state::EngineState;
    use crate::sim::costmodel::CostModel;
    use crate::sim::SimBackend;

    /// Delegates to a real sim backend, failing every execution while the
    /// shared flag is up.
    struct FlakyBackend {
        fail: Arc<AtomicBool>,
        inner: SimBackend,
    }

    impl ExecutionBackend for FlakyBackend {
        fn execute(&mut self, batch: &Batch, state: &mut EngineState) -> anyhow::Result<f64> {
            anyhow::ensure!(!self.fail.load(Ordering::SeqCst), "injected backend failure");
            self.inner.execute(batch, state)
        }

        fn on_removed(&mut self, id: RequestId) {
            self.inner.on_removed(id);
        }
    }

    fn flaky_factory(
        fail: Arc<AtomicBool>,
    ) -> impl Fn() -> anyhow::Result<Engine<FlakyBackend>> + Send + 'static {
        move || {
            let state = EngineState::new(OfflinePolicy::Fcfs, 256, 16, 0);
            let sched =
                HybridScheduler::new(SchedulerConfig::default(), LatencyPredictor::default_seed());
            let backend = FlakyBackend {
                fail: Arc::clone(&fail),
                inner: SimBackend::new(CostModel::a100_llama7b(), 0),
            };
            Ok(Engine::new(sched, state, backend))
        }
    }

    fn send_job(tx: &Sender<Job>, shared: &ReplicaShared) -> Receiver<Result<Completion, JobError>> {
        send_job_deadline(tx, shared, None)
    }

    fn send_job_deadline(
        tx: &Sender<Job>,
        shared: &ReplicaShared,
        deadline: Option<Instant>,
    ) -> Receiver<Result<Completion, JobError>> {
        let (reply, reply_rx) = channel();
        shared.note_submitted(Class::ONLINE);
        tx.send(Job { prompt: vec![1, 2, 3], max_tokens: 4, class: Class::ONLINE, deadline, reply })
            .unwrap();
        reply_rx
    }

    const RECV: Duration = Duration::from_secs(10);

    #[test]
    fn supervisor_restarts_a_failed_engine_and_recovers() {
        let fail = Arc::new(AtomicBool::new(true));
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = SupervisorConfig {
            max_restarts: 50,
            backoff_initial: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(4),
        };
        let mut sup = Supervisor::spawn(
            "sup-recover".into(),
            flaky_factory(Arc::clone(&fail)),
            Arc::clone(&stop),
            Duration::from_secs(5),
            cfg,
        )
        .unwrap();
        // First job hits the failing backend: an explicit error, never a
        // dropped reply channel.
        let reply = send_job(&sup.tx, &sup.shared);
        assert_eq!(reply.recv_timeout(RECV).unwrap().unwrap_err(), JobError::BackendFailed);
        // Heal the backend and keep submitting: the supervisor's backoff
        // restart must bring the replica back to serving.
        fail.store(false, Ordering::SeqCst);
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut served = false;
        while Instant::now() < deadline {
            let reply = send_job(&sup.tx, &sup.shared);
            match reply.recv_timeout(RECV).unwrap() {
                Ok(c) => {
                    assert!(!c.tokens.is_empty());
                    served = true;
                    break;
                }
                Err(JobError::BackendFailed) => std::thread::sleep(Duration::from_millis(2)),
                Err(e) => panic!("unexpected reply: {e:?}"),
            }
        }
        assert!(served, "replica never recovered after the backend healed");
        assert!(sup.shared.restarts.load(Ordering::Relaxed) >= 1);
        let snap = sup.shared.routing_snapshot();
        assert!(snap.generation >= 1, "a successful restart bumps the generation");
        assert!(!snap.failed, "recovery clears the failed flag");
        stop.store(true, Ordering::SeqCst);
        sup.join();
    }

    #[test]
    fn supervisor_gives_up_after_the_restart_cap() {
        let fail = Arc::new(AtomicBool::new(true)); // never heals
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = SupervisorConfig {
            max_restarts: 1,
            backoff_initial: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(2),
        };
        let mut sup = Supervisor::spawn(
            "sup-cap".into(),
            flaky_factory(Arc::clone(&fail)),
            Arc::clone(&stop),
            Duration::from_secs(5),
            cfg,
        )
        .unwrap();
        // Each job that reaches a live incarnation kills it; past the cap
        // the replica parks as permanently failed.
        let deadline = Instant::now() + Duration::from_secs(30);
        while sup.shared.restarts.load(Ordering::Relaxed) <= cfg.max_restarts {
            assert!(Instant::now() < deadline, "restart cap never reached");
            let reply = send_job(&sup.tx, &sup.shared);
            assert_eq!(reply.recv_timeout(RECV).unwrap().unwrap_err(), JobError::BackendFailed);
        }
        // Pinned: a permanently failed replica still refuses explicitly
        // and publishes `failed` so routers skip it (see the router tests
        // for the skip itself).
        let reply = send_job(&sup.tx, &sup.shared);
        assert_eq!(reply.recv_timeout(RECV).unwrap().unwrap_err(), JobError::BackendFailed);
        assert!(sup.shared.routing_snapshot().failed);
        assert_eq!(
            sup.shared.generation.load(Ordering::Relaxed),
            1,
            "exactly one restart succeeded before the cap"
        );
        stop.store(true, Ordering::SeqCst);
        sup.join();
    }

    #[test]
    fn failure_during_drain_is_not_restarted() {
        let fail = Arc::new(AtomicBool::new(true));
        let stop = Arc::new(AtomicBool::new(false));
        let mut sup = Supervisor::spawn(
            "sup-drain".into(),
            flaky_factory(Arc::clone(&fail)),
            Arc::clone(&stop),
            Duration::from_secs(5),
            SupervisorConfig::default(),
        )
        .unwrap();
        stop.store(true, Ordering::SeqCst);
        // Whether the job dies with the backend or is caught by the
        // shutdown drain, it gets an explicit error...
        let reply = send_job(&sup.tx, &sup.shared);
        assert!(reply.recv_timeout(RECV).unwrap().is_err());
        // ...and the thread exits instead of burning backoff restarts.
        sup.join();
        assert_eq!(sup.shared.restarts.load(Ordering::Relaxed), 0, "no restart during shutdown");
        assert_eq!(sup.shared.generation.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn expired_job_is_shed_in_engine_and_replica_keeps_serving() {
        let stop = Arc::new(AtomicBool::new(false));
        let fail = Arc::new(AtomicBool::new(false));
        let mut rep = Replica::spawn(
            "shed".into(),
            flaky_factory(fail),
            Arc::clone(&stop),
            Duration::from_secs(5),
        )
        .unwrap();
        // A job whose deadline has already passed is shed in-engine, never
        // served — the reply is the deadline error, not a completion.
        let reply = send_job_deadline(&rep.tx, &rep.shared, Some(Instant::now()));
        assert_eq!(reply.recv_timeout(RECV).unwrap().unwrap_err(), JobError::DeadlineExceeded);
        // The shed freed the engine's state: nothing waiting or running
        // remains once the shed reply has been observed, and the replica
        // keeps serving deadline-free work.
        let deadline = Instant::now() + RECV;
        loop {
            if rep.shared.routing_snapshot().total_depth() == 0 {
                break;
            }
            assert!(Instant::now() < deadline, "shed work still occupies the engine");
            std::thread::sleep(Duration::from_millis(2));
        }
        let reply = send_job(&rep.tx, &rep.shared);
        assert!(reply.recv_timeout(RECV).unwrap().is_ok(), "replica serves after a shed");
        stop.store(true, Ordering::SeqCst);
        rep.join();
        // The final publish dumps the flight recorder: the shed decision
        // and the served request's lifecycle are both in the trace.
        let trace = lock_published(&rep.shared.trace_json).clone();
        assert!(trace.contains("\"shed\""), "shed event in trace: {trace}");
        assert!(trace.contains("\"finish\""), "finish event in trace: {trace}");
    }

    #[test]
    fn factory_error_surfaces_at_spawn() {
        // A replica that dies before its first snapshot publish reports
        // the error at spawn, like `Replica::spawn`.
        let stop = Arc::new(AtomicBool::new(false));
        let err = Supervisor::spawn(
            "sup-bad".into(),
            || -> anyhow::Result<Engine<SimBackend>> { anyhow::bail!("no device") },
            stop,
            Duration::from_secs(1),
            SupervisorConfig::default(),
        );
        assert!(err.is_err());
    }
}
