//! One engine replica on its own thread behind an mpsc job queue — the
//! `server::engine_loop` message-passing shape, factored out so the HTTP
//! front end and the multi-replica cluster share it.
//!
//! The engine is *constructed on* the replica thread by the factory (PJRT
//! handles are not `Send`, so they must never cross threads). The thread:
//!
//! * ingests [`Job`]s, replying on each job's channel with an explicit
//!   `Result` — there is no in-band failure sentinel (a `Completion` with
//!   a fake request id 0 used to mean "failed", which collided with
//!   nothing only by luck);
//! * publishes a [`ReplicaSnapshot`] every loop iteration (cheap copy)
//!   and a metrics report every [`PUBLISH_INTERVAL`] for `/metrics`;
//! * on stop, **drains**: in-flight requests keep executing until they
//!   complete or the drain deadline passes, at which point the stragglers
//!   get [`JobError::DrainTimeout`] instead of a dropped channel.

use super::ReplicaSnapshot;
use crate::coordinator::classes::MAX_CLASSES;
use crate::coordinator::request::{Class, Request, RequestId};
use crate::engine::{Engine, ExecutionBackend};
use crate::runtime::tokenizer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How often the replica thread refreshes its published metrics report.
pub const PUBLISH_INTERVAL: Duration = Duration::from_millis(200);

/// A submission travelling from a connection handler to a replica thread.
pub struct Job {
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
    pub class: Class,
    pub reply: Sender<Result<Completion, JobError>>,
}

/// A successfully served request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Replica-local request id (each replica numbers its own requests).
    pub id: RequestId,
    pub text: String,
    pub tokens: Vec<u32>,
    pub latency_ms: f64,
}

/// Why a job could not be served. Explicit on the reply channel — callers
/// never have to sniff sentinel field values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobError {
    /// The execution backend failed persistently; the replica aborted its
    /// work and refuses new jobs.
    BackendFailed,
    /// The server stopped and the drain deadline passed before this
    /// request completed.
    DrainTimeout,
}

impl JobError {
    pub fn message(&self) -> &'static str {
        match self {
            JobError::BackendFailed => "backend failed",
            JobError::DrainTimeout => "server stopping",
        }
    }
}

/// State a replica thread publishes for the front end and the router.
#[derive(Default)]
pub struct ReplicaShared {
    /// Latest metrics report (pretty JSON), refreshed every
    /// [`PUBLISH_INTERVAL`].
    pub metrics_json: Mutex<String>,
    /// Latest census snapshot (refreshed every loop iteration).
    pub snapshot: Mutex<ReplicaSnapshot>,
    /// Jobs sent toward this replica per class (incremented by submitters
    /// *before* sending). Together with the `ingested` counters this
    /// gives the router an estimate of work still in the channel, so a
    /// burst between two snapshot refreshes does not all land on the same
    /// replica — and each class's burst counts against its own census
    /// slot (elastic bursts hit the harvest buffer, not the interactive
    /// depth).
    pub submitted: [AtomicUsize; MAX_CLASSES],
    /// Jobs the engine thread has taken off the channel, per class.
    pub ingested: [AtomicUsize; MAX_CLASSES],
    /// Set after a persistent backend failure: the engine aborted its
    /// work and new completions are refused (health/metrics stay up).
    pub failed: AtomicBool,
}

impl ReplicaShared {
    /// The published snapshot plus the not-yet-ingested job counts — the
    /// router's view of this replica.
    pub fn routing_snapshot(&self) -> ReplicaSnapshot {
        let mut s = *self.snapshot.lock().unwrap();
        // Saturating: a submitter that skips the counters (tests driving
        // a replica directly) must not underflow the estimates.
        for i in 0..MAX_CLASSES {
            s.waiting[i] += self.submitted[i]
                .load(Ordering::Relaxed)
                .saturating_sub(self.ingested[i].load(Ordering::Relaxed));
        }
        s.failed = self.failed.load(Ordering::SeqCst);
        s
    }

    /// Record a job heading toward this replica (call before sending).
    pub fn note_submitted(&self, class: Class) {
        self.submitted[class.index().min(MAX_CLASSES - 1)].fetch_add(1, Ordering::Relaxed);
    }

    fn note_ingested(&self, class: Class) {
        self.ingested[class.index().min(MAX_CLASSES - 1)].fetch_add(1, Ordering::Relaxed);
    }
}

/// Handle to one running replica: the job sender, the published state,
/// and the thread handle (joined by [`Replica::join`]).
pub struct Replica {
    pub tx: Sender<Job>,
    pub shared: Arc<ReplicaShared>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Replica {
    /// Spawn a replica thread. Blocks until the factory has run; a
    /// factory error is returned here rather than left to surface on the
    /// first request.
    pub fn spawn<B, F>(
        name: String,
        factory: F,
        stop: Arc<AtomicBool>,
        drain: Duration,
    ) -> anyhow::Result<Replica>
    where
        B: ExecutionBackend + 'static,
        F: FnOnce() -> anyhow::Result<Engine<B>> + Send + 'static,
    {
        let shared = Arc::new(ReplicaShared::default());
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let thread = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new().name(name).spawn(move || {
                let engine = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(engine, rx, stop, shared, drain)
            })?
        };
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("replica thread died during startup"))??;
        Ok(Replica { tx, shared, thread: Some(thread) })
    }

    /// Join the replica thread (idempotent). The caller must have set the
    /// shared stop flag first or this blocks until every submitter hangs
    /// up and the engine drains.
    pub fn join(&mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The replica iteration loop: ingest -> step -> deliver -> publish, with
/// graceful drain on stop. See the module docs for the contract.
pub fn engine_loop<B: ExecutionBackend>(
    mut engine: Engine<B>,
    rx: Receiver<Job>,
    stop: Arc<AtomicBool>,
    shared: Arc<ReplicaShared>,
    drain: Duration,
) {
    let start = Instant::now();
    type Reply = Sender<Result<Completion, JobError>>;
    let mut inflight: HashMap<RequestId, (Reply, Instant)> = HashMap::new();
    engine.state.keep_finished = true;
    let mut last_publish = Instant::now();
    let mut drain_deadline: Option<Instant> = None;
    let mut disconnected = false;
    loop {
        if drain_deadline.is_none() && stop.load(Ordering::SeqCst) {
            drain_deadline = Some(Instant::now() + drain);
        }
        // Ingest everything already queued (jobs sent before the stop
        // flag flipped were *accepted* and still participate in the
        // drain).
        loop {
            match rx.try_recv() {
                Ok(job) => {
                    shared.note_ingested(job.class);
                    if shared.failed.load(Ordering::SeqCst) {
                        // Backend already declared dead: refuse instead of
                        // queueing work that can never execute (jobs racing
                        // the handler's own failed check land here).
                        let _ = job.reply.send(Err(JobError::BackendFailed));
                        continue;
                    }
                    let id = engine.fresh_id();
                    let now = start.elapsed().as_secs_f64();
                    let req = Request::new(id, job.class, now, job.prompt.len(), job.max_tokens)
                        .with_prompt(job.prompt);
                    inflight.insert(id, (job.reply, Instant::now()));
                    engine.submit(req);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // Publish *after* ingest, before the (possibly tens-of-ms) step:
        // routers must see a burst in the queue census as soon as it is
        // ingested, or the submitted/ingested in-channel delta drops to
        // zero while the published depth still shows the pre-burst state
        // — exactly the misrouting window the counters exist to close.
        *shared.snapshot.lock().unwrap() = ReplicaSnapshot::of(&engine);
        if let Some(deadline) = drain_deadline {
            if inflight.is_empty() {
                break; // drained: every accepted request was answered
            }
            if Instant::now() >= deadline {
                for (_, (reply, _)) in inflight.drain() {
                    let _ = reply.send(Err(JobError::DrainTimeout));
                }
                break;
            }
        } else if disconnected && inflight.is_empty() {
            return; // every submitter hung up with nothing in flight
        }
        if engine.has_work() {
            match engine.step() {
                Err(_) => {
                    // Execution error: fail all inflight requests AND tear
                    // the engine's in-flight work down (release blocks,
                    // empty the queues/running sets). Leaving it intact
                    // re-schedules the same doomed batch every loop — a
                    // 100% CPU livelock with no reply channels left to
                    // observe it.
                    for (_, (reply, _)) in inflight.drain() {
                        let _ = reply.send(Err(JobError::BackendFailed));
                    }
                    engine.abort_all();
                    shared.failed.store(true, Ordering::SeqCst);
                }
                Ok(0) => {
                    // Work exists but nothing is schedulable right now
                    // (e.g. a queued prompt waiting on KV memory): back
                    // off instead of re-running the scheduler at 100% CPU
                    // until something changes.
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(_) => {}
            }
            // deliver completions
            for req in engine.state.finished.drain(..) {
                if let Some((reply, t0)) = inflight.remove(&req.id) {
                    let _ = reply.send(Ok(Completion {
                        id: req.id,
                        text: tokenizer::decode(&req.output_tokens),
                        tokens: req.output_tokens,
                        latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                    }));
                }
            }
        } else {
            std::thread::sleep(Duration::from_millis(1));
        }
        if last_publish.elapsed() > PUBLISH_INTERVAL {
            let report = engine.metrics.report(Some(start.elapsed().as_secs_f64()));
            *shared.metrics_json.lock().unwrap() = report.to_json().to_pretty();
            last_publish = Instant::now();
        }
    }
    // Jobs that raced into the channel after the final ingest pass get an
    // explicit error instead of a dropped reply channel (the handler also
    // maps a disconnected reply to 503 — belt and braces for the race).
    while let Ok(job) = rx.try_recv() {
        let _ = job.reply.send(Err(JobError::DrainTimeout));
    }
    // Final publish so a post-shutdown `/metrics` scrape (or a test)
    // observes the drained state.
    let report = engine.metrics.report(Some(start.elapsed().as_secs_f64()));
    *shared.metrics_json.lock().unwrap() = report.to_json().to_pretty();
}
