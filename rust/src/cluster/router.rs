//! Routing policies: which replica serves the next request.
//!
//! A [`Router`] sees only [`ReplicaSnapshot`]s — never engine state — so
//! the same policy drives the threaded server front end and the
//! deterministic cluster simulation. All tie-breaks resolve to the lowest
//! replica index, which keeps every decision (and therefore the
//! `cluster-sim` CSV) byte-reproducible for a fixed seed.
//!
//! Interactive (TTFT-SLO-bound) requests need an immediate placement
//! ([`Router::route_online`] always returns an index). Elastic work —
//! classes with no TTFT SLO — is a *shared backlog*:
//! [`Router::route_offline`] may return `None` to keep a request in the
//! backlog until a later rebalance tick — that deferral is how
//! [`SloHeadroom`] implements elastic placement, while [`RoundRobin`] and
//! [`JoinShortestQueue`] dispatch the backlog eagerly. `SloHeadroom`'s
//! headroom signal is computed against the **tightest class present** on
//! each replica (see [`ReplicaSnapshot::headroom_ms`]).

use super::ReplicaSnapshot;

/// A cluster routing policy. Implementations must be deterministic
/// functions of their own state and the snapshot slice.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Replica for an arriving interactive request. `snaps` is non-empty
    /// and the returned index is always in range; routable (non-failed,
    /// non-draining) replicas are preferred, and any index is acceptable
    /// once every replica is failed or draining (the caller surfaces the
    /// error or holds the work).
    fn route_online(&mut self, snaps: &[ReplicaSnapshot]) -> usize;

    /// Replica for an arriving interactive request whose prompt's
    /// full-block hash chain is known. Prefix-blind policies ignore the
    /// chain (this default delegates to [`route_online`]
    /// (Router::route_online)); [`PrefixAffinity`] weighs each replica's
    /// [`ReplicaSnapshot::cached_prefix_tokens`] against its SLO headroom.
    fn route_online_with_prefix(&mut self, snaps: &[ReplicaSnapshot], chain: &[u64]) -> usize {
        let _ = chain;
        self.route_online(snaps)
    }

    /// Replica for the next shared-backlog elastic request, or `None` to
    /// defer placement to a later rebalance tick.
    fn route_offline(&mut self, snaps: &[ReplicaSnapshot]) -> Option<usize>;
}

/// The named policies (config files, `--router`, `cluster-sim`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    JoinShortestQueue,
    SloHeadroom,
    PrefixAffinity,
}

impl RouterPolicy {
    pub const ALL: [RouterPolicy; 4] = [
        RouterPolicy::RoundRobin,
        RouterPolicy::JoinShortestQueue,
        RouterPolicy::SloHeadroom,
        RouterPolicy::PrefixAffinity,
    ];

    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "round-robin" | "rr" => Some(RouterPolicy::RoundRobin),
            "jsq" | "join-shortest-queue" => Some(RouterPolicy::JoinShortestQueue),
            "slo-headroom" | "slo" => Some(RouterPolicy::SloHeadroom),
            "prefix-affinity" | "affinity" => Some(RouterPolicy::PrefixAffinity),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::JoinShortestQueue => "jsq",
            RouterPolicy::SloHeadroom => "slo-headroom",
            RouterPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    /// Instantiate the policy.
    pub fn build(self) -> Box<dyn Router> {
        match self {
            RouterPolicy::RoundRobin => Box::new(RoundRobin::default()),
            RouterPolicy::JoinShortestQueue => Box::new(JoinShortestQueue),
            RouterPolicy::SloHeadroom => Box::new(SloHeadroom::default()),
            RouterPolicy::PrefixAffinity => Box::new(PrefixAffinity::default()),
        }
    }
}

/// A replica eligible for new placements: not failed (supervisor gave up
/// or backend dead) and not draining (scale-down / dying generation).
fn routable(s: &ReplicaSnapshot) -> bool {
    !s.failed && !s.draining
}

/// Index of the routable replica minimizing `key` (ties -> lowest index);
/// falls back over failed/draining replicas only when no routable one
/// exists.
fn argmin_live<K: PartialOrd, F: Fn(&ReplicaSnapshot) -> K>(
    snaps: &[ReplicaSnapshot],
    key: F,
) -> usize {
    let mut best: Option<(usize, K)> = None;
    for (i, s) in snaps.iter().enumerate() {
        if !routable(s) {
            continue;
        }
        let k = key(s);
        match &best {
            Some((_, bk)) if *bk <= k => {}
            _ => best = Some((i, k)),
        }
    }
    best.map(|(i, _)| i).unwrap_or(0)
}

/// Load-oblivious baseline: replicas take turns (skipping failed ones).
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        RouterPolicy::RoundRobin.name()
    }

    fn route_online(&mut self, snaps: &[ReplicaSnapshot]) -> usize {
        let n = snaps.len();
        for probe in 0..n {
            let i = (self.next + probe) % n;
            if routable(&snaps[i]) {
                self.next = (i + 1) % n;
                return i;
            }
        }
        let i = self.next % n;
        self.next = (i + 1) % n;
        i
    }

    fn route_offline(&mut self, snaps: &[ReplicaSnapshot]) -> Option<usize> {
        Some(self.route_online(snaps))
    }
}

/// Classic join-shortest-queue: route to the replica with the smallest
/// total depth (waiting + running, both classes). Never picks a replica
/// with a strictly longer queue than another live one.
#[derive(Debug, Default)]
pub struct JoinShortestQueue;

impl Router for JoinShortestQueue {
    fn name(&self) -> &'static str {
        RouterPolicy::JoinShortestQueue.name()
    }

    fn route_online(&mut self, snaps: &[ReplicaSnapshot]) -> usize {
        argmin_live(snaps, |s| s.total_depth())
    }

    fn route_offline(&mut self, snaps: &[ReplicaSnapshot]) -> Option<usize> {
        Some(argmin_live(snaps, |s| s.total_depth()))
    }
}

/// SLO-headroom routing (the cross-replica analogue of the paper's
/// SLO-aware offline scheduling):
///
/// * **online** — route to the replica whose latency-predictor estimate
///   leaves the most slack under its per-iteration budget (ties: smaller
///   online depth, then lower index), so bursts land where they disturb
///   running decodes least;
/// * **offline** — place shared-backlog work only on replicas with
///   *positive* headroom whose local offline buffer is below
///   [`SloHeadroom::offline_buffer`], keeping the rest of the backlog
///   central. Deferred work flows to whichever replica frees up first —
///   the elastic placement/rebalance loop — instead of being pinned to a
///   replica chosen at arrival time.
#[derive(Debug)]
pub struct SloHeadroom {
    /// Max offline requests kept waiting on one replica before further
    /// placement defers to the shared backlog.
    pub offline_buffer: usize,
}

impl Default for SloHeadroom {
    fn default() -> Self {
        SloHeadroom { offline_buffer: 32 }
    }
}

impl Router for SloHeadroom {
    fn name(&self) -> &'static str {
        RouterPolicy::SloHeadroom.name()
    }

    fn route_online(&mut self, snaps: &[ReplicaSnapshot]) -> usize {
        // Max headroom == min (-headroom); encode the tie-breaks in the
        // comparison key. NaN never occurs (budget and prediction are
        // finite or +inf, and inf - inf cannot arise: an infinite budget
        // gives infinite headroom regardless of the prediction).
        argmin_live(snaps, |s| (-s.headroom_ms(), s.online_depth()))
    }

    fn route_offline(&mut self, snaps: &[ReplicaSnapshot]) -> Option<usize> {
        let buffer = self.offline_buffer;
        let mut best: Option<(usize, (f64, usize))> = None;
        for (i, s) in snaps.iter().enumerate() {
            if !routable(s) || s.headroom_ms() <= 0.0 || s.offline_waiting() >= buffer {
                continue;
            }
            let k = (-s.headroom_ms(), s.offline_waiting());
            match &best {
                Some((_, bk)) if *bk <= k => {}
                _ => best = Some((i, k)),
            }
        }
        best.map(|(i, _)| i)
    }
}

/// Prefix-affinity routing: send an interactive request to the replica
/// that already holds its prompt prefix in KV cache — *unless* that
/// replica is short on SLO headroom. Each routable replica is scored
///
/// ```text
/// score = weight_ms_per_token × cached_prefix_tokens(chain) + headroom_ms
/// ```
///
/// and the highest score wins (ties: smaller online depth, then lower
/// index). `weight_ms_per_token` converts resident prefix tokens into
/// the same milliseconds currency as headroom — it is roughly "prefill
/// milliseconds saved per cached token", so a warm replica can outbid a
/// cold one with up to `weight × cached` extra predicted load, and no
/// more. When every replica is cold for the chain (or the chain is
/// empty/unknown), the decision is exactly [`SloHeadroom`]'s, and
/// offline placement always delegates to the embedded fallback.
#[derive(Debug)]
pub struct PrefixAffinity {
    /// Milliseconds of headroom one cached prefix token is worth.
    pub weight_ms_per_token: f64,
    /// Cold-path policy (also serves `route_online` when no chain is
    /// available, and all offline placement).
    pub fallback: SloHeadroom,
}

impl Default for PrefixAffinity {
    fn default() -> Self {
        PrefixAffinity { weight_ms_per_token: 0.1, fallback: SloHeadroom::default() }
    }
}

impl Router for PrefixAffinity {
    fn name(&self) -> &'static str {
        RouterPolicy::PrefixAffinity.name()
    }

    fn route_online(&mut self, snaps: &[ReplicaSnapshot]) -> usize {
        // No chain in hand: indistinguishable from SloHeadroom.
        self.fallback.route_online(snaps)
    }

    fn route_online_with_prefix(&mut self, snaps: &[ReplicaSnapshot], chain: &[u64]) -> usize {
        let mut any_warm = false;
        for s in snaps {
            if routable(s) && s.cached_prefix_tokens(chain) > 0 {
                any_warm = true;
                break;
            }
        }
        if !any_warm {
            return self.fallback.route_online(snaps);
        }
        let w = self.weight_ms_per_token;
        argmin_live(snaps, |s| {
            let score = w * s.cached_prefix_tokens(chain) as f64 + s.headroom_ms();
            (-score, s.online_depth())
        })
    }

    fn route_offline(&mut self, snaps: &[ReplicaSnapshot]) -> Option<usize> {
        self.fallback.route_offline(snaps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(depth: usize, headroom: f64) -> ReplicaSnapshot {
        let mut s = ReplicaSnapshot {
            predicted_iter_ms: 40.0 - headroom,
            latency_budget_ms: 40.0,
            ..Default::default()
        };
        s.waiting[0] = depth;
        s
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in RouterPolicy::ALL {
            assert_eq!(RouterPolicy::parse(p.name()), Some(p));
            assert_eq!(p.build().name(), p.name());
        }
        assert_eq!(RouterPolicy::parse("rr"), Some(RouterPolicy::RoundRobin));
        assert_eq!(RouterPolicy::parse("slo"), Some(RouterPolicy::SloHeadroom));
        assert_eq!(RouterPolicy::parse("bogus"), None);
    }

    #[test]
    fn round_robin_cycles_and_skips_failed() {
        let mut rr = RoundRobin::default();
        let mut snaps = vec![snap(0, 10.0); 3];
        assert_eq!(rr.route_online(&snaps), 0);
        assert_eq!(rr.route_online(&snaps), 1);
        assert_eq!(rr.route_online(&snaps), 2);
        assert_eq!(rr.route_online(&snaps), 0);
        snaps[1].failed = true;
        assert_eq!(rr.route_online(&snaps), 2, "failed replica skipped");
        assert_eq!(rr.route_online(&snaps), 0);
    }

    #[test]
    fn jsq_picks_min_depth_with_low_index_ties() {
        let mut jsq = JoinShortestQueue;
        let snaps = vec![snap(3, 10.0), snap(1, 10.0), snap(1, 10.0)];
        assert_eq!(jsq.route_online(&snaps), 1, "tie resolves to the lower index");
        assert_eq!(jsq.route_offline(&snaps), Some(1));
    }

    #[test]
    fn slo_headroom_routes_online_to_most_slack() {
        let mut r = SloHeadroom::default();
        let snaps = vec![snap(0, 5.0), snap(0, 25.0), snap(0, 15.0)];
        assert_eq!(r.route_online(&snaps), 1);
    }

    #[test]
    fn slo_headroom_defers_offline_without_slack() {
        let mut r = SloHeadroom { offline_buffer: 2 };
        // No replica has positive headroom: defer.
        let tight = vec![snap(0, -1.0), snap(0, 0.0)];
        assert_eq!(r.route_offline(&tight), None);
        // Buffer full on the best replica: spill to the next.
        let mut snaps = vec![snap(0, 30.0), snap(0, 20.0)];
        snaps[0].waiting[1] = 2;
        assert_eq!(r.route_offline(&snaps), Some(1));
        snaps[1].waiting[1] = 2;
        assert_eq!(r.route_offline(&snaps), None, "all buffers full: keep central");
    }

    #[test]
    fn every_policy_skips_failed_replicas() {
        // Pins the failed-replica-skip contract explicitly (a supervisor
        // that exhausted its restart budget marks the replica failed and
        // it must never see new work while any live replica exists).
        let mut snaps = vec![snap(0, 25.0), snap(0, 30.0), snap(5, 5.0)];
        snaps[1].failed = true;
        for p in RouterPolicy::ALL {
            let mut r = p.build();
            for _ in 0..4 {
                let i = r.route_online(&snaps);
                assert_ne!(i, 1, "{} routed online to a failed replica", p.name());
                if let Some(j) = r.route_offline(&snaps) {
                    assert_ne!(j, 1, "{} placed offline on a failed replica", p.name());
                }
            }
        }
    }

    #[test]
    fn every_policy_skips_draining_replicas() {
        // A draining replica (scale-down or dying generation) still
        // reports the best headroom/depth — routers must not place new
        // work on it anyway.
        let mut snaps = vec![snap(4, 10.0), snap(0, 35.0), snap(2, 20.0)];
        snaps[1].draining = true;
        for p in RouterPolicy::ALL {
            let mut r = p.build();
            for _ in 0..4 {
                let i = r.route_online(&snaps);
                assert_ne!(i, 1, "{} routed online to a draining replica", p.name());
                if let Some(j) = r.route_offline(&snaps) {
                    assert_ne!(j, 1, "{} placed offline on a draining replica", p.name());
                }
            }
        }
    }

    /// Mark `s` warm for the family rooted at `fp`, holding `tokens`.
    fn warm(s: &mut ReplicaSnapshot, fp: u64, tokens: u32) {
        use crate::coordinator::block_manager::PROBE_SLOTS;
        s.prefix_probe[(fp % PROBE_SLOTS as u64) as usize] = (fp, tokens);
    }

    #[test]
    fn prefix_affinity_routes_to_warm_replica() {
        let mut r = PrefixAffinity::default();
        let fp = 0x1234_5678_9abc_def0u64;
        let mut snaps = vec![snap(0, 20.0), snap(0, 20.0), snap(0, 20.0)];
        warm(&mut snaps[2], fp, 512);
        assert_eq!(r.route_online_with_prefix(&snaps, &[fp]), 2, "warm replica wins at equal headroom");
        // A different family's chain is cold everywhere: exact SloHeadroom
        // behaviour (lowest index at equal headroom).
        assert_eq!(r.route_online_with_prefix(&snaps, &[fp ^ 1]), 0);
        assert_eq!(r.route_online_with_prefix(&snaps, &[]), 0);
        assert_eq!(r.route_online(&snaps), 0, "chain-less entry point is SloHeadroom");
    }

    #[test]
    fn prefix_affinity_weight_trades_against_headroom() {
        let fp = 77u64;
        // Warm replica has 10 ms less headroom; 256 cached tokens at the
        // default 0.1 ms/token are worth 25.6 ms — affinity wins.
        let mut snaps = vec![snap(0, 30.0), snap(0, 20.0)];
        warm(&mut snaps[1], fp, 256);
        let mut r = PrefixAffinity::default();
        assert_eq!(r.route_online_with_prefix(&snaps, &[fp]), 1);
        // Tiny weight: the cached tokens cannot cover the headroom gap.
        let mut r = PrefixAffinity { weight_ms_per_token: 0.01, ..PrefixAffinity::default() };
        assert_eq!(r.route_online_with_prefix(&snaps, &[fp]), 0, "headroom dominates at low weight");
    }

    #[test]
    fn prefix_affinity_skips_failed_and_draining_warm_replicas() {
        let fp = 9u64;
        let mut snaps = vec![snap(0, 10.0), snap(0, 30.0), snap(0, 20.0)];
        warm(&mut snaps[1], fp, 4096);
        snaps[1].failed = true;
        let mut r = PrefixAffinity::default();
        assert_ne!(r.route_online_with_prefix(&snaps, &[fp]), 1, "failed warm replica skipped");
        snaps[1].failed = false;
        snaps[1].draining = true;
        assert_ne!(r.route_online_with_prefix(&snaps, &[fp]), 1, "draining warm replica skipped");
        // Offline placement delegates to the SloHeadroom fallback.
        assert_eq!(r.route_offline(&snaps), Some(2));
    }

    #[test]
    fn default_prefix_route_ignores_chain() {
        // Prefix-blind policies get the trait default: the chain is a
        // no-op and both entry points agree.
        let fp = 5u64;
        let mut snaps = vec![snap(2, 10.0), snap(1, 10.0)];
        warm(&mut snaps[0], fp, 1024);
        let mut jsq = JoinShortestQueue;
        assert_eq!(jsq.route_online_with_prefix(&snaps, &[fp]), jsq.route_online(&snaps));
    }

    #[test]
    fn all_failed_still_returns_an_index() {
        let mut snaps = vec![snap(0, 10.0); 2];
        for s in &mut snaps {
            s.failed = true;
        }
        for p in RouterPolicy::ALL {
            let mut r = p.build();
            assert!(r.route_online(&snaps) < snaps.len(), "{}", p.name());
        }
    }
}
