//! Deterministic multi-replica trace replay: N engines on virtual clocks,
//! one router, shared per-class elastic backlogs.
//!
//! The driver always steps the *lagging* replica (smallest virtual
//! clock), so cluster time advances evenly and admission happens exactly
//! when the cluster-wide clock passes an event's arrival. Events of
//! **interactive** classes (any class with a TTFT SLO) are routed
//! immediately ([`Router::route_online`]); **elastic** classes enter a
//! shared per-class backlog and are placed by [`Router::route_offline`]
//! at periodic *rebalance ticks* — highest-tier backlog first — which
//! also pull still-waiting elastic work back from replicas whose
//! predicted batch time exceeds their effective latency budget (negative
//! SLO headroom), lowest-tier work first. This is the cross-replica
//! analogue of the paper's elastic offline scheduling; with the default
//! two-class registry it is exactly the single-backlog online/offline
//! behavior.
//!
//! Everything is seeded and single-threaded: the same trace, router, and
//! seeds produce bit-identical results (the `cluster-sim` and
//! `multi-slo` CSVs are compared byte-for-byte in CI).
//!
//! Measurement note: a routed request is admitted on its target replica's
//! clock, which can run ahead of the cluster-wide minimum by up to one
//! batch latency; TTFT is measured from that admission instant. The skew
//! is bounded by the lagging-replica stepping rule and identical across
//! policies.

use super::router::Router;
use super::ReplicaSnapshot;
use crate::coordinator::classes::ClassRegistry;
use crate::coordinator::metrics::{Metrics, Report};
use crate::coordinator::request::{Class, Request, RequestId};
use crate::engine::{Engine, ExecutionBackend};
use crate::workload::trace::{Trace, TraceEvent};
use std::collections::VecDeque;
use std::sync::Arc;

/// One replica's share of a cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaRunStats {
    pub report: Report,
    /// The replica's virtual clock at the end of the run.
    pub clock_s: f64,
    /// Requests dispatched to this replica (including re-dispatch after a
    /// reclaim).
    pub routed: usize,
    /// Output tokens the replica generated (all classes).
    pub out_tokens: u64,
}

/// Outcome of [`ClusterSim::run`].
#[derive(Debug, Clone)]
pub struct ClusterRunResult {
    pub per_replica: Vec<ReplicaRunStats>,
    /// Cluster-wide report: latency summaries merged sample-by-sample
    /// per class (exact percentiles, not an average of averages),
    /// counters summed.
    pub aggregate: Report,
    /// Max replica clock at stop — the denominator of every rate.
    pub duration_s: f64,
    /// Age of the oldest elastic request still waiting (shared backlog or
    /// a replica queue) when the run stopped; 0 when everything started.
    pub offline_starvation_age_s: f64,
    /// Max/mean ratio of per-replica generated tokens (1.0 = perfectly
    /// even utilization).
    pub util_imbalance: f64,
    /// Total dispatches to replicas (>= admitted events when reclaims
    /// re-dispatched work).
    pub dispatched: usize,
    /// Elastic requests pulled back into the shared backlog from
    /// overloaded replicas.
    pub reclaimed: usize,
    /// Elastic events never placed on any replica.
    pub backlog_left: usize,
}

/// The cluster driver. Build it with per-replica engines (seeded however
/// the caller wants; all replicas must share one registry), run one
/// trace, then inspect the engines freely — `run` leaves them in their
/// final state for invariant checks.
pub struct ClusterSim<B: ExecutionBackend> {
    pub engines: Vec<Engine<B>>,
    registry: Arc<ClassRegistry>,
    router: Box<dyn Router>,
    rebalance_interval_s: f64,
    next_rebalance_s: f64,
    /// Shared elastic backlogs, one deque per class (only elastic
    /// classes' deques are ever used). Placement drains the
    /// highest-tier non-empty deque first.
    backlog: Vec<VecDeque<TraceEvent>>,
    /// Elastic work placed on a replica but (possibly) still waiting
    /// there: `(replica, id, arrival, class)`. Consulted for reclaim and
    /// starvation accounting; entries whose request started are pruned at
    /// each rebalance tick.
    dispatched_elastic: Vec<(usize, RequestId, f64, Class)>,
    /// Dispatch tally per replica.
    pub routed: Vec<usize>,
    dispatched: usize,
    reclaimed: usize,
    stalled: u64,
}

impl<B: ExecutionBackend> ClusterSim<B> {
    pub fn new(
        engines: Vec<Engine<B>>,
        router: Box<dyn Router>,
        rebalance_interval_s: f64,
    ) -> ClusterSim<B> {
        assert!(!engines.is_empty(), "cluster needs at least one replica");
        assert!(rebalance_interval_s > 0.0, "rebalance interval must be positive");
        let n = engines.len();
        let registry = Arc::clone(&engines[0].state.registry);
        ClusterSim {
            backlog: (0..registry.len()).map(|_| VecDeque::new()).collect(),
            engines,
            registry,
            router,
            rebalance_interval_s,
            next_rebalance_s: 0.0,
            dispatched_elastic: Vec::new(),
            routed: vec![0; n],
            dispatched: 0,
            reclaimed: 0,
            stalled: 0,
        }
    }

    /// Elastic events currently held centrally (tests/observability).
    pub fn backlog_len(&self) -> usize {
        self.backlog.iter().map(|b| b.len()).sum()
    }

    fn snaps(&self) -> Vec<ReplicaSnapshot> {
        self.engines.iter().map(ReplicaSnapshot::of).collect()
    }

    /// Highest-tier class with pending backlog work (placement order: the
    /// most latency-sensitive elastic work leaves the backlog first).
    fn next_backlog_class(&self) -> Option<Class> {
        self.registry
            .tier_order_desc()
            .iter()
            .copied()
            .find(|&c| !self.backlog[c.index()].is_empty())
    }

    /// Replica to step next: smallest clock; on ties, prefer one with
    /// work (so an idle replica parked at the same instant never shadows
    /// a busy one).
    fn lagging_replica(&self) -> usize {
        let mut best = 0usize;
        for i in 1..self.engines.len() {
            let (ci, cb) = (self.engines[i].clock_s, self.engines[best].clock_s);
            if ci < cb
                || (ci == cb && self.engines[i].has_work() && !self.engines[best].has_work())
            {
                best = i;
            }
        }
        best
    }

    fn min_clock(&self) -> f64 {
        self.engines.iter().map(|e| e.clock_s).fold(f64::INFINITY, f64::min)
    }

    /// Create the event's request on replica `i` (fresh replica-local id)
    /// and admit it.
    fn submit_event(&mut self, i: usize, e: &TraceEvent) {
        let engine = &mut self.engines[i];
        let id = engine.fresh_id();
        let mut req = Request::new(id, e.class, e.arrival_s, e.prompt_len, e.output_len);
        if !e.prompt.is_empty() {
            req = req.with_prompt(e.prompt.clone());
        }
        engine.submit(req);
        self.routed[i] += 1;
        self.dispatched += 1;
        if self.registry.spec(e.class).elastic() {
            self.dispatched_elastic.push((i, id, e.arrival_s, e.class));
        }
    }

    /// One rebalance tick: reclaim waiting elastic work from replicas
    /// with negative SLO headroom (lowest-tier work first — the
    /// dispatch-entry order is ascending-tier within each push batch, and
    /// every waiting entry on a hot replica is reclaimed), prune tracking
    /// entries whose requests started, then place backlog work —
    /// highest-tier first — wherever the router finds room.
    fn rebalance(&mut self) {
        let mut snaps = self.snaps();
        let hot: Vec<bool> = snaps.iter().map(|s| s.headroom_ms() < 0.0).collect();
        let entries = std::mem::take(&mut self.dispatched_elastic);
        let mut keep = Vec::with_capacity(entries.len());
        for (rep, id, arrival, class) in entries {
            let waiting = self.engines[rep].state.queue(class).contains(id);
            if waiting && hot[rep] {
                if let Some(req) = self.engines[rep].state.queue_mut(class).remove(id) {
                    self.backlog[class.index()].push_back(TraceEvent {
                        arrival_s: arrival,
                        class,
                        prompt_len: req.prompt_len,
                        output_len: req.output_len,
                        prompt: req.prompt,
                    });
                    self.reclaimed += 1;
                    snaps[rep].waiting[class.index()] =
                        snaps[rep].waiting[class.index()].saturating_sub(1);
                    continue;
                }
            }
            if waiting {
                keep.push((rep, id, arrival, class));
            }
        }
        self.dispatched_elastic = keep;
        while let Some(class) = self.next_backlog_class() {
            match self.router.route_offline(&snaps) {
                Some(i) if i < self.engines.len() => {
                    let e = self.backlog[class.index()].pop_front().expect("checked non-empty");
                    self.submit_event(i, &e);
                    snaps[i].waiting[class.index()] += 1;
                }
                _ => break,
            }
        }
    }

    /// Replay `trace` until its interactive portion is fully served
    /// (elastic work is a backlog, the paper's throughput accounting) or
    /// `max_clock_s` passes. One run per `ClusterSim` — metrics
    /// accumulate.
    pub fn run(&mut self, trace: &Trace, max_clock_s: f64) -> anyhow::Result<ClusterRunResult> {
        let events = &trace.events;
        let mut next_event = 0usize;
        let registry = Arc::clone(&self.registry);
        let mut interactive_ahead: usize = registry
            .ids()
            .filter(|&c| !registry.spec(c).elastic())
            .map(|c| trace.num_of(c))
            .sum();
        loop {
            let now = self.min_clock();
            while next_event < events.len() && events[next_event].arrival_s <= now {
                let e = events[next_event].clone();
                next_event += 1;
                if registry.spec(e.class).elastic() {
                    self.backlog[e.class.index()].push_back(e);
                } else {
                    interactive_ahead -= 1;
                    let snaps = self.snaps();
                    let i = self.router.route_online(&snaps);
                    anyhow::ensure!(i < self.engines.len(), "router index out of range");
                    self.submit_event(i, &e);
                }
            }
            if now >= self.next_rebalance_s {
                self.rebalance();
                while self.next_rebalance_s <= now {
                    self.next_rebalance_s += self.rebalance_interval_s;
                }
            }
            let online_left = interactive_ahead > 0
                || self.engines.iter().any(|e| e.state.interactive_pending());
            if !online_left || now >= max_clock_s {
                break;
            }
            let i = self.lagging_replica();
            if self.engines[i].has_work() {
                if self.engines[i].step()? == 0 {
                    // Stalled (memory or budget starvation): advance to
                    // the next actionable instant.
                    self.stalled += 1;
                    anyhow::ensure!(
                        self.stalled < 5_000_000,
                        "cluster livelock: {} stalled iterations",
                        self.stalled
                    );
                    let c = self.engines[i].clock_s;
                    let mut t = c + 0.005;
                    if let Some(e) = events.get(next_event) {
                        if e.arrival_s > c {
                            t = t.min(e.arrival_s);
                        }
                    }
                    self.engines[i].clock_s = t;
                }
            } else {
                // Idle replica: skip to the next instant that can hand it
                // work (arrival or, with a pending backlog, the next
                // rebalance tick), or park it at the slowest busy clock.
                let c = self.engines[i].clock_s;
                let mut t = f64::INFINITY;
                if let Some(e) = events.get(next_event) {
                    t = t.min(e.arrival_s);
                }
                if self.backlog_len() > 0 {
                    t = t.min(self.next_rebalance_s);
                }
                if t.is_finite() && t > c {
                    self.engines[i].clock_s = t;
                } else {
                    let busy = self
                        .engines
                        .iter()
                        .filter(|e| e.has_work())
                        .map(|e| e.clock_s)
                        .fold(f64::INFINITY, f64::min);
                    if busy.is_finite() && busy > c {
                        self.engines[i].clock_s = busy;
                    } else {
                        // Nothing pending anywhere and no arrivals left.
                        break;
                    }
                }
            }
        }
        Ok(self.collect())
    }

    fn collect(&mut self) -> ClusterRunResult {
        let end = self.engines.iter().map(|e| e.clock_s).fold(0.0, f64::max).max(1e-9);
        let mut agg = Metrics::new(1.0);
        let routed = self.routed.clone();
        let mut per_replica = Vec::with_capacity(self.engines.len());
        for (i, e) in self.engines.iter_mut().enumerate() {
            agg.absorb(&e.metrics);
            let out_tokens = e.metrics.online_token_count() + e.metrics.offline_token_count();
            per_replica.push(ReplicaRunStats {
                report: e.metrics.report(Some(end)),
                clock_s: e.clock_s,
                routed: routed[i],
                out_tokens,
            });
        }
        let mean = per_replica.iter().map(|r| r.out_tokens as f64).sum::<f64>()
            / per_replica.len() as f64;
        let max = per_replica.iter().map(|r| r.out_tokens as f64).fold(0.0, f64::max);
        let util_imbalance = if mean > 0.0 { max / mean } else { 1.0 };
        let mut starvation = 0.0f64;
        for deque in &self.backlog {
            for e in deque {
                starvation = starvation.max(end - e.arrival_s);
            }
        }
        for &(rep, id, arrival, class) in &self.dispatched_elastic {
            if self.engines[rep].state.queue(class).contains(id) {
                starvation = starvation.max(end - arrival);
            }
        }
        ClusterRunResult {
            per_replica,
            aggregate: agg.report(Some(end)),
            duration_s: end,
            offline_starvation_age_s: starvation,
            util_imbalance,
            dispatched: self.dispatched,
            reclaimed: self.reclaimed,
            backlog_left: self.backlog_len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::RouterPolicy;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
    use crate::coordinator::state::EngineState;
    use crate::sim::costmodel::CostModel;
    use crate::sim::SimBackend;

    fn engines(n: usize, budget: Option<f64>) -> Vec<Engine<SimBackend>> {
        (0..n)
            .map(|i| {
                let state = EngineState::new(OfflinePolicy::Fcfs, 1024, 16, i as u64);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: budget, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                let mut e = Engine::new(
                    sched,
                    state,
                    SimBackend::new(CostModel::a100_llama7b(), i as u64),
                );
                e.state.keep_finished = false;
                e
            })
            .collect()
    }

    fn ev(t: f64, class: Class, p: usize, o: usize) -> TraceEvent {
        TraceEvent { arrival_s: t, class, prompt_len: p, output_len: o, prompt: Vec::new().into() }
    }

    fn mixed_trace(n_online: usize, n_offline: usize) -> Trace {
        let mut events = Vec::new();
        for i in 0..n_online {
            events.push(ev(i as f64 * 0.05, Class::ONLINE, 64, 8));
        }
        for _ in 0..n_offline {
            events.push(ev(0.0, Class::OFFLINE, 128, 16));
        }
        Trace::new(events)
    }

    #[test]
    fn every_policy_serves_the_whole_online_trace() {
        for policy in RouterPolicy::ALL {
            let mut sim = ClusterSim::new(engines(3, Some(40.0)), policy.build(), 0.5);
            let r = sim.run(&mixed_trace(30, 12), 600.0).unwrap();
            assert_eq!(r.aggregate.online_finished, 30, "{}", policy.name());
            assert!(r.duration_s > 0.0);
            assert!(r.util_imbalance >= 1.0);
            assert_eq!(
                r.dispatched - r.reclaimed,
                42 - r.backlog_left,
                "{}: each admitted event lives on exactly one replica",
                policy.name()
            );
            for e in &sim.engines {
                e.state.check_invariants().unwrap();
            }
        }
    }

    #[test]
    fn round_robin_spreads_online_evenly() {
        let mut sim = ClusterSim::new(engines(4, None), RouterPolicy::RoundRobin.build(), 0.5);
        let r = sim.run(&mixed_trace(40, 0), 600.0).unwrap();
        assert_eq!(r.aggregate.online_finished, 40);
        assert_eq!(sim.routed, vec![10, 10, 10, 10]);
    }

    #[test]
    fn slo_headroom_keeps_backlog_central_until_there_is_room() {
        let mut sim =
            ClusterSim::new(engines(2, Some(40.0)), RouterPolicy::SloHeadroom.build(), 0.5);
        // 100 offline requests against a 32-per-replica buffer: the first
        // tick must leave work central instead of pinning everything.
        let mut events = vec![ev(0.0, Class::ONLINE, 64, 4)];
        for _ in 0..100 {
            events.push(ev(0.0, Class::OFFLINE, 512, 64));
        }
        let r = sim.run(&Trace::new(events), 20.0).unwrap();
        assert_eq!(r.aggregate.online_finished, 1);
        assert!(
            r.backlog_left > 0,
            "elastic placement defers most of a large backlog ({} left)",
            r.backlog_left
        );
        assert!(r.offline_starvation_age_s > 0.0, "waiting work has a measurable age");
    }

    #[test]
    fn same_inputs_same_result() {
        let run = || {
            let mut sim =
                ClusterSim::new(engines(2, Some(40.0)), RouterPolicy::SloHeadroom.build(), 0.5);
            sim.run(&mixed_trace(20, 30), 600.0).unwrap().aggregate
        };
        assert_eq!(run(), run(), "cluster replay must be deterministic");
    }
}
