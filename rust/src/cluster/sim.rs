//! Deterministic multi-replica trace replay: N engines on virtual clocks,
//! one router, shared per-class elastic backlogs.
//!
//! The driver always steps the *lagging* replica (smallest virtual
//! clock), so cluster time advances evenly and admission happens exactly
//! when the cluster-wide clock passes an event's arrival. Events of
//! **interactive** classes (any class with a TTFT SLO) are routed
//! immediately ([`Router::route_online`]); **elastic** classes enter a
//! shared per-class backlog and are placed by [`Router::route_offline`]
//! at periodic *rebalance ticks* — highest-tier backlog first — which
//! also pull still-waiting elastic work back from replicas whose
//! predicted batch time exceeds their effective latency budget (negative
//! SLO headroom), lowest-tier work first. This is the cross-replica
//! analogue of the paper's elastic offline scheduling; with the default
//! two-class registry it is exactly the single-backlog online/offline
//! behavior.
//!
//! Everything is seeded and single-threaded: the same trace, router, and
//! seeds produce bit-identical results (the `cluster-sim` and
//! `multi-slo` CSVs are compared byte-for-byte in CI).
//!
//! Measurement note: a routed request is admitted on its target replica's
//! clock, which can run ahead of the cluster-wide minimum by up to one
//! batch latency; TTFT is measured from that admission instant. The skew
//! is bounded by the lagging-replica stepping rule and identical across
//! policies.
//!
//! **Fault injection** (DESIGN.md §7c): a [`FaultSchedule`] kills and
//! restarts replicas at trace time. A kill tears the replica's resident
//! work down — elastic requests migrate back to the shared backlog
//! (their progress resets with the lost KV), interactive requests are
//! rerouted to a live replica if their TTFT deadline still stands and
//! fail fast (503) otherwise — and a restart revives the replica empty,
//! one generation up. An optional [`Autoscaler`] activates parked
//! replicas or drains live ones at rebalance ticks. Every admitted
//! request is accounted for in [`ClusterRunResult::lost`]: finished,
//! resident, backlogged, or failed-with-a-report — never silently
//! dropped, never finished twice.

use super::autoscale::{Autoscaler, ScaleDecision};
use super::router::Router;
use super::ReplicaSnapshot;
use crate::coordinator::block_manager::chain_hashes_into;
use crate::coordinator::classes::ClassRegistry;
use crate::coordinator::metrics::{Metrics, Report};
use crate::coordinator::request::{Class, Request, RequestId};
use crate::engine::{Engine, ExecutionBackend};
use crate::obs::recorder::EventKind;
use crate::workload::trace::{Trace, TraceEvent};
use std::collections::VecDeque;
use std::sync::Arc;

/// What a scheduled fault does to its target replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Tear the replica down (migrate/reroute its resident work).
    Kill,
    /// Revive a dead replica, empty, one generation up.
    Restart,
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Trace time (seconds) at which the fault fires.
    pub t_s: f64,
    pub replica: usize,
    pub kind: FaultKind,
}

/// A trace-time kill/restart schedule, built fluently:
/// `FaultSchedule::new().kill(0, 2.0).restart(0, 5.0)`. Attach it with
/// [`ClusterSim::with_faults`]; events fire as the cluster frontier
/// passes their timestamps (ties fire in insertion order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
}

impl FaultSchedule {
    pub fn new() -> FaultSchedule {
        FaultSchedule::default()
    }

    pub fn kill(mut self, replica: usize, t_s: f64) -> FaultSchedule {
        self.events.push(FaultEvent { t_s, replica, kind: FaultKind::Kill });
        self
    }

    pub fn restart(mut self, replica: usize, t_s: f64) -> FaultSchedule {
        self.events.push(FaultEvent { t_s, replica, kind: FaultKind::Restart });
        self
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// One replica's share of a cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaRunStats {
    pub report: Report,
    /// The replica's virtual clock at the end of the run.
    pub clock_s: f64,
    /// Requests dispatched to this replica (including re-dispatch after a
    /// reclaim).
    pub routed: usize,
    /// Output tokens the replica generated (all classes).
    pub out_tokens: u64,
}

/// Outcome of [`ClusterSim::run`].
#[derive(Debug, Clone)]
pub struct ClusterRunResult {
    pub per_replica: Vec<ReplicaRunStats>,
    /// Cluster-wide report: latency summaries merged sample-by-sample
    /// per class (exact percentiles, not an average of averages),
    /// counters summed.
    pub aggregate: Report,
    /// Max replica clock at stop — the denominator of every rate.
    pub duration_s: f64,
    /// Age of the oldest elastic request still waiting (shared backlog or
    /// a replica queue) when the run stopped; 0 when everything started.
    pub offline_starvation_age_s: f64,
    /// Max/mean ratio of per-replica generated tokens (1.0 = perfectly
    /// even utilization).
    pub util_imbalance: f64,
    /// Total dispatches to replicas (>= admitted events when reclaims
    /// re-dispatched work).
    pub dispatched: usize,
    /// Elastic requests pulled back into the shared backlog from
    /// overloaded replicas.
    pub reclaimed: usize,
    /// Elastic events never placed on any replica.
    pub backlog_left: usize,
    /// Trace events the run admitted (== the trace length whenever the
    /// run reached the end of the trace).
    pub admitted: usize,
    /// Elastic requests moved from a killed replica back to the shared
    /// backlog (their decode progress reset with the lost KV).
    pub migrated: usize,
    /// Interactive requests re-placed on a live replica after theirs was
    /// killed, inside their TTFT deadline.
    pub rerouted: usize,
    /// Interactive requests failed fast with a reported error (killed
    /// past their TTFT deadline, or no live replica to take them).
    pub failed_503: usize,
    /// Replicas revived by the fault schedule.
    pub fault_restarts: usize,
    /// Autoscaler activations.
    pub scale_ups: usize,
    /// Autoscaler drains started.
    pub scale_downs: usize,
    /// Mean delay (ms) between a rerouted request's original arrival and
    /// its re-placement — the reroute TTFT penalty. (The engine-measured
    /// TTFT restarts at re-submission; this column carries the part the
    /// kill added.) 0 when nothing was rerouted.
    pub rerouted_delay_ms: f64,
    /// Conservation ledger: `admitted − (finished + resident + backlog +
    /// failed_503)`. Exactly 0 when no request was silently lost; a
    /// negative value would mean a double-completion.
    pub lost: i64,
}

/// The cluster driver. Build it with per-replica engines (seeded however
/// the caller wants; all replicas must share one registry), run one
/// trace, then inspect the engines freely — `run` leaves them in their
/// final state for invariant checks.
pub struct ClusterSim<B: ExecutionBackend> {
    pub engines: Vec<Engine<B>>,
    registry: Arc<ClassRegistry>,
    router: Box<dyn Router>,
    rebalance_interval_s: f64,
    next_rebalance_s: f64,
    /// Shared elastic backlogs, one deque per class (only elastic
    /// classes' deques are ever used). Placement drains the
    /// highest-tier non-empty deque first.
    backlog: Vec<VecDeque<TraceEvent>>,
    /// Elastic work placed on a replica but (possibly) still waiting
    /// there: `(replica, id, arrival, class)`. Consulted for reclaim and
    /// starvation accounting; entries whose request started are pruned at
    /// each rebalance tick.
    dispatched_elastic: Vec<(usize, RequestId, f64, Class)>,
    /// Dispatch tally per replica.
    pub routed: Vec<usize>,
    dispatched: usize,
    reclaimed: usize,
    stalled: u64,
    /// Liveness per replica: false = killed, drained away, or parked by
    /// the autoscaler. Dead replicas hold no work and never step.
    alive: Vec<bool>,
    /// Replicas finishing resident work before parking (scale-down).
    /// Routers see the flag and place nothing new on them.
    draining: Vec<bool>,
    /// Engine incarnation per replica; bumped on every revival so
    /// observers can tell "recovered" apart from "never died".
    generation: Vec<u64>,
    /// Sorted fault schedule + fire cursor.
    faults: Vec<FaultEvent>,
    next_fault: usize,
    autoscaler: Option<Autoscaler>,
    /// Run `check_invariants` on every engine after every sim step
    /// (chaos property tests; too slow to default on).
    pub check_invariants_each_step: bool,
    admitted: usize,
    migrated: usize,
    rerouted: usize,
    failed_503: usize,
    fault_restarts: usize,
    scale_ups: usize,
    scale_downs: usize,
    rerouted_delay_s: f64,
    /// Reused prompt hash-chain buffer for prefix-aware online routing
    /// (one chain per interactive arrival; capacity persists).
    chain_scratch: Vec<u64>,
}

impl<B: ExecutionBackend> ClusterSim<B> {
    pub fn new(
        engines: Vec<Engine<B>>,
        router: Box<dyn Router>,
        rebalance_interval_s: f64,
    ) -> ClusterSim<B> {
        assert!(!engines.is_empty(), "cluster needs at least one replica");
        assert!(rebalance_interval_s > 0.0, "rebalance interval must be positive");
        let n = engines.len();
        let registry = Arc::clone(&engines[0].state.registry);
        ClusterSim {
            backlog: (0..registry.len()).map(|_| VecDeque::new()).collect(),
            engines,
            registry,
            router,
            rebalance_interval_s,
            next_rebalance_s: 0.0,
            dispatched_elastic: Vec::new(),
            routed: vec![0; n],
            dispatched: 0,
            reclaimed: 0,
            stalled: 0,
            alive: vec![true; n],
            draining: vec![false; n],
            generation: vec![0; n],
            faults: Vec::new(),
            next_fault: 0,
            autoscaler: None,
            check_invariants_each_step: false,
            admitted: 0,
            migrated: 0,
            rerouted: 0,
            failed_503: 0,
            fault_restarts: 0,
            scale_ups: 0,
            scale_downs: 0,
            rerouted_delay_s: 0.0,
            chain_scratch: Vec::new(),
        }
    }

    /// Attach a kill/restart schedule (builder style).
    pub fn with_faults(mut self, schedule: FaultSchedule) -> ClusterSim<B> {
        let mut events = schedule.events;
        for f in &events {
            assert!(
                f.replica < self.engines.len(),
                "fault targets replica {} of {}",
                f.replica,
                self.engines.len()
            );
            assert!(f.t_s.is_finite() && f.t_s >= 0.0, "fault time must be finite, non-negative");
        }
        // Stable sort: same-instant faults fire in insertion order.
        events.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap());
        self.faults = events;
        self.next_fault = 0;
        self
    }

    /// Attach an autoscaler (builder style). Replicas `initial_active..`
    /// start parked (dead, no work) and are activated by scale-up
    /// decisions; scale-down picks the highest-index routable replica and
    /// drains it gracefully.
    pub fn with_autoscaler(mut self, autoscaler: Autoscaler, initial_active: usize) -> Self {
        assert!(
            initial_active >= 1 && initial_active <= self.engines.len(),
            "initial_active must be in 1..={}",
            self.engines.len()
        );
        for i in initial_active..self.engines.len() {
            self.alive[i] = false;
        }
        self.autoscaler = Some(autoscaler);
        self
    }

    /// Replicas currently live (routable or draining).
    pub fn live_replicas(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Engine incarnation of replica `i` (0 = never revived).
    pub fn generation_of(&self, i: usize) -> u64 {
        self.generation[i]
    }

    /// Elastic events currently held centrally (tests/observability).
    pub fn backlog_len(&self) -> usize {
        self.backlog.iter().map(|b| b.len()).sum()
    }

    /// Merge every replica's flight recorder into one Chrome-trace JSON
    /// document (`hygen trace-dump` output; load in Perfetto /
    /// `chrome://tracing`). Deterministic: replica order then ring order.
    pub fn chrome_trace(&self) -> crate::util::json::Json {
        let recs: Vec<(usize, &crate::obs::Recorder)> =
            self.engines.iter().enumerate().map(|(i, e)| (i, &e.state.recorder)).collect();
        crate::obs::chrome_trace(&recs)
    }

    fn snaps(&self) -> Vec<ReplicaSnapshot> {
        self.engines
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let mut s = ReplicaSnapshot::of(e);
                s.failed |= !self.alive[i];
                s.draining = self.draining[i];
                s.generation = self.generation[i];
                s
            })
            .collect()
    }

    /// Highest-tier class with pending backlog work (placement order: the
    /// most latency-sensitive elastic work leaves the backlog first).
    fn next_backlog_class(&self) -> Option<Class> {
        self.registry
            .tier_order_desc()
            .iter()
            .copied()
            .find(|&c| !self.backlog[c.index()].is_empty())
    }

    /// Live replica to step next: smallest clock; on ties, prefer one
    /// with work (so an idle replica parked at the same instant never
    /// shadows a busy one). `None` when every replica is down.
    fn lagging_replica(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.engines.len() {
            if !self.alive[i] {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) => {
                    let (ci, cb) = (self.engines[i].clock_s, self.engines[b].clock_s);
                    if ci < cb
                        || (ci == cb
                            && self.engines[i].has_work()
                            && !self.engines[b].has_work())
                    {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }

    /// Cluster frontier: the smallest live-replica clock (infinite when
    /// every replica is down — only a scheduled restart can advance time
    /// from there).
    fn min_live_clock(&self) -> f64 {
        self.engines
            .iter()
            .zip(&self.alive)
            .filter(|&(_, &a)| a)
            .map(|(e, _)| e.clock_s)
            .fold(f64::INFINITY, f64::min)
    }

    fn apply_fault(&mut self, f: FaultEvent, now: f64) {
        match f.kind {
            FaultKind::Kill => self.kill_replica(f.replica, now),
            FaultKind::Restart => self.restart_replica(f.replica, now),
        }
    }

    /// Tear replica `i` down at trace time `now`. Elastic resident work
    /// migrates to the shared backlog (its decode progress resets — the
    /// KV died with the replica); interactive work is rerouted to a live
    /// replica while its TTFT deadline stands, else failed fast. Either
    /// way every resident request is accounted for — none silently lost.
    fn kill_replica(&mut self, i: usize, now: f64) {
        if !self.alive[i] {
            return;
        }
        self.alive[i] = false;
        self.draining[i] = false;
        // The backlog re-tracks migrated elastic work from scratch.
        self.dispatched_elastic.retain(|&(rep, ..)| rep != i);
        let mut doomed: Vec<Request> = Vec::new();
        let classes: Vec<Class> = self.registry.ids().collect();
        {
            let state = &mut self.engines[i].state;
            for &c in &classes {
                while let Some(req) = state.queue_mut(c).pop_next() {
                    doomed.push(req);
                }
            }
            // Running + preempted bodies. The map iterates in hash order —
            // sort so teardown (and thus the whole run) is deterministic.
            let mut resident: Vec<Request> = state.requests.values().cloned().collect();
            resident.sort_by_key(|r| r.id);
            doomed.extend(resident);
        }
        // Release KV blocks, empty running/preempted sets, reset queue
        // LCP baselines (the abort clears every queue).
        self.engines[i].abort_all();
        // Audit the teardown on the dying replica's recorder: every
        // resident request leaves a migrate/reroute/shed record stamped
        // with the kill instant.
        self.engines[i].state.recorder.now_ms = now * 1e3;
        for req in doomed {
            let e = TraceEvent {
                arrival_s: req.arrival,
                class: req.class,
                prompt_len: req.prompt_len,
                output_len: req.output_len,
                prompt: req.prompt.clone(),
            };
            if self.registry.spec(req.class).elastic() {
                self.engines[i].state.recorder.record(
                    EventKind::Migrate,
                    req.id,
                    req.class.index() as u16,
                    i as f64,
                    -1.0, // destination: the shared backlog
                    0.0,
                );
                self.backlog[req.class.index()].push_back(e);
                self.migrated += 1;
            } else {
                // Reroute inside the remaining TTFT budget; a request the
                // kill already pushed past its deadline fails fast
                // instead of burning a live replica's budget on it.
                let within_ttft = match self.registry.spec(req.class).ttft_slo_ms {
                    Some(ms) => req.arrival + ms / 1e3 >= now,
                    None => true,
                };
                let snaps = self.snaps();
                let j = self.router.route_online(&snaps);
                if within_ttft && j < self.engines.len() && self.alive[j] && !self.draining[j] {
                    self.engines[i].state.recorder.record(
                        EventKind::Reroute,
                        req.id,
                        req.class.index() as u16,
                        i as f64,
                        j as f64,
                        0.0,
                    );
                    self.rerouted += 1;
                    self.rerouted_delay_s += (now - req.arrival).max(0.0);
                    self.submit_event(j, &e);
                } else {
                    // Reason 1 = no capacity / past deadline after a kill
                    // (reason 0 = deadline shed, see `cluster::replica`).
                    self.engines[i].state.recorder.record(
                        EventKind::Shed,
                        req.id,
                        req.class.index() as u16,
                        1.0,
                        self.alive.iter().filter(|&&a| a).count() as f64,
                        0.0,
                    );
                    self.failed_503 += 1;
                }
            }
        }
    }

    /// Revive a dead replica: it returns empty, one generation up, with
    /// its clock advanced to the revival instant. No-op on a live one.
    fn restart_replica(&mut self, i: usize, now: f64) {
        if self.alive[i] {
            return;
        }
        self.alive[i] = true;
        self.draining[i] = false;
        self.generation[i] += 1;
        self.fault_restarts += 1;
        let e = &mut self.engines[i];
        e.clock_s = e.clock_s.max(now);
        e.state.recorder.generation = self.generation[i] as u32;
    }

    /// Create the event's request on replica `i` (fresh replica-local id)
    /// and admit it.
    fn submit_event(&mut self, i: usize, e: &TraceEvent) {
        let engine = &mut self.engines[i];
        let id = engine.fresh_id();
        let mut req = Request::new(id, e.class, e.arrival_s, e.prompt_len, e.output_len);
        if !e.prompt.is_empty() {
            req = req.with_prompt(e.prompt.clone());
        }
        engine.submit(req);
        self.routed[i] += 1;
        self.dispatched += 1;
        if self.registry.spec(e.class).elastic() {
            self.dispatched_elastic.push((i, id, e.arrival_s, e.class));
        }
    }

    /// One rebalance tick: reclaim waiting elastic work from replicas
    /// with negative SLO headroom (lowest-tier work first — the
    /// dispatch-entry order is ascending-tier within each push batch, and
    /// every waiting entry on a hot replica is reclaimed), prune tracking
    /// entries whose requests started, then place backlog work —
    /// highest-tier first — wherever the router finds room.
    fn rebalance(&mut self) {
        // Scale-down drains that ran dry park their replica.
        for i in 0..self.engines.len() {
            if self.draining[i] && self.alive[i] && !self.engines[i].has_work() {
                self.alive[i] = false;
                self.draining[i] = false;
            }
        }
        // Autoscale on the same census the routers see. (Take/put-back
        // dance: `observe` borrows the snapshots while we own the scaler.)
        if let Some(mut scaler) = self.autoscaler.take() {
            match scaler.observe(&self.snaps()) {
                ScaleDecision::Up => {
                    if let Some(i) = (0..self.engines.len()).find(|&i| !self.alive[i]) {
                        let now = self.min_live_clock();
                        self.alive[i] = true;
                        self.draining[i] = false;
                        self.generation[i] += 1;
                        self.scale_ups += 1;
                        self.engines[i].state.recorder.generation = self.generation[i] as u32;
                        if now.is_finite() {
                            let e = &mut self.engines[i];
                            e.clock_s = e.clock_s.max(now);
                        }
                    }
                }
                ScaleDecision::Down => {
                    // Highest-index routable replica drains; the
                    // autoscaler's floor guarantees another one remains.
                    if let Some(i) =
                        (0..self.engines.len()).rev().find(|&i| self.alive[i] && !self.draining[i])
                    {
                        self.draining[i] = true;
                        self.scale_downs += 1;
                    }
                }
                ScaleDecision::Hold => {}
            }
            self.autoscaler = Some(scaler);
        }
        let mut snaps = self.snaps();
        // Draining replicas count as hot: pulling their waiting elastic
        // work back to the backlog lets the drain finish sooner.
        let hot: Vec<bool> = snaps
            .iter()
            .enumerate()
            .map(|(i, s)| s.headroom_ms() < 0.0 || self.draining[i])
            .collect();
        let entries = std::mem::take(&mut self.dispatched_elastic);
        let mut keep = Vec::with_capacity(entries.len());
        for (rep, id, arrival, class) in entries {
            let waiting = self.engines[rep].state.queue(class).contains(id);
            if waiting && hot[rep] {
                if let Some(req) = self.engines[rep].state.queue_mut(class).remove(id) {
                    // The request leaves through the backlog detour, so
                    // the source queue's consecutive-pop LCP baseline no
                    // longer describes what the scheduler will pop next —
                    // drop it (same over-credit class as the self-LCP
                    // requeue fix).
                    self.engines[rep].state.queue_mut(class).reset_prefix_context();
                    self.backlog[class.index()].push_back(TraceEvent {
                        arrival_s: arrival,
                        class,
                        prompt_len: req.prompt_len,
                        output_len: req.output_len,
                        prompt: req.prompt,
                    });
                    self.reclaimed += 1;
                    snaps[rep].waiting[class.index()] =
                        snaps[rep].waiting[class.index()].saturating_sub(1);
                    continue;
                }
            }
            if waiting {
                keep.push((rep, id, arrival, class));
            }
        }
        self.dispatched_elastic = keep;
        while let Some(class) = self.next_backlog_class() {
            match self.router.route_offline(&snaps) {
                // The liveness guard covers eager routers whose
                // all-failed fallback still returns an index.
                Some(i) if i < self.engines.len() && self.alive[i] && !self.draining[i] => {
                    let e = self.backlog[class.index()].pop_front().expect("checked non-empty");
                    self.submit_event(i, &e);
                    snaps[i].waiting[class.index()] += 1;
                }
                _ => break,
            }
        }
    }

    /// Replay `trace` until its interactive portion is fully served
    /// (elastic work is a backlog, the paper's throughput accounting) or
    /// `max_clock_s` passes, firing scheduled faults as the cluster
    /// frontier passes their timestamps. One run per `ClusterSim` —
    /// metrics accumulate.
    pub fn run(&mut self, trace: &Trace, max_clock_s: f64) -> anyhow::Result<ClusterRunResult> {
        let events = &trace.events;
        let mut next_event = 0usize;
        let registry = Arc::clone(&self.registry);
        let mut interactive_ahead: usize = registry
            .ids()
            .filter(|&c| !registry.spec(c).elastic())
            .map(|c| trace.num_of(c))
            .sum();
        loop {
            // Fire every fault due at the cluster frontier. With no live
            // replica the frontier jumps to the next scheduled fault (a
            // restart can revive the cluster).
            loop {
                let live = self.min_live_clock();
                let due = match self.faults.get(self.next_fault).copied() {
                    Some(f) if live.is_finite() => (f.t_s <= live).then_some((f, live)),
                    Some(f) => Some((f, f.t_s)),
                    None => None,
                };
                match due {
                    Some((f, at)) => {
                        self.next_fault += 1;
                        self.apply_fault(f, at.max(f.t_s));
                    }
                    None => break,
                }
            }
            let now = self.min_live_clock();
            while next_event < events.len() && events[next_event].arrival_s <= now {
                let e = events[next_event].clone();
                next_event += 1;
                self.admitted += 1;
                if registry.spec(e.class).elastic() {
                    self.backlog[e.class.index()].push_back(e);
                } else {
                    interactive_ahead -= 1;
                    // Hash the prompt's full-block chain so prefix-aware
                    // policies can weigh replica cache residency;
                    // prefix-blind policies ignore it via the trait
                    // default. All replicas share one block size.
                    let mut chain = std::mem::take(&mut self.chain_scratch);
                    if e.prompt.is_empty() {
                        chain.clear();
                    } else {
                        let bs = self.engines[0].state.blocks.block_size();
                        chain_hashes_into(&e.prompt, bs, &mut chain);
                    }
                    let snaps = self.snaps();
                    let i = self.router.route_online_with_prefix(&snaps, &chain);
                    self.chain_scratch = chain;
                    anyhow::ensure!(i < self.engines.len(), "router index out of range");
                    if self.alive[i] && !self.draining[i] {
                        self.submit_event(i, &e);
                    } else {
                        // The router only falls back to a dead/draining
                        // index when no routable replica exists: fail
                        // fast with a reported error.
                        self.failed_503 += 1;
                    }
                }
            }
            if now.is_finite() && now >= self.next_rebalance_s {
                self.rebalance();
                while self.next_rebalance_s <= now {
                    self.next_rebalance_s += self.rebalance_interval_s;
                }
            }
            let online_left = interactive_ahead > 0
                || self.engines.iter().any(|e| e.state.interactive_pending());
            if !online_left || now >= max_clock_s {
                break;
            }
            let Some(i) = self.lagging_replica() else {
                // Every replica is down but interactive work remains:
                // only a scheduled fault can advance the run (handled at
                // the top of the loop, which fires one fault per pass).
                continue;
            };
            if self.engines[i].has_work() {
                if self.engines[i].step()? == 0 {
                    // Stalled (memory or budget starvation): advance to
                    // the next actionable instant.
                    self.stalled += 1;
                    anyhow::ensure!(
                        self.stalled < 5_000_000,
                        "cluster livelock: {} stalled iterations",
                        self.stalled
                    );
                    let c = self.engines[i].clock_s;
                    let mut t = c + 0.005;
                    if let Some(e) = events.get(next_event) {
                        if e.arrival_s > c {
                            t = t.min(e.arrival_s);
                        }
                    }
                    self.engines[i].clock_s = t;
                }
                if self.check_invariants_each_step {
                    for e in &self.engines {
                        e.state
                            .check_invariants()
                            .map_err(|m| anyhow::anyhow!("post-step invariants: {m}"))?;
                    }
                }
            } else {
                // Idle replica: skip to the next instant that can hand it
                // work (arrival or, with a pending backlog, the next
                // rebalance tick), or park it at the slowest busy clock.
                let c = self.engines[i].clock_s;
                let mut t = f64::INFINITY;
                if let Some(e) = events.get(next_event) {
                    t = t.min(e.arrival_s);
                }
                if self.backlog_len() > 0 {
                    t = t.min(self.next_rebalance_s);
                }
                if t.is_finite() && t > c {
                    self.engines[i].clock_s = t;
                } else {
                    let busy = self
                        .engines
                        .iter()
                        .filter(|e| e.has_work())
                        .map(|e| e.clock_s)
                        .fold(f64::INFINITY, f64::min);
                    if busy.is_finite() && busy > c {
                        self.engines[i].clock_s = busy;
                    } else {
                        // Nothing pending anywhere and no arrivals left.
                        break;
                    }
                }
            }
        }
        Ok(self.collect())
    }

    fn collect(&mut self) -> ClusterRunResult {
        let end = self.engines.iter().map(|e| e.clock_s).fold(0.0, f64::max).max(1e-9);
        let mut agg = Metrics::new(1.0);
        let routed = self.routed.clone();
        let mut per_replica = Vec::with_capacity(self.engines.len());
        for (i, e) in self.engines.iter_mut().enumerate() {
            agg.absorb(&e.metrics);
            let out_tokens = e.metrics.online_token_count() + e.metrics.offline_token_count();
            per_replica.push(ReplicaRunStats {
                report: e.metrics.report(Some(end)),
                clock_s: e.clock_s,
                routed: routed[i],
                out_tokens,
            });
        }
        let mean = per_replica.iter().map(|r| r.out_tokens as f64).sum::<f64>()
            / per_replica.len() as f64;
        let max = per_replica.iter().map(|r| r.out_tokens as f64).fold(0.0, f64::max);
        let util_imbalance = if mean > 0.0 { max / mean } else { 1.0 };
        let mut starvation = 0.0f64;
        for deque in &self.backlog {
            for e in deque {
                starvation = starvation.max(end - e.arrival_s);
            }
        }
        for &(rep, id, arrival, class) in &self.dispatched_elastic {
            if self.engines[rep].state.queue(class).contains(id) {
                starvation = starvation.max(end - arrival);
            }
        }
        let aggregate = agg.report(Some(end));
        // Conservation ledger: every admitted request must be finished,
        // resident on a replica, in the shared backlog, or failed with a
        // reported error. Anything else was lost (or, negative, finished
        // twice).
        let resident: usize = self
            .engines
            .iter()
            .map(|e| e.state.num_running() + e.state.total_waiting() + e.state.total_preempted())
            .sum();
        let finished = aggregate.online_finished + aggregate.offline_finished;
        let lost = self.admitted as i64
            - (finished + resident + self.backlog_len() + self.failed_503) as i64;
        let rerouted_delay_ms = if self.rerouted > 0 {
            self.rerouted_delay_s * 1e3 / self.rerouted as f64
        } else {
            0.0
        };
        ClusterRunResult {
            per_replica,
            aggregate,
            duration_s: end,
            offline_starvation_age_s: starvation,
            util_imbalance,
            dispatched: self.dispatched,
            reclaimed: self.reclaimed,
            backlog_left: self.backlog_len(),
            admitted: self.admitted,
            migrated: self.migrated,
            rerouted: self.rerouted,
            failed_503: self.failed_503,
            fault_restarts: self.fault_restarts,
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            rerouted_delay_ms,
            lost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::RouterPolicy;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
    use crate::coordinator::state::EngineState;
    use crate::sim::costmodel::CostModel;
    use crate::sim::SimBackend;

    fn engines(n: usize, budget: Option<f64>) -> Vec<Engine<SimBackend>> {
        (0..n)
            .map(|i| {
                let state = EngineState::new(OfflinePolicy::Fcfs, 1024, 16, i as u64);
                let sched = HybridScheduler::new(
                    SchedulerConfig { latency_budget_ms: budget, ..Default::default() },
                    LatencyPredictor::default_seed(),
                );
                let mut e = Engine::new(
                    sched,
                    state,
                    SimBackend::new(CostModel::a100_llama7b(), i as u64),
                );
                e.state.keep_finished = false;
                e
            })
            .collect()
    }

    fn ev(t: f64, class: Class, p: usize, o: usize) -> TraceEvent {
        TraceEvent { arrival_s: t, class, prompt_len: p, output_len: o, prompt: Vec::new().into() }
    }

    fn mixed_trace(n_online: usize, n_offline: usize) -> Trace {
        let mut events = Vec::new();
        for i in 0..n_online {
            events.push(ev(i as f64 * 0.05, Class::ONLINE, 64, 8));
        }
        for _ in 0..n_offline {
            events.push(ev(0.0, Class::OFFLINE, 128, 16));
        }
        Trace::new(events)
    }

    #[test]
    fn every_policy_serves_the_whole_online_trace() {
        for policy in RouterPolicy::ALL {
            let mut sim = ClusterSim::new(engines(3, Some(40.0)), policy.build(), 0.5);
            let r = sim.run(&mixed_trace(30, 12), 600.0).unwrap();
            assert_eq!(r.aggregate.online_finished, 30, "{}", policy.name());
            assert!(r.duration_s > 0.0);
            assert!(r.util_imbalance >= 1.0);
            assert_eq!(
                r.dispatched - r.reclaimed,
                42 - r.backlog_left,
                "{}: each admitted event lives on exactly one replica",
                policy.name()
            );
            for e in &sim.engines {
                e.state.check_invariants().unwrap();
            }
        }
    }

    #[test]
    fn round_robin_spreads_online_evenly() {
        let mut sim = ClusterSim::new(engines(4, None), RouterPolicy::RoundRobin.build(), 0.5);
        let r = sim.run(&mixed_trace(40, 0), 600.0).unwrap();
        assert_eq!(r.aggregate.online_finished, 40);
        assert_eq!(sim.routed, vec![10, 10, 10, 10]);
    }

    #[test]
    fn slo_headroom_keeps_backlog_central_until_there_is_room() {
        let mut sim =
            ClusterSim::new(engines(2, Some(40.0)), RouterPolicy::SloHeadroom.build(), 0.5);
        // 100 offline requests against a 32-per-replica buffer: the first
        // tick must leave work central instead of pinning everything.
        let mut events = vec![ev(0.0, Class::ONLINE, 64, 4)];
        for _ in 0..100 {
            events.push(ev(0.0, Class::OFFLINE, 512, 64));
        }
        let r = sim.run(&Trace::new(events), 20.0).unwrap();
        assert_eq!(r.aggregate.online_finished, 1);
        assert!(
            r.backlog_left > 0,
            "elastic placement defers most of a large backlog ({} left)",
            r.backlog_left
        );
        assert!(r.offline_starvation_age_s > 0.0, "waiting work has a measurable age");
    }

    #[test]
    fn same_inputs_same_result() {
        let run = || {
            let mut sim =
                ClusterSim::new(engines(2, Some(40.0)), RouterPolicy::SloHeadroom.build(), 0.5);
            sim.run(&mixed_trace(20, 30), 600.0).unwrap().aggregate
        };
        assert_eq!(run(), run(), "cluster replay must be deterministic");
    }

    #[test]
    fn prefix_affinity_pins_families_and_matches_ledger() {
        // Four prefix families cycling through dense online arrivals: the
        // affinity router should keep each family on its warm replica, so
        // the cluster-wide block-cache hit count can only match or exceed
        // the prefix-blind headroom router's (both runs are deterministic).
        let family = |tag: u32| -> std::sync::Arc<[u32]> {
            (0..64u32).map(|i| i.wrapping_mul(2654435761).wrapping_add(tag)).collect::<Vec<_>>().into()
        };
        let fams = [family(1), family(2), family(3), family(4)];
        let mut events = Vec::new();
        for k in 0..32usize {
            events.push(TraceEvent {
                arrival_s: k as f64 * 0.02,
                class: Class::ONLINE,
                prompt_len: 64,
                output_len: 8,
                prompt: fams[k % 4].clone(),
            });
        }
        let trace = Trace::new(events);
        let hits = |policy: RouterPolicy| {
            let mut sim = ClusterSim::new(engines(2, Some(40.0)), policy.build(), 0.5);
            let r = sim.run(&trace, 600.0).unwrap();
            assert_eq!(r.aggregate.online_finished, 32, "{}", policy.name());
            assert_eq!(r.lost, 0, "{}", policy.name());
            let c = r.aggregate.classes[0].cache;
            assert!(c.hits + c.misses > 0, "{}: admissions hashed their chains", policy.name());
            c.hits
        };
        let affinity = hits(RouterPolicy::PrefixAffinity);
        let headroom = hits(RouterPolicy::SloHeadroom);
        assert!(affinity > 0, "repeat families hit the warm replica's cache");
        assert!(
            affinity >= headroom,
            "affinity routing lost cache hits: {affinity} < {headroom}"
        );
    }

    #[test]
    fn fault_free_runs_keep_the_chaos_ledger_clean() {
        let mut sim =
            ClusterSim::new(engines(2, Some(40.0)), RouterPolicy::SloHeadroom.build(), 0.5);
        let r = sim.run(&mixed_trace(20, 10), 600.0).unwrap();
        assert_eq!(r.lost, 0);
        assert_eq!(r.admitted, 30);
        assert_eq!(
            (r.migrated, r.rerouted, r.failed_503, r.fault_restarts, r.scale_ups, r.scale_downs),
            (0, 0, 0, 0, 0, 0)
        );
        assert_eq!(r.rerouted_delay_ms, 0.0);
    }

    #[test]
    fn kill_migrates_elastic_and_accounts_for_every_online() {
        let trace = mixed_trace(20, 30);
        let mut sim =
            ClusterSim::new(engines(2, Some(40.0)), RouterPolicy::RoundRobin.build(), 0.5)
                .with_faults(FaultSchedule::new().kill(0, 0.25));
        let r = sim.run(&trace, 600.0).unwrap();
        assert_eq!(sim.live_replicas(), 1);
        assert_eq!(r.lost, 0, "no request silently lost across the kill");
        assert_eq!(
            r.aggregate.online_finished + r.failed_503,
            20,
            "every online request finished or failed with a reported error"
        );
        assert!(r.migrated > 0, "replica 0 held elastic work when it died");
        // The kill left an audit trail on the dead replica's recorder:
        // one migrate per elastic resident, one reroute or shed per
        // interactive resident.
        let (mut migrates, mut reroutes, mut sheds) = (0usize, 0usize, 0usize);
        sim.engines[0].state.recorder.for_each(|e| match e.kind {
            EventKind::Migrate => {
                migrates += 1;
                assert_eq!(e.a, 0.0, "source replica");
                assert_eq!(e.b, -1.0, "destination: shared backlog");
            }
            EventKind::Reroute => reroutes += 1,
            EventKind::Shed => sheds += 1,
            _ => {}
        });
        assert_eq!(migrates, r.migrated, "each migration audited exactly once");
        assert_eq!(reroutes + sheds, r.rerouted + r.failed_503);
        for e in &sim.engines {
            e.state.check_invariants().unwrap();
        }
    }

    #[test]
    fn restart_revives_a_generation_up_and_serves_again() {
        let trace = mixed_trace(40, 0); // online every 50 ms for 2 s
        let mut sim =
            ClusterSim::new(engines(2, Some(40.0)), RouterPolicy::RoundRobin.build(), 0.5)
                .with_faults(FaultSchedule::new().kill(1, 0.4).restart(1, 0.8));
        let r = sim.run(&trace, 600.0).unwrap();
        assert_eq!(sim.live_replicas(), 2, "replica 1 came back");
        assert_eq!(sim.generation_of(1), 1);
        assert_eq!(
            sim.engines[1].state.recorder.generation,
            1,
            "post-restart events are stamped with the new incarnation"
        );
        assert_eq!(r.fault_restarts, 1);
        assert_eq!(r.lost, 0);
        // Replica 0 stayed live throughout, so everything rerouted inside
        // the 1 s TTFT window and nothing had to 503.
        assert_eq!(r.aggregate.online_finished, 40);
        assert_eq!(r.failed_503, 0);
        assert!(
            sim.routed[1] > 5,
            "the revived replica took arrivals again (routed {})",
            sim.routed[1]
        );
    }

    #[test]
    fn losing_every_replica_fails_fast_and_terminates() {
        let trace = mixed_trace(10, 4);
        let mut sim =
            ClusterSim::new(engines(1, Some(40.0)), RouterPolicy::JoinShortestQueue.build(), 0.5)
                .with_faults(FaultSchedule::new().kill(0, 0.1));
        let r = sim.run(&trace, 600.0).unwrap();
        assert_eq!(sim.live_replicas(), 0);
        assert!(r.failed_503 > 0, "arrivals with no live replica fail fast");
        assert_eq!(r.lost, 0, "failed requests are reported, not lost");
        assert_eq!(r.aggregate.online_finished + r.failed_503, 10);
    }

    #[test]
    fn reclaim_detour_drops_the_lcp_baseline() {
        // Prefix-admission queue on a permanently hot replica
        // (microscopic budget): pop one request to set the
        // consecutive-pop LCP baseline, then let a rebalance reclaim the
        // sibling through the backlog and re-place it. Its pop must claim
        // no shared prefix — the detour broke the consecutive-scheduling
        // assumption behind the credit. (Without the reset in
        // `rebalance` this pops with shared_prefix_len == 3.)
        let state = EngineState::new(OfflinePolicy::Psm, 1024, 16, 0);
        let sched = HybridScheduler::new(
            SchedulerConfig { latency_budget_ms: Some(1e-6), ..Default::default() },
            LatencyPredictor::default_seed(),
        );
        let mut e = Engine::new(sched, state, SimBackend::new(CostModel::a100_llama7b(), 0));
        e.state.keep_finished = false;
        let mut sim = ClusterSim::new(vec![e], RouterPolicy::RoundRobin.build(), 0.5);
        let event = |prompt: Vec<u32>| TraceEvent {
            arrival_s: 0.0,
            class: Class::OFFLINE,
            prompt_len: prompt.len(),
            output_len: 4,
            prompt: prompt.into(),
        };
        sim.submit_event(0, &event(vec![1, 1, 1, 1]));
        sim.submit_event(0, &event(vec![1, 1, 1, 2]));
        let popped = sim.engines[0].state.queue_mut(Class::OFFLINE).pop_next().unwrap();
        assert_eq!(popped.shared_prefix_len, 0, "first pop has no baseline");
        sim.rebalance();
        assert_eq!(sim.reclaimed, 1, "the microscopic budget marks the replica hot");
        assert_eq!(sim.backlog_len(), 0, "round-robin re-placed the reclaim immediately");
        let replaced = sim.engines[0].state.queue_mut(Class::OFFLINE).pop_next().unwrap();
        assert_eq!(
            replaced.shared_prefix_len, 0,
            "a request re-entering via the backlog detour gets no LCP credit"
        );
    }

    #[test]
    fn autoscaler_activates_parked_replicas_under_pressure() {
        use crate::cluster::autoscale::{AutoscaleConfig, Autoscaler};
        let scaler = Autoscaler::new(AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            // Any finite headroom reads as pressure: the wiring (parked
            // replicas activate, get clocks, take work) is what this
            // pins — threshold realism lives in the autoscale unit tests.
            up_headroom_ms: 1000.0,
            down_headroom_ms: 2000.0,
            hysteresis_ticks: 1,
        });
        let mut sim =
            ClusterSim::new(engines(4, Some(40.0)), RouterPolicy::SloHeadroom.build(), 0.25)
                .with_autoscaler(scaler, 1);
        assert_eq!(sim.live_replicas(), 1, "replicas beyond initial_active start parked");
        let r = sim.run(&mixed_trace(40, 8), 600.0).unwrap();
        assert_eq!(r.scale_ups, 3, "pressure activated every parked replica");
        assert_eq!(sim.live_replicas(), 4);
        assert_eq!(r.lost, 0);
        assert_eq!(r.aggregate.online_finished, 40);
    }

    #[test]
    fn autoscaler_drains_idle_replicas_to_the_floor() {
        use crate::cluster::autoscale::{AutoscaleConfig, Autoscaler};
        let scaler = Autoscaler::new(AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            up_headroom_ms: -2000.0, // never fires
            down_headroom_ms: -1000.0, // any finite headroom reads as idle
            hysteresis_ticks: 1,
        });
        let mut sim =
            ClusterSim::new(engines(4, Some(40.0)), RouterPolicy::RoundRobin.build(), 0.25)
                .with_autoscaler(scaler, 4);
        let r = sim.run(&mixed_trace(40, 0), 600.0).unwrap();
        assert_eq!(r.scale_downs, 3, "idle capacity drained down to the floor");
        assert_eq!(r.lost, 0);
        assert_eq!(
            r.aggregate.online_finished,
            40,
            "draining is graceful: resident work still finishes"
        );
        for e in &sim.engines {
            e.state.check_invariants().unwrap();
        }
    }
}
