//! Multi-replica cluster layer: SLO-aware request routing and elastic
//! placement of harvest work across engine instances.
//!
//! One HyGen instance co-locates its SLO classes inside a single engine
//! (the paper's Fig. 2). A production deployment runs *N* such replicas
//! behind a router — and multi-SLO dispatch decisions belong above the
//! per-engine scheduler (SLOs-Serve), while idle capacity across serving
//! instances can be harvested for elastic work (ConServe). This module is
//! that layer:
//!
//! * [`router::Router`] — the routing policy interface over per-replica
//!   [`ReplicaSnapshot`]s, with three implementations:
//!   [`router::RoundRobin`], [`router::JoinShortestQueue`], and
//!   [`router::SloHeadroom`] (routes interactive requests to the replica
//!   with the most SLO headroom — measured against the **tightest class
//!   present** on that replica — and elastically places the shared
//!   backlog onto replicas whose predicted batch time leaves slack).
//! * [`replica::Replica`] — one engine on its own thread behind an mpsc
//!   job queue (the `server::engine_loop` message-passing shape),
//!   publishing a census snapshot and a metrics report, and draining
//!   in-flight work gracefully on shutdown.
//! * [`sim::ClusterSim`] — a deterministic virtual-clock driver over N
//!   sim-backend engines with shared per-class backlogs and periodic
//!   rebalance ticks; `hygen cluster-sim` measures the policies on the
//!   calibrated mixed trace (`artifacts/cluster_compare.csv`) and
//!   `hygen multi-slo` replays the 4-class trace
//!   (`artifacts/multi_slo.csv`).
//!
//! The server front end ([`crate::server`]) builds on [`replica`] for
//! `hygen serve --replicas N --router <policy>`.
//!
//! Fault tolerance lives in the same layer (DESIGN.md §7c):
//! [`sim::FaultSchedule`] injects kill/restart events into the
//! simulation (in-flight work migrates or fails fast),
//! [`replica::Supervisor`] restarts dead engine threads with capped
//! exponential backoff, and [`autoscale::Autoscaler`] grows/drains the
//! replica set from the aggregate SLO-headroom signal with hysteresis.
//! `hygen chaos` measures the whole stack under seeded kill schedules
//! (`artifacts/chaos_compare.csv`).

pub mod autoscale;
pub mod replica;
pub mod router;
pub mod sim;

use crate::coordinator::block_manager::PROBE_SLOTS;
use crate::coordinator::classes::MAX_CLASSES;
use crate::coordinator::request::Class;
use crate::engine::{Engine, ExecutionBackend};

/// A point-in-time census of one replica, published by its engine thread
/// (server mode) or computed on demand (simulation). Routers make every
/// decision from these snapshots only — they never touch engine state.
///
/// Per-class counts are dense fixed arrays (`Copy`, allocation-free —
/// snapshots are taken every engine iteration); `n_classes` says how many
/// slots are meaningful. By the registry convention, index 0 is the
/// flagship interactive class and indices 1.. are the harvest/elastic
/// spectrum — the `online_*`/`offline_*` views below encode that split.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaSnapshot {
    /// Waiting requests per class.
    pub waiting: [usize; MAX_CLASSES],
    /// Running requests per class.
    pub running: [usize; MAX_CLASSES],
    /// Preempted (preserved-state) requests per class.
    pub preempted: [usize; MAX_CLASSES],
    /// Meaningful class slots (registry size).
    pub n_classes: usize,
    /// Free KV-cache capacity in tokens.
    pub free_kv_tokens: usize,
    /// Latency-predictor estimate (ms) of the replica's next iteration
    /// given its running census — the load signal `SloHeadroom` routes on.
    pub predicted_iter_ms: f64,
    /// Per-iteration latency budget the replica schedules under
    /// (`f64::INFINITY` when SLO-unaware).
    pub latency_budget_ms: f64,
    /// Budget tolerance of the tightest class *present* on the replica
    /// (min over classes with any waiting/running/preempted work of the
    /// spec's `latency_budget` multiplier; bypass classes count as 1.0).
    /// 1.0 with the default two-class registry; an idle replica reports
    /// its registry's loosest tolerance (most headroom).
    pub min_present_tolerance: f64,
    /// The replica's backend failed persistently; routers must prefer any
    /// live replica over a failed one.
    pub failed: bool,
    /// The replica is being drained for scale-down (or teardown): it
    /// finishes its resident work but must receive no new placements.
    pub draining: bool,
    /// Engine incarnation: bumped every time a supervisor (or the fault
    /// schedule) restarts the replica's engine. Routers treat a snapshot
    /// from a dying generation like any other stale census — the failed /
    /// draining flags gate placement; the generation lets observers tell
    /// "recovered" apart from "never died".
    pub generation: u64,
    /// Direct-mapped prefix-residency probe exported by the replica's
    /// block manager: `(root-block fingerprint, resident prefix tokens)`
    /// per slot, fingerprint 0 = empty. A fixed-size census summary —
    /// routers query it through [`cached_prefix_tokens`]
    /// (ReplicaSnapshot::cached_prefix_tokens) without ever touching the
    /// replica's cache map.
    pub prefix_probe: [(u64, u32); PROBE_SLOTS],
}

impl Default for ReplicaSnapshot {
    fn default() -> Self {
        ReplicaSnapshot {
            waiting: [0; MAX_CLASSES],
            running: [0; MAX_CLASSES],
            preempted: [0; MAX_CLASSES],
            n_classes: 2,
            free_kv_tokens: 0,
            predicted_iter_ms: 0.0,
            latency_budget_ms: 0.0,
            min_present_tolerance: 1.0,
            failed: false,
            draining: false,
            generation: 0,
            prefix_probe: [(0, 0); PROBE_SLOTS],
        }
    }
}

impl ReplicaSnapshot {
    /// Snapshot an engine's current census (any backend).
    pub fn of<B: ExecutionBackend>(engine: &Engine<B>) -> ReplicaSnapshot {
        let state = &engine.state;
        let registry = &state.registry;
        let counts = state.counts;
        // Estimate the next iteration from the running census: every
        // running decode contributes one token; running prefills are
        // assumed to fill the chunk budget between them (the scheduler
        // schedules at most `chunk_tokens` of prefill per iteration).
        // Snapshots are taken every engine-loop iteration, so this is
        // O(classes) in the running-set size.
        let decodes = counts.total_decode() as f64;
        let mut f =
            crate::coordinator::batch::Features { sp: 0.0, sd: decodes, np: 0.0, nd: decodes };
        if counts.total_prefill() > 0 {
            f.add_prefill(engine.scheduler.cfg.chunk_tokens);
        }
        let mut snap = ReplicaSnapshot {
            n_classes: registry.len(),
            free_kv_tokens: state.blocks.free_tokens(),
            predicted_iter_ms: engine.scheduler.predictor.predict(&f),
            latency_budget_ms: engine.scheduler.cfg.latency_budget_ms.unwrap_or(f64::INFINITY),
            prefix_probe: *state.blocks.prefix_probe(),
            ..ReplicaSnapshot::default()
        };
        let mut min_present = f64::INFINITY;
        let mut loosest = 1.0f64;
        for c in registry.ids() {
            let i = c.index();
            snap.waiting[i] = state.queue(c).len();
            snap.running[i] = state.running(c).len();
            snap.preempted[i] = state.preempted(c).len();
            let tol = registry.spec(c).budget_tolerance();
            loosest = loosest.max(tol);
            if snap.waiting[i] + snap.running[i] + snap.preempted[i] > 0 {
                min_present = min_present.min(tol);
            }
        }
        // Idle replica: nothing present constrains it — report the
        // loosest tolerance in the registry (max headroom).
        snap.min_present_tolerance = if min_present.is_finite() { min_present } else { loosest };
        snap
    }

    /// Waiting requests of the flagship interactive class.
    pub fn online_waiting(&self) -> usize {
        self.waiting[0]
    }

    /// Waiting requests across the harvest spectrum (classes 1..N).
    pub fn offline_waiting(&self) -> usize {
        self.waiting[1..self.n_classes.min(MAX_CLASSES)].iter().sum()
    }

    /// Everything queued or in flight on the replica (JSQ's load measure).
    pub fn total_depth(&self) -> usize {
        let n = self.n_classes.min(MAX_CLASSES);
        self.waiting[..n].iter().sum::<usize>()
            + self.running[..n].iter().sum::<usize>()
            + self.preempted[..n].iter().sum::<usize>()
    }

    /// Flagship-class load (waiting + running).
    pub fn online_depth(&self) -> usize {
        self.waiting[0] + self.running[0]
    }

    /// Per-class waiting count.
    pub fn class_waiting(&self, class: Class) -> usize {
        self.waiting[class.index()]
    }

    /// Prefix tokens of `chain` (a request's full-block hash chain, root
    /// first) already resident in this replica's KV cache, according to
    /// the probe summary. A direct-mapped lookup on the root-block
    /// fingerprint: exact when the prefix family is tracked in its slot, 0
    /// (a conservative miss) when the family was displaced. O(1),
    /// allocation-free — the `PrefixAffinity` router calls it once per
    /// replica per routing decision.
    // lint: alloc-free
    pub fn cached_prefix_tokens(&self, chain: &[u64]) -> usize {
        let Some(&fp) = chain.first() else { return 0 };
        if fp == 0 {
            return 0;
        }
        let slot = (fp % PROBE_SLOTS as u64) as usize;
        let (slot_fp, tokens) = self.prefix_probe[slot];
        if slot_fp == fp {
            tokens as usize
        } else {
            0
        }
    }

    /// Predicted slack (ms) between the replica's effective latency
    /// budget and its next iteration — the `SloHeadroom` routing signal.
    /// The effective budget is the scheduling budget scaled by the
    /// tolerance of the **tightest class present** on the replica: a
    /// replica running only tolerant harvest classes advertises more
    /// room. Infinite when the replica is SLO-unaware.
    pub fn headroom_ms(&self) -> f64 {
        self.latency_budget_ms * self.min_present_tolerance - self.predicted_iter_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::request::Request;
    use crate::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
    use crate::coordinator::state::EngineState;
    use crate::sim::costmodel::CostModel;
    use crate::sim::SimBackend;

    fn engine(budget: Option<f64>) -> Engine<SimBackend> {
        let state = EngineState::new(OfflinePolicy::Fcfs, 1024, 16, 0);
        let sched = HybridScheduler::new(
            SchedulerConfig { latency_budget_ms: budget, ..Default::default() },
            LatencyPredictor::default_seed(),
        );
        Engine::new(sched, state, SimBackend::new(CostModel::a100_llama7b(), 0))
    }

    #[test]
    fn snapshot_reflects_census() {
        let mut e = engine(Some(40.0));
        e.submit(Request::new(1, Class::ONLINE, 0.0, 64, 8));
        e.submit(Request::new(2, Class::OFFLINE, 0.0, 64, 8));
        let s = ReplicaSnapshot::of(&e);
        assert_eq!(s.online_waiting(), 1);
        assert_eq!(s.offline_waiting(), 1);
        assert_eq!(s.total_depth(), 2);
        assert_eq!(s.n_classes, 2);
        assert_eq!(s.latency_budget_ms, 40.0);
        assert_eq!(s.min_present_tolerance, 1.0, "default registry tolerances are 1.0");
        assert!(s.headroom_ms() < 40.0, "empty-batch bias charged");
        assert_eq!(s.generation, 0, "a never-restarted engine is generation 0");
        assert!(!s.draining && !s.failed);
        e.step().unwrap();
        let s2 = ReplicaSnapshot::of(&e);
        assert!(s2.running[0] + s2.running[1] > 0);
        assert!(s2.predicted_iter_ms > s.predicted_iter_ms, "load raises the estimate");
    }

    #[test]
    fn snapshot_probe_reports_resident_prefixes() {
        use crate::coordinator::block_manager::chain_hashes;
        let mut e = engine(Some(40.0));
        let prompt: std::sync::Arc<[u32]> = (0..64u32).collect::<Vec<_>>().into();
        e.submit(Request::new(1, Class::ONLINE, 0.0, 64, 2).with_prompt(prompt.clone()));
        while e.has_work() {
            e.step().unwrap();
        }
        let chain = chain_hashes(&prompt, 16);
        let s = ReplicaSnapshot::of(&e);
        assert_eq!(s.cached_prefix_tokens(&chain), 64, "whole prompt resident after run");
        assert_eq!(s.cached_prefix_tokens(&chain[..1]), 64, "probe keys on the chain root");
        assert_eq!(s.cached_prefix_tokens(&[0xdead_beef]), 0, "foreign family misses");
        assert_eq!(s.cached_prefix_tokens(&[]), 0, "empty chain is cold");
        assert_eq!(ReplicaSnapshot::default().cached_prefix_tokens(&chain), 0);
    }

    #[test]
    fn slo_unaware_headroom_is_infinite() {
        let e = engine(None);
        let s = ReplicaSnapshot::of(&e);
        assert_eq!(s.latency_budget_ms, f64::INFINITY);
        assert_eq!(s.headroom_ms(), f64::INFINITY);
    }

    #[test]
    fn tightest_present_class_scales_headroom() {
        use crate::coordinator::classes::{AdmissionPolicy, ClassRegistry, ClassSpec};
        use std::sync::Arc;
        let reg = Arc::new(
            ClassRegistry::new(vec![
                ClassSpec {
                    name: "chat".into(),
                    tier: 1,
                    ttft_slo_ms: Some(500.0),
                    tbt_slo_ms: Some(50.0),
                    latency_budget: None,
                    preempt_priority: 100,
                    admission: AdmissionPolicy::Fcfs,
                    starvation_age_s: None,
                },
                ClassSpec {
                    name: "batch".into(),
                    tier: 0,
                    ttft_slo_ms: None,
                    tbt_slo_ms: None,
                    latency_budget: Some(4.0),
                    preempt_priority: 0,
                    admission: AdmissionPolicy::Fcfs,
                    starvation_age_s: None,
                },
            ])
            .unwrap(),
        );
        let state = EngineState::with_registry(reg, OfflinePolicy::Fcfs, 1024, 16, 0);
        let sched = HybridScheduler::new(
            SchedulerConfig { latency_budget_ms: Some(40.0), ..Default::default() },
            LatencyPredictor::default_seed(),
        );
        let mut e = Engine::new(sched, state, SimBackend::new(CostModel::a100_llama7b(), 0));
        // Idle: the loosest tolerance (4.0) applies.
        let idle = ReplicaSnapshot::of(&e);
        assert_eq!(idle.min_present_tolerance, 4.0);
        // Only batch present: still 4x headroom.
        e.submit(Request::new(1, Class::OFFLINE, 0.0, 32, 4));
        let batch_only = ReplicaSnapshot::of(&e);
        assert_eq!(batch_only.min_present_tolerance, 4.0);
        // Chat arrives: the tightest present class clamps to 1.0.
        e.submit(Request::new(2, Class::ONLINE, 0.0, 32, 4));
        let both = ReplicaSnapshot::of(&e);
        assert_eq!(both.min_present_tolerance, 1.0);
        assert!(both.headroom_ms() < batch_only.headroom_ms());
    }
}
