//! Multi-replica cluster layer: SLO-aware request routing and elastic
//! offline placement across engine instances.
//!
//! One HyGen instance co-locates online and offline work inside a single
//! engine (the paper's Fig. 2). A production deployment runs *N* such
//! replicas behind a router — and multi-SLO dispatch decisions belong
//! above the per-engine scheduler (SLOs-Serve), while idle capacity
//! across serving instances can be harvested for offline work (ConServe).
//! This module is that layer:
//!
//! * [`router::Router`] — the routing policy interface over per-replica
//!   [`ReplicaSnapshot`]s, with three implementations:
//!   [`router::RoundRobin`], [`router::JoinShortestQueue`], and
//!   [`router::SloHeadroom`] (routes online requests to the replica with
//!   the most SLO headroom and elastically places the shared offline
//!   backlog onto replicas whose predicted batch time leaves slack — the
//!   cross-replica analogue of the paper's SLO-aware offline scheduling).
//! * [`replica::Replica`] — one engine on its own thread behind an mpsc
//!   job queue (the `server::engine_loop` message-passing shape),
//!   publishing a census snapshot and a metrics report, and draining
//!   in-flight work gracefully on shutdown.
//! * [`sim::ClusterSim`] — a deterministic virtual-clock driver over N
//!   sim-backend engines with a shared offline backlog and periodic
//!   rebalance ticks; `hygen cluster-sim` measures the policies on the
//!   calibrated mixed trace (`artifacts/cluster_compare.csv`).
//!
//! The server front end ([`crate::server`]) builds on [`replica`] for
//! `hygen serve --replicas N --router <policy>`.

pub mod replica;
pub mod router;
pub mod sim;

use crate::coordinator::batch::Features;
use crate::coordinator::request::Class;
use crate::engine::{Engine, ExecutionBackend};

/// A point-in-time census of one replica, published by its engine thread
/// (server mode) or computed on demand (simulation). Routers make every
/// decision from these snapshots only — they never touch engine state.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReplicaSnapshot {
    /// Online requests waiting in the replica's FCFS queue.
    pub online_waiting: usize,
    /// Offline requests waiting in the replica's offline queue.
    pub offline_waiting: usize,
    pub running_online: usize,
    pub running_offline: usize,
    pub preempted_offline: usize,
    /// Free KV-cache capacity in tokens.
    pub free_kv_tokens: usize,
    /// Latency-predictor estimate (ms) of the replica's next iteration
    /// given its running census — the load signal `SloHeadroom` routes on.
    pub predicted_iter_ms: f64,
    /// Per-iteration latency budget the replica schedules under
    /// (`f64::INFINITY` when SLO-unaware).
    pub latency_budget_ms: f64,
    /// The replica's backend failed persistently; routers must prefer any
    /// live replica over a failed one.
    pub failed: bool,
}

impl ReplicaSnapshot {
    /// Snapshot an engine's current census (any backend).
    pub fn of<B: ExecutionBackend>(engine: &Engine<B>) -> ReplicaSnapshot {
        let counts = engine.state.counts;
        // Estimate the next iteration from the running census: every
        // running decode contributes one token; running prefills are
        // assumed to fill the chunk budget between them (the scheduler
        // schedules at most `chunk_tokens` of prefill per iteration).
        // Snapshots are taken every engine-loop iteration, so this is
        // O(1) in the running-set size.
        let decodes = (counts.decode(Class::Online) + counts.decode(Class::Offline)) as f64;
        let mut f = Features { sp: 0.0, sd: decodes, np: 0.0, nd: decodes };
        let prefills = counts.prefill(Class::Online) + counts.prefill(Class::Offline);
        if prefills > 0 {
            f.add_prefill(engine.scheduler.cfg.chunk_tokens);
        }
        ReplicaSnapshot {
            online_waiting: engine.state.online_queue.len(),
            offline_waiting: engine.state.offline_queue.len(),
            running_online: engine.state.running_online.len(),
            running_offline: engine.state.running_offline.len(),
            preempted_offline: engine.state.preempted_offline.len(),
            free_kv_tokens: engine.state.blocks.free_tokens(),
            predicted_iter_ms: engine.scheduler.predictor.predict(&f),
            latency_budget_ms: engine.scheduler.cfg.latency_budget_ms.unwrap_or(f64::INFINITY),
            failed: false,
        }
    }

    /// Everything queued or in flight on the replica (JSQ's load measure).
    pub fn total_depth(&self) -> usize {
        self.online_waiting
            + self.offline_waiting
            + self.running_online
            + self.running_offline
            + self.preempted_offline
    }

    /// Online-only load (waiting + running).
    pub fn online_depth(&self) -> usize {
        self.online_waiting + self.running_online
    }

    /// Predicted slack (ms) between the replica's latency budget and its
    /// next iteration — the `SloHeadroom` routing signal. Infinite when
    /// the replica is SLO-unaware.
    pub fn headroom_ms(&self) -> f64 {
        self.latency_budget_ms - self.predicted_iter_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::predictor::LatencyPredictor;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::request::Request;
    use crate::coordinator::scheduler::{HybridScheduler, SchedulerConfig};
    use crate::coordinator::state::EngineState;
    use crate::sim::costmodel::CostModel;
    use crate::sim::SimBackend;

    fn engine(budget: Option<f64>) -> Engine<SimBackend> {
        let state = EngineState::new(OfflinePolicy::Fcfs, 1024, 16, 0);
        let sched = HybridScheduler::new(
            SchedulerConfig { latency_budget_ms: budget, ..Default::default() },
            LatencyPredictor::default_seed(),
        );
        Engine::new(sched, state, SimBackend::new(CostModel::a100_llama7b(), 0))
    }

    #[test]
    fn snapshot_reflects_census() {
        let mut e = engine(Some(40.0));
        e.submit(Request::new(1, Class::Online, 0.0, 64, 8));
        e.submit(Request::new(2, Class::Offline, 0.0, 64, 8));
        let s = ReplicaSnapshot::of(&e);
        assert_eq!(s.online_waiting, 1);
        assert_eq!(s.offline_waiting, 1);
        assert_eq!(s.total_depth(), 2);
        assert_eq!(s.latency_budget_ms, 40.0);
        assert!(s.headroom_ms() < 40.0, "empty-batch bias charged");
        e.step().unwrap();
        let s2 = ReplicaSnapshot::of(&e);
        assert!(s2.running_online + s2.running_offline > 0);
        assert!(s2.predicted_iter_ms > s.predicted_iter_ms, "load raises the estimate");
    }

    #[test]
    fn slo_unaware_headroom_is_infinite() {
        let e = engine(None);
        let s = ReplicaSnapshot::of(&e);
        assert_eq!(s.latency_budget_ms, f64::INFINITY);
        assert_eq!(s.headroom_ms(), f64::INFINITY);
    }
}
