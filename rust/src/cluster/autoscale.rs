//! SLO-headroom-driven autoscaling with hysteresis.
//!
//! The autoscaler consumes the same per-replica census the routers route
//! on ([`ReplicaSnapshot`]) and turns the *aggregate* headroom signal
//! into scale decisions: persistently negative-ish headroom means the
//! live set cannot absorb the interactive load inside its latency
//! budgets (add a replica); persistently generous headroom means
//! capacity is idle (drain one). Both directions require the signal to
//! hold for [`AutoscaleConfig::hysteresis_ticks`] consecutive
//! observations so a single bursty tick never flaps the fleet
//! (DESIGN.md §7c).
//!
//! The autoscaler only *decides*; the owner (the cluster simulation, or
//! an operator loop around the server) activates a parked replica or
//! marks one draining. Draining is graceful by construction: a draining
//! replica keeps its resident work and is simply skipped by the routers
//! until it runs dry.

use super::ReplicaSnapshot;

/// Scaling knobs (config keys `autoscale_*`, see `config::ClusterConfig`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleConfig {
    /// Never drain below this many live replicas.
    pub min_replicas: usize,
    /// Never grow above this many live replicas.
    pub max_replicas: usize,
    /// Scale **up** when mean live headroom stays below this (ms).
    pub up_headroom_ms: f64,
    /// Scale **down** when mean live headroom stays above this (ms).
    pub down_headroom_ms: f64,
    /// Consecutive observations a signal must hold before a decision
    /// fires (>= 1; 1 disables hysteresis).
    pub hysteresis_ticks: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 8,
            up_headroom_ms: 5.0,
            down_headroom_ms: 30.0,
            hysteresis_ticks: 3,
        }
    }
}

/// What the autoscaler wants done after one observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Activate one more replica (the owner picks which parked one).
    Up,
    /// Drain one live replica (the owner picks which and lets it run dry).
    Down,
}

/// Hysteresis state machine over the aggregate headroom signal. One
/// instance per cluster; feed it a snapshot vector per rebalance tick.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    up_streak: usize,
    down_streak: usize,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        assert!(cfg.min_replicas >= 1, "autoscaler floor must keep one replica");
        assert!(cfg.max_replicas >= cfg.min_replicas, "autoscale_max below autoscale_min");
        assert!(
            cfg.up_headroom_ms < cfg.down_headroom_ms,
            "up threshold must sit below the down threshold"
        );
        assert!(cfg.hysteresis_ticks >= 1, "hysteresis needs at least one tick");
        Autoscaler { cfg, up_streak: 0, down_streak: 0 }
    }

    /// Observe one census and decide. Live = not failed and not draining
    /// (a draining replica is already on its way out; a failed one
    /// contributes no capacity). With *no* live replica the signal is
    /// treated as maximally overloaded — an immediate up-streak tick.
    pub fn observe(&mut self, snaps: &[ReplicaSnapshot]) -> ScaleDecision {
        let live: Vec<&ReplicaSnapshot> =
            snaps.iter().filter(|s| !s.failed && !s.draining).collect();
        let mean_headroom = if live.is_empty() {
            f64::NEG_INFINITY
        } else {
            live.iter().map(|s| s.headroom_ms()).sum::<f64>() / live.len() as f64
        };
        if mean_headroom < self.cfg.up_headroom_ms {
            self.down_streak = 0;
            self.up_streak += 1;
            if self.up_streak >= self.cfg.hysteresis_ticks && live.len() < self.cfg.max_replicas {
                self.up_streak = 0;
                return ScaleDecision::Up;
            }
        } else if mean_headroom > self.cfg.down_headroom_ms {
            self.up_streak = 0;
            self.down_streak += 1;
            if self.down_streak >= self.cfg.hysteresis_ticks && live.len() > self.cfg.min_replicas
            {
                self.down_streak = 0;
                return ScaleDecision::Down;
            }
        } else {
            // In-band headroom: a healthy fleet. Any accumulated streak
            // was interrupted — reset so only *consecutive* signals fire.
            self.up_streak = 0;
            self.down_streak = 0;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(headroom: f64) -> ReplicaSnapshot {
        ReplicaSnapshot {
            predicted_iter_ms: 40.0 - headroom,
            latency_budget_ms: 40.0,
            ..Default::default()
        }
    }

    fn scaler(hysteresis: usize) -> Autoscaler {
        Autoscaler::new(AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            up_headroom_ms: 5.0,
            down_headroom_ms: 30.0,
            hysteresis_ticks: hysteresis,
        })
    }

    #[test]
    fn fires_up_only_after_consecutive_ticks() {
        let mut a = scaler(3);
        let hot = vec![snap(1.0), snap(2.0)];
        assert_eq!(a.observe(&hot), ScaleDecision::Hold);
        assert_eq!(a.observe(&hot), ScaleDecision::Hold);
        assert_eq!(a.observe(&hot), ScaleDecision::Up, "third consecutive hot tick fires");
        // The streak reset on fire: it takes another 3 ticks to fire again.
        assert_eq!(a.observe(&hot), ScaleDecision::Hold);
    }

    #[test]
    fn interrupted_streak_resets() {
        let mut a = scaler(3);
        let hot = vec![snap(1.0)];
        let ok = vec![snap(15.0)];
        assert_eq!(a.observe(&hot), ScaleDecision::Hold);
        assert_eq!(a.observe(&hot), ScaleDecision::Hold);
        assert_eq!(a.observe(&ok), ScaleDecision::Hold, "in-band tick interrupts");
        assert_eq!(a.observe(&hot), ScaleDecision::Hold, "streak restarted from zero");
        assert_eq!(a.observe(&hot), ScaleDecision::Hold);
        assert_eq!(a.observe(&hot), ScaleDecision::Up);
    }

    #[test]
    fn fires_down_with_idle_headroom_and_respects_floor() {
        let mut a = scaler(2);
        let idle = vec![snap(38.0), snap(39.0)];
        assert_eq!(a.observe(&idle), ScaleDecision::Hold);
        assert_eq!(a.observe(&idle), ScaleDecision::Down);
        // At the floor the streak saturates without firing.
        let one = vec![snap(39.0)];
        assert_eq!(a.observe(&one), ScaleDecision::Hold);
        assert_eq!(a.observe(&one), ScaleDecision::Hold);
        assert_eq!(a.observe(&one), ScaleDecision::Hold, "never drains below min_replicas");
    }

    #[test]
    fn up_respects_ceiling() {
        let mut a = scaler(1);
        let hot: Vec<ReplicaSnapshot> = (0..4).map(|_| snap(0.0)).collect();
        assert_eq!(a.observe(&hot), ScaleDecision::Hold, "already at max_replicas");
    }

    #[test]
    fn failed_and_draining_replicas_do_not_count_as_capacity() {
        let mut a = scaler(1);
        // Plenty of headroom on paper, but every replica is failed or
        // draining: that is an overloaded cluster, not an idle one.
        let mut snaps = vec![snap(39.0), snap(39.0)];
        snaps[0].failed = true;
        snaps[1].draining = true;
        assert_eq!(a.observe(&snaps), ScaleDecision::Up, "no live capacity is an up-signal");
        // One live idle replica among the dead ones: down is gated by the
        // floor (1 live replica == min_replicas).
        let mut snaps = vec![snap(39.0), snap(39.0)];
        snaps[0].failed = true;
        assert_eq!(a.observe(&snaps), ScaleDecision::Hold);
    }

    #[test]
    #[should_panic(expected = "up threshold")]
    fn rejects_inverted_thresholds() {
        Autoscaler::new(AutoscaleConfig {
            up_headroom_ms: 30.0,
            down_headroom_ms: 5.0,
            ..Default::default()
        });
    }
}
