//! Shared engine state the scheduler operates on: queues, running sets,
//! preempted set, the block manager, and the request table.
//!
//! Hot-path complexity contract (see DESIGN.md "Scheduler data
//! structures"): one `schedule()` + apply iteration is O(batch). The
//! running sets are [`RunSet`]s (O(1) insert/remove/contains, ordered
//! iteration), the preempted set is a `VecDeque` (O(1) resume pop), and
//! [`PhaseCounts`] tracks how many running requests sit in each
//! (class, phase) bucket so scheduler passes with no candidates are
//! skipped without touching the sets at all.

use super::block_manager::{chain_hashes, BlockManager};
use super::queues::{OfflinePolicy, OfflineQueue, OnlineQueue};
use super::request::{Class, Phase, Request, RequestId};
use super::runset::RunSet;
use std::collections::{HashMap, HashSet, VecDeque};

/// Counts of *running* requests by (class, phase). Maintained by every
/// [`EngineState`] transition so the scheduler can size (or skip) its
/// per-phase passes without re-scanning the running sets.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseCounts {
    pub online_prefill: usize,
    pub online_decode: usize,
    pub offline_prefill: usize,
    pub offline_decode: usize,
}

impl PhaseCounts {
    pub fn prefill(&self, class: Class) -> usize {
        match class {
            Class::Online => self.online_prefill,
            Class::Offline => self.offline_prefill,
        }
    }

    pub fn decode(&self, class: Class) -> usize {
        match class {
            Class::Online => self.online_decode,
            Class::Offline => self.offline_decode,
        }
    }

    fn slot(&mut self, class: Class, phase: Phase) -> Option<&mut usize> {
        match (class, phase) {
            (Class::Online, Phase::Prefill) => Some(&mut self.online_prefill),
            (Class::Online, Phase::Decode) => Some(&mut self.online_decode),
            (Class::Offline, Phase::Prefill) => Some(&mut self.offline_prefill),
            (Class::Offline, Phase::Decode) => Some(&mut self.offline_decode),
            // Waiting/Preempted/Finished requests are not "running work".
            _ => None,
        }
    }

    fn add(&mut self, class: Class, phase: Phase) {
        if let Some(c) = self.slot(class, phase) {
            *c += 1;
        }
    }

    fn sub(&mut self, class: Class, phase: Phase) {
        if let Some(c) = self.slot(class, phase) {
            debug_assert!(*c > 0, "phase count underflow for {class:?}/{phase:?}");
            *c = c.saturating_sub(1);
        }
    }
}

/// All mutable serving state of one engine instance.
pub struct EngineState {
    /// Every request known to the instance (running or preempted).
    /// Waiting requests live in their queue; finished ones in `finished`.
    pub requests: HashMap<RequestId, Request>,
    pub online_queue: OnlineQueue,
    pub offline_queue: OfflineQueue,
    /// Running online requests in admission order.
    pub running_online: RunSet,
    /// Running offline requests — kept in their scheduling (DFS) order, per
    /// Alg. 3 ("running requests keep their original DFS order").
    pub running_offline: RunSet,
    /// Offline requests preempted with preserved state, newest last.
    /// Resumed FIFO (oldest progress first) from the front.
    pub preempted_offline: VecDeque<RequestId>,
    /// Running-request census by (class, phase); kept in lockstep with the
    /// sets above by the transition methods. Mutate phases through
    /// [`EngineState`] methods or the census drifts (`check_invariants`
    /// verifies it).
    pub counts: PhaseCounts,
    pub blocks: BlockManager,
    pub finished: Vec<Request>,
    /// Keep finished request bodies (tests want them; long sims can turn
    /// this off to bound memory).
    pub keep_finished: bool,
    /// Honor prefix-cache hits as skipped prefill work. True for the
    /// simulation backend; the real PJRT backend keeps a *per-slot* KV
    /// layout where cross-request row reuse is physically impossible, so
    /// it runs with this off (block sharing then degrades to plain
    /// accounting with empty hash chains).
    pub prefix_caching: bool,
}

impl EngineState {
    pub fn new(policy: OfflinePolicy, num_blocks: usize, block_size: usize, seed: u64) -> Self {
        EngineState {
            requests: HashMap::new(),
            online_queue: OnlineQueue::new(),
            offline_queue: OfflineQueue::new(policy, seed),
            running_online: RunSet::new(),
            running_offline: RunSet::new(),
            preempted_offline: VecDeque::new(),
            counts: PhaseCounts::default(),
            blocks: BlockManager::new(num_blocks, block_size),
            finished: Vec::new(),
            keep_finished: true,
            prefix_caching: true,
        }
    }

    /// Admit an arriving request into its class queue.
    pub fn enqueue(&mut self, req: Request) {
        match req.class {
            Class::Online => self.online_queue.push(req),
            Class::Offline => self.offline_queue.push(req),
        }
    }

    pub fn req(&self, id: RequestId) -> &Request {
        &self.requests[&id]
    }

    pub fn req_mut(&mut self, id: RequestId) -> &mut Request {
        self.requests.get_mut(&id).expect("request exists")
    }

    /// Total requests currently running (both classes).
    pub fn num_running(&self) -> usize {
        self.running_online.len() + self.running_offline.len()
    }

    /// KV hash chain for a request's prompt (prefix-cache key). Empty
    /// when prefix caching is disabled (real backend).
    pub fn prompt_chain(&self, req: &Request) -> Vec<u64> {
        if !self.prefix_caching {
            return Vec::new();
        }
        chain_hashes(&req.prompt, self.blocks.block_size())
    }

    /// Move an admitted request (blocks already allocated, phase set to
    /// `Prefill`/`Decode`) into its class's running set.
    pub fn insert_running(&mut self, req: Request) {
        debug_assert!(
            matches!(req.phase, Phase::Prefill | Phase::Decode),
            "admitting {} in phase {:?}",
            req.id,
            req.phase
        );
        self.counts.add(req.class, req.phase);
        match req.class {
            Class::Online => self.running_online.push(req.id),
            Class::Offline => self.running_offline.push(req.id),
        }
        self.requests.insert(req.id, req);
    }

    /// Advance a running request's prefill cursor by a scheduled chunk of
    /// `n` tokens. Returns true when this chunk completed the prompt (the
    /// same iteration emits the first output token).
    pub fn advance_prefill(&mut self, id: RequestId, n: usize) -> bool {
        let req = self.requests.get_mut(&id).expect("request exists");
        let (class, before) = (req.class, req.phase);
        req.advance_prefill(n);
        if req.phase != before {
            self.counts.sub(class, before);
            self.counts.add(class, req.phase);
        }
        req.prefill_done()
    }

    /// Record one generated token for a running request. Returns true
    /// when the request reached its output budget (caller should
    /// [`finish`](Self::finish) it).
    pub fn advance_decode(&mut self, id: RequestId) -> bool {
        let req = self.requests.get_mut(&id).expect("request exists");
        let (class, before) = (req.class, req.phase);
        req.advance_decode();
        if req.phase != before {
            self.counts.sub(class, before);
            self.counts.add(class, req.phase);
        }
        req.is_finished()
    }

    /// Move a running request to `finished`, releasing its blocks.
    pub fn finish(&mut self, id: RequestId) {
        self.blocks.release(id);
        if !self.running_online.remove(id) {
            self.running_offline.remove(id);
        }
        if let Some(mut r) = self.requests.remove(&id) {
            self.counts.sub(r.class, r.phase);
            r.phase = Phase::Finished;
            if self.keep_finished {
                self.finished.push(r);
            }
        }
    }

    /// Preempt one running offline request (the most recently admitted,
    /// vLLM-style LIFO so earlier requests keep progress), releasing its
    /// blocks. Returns the id, or None if nothing can be preempted.
    pub fn preempt_last_offline(&mut self, discard: bool) -> Option<RequestId> {
        let id = self.running_offline.pop()?;
        self.blocks.release(id);
        let req = self.requests.get_mut(&id).expect("running request exists");
        self.counts.sub(req.class, req.phase);
        if discard {
            req.preempt_discard();
            // discarded state returns to the offline queue for rescheduling
            let req = self.requests.remove(&id).unwrap();
            self.offline_queue.push(req);
            // Its KV (and the whole LCP baseline's residency assumption)
            // is gone; without this its next pop would claim a self-LCP.
            self.offline_queue.reset_prefix_context();
        } else {
            req.preempt_preserve();
            self.preempted_offline.push_back(id);
        }
        Some(id)
    }

    /// Re-admit the *front* (oldest-progress) preempted offline request —
    /// the caller already re-allocated its context. Returns the phase it
    /// resumes in.
    pub fn resume_front_preempted(&mut self) -> Phase {
        let id = self.preempted_offline.pop_front().expect("preempted request to resume");
        let req = self.requests.get_mut(&id).expect("preempted request in table");
        debug_assert_eq!(req.phase, Phase::Preempted);
        req.phase = if req.prefill_done() { Phase::Decode } else { Phase::Prefill };
        let phase = req.phase;
        self.counts.add(req.class, phase);
        self.running_offline.push(id);
        phase
    }

    /// Abort every queued, running, and preempted request, releasing all
    /// KV blocks. Returns the ids that were running *or* preempted —
    /// backends hold per-request resources (e.g. sequence slots) for both,
    /// since preempted requests only get reconciled lazily on the next
    /// execute, which never comes after an abort. Used by the server when
    /// the execution backend fails: the engine must not keep re-scheduling
    /// a doomed batch.
    pub fn abort_all(&mut self) -> Vec<RequestId> {
        let torn_down: Vec<RequestId> = self
            .running_online
            .iter()
            .chain(self.running_offline.iter())
            .chain(self.preempted_offline.iter().copied())
            .collect();
        // Only running requests hold blocks (preemption already released
        // theirs); release() is a no-op for unallocated ids.
        for &id in &torn_down {
            self.blocks.release(id);
        }
        self.running_online.clear();
        self.running_offline.clear();
        self.preempted_offline.clear();
        self.requests.clear();
        self.online_queue.clear();
        self.offline_queue.clear();
        self.counts = PhaseCounts::default();
        torn_down
    }

    /// Sanity invariants used by tests: every running id has a request and
    /// an allocation; no id is in two places at once; queued requests are
    /// not also tracked in the table; the phase census matches the sets.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen: HashSet<RequestId> = HashSet::new();
        let mut recount = PhaseCounts::default();
        for id in self.running_online.iter().chain(self.running_offline.iter()) {
            if !seen.insert(id) {
                return Err(format!("{id} in two running sets"));
            }
            let r = self
                .requests
                .get(&id)
                .ok_or_else(|| format!("running {id} missing from table"))?;
            if !self.blocks.is_allocated(id) {
                return Err(format!("running {id} has no blocks"));
            }
            if matches!(r.phase, Phase::Waiting | Phase::Finished | Phase::Preempted) {
                return Err(format!("running {id} in phase {:?}", r.phase));
            }
            recount.add(r.class, r.phase);
        }
        for &id in &self.preempted_offline {
            if !seen.insert(id) {
                return Err(format!("{id} both running and preempted"));
            }
            if self.blocks.is_allocated(id) {
                return Err(format!("preempted {id} still holds blocks"));
            }
            if !self.requests.contains_key(&id) {
                return Err(format!("preempted {id} missing from table"));
            }
        }
        if recount != self.counts {
            return Err(format!(
                "phase census drift: counted {recount:?}, tracked {:?}",
                self.counts
            ));
        }
        for id in self.online_queue.ids().chain(self.offline_queue.ids()) {
            if self.requests.contains_key(&id) {
                return Err(format!("queued {id} also in the request table"));
            }
            if !seen.insert(id) {
                return Err(format!("queued {id} also running/preempted"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queues::OfflinePolicy;

    fn state() -> EngineState {
        EngineState::new(OfflinePolicy::Fcfs, 64, 16, 0)
    }

    fn running(state: &mut EngineState, id: RequestId, class: Class, prompt: usize, out: usize) {
        let mut r = Request::new(id, class, 0.0, prompt, out);
        r.phase = Phase::Decode;
        r.prefilled = prompt;
        state.blocks.allocate(id, r.context_len().max(1), &[]).unwrap();
        state.insert_running(r);
    }

    #[test]
    fn enqueue_routes_by_class() {
        let mut s = state();
        s.enqueue(Request::new(1, Class::Online, 0.0, 4, 4));
        s.enqueue(Request::new(2, Class::Offline, 0.0, 4, 4));
        assert_eq!(s.online_queue.len(), 1);
        assert_eq!(s.offline_queue.len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn finish_releases_everything() {
        let mut s = state();
        running(&mut s, 1, Class::Online, 16, 2);
        assert_eq!(s.counts.decode(Class::Online), 1);
        s.check_invariants().unwrap();
        s.finish(1);
        assert_eq!(s.num_running(), 0);
        assert_eq!(s.counts, PhaseCounts::default());
        assert_eq!(s.blocks.used_blocks(), 0);
        assert_eq!(s.finished.len(), 1);
        assert_eq!(s.finished[0].phase, Phase::Finished);
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempt_preserve_moves_to_preempted() {
        let mut s = state();
        let mut r = Request::new(5, Class::Offline, 0.0, 16, 4);
        r.phase = Phase::Decode;
        r.prefilled = 16;
        r.generated = 2;
        s.blocks.allocate(5, 18, &[]).unwrap();
        s.insert_running(r);
        let got = s.preempt_last_offline(false);
        assert_eq!(got, Some(5));
        assert_eq!(s.preempted_offline, vec![5]);
        assert_eq!(s.requests[&5].generated, 2, "state preserved");
        assert_eq!(s.blocks.used_blocks(), 0);
        assert_eq!(s.counts, PhaseCounts::default());
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempt_discard_requeues() {
        let mut s = state();
        let mut r = Request::new(5, Class::Offline, 0.0, 16, 4);
        r.phase = Phase::Decode;
        r.prefilled = 16;
        r.generated = 2;
        s.blocks.allocate(5, 18, &[]).unwrap();
        s.insert_running(r);
        s.preempt_last_offline(true);
        assert!(s.preempted_offline.is_empty());
        assert_eq!(s.offline_queue.len(), 1, "discarded request requeued");
        assert!(!s.requests.contains_key(&5));
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempt_on_empty_is_none() {
        let mut s = state();
        assert_eq!(s.preempt_last_offline(false), None);
    }

    #[test]
    fn resume_front_restores_counts_and_order() {
        let mut s = state();
        for id in [5, 6] {
            let mut r = Request::new(id, Class::Offline, 0.0, 16, 4);
            r.phase = Phase::Decode;
            r.prefilled = 16;
            s.blocks.allocate(id, 17, &[]).unwrap();
            s.insert_running(r);
        }
        s.preempt_last_offline(false); // 6
        s.preempt_last_offline(false); // 5
        assert_eq!(s.preempted_offline, vec![6, 5]);
        s.blocks.allocate(6, 17, &[]).unwrap();
        let phase = s.resume_front_preempted();
        assert_eq!(phase, Phase::Decode);
        assert_eq!(s.running_offline, vec![6]);
        assert_eq!(s.preempted_offline, vec![5]);
        assert_eq!(s.counts.decode(Class::Offline), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn advance_transitions_update_census() {
        let mut s = state();
        let mut r = Request::new(9, Class::Online, 0.0, 8, 2);
        r.phase = Phase::Prefill;
        s.blocks.allocate(9, 8, &[]).unwrap();
        s.insert_running(r);
        assert_eq!(s.counts.prefill(Class::Online), 1);
        assert!(!s.advance_prefill(9, 4), "prompt not done yet");
        assert!(s.advance_prefill(9, 4), "prompt completed");
        assert_eq!(s.counts.prefill(Class::Online), 0);
        assert_eq!(s.counts.decode(Class::Online), 1);
        assert!(!s.advance_decode(9));
        assert!(s.advance_decode(9), "output budget reached");
        s.finish(9);
        assert_eq!(s.counts, PhaseCounts::default());
        s.check_invariants().unwrap();
    }

    #[test]
    fn abort_all_clears_every_set() {
        let mut s = state();
        running(&mut s, 1, Class::Online, 16, 4);
        running(&mut s, 2, Class::Offline, 16, 4);
        s.preempt_last_offline(false);
        s.enqueue(Request::new(3, Class::Online, 0.0, 4, 4));
        s.enqueue(Request::new(4, Class::Offline, 0.0, 4, 4));
        let aborted = s.abort_all();
        assert_eq!(aborted, vec![1, 2], "running and preempted ids both reported");
        assert_eq!(s.num_running(), 0);
        assert!(s.preempted_offline.is_empty());
        assert!(s.online_queue.is_empty() && s.offline_queue.is_empty());
        assert_eq!(s.blocks.used_blocks(), 0);
        assert_eq!(s.counts, PhaseCounts::default());
        s.check_invariants().unwrap();
    }

    #[test]
    fn invariants_reject_queue_table_overlap() {
        let mut s = state();
        running(&mut s, 7, Class::Online, 8, 2);
        // Simulate a duplication bug: the running request also re-enters
        // the queue.
        s.enqueue(Request::new(7, Class::Online, 0.0, 8, 2));
        assert!(s.check_invariants().is_err());
    }

    #[test]
    fn invariants_reject_census_drift() {
        let mut s = state();
        running(&mut s, 7, Class::Online, 8, 2);
        s.counts.online_decode = 0; // simulate drift
        assert!(s.check_invariants().is_err());
    }
}
