//! Shared engine state the scheduler operates on: the class registry,
//! per-class queues, running sets and preempted sets, the block manager,
//! and the request table.
//!
//! Everything is **class-indexed** (one slot per registry class) instead
//! of class-matched: `queues[c]`, `runs[c]`, `preempted[c]`, and the
//! [`PhaseCounts`] census are dense arrays over
//! [`Class`](super::request::ClassId). The paper's online/offline pair
//! is the registry's two-class default.
//!
//! Hot-path complexity contract (see DESIGN.md "Scheduler data
//! structures"): one `schedule()` + apply iteration is O(batch +
//! classes). The running sets are [`RunSet`]s (O(1)
//! insert/remove/contains, ordered iteration), each preempted set is a
//! `VecDeque` (O(1) resume pop), and [`PhaseCounts`] tracks how many
//! running requests sit in each (class, phase) bucket so scheduler passes
//! with no candidates are skipped without touching the sets at all.

use super::block_manager::{chain_hashes, chain_hashes_into, BlockManager};
use super::classes::{AdmissionPolicy, ClassRegistry, MAX_CLASSES};
use super::queues::{ClassQueue, FcfsQueue, OfflinePolicy, OfflineQueue};
use super::request::{Class, Phase, Request, RequestId};
use super::runset::RunSet;
use crate::obs::recorder::{EventKind, Recorder};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;

/// Counts of *running* requests by (class, phase), as dense fixed arrays
/// indexed by [`Class`] (`Copy` and allocation-free — snapshots copy it
/// every engine iteration). Maintained by every [`EngineState`]
/// transition so the scheduler can size (or skip) its per-phase passes
/// without re-scanning the running sets.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseCounts {
    prefill: [usize; MAX_CLASSES],
    decode: [usize; MAX_CLASSES],
}

impl PhaseCounts {
    // lint: allow(panic, reason=Class indices are bounded by MAX_CLASSES at registry construction)
    pub fn prefill(&self, class: Class) -> usize {
        self.prefill[class.index()]
    }

    // lint: allow(panic, reason=Class indices are bounded by MAX_CLASSES at registry construction)
    pub fn decode(&self, class: Class) -> usize {
        self.decode[class.index()]
    }

    /// Running requests (prefill + decode) of one class.
    pub fn running(&self, class: Class) -> usize {
        self.prefill(class) + self.decode(class)
    }

    /// Total running prefills across every class.
    pub fn total_prefill(&self) -> usize {
        self.prefill.iter().sum()
    }

    /// Total running decodes across every class.
    pub fn total_decode(&self) -> usize {
        self.decode.iter().sum()
    }

    // lint: allow(panic, reason=Class indices are bounded by MAX_CLASSES at registry construction)
    fn slot(&mut self, class: Class, phase: Phase) -> Option<&mut usize> {
        match phase {
            Phase::Prefill => Some(&mut self.prefill[class.index()]),
            Phase::Decode => Some(&mut self.decode[class.index()]),
            // Waiting/Preempted/Finished requests are not "running work".
            _ => None,
        }
    }

    fn add(&mut self, class: Class, phase: Phase) {
        if let Some(c) = self.slot(class, phase) {
            *c += 1;
        }
    }

    fn sub(&mut self, class: Class, phase: Phase) {
        if let Some(c) = self.slot(class, phase) {
            debug_assert!(*c > 0, "phase count underflow for {class:?}/{phase:?}");
            *c = c.saturating_sub(1);
        }
    }
}

/// All mutable serving state of one engine instance.
pub struct EngineState {
    /// The class table every layer indexes by [`Class`]. Immutable for
    /// the lifetime of the instance.
    pub registry: Arc<ClassRegistry>,
    /// Every request known to the instance (running or preempted).
    /// Waiting requests live in their class queue; finished ones in
    /// `finished`.
    pub requests: HashMap<RequestId, Request>,
    /// One waiting queue per class (registry order).
    pub queues: Vec<ClassQueue>,
    /// Per-class running sets. FCFS classes keep admission order;
    /// prefix classes keep their scheduling (DFS) order, per Alg. 3
    /// ("running requests keep their original DFS order").
    pub runs: Vec<RunSet>,
    /// Per-class preempted-with-preserved-state deques, newest last.
    /// Resumed FIFO (oldest progress first) from the front.
    pub preempted_by_class: Vec<VecDeque<RequestId>>,
    /// Running-request census by (class, phase); kept in lockstep with
    /// the sets above by the transition methods. Mutate phases through
    /// [`EngineState`] methods or the census drifts (`check_invariants`
    /// verifies it).
    pub counts: PhaseCounts,
    pub blocks: BlockManager,
    pub finished: Vec<Request>,
    /// Keep finished request bodies (tests want them; long sims can turn
    /// this off to bound memory).
    pub keep_finished: bool,
    /// Honor prefix-cache hits as skipped prefill work. True for the
    /// simulation backend; the real PJRT backend keeps a *per-slot* KV
    /// layout where cross-request row reuse is physically impossible, so
    /// it runs with this off (block sharing then degrades to plain
    /// accounting with empty hash chains).
    pub prefix_caching: bool,
    /// Consistency anomalies observed at runtime (e.g. a finish/abort
    /// race detected during preemption). Diagnosable instead of a panic;
    /// `check_invariants` reports them.
    pub anomalies: Vec<String>,
    /// Flight recorder fed by every transition method. The engine/sim
    /// layer keeps `recorder.now_ms` in lockstep with the virtual clock
    /// and the scheduler stages its decision audit in
    /// `recorder.audit_a/b` before invoking preemptions.
    pub recorder: Recorder,
}

impl EngineState {
    /// The classic two-class instance: a FCFS online queue above an
    /// offline queue ordered by `policy`.
    pub fn new(policy: OfflinePolicy, num_blocks: usize, block_size: usize, seed: u64) -> Self {
        Self::with_registry(
            Arc::new(ClassRegistry::default_two()),
            policy,
            num_blocks,
            block_size,
            seed,
        )
    }

    /// Build an instance over an arbitrary registry. Classes with
    /// `longest-prefix` admission get an [`OfflineQueue`] ordered by
    /// `prefix_policy` (seeded per class so fair-PSM streams stay
    /// independent); `fcfs` / `rate-capped` classes get a plain FCFS
    /// deque. With [`ClassRegistry::default_two`] this is exactly the
    /// classic dual-queue instance.
    pub fn with_registry(
        registry: Arc<ClassRegistry>,
        prefix_policy: OfflinePolicy,
        num_blocks: usize,
        block_size: usize,
        seed: u64,
    ) -> Self {
        let mut queues = Vec::with_capacity(registry.len());
        let mut prefix_slot = 0u64;
        for spec in registry.specs() {
            queues.push(match spec.admission {
                AdmissionPolicy::LongestPrefix => {
                    // The first prefix class keeps the instance seed
                    // exactly (the classic offline queue); later ones get
                    // distinct streams.
                    let q = OfflineQueue::new(prefix_policy, seed + prefix_slot);
                    prefix_slot += 1;
                    ClassQueue::prefix(q)
                }
                AdmissionPolicy::Fcfs | AdmissionPolicy::RateCapped { .. } => {
                    ClassQueue::Fcfs(FcfsQueue::new())
                }
            });
        }
        let n = registry.len();
        EngineState {
            registry,
            requests: HashMap::new(),
            queues,
            runs: (0..n).map(|_| RunSet::new()).collect(),
            preempted_by_class: (0..n).map(|_| VecDeque::new()).collect(),
            counts: PhaseCounts::default(),
            blocks: BlockManager::new(num_blocks, block_size),
            finished: Vec::new(),
            keep_finished: true,
            prefix_caching: true,
            anomalies: Vec::new(),
            recorder: Recorder::new(),
        }
    }

    // ------------------------------------------------------ class accessors
    //
    // The per-class tables are built with exactly `registry.len()` slots
    // and a registry is immutable for the instance's lifetime, so every
    // `class.index()` below is in bounds by construction; the accessors
    // carry the one justified annotation instead of sprinkling indexing
    // through the transition methods.

    // lint: allow(panic, reason=per-class tables are sized to the immutable registry)
    pub fn queue(&self, class: Class) -> &ClassQueue {
        &self.queues[class.index()]
    }

    // lint: allow(panic, reason=per-class tables are sized to the immutable registry)
    pub fn queue_mut(&mut self, class: Class) -> &mut ClassQueue {
        &mut self.queues[class.index()]
    }

    // lint: allow(panic, reason=per-class tables are sized to the immutable registry)
    pub fn running(&self, class: Class) -> &RunSet {
        &self.runs[class.index()]
    }

    // lint: allow(panic, reason=per-class tables are sized to the immutable registry)
    fn running_mut(&mut self, class: Class) -> &mut RunSet {
        &mut self.runs[class.index()]
    }

    // lint: allow(panic, reason=per-class tables are sized to the immutable registry)
    pub fn preempted(&self, class: Class) -> &VecDeque<RequestId> {
        &self.preempted_by_class[class.index()]
    }

    // lint: allow(panic, reason=per-class tables are sized to the immutable registry)
    fn preempted_mut(&mut self, class: Class) -> &mut VecDeque<RequestId> {
        &mut self.preempted_by_class[class.index()]
    }

    /// Waiting requests across every class queue.
    pub fn total_waiting(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Preempted requests across every class.
    pub fn total_preempted(&self) -> usize {
        self.preempted_by_class.iter().map(|p| p.len()).sum()
    }

    /// Any admitted-but-unfinished work (running, waiting, or preempted)?
    pub fn has_pending(&self) -> bool {
        self.num_running() > 0
            || self.queues.iter().any(|q| !q.is_empty())
            || self.preempted_by_class.iter().any(|p| !p.is_empty())
    }

    /// Any *interactive* (non-elastic, i.e. TTFT-SLO-bound) class with
    /// waiting, running, or preempted work? The replay loops use this as
    /// their completion criterion — elastic work is a backlog that never
    /// "completes". Preempted work counts: a mid-tier interactive class
    /// evicted by a higher tier is still in flight, and ending a run
    /// while it sits in the deque would silently drop it.
    pub fn interactive_pending(&self) -> bool {
        self.registry.ids().any(|c| {
            !self.registry.spec(c).elastic()
                && (!self.queue(c).is_empty()
                    || !self.running(c).is_empty()
                    || !self.preempted(c).is_empty())
        })
    }

    /// Admit an arriving request into its class queue, stamping the class
    /// spec's preemption priority.
    pub fn enqueue(&mut self, mut req: Request) {
        let idx = req.class.index();
        assert!(
            idx < self.queues.len(),
            "request {} names class {idx} outside the {}-class registry",
            req.id,
            self.queues.len()
        );
        req.priority = self.registry.spec(req.class).preempt_priority;
        self.recorder.record(
            EventKind::Admit,
            req.id,
            idx as u16,
            req.prompt_len as f64,
            req.output_len as f64,
            0.0,
        );
        // lint: allow(panic, reason=bounds asserted above)
        self.queues[idx].push(req);
    }

    /// By-id request lookup. The id must be live (running or preempted) —
    /// callers take ids straight out of the running sets / deques, so a
    /// miss is a caller bug, not a runtime condition.
    // lint: allow(panic, reason=by-contract accessor; ids come from the live sets)
    pub fn req(&self, id: RequestId) -> &Request {
        &self.requests[&id]
    }

    // lint: allow(panic, reason=by-contract accessor; ids come from the live sets)
    pub fn req_mut(&mut self, id: RequestId) -> &mut Request {
        self.requests.get_mut(&id).expect("request exists")
    }

    /// Total requests currently running (all classes).
    pub fn num_running(&self) -> usize {
        self.runs.iter().map(|r| r.len()).sum()
    }

    /// KV hash chain for a request's prompt (prefix-cache key). Empty
    /// when prefix caching is disabled (real backend).
    // lint: allow(alloc, reason=cold-path wrapper; the scheduler uses prompt_chain_into with a reused scratch)
    pub fn prompt_chain(&self, req: &Request) -> Vec<u64> {
        if !self.prefix_caching {
            return Vec::new();
        }
        chain_hashes(&req.prompt, self.blocks.block_size())
    }

    /// Scratch-buffer form of [`prompt_chain`](Self::prompt_chain): fills
    /// a caller-owned Vec (cleared first) so admission/resume passes reuse
    /// one buffer across every request instead of allocating per call.
    // lint: alloc-free
    pub fn prompt_chain_into(&self, req: &Request, out: &mut Vec<u64>) {
        if !self.prefix_caching {
            out.clear();
            return;
        }
        chain_hashes_into(&req.prompt, self.blocks.block_size(), out);
    }

    /// Move an admitted request (blocks already allocated, phase set to
    /// `Prefill`/`Decode`) into its class's running set.
    pub fn insert_running(&mut self, req: Request) {
        debug_assert!(
            matches!(req.phase, Phase::Prefill | Phase::Decode),
            "admitting {} in phase {:?}",
            req.id,
            req.phase
        );
        if req.phase == Phase::Prefill {
            self.recorder.record(
                EventKind::PrefillStart,
                req.id,
                req.class.index() as u16,
                req.prompt_len as f64,
                req.prefilled as f64,
                0.0,
            );
        }
        self.counts.add(req.class, req.phase);
        self.running_mut(req.class).push(req.id);
        self.requests.insert(req.id, req);
    }

    /// Advance a running request's prefill cursor by a scheduled chunk of
    /// `n` tokens. Returns true when this chunk completed the prompt (the
    /// same iteration emits the first output token).
    pub fn advance_prefill(&mut self, id: RequestId, n: usize) -> bool {
        let Some(req) = self.requests.get_mut(&id) else {
            // A scheduled id the table no longer holds is a finish/abort
            // race; record it and drop the chunk instead of panicking.
            // lint: allow(alloc, reason=cold anomaly ledger)
            self.anomalies.push(format!("prefill advance for unknown request {id}"));
            return false;
        };
        let (class, before) = (req.class, req.phase);
        req.advance_prefill(n);
        if req.phase != before {
            self.counts.sub(class, before);
            self.counts.add(class, req.phase);
        }
        req.prefill_done()
    }

    /// Record one generated token for a running request. Returns true
    /// when the request reached its output budget (caller should
    /// [`finish`](Self::finish) it).
    pub fn advance_decode(&mut self, id: RequestId) -> bool {
        let Some(req) = self.requests.get_mut(&id) else {
            // lint: allow(alloc, reason=cold anomaly ledger)
            self.anomalies.push(format!("decode advance for unknown request {id}"));
            return false;
        };
        let (class, before) = (req.class, req.phase);
        req.advance_decode();
        if req.phase != before {
            self.counts.sub(class, before);
            self.counts.add(class, req.phase);
        }
        req.is_finished()
    }

    /// Move a running request to `finished`, releasing its blocks.
    pub fn finish(&mut self, id: RequestId) {
        self.blocks.release(id);
        for set in &mut self.runs {
            if set.remove(id) {
                break;
            }
        }
        if let Some(mut r) = self.requests.remove(&id) {
            self.counts.sub(r.class, r.phase);
            self.recorder.record(
                EventKind::Finish,
                id,
                r.class.index() as u16,
                r.generated as f64,
                0.0,
                0.0,
            );
            r.phase = Phase::Finished;
            if self.keep_finished {
                self.finished.push(r);
            }
        }
    }

    /// Preempt one running request of `class` (the most recently
    /// admitted, vLLM-style LIFO so earlier requests keep progress),
    /// releasing its blocks. Returns the id, or None if the class has
    /// nothing running.
    ///
    /// A finish/abort race (the running set names an id the table no
    /// longer holds) is recorded in [`EngineState::anomalies`] and
    /// skipped instead of panicking — the scheduler retries with the next
    /// victim.
    pub fn preempt_last_of(&mut self, class: Class, discard: bool) -> Option<RequestId> {
        let id = self.running_mut(class).pop()?;
        self.blocks.release(id);
        let Some(mut req) = self.requests.remove(&id) else {
            // lint: allow(alloc, reason=cold anomaly ledger)
            self.anomalies.push(format!(
                "preempt of class {} popped request {id} that is missing from the \
                 table (finish/abort race)",
                class.index()
            ));
            return None;
        };
        self.counts.sub(req.class, req.phase);
        // Decision audit: the scheduler staged the preemptor's tier and
        // its residual budget before asking for a victim.
        let (aa, ab) = (self.recorder.audit_a, self.recorder.audit_b);
        self.recorder.record(
            EventKind::Preempt,
            id,
            req.class.index() as u16,
            aa,
            ab,
            if discard { 1.0 } else { 0.0 },
        );
        if discard {
            req.preempt_discard();
            // Discarded state returns to its class queue for rescheduling.
            // Its KV (and the whole LCP baseline's residency assumption)
            // is gone; without the reset its next pop would claim a
            // self-LCP.
            self.queue_mut(class).push(req);
            self.queue_mut(class).reset_prefix_context();
        } else {
            req.preempt_preserve();
            self.requests.insert(id, req);
            self.preempted_mut(class).push_back(id);
        }
        Some(id)
    }

    /// Classic spelling: preempt the newest running request of the
    /// default harvest class.
    pub fn preempt_last_offline(&mut self, discard: bool) -> Option<RequestId> {
        self.preempt_last_of(Class::OFFLINE, discard)
    }

    /// Preempt one running request from the lowest tier *strictly below*
    /// `tier` (ascending tier order; LIFO within the victim class).
    /// Preemption only flows down-tier — equal tiers never preempt each
    /// other through this path.
    pub fn preempt_lowest_below(&mut self, tier: u8, discard: bool) -> Option<RequestId> {
        let registry = Arc::clone(&self.registry);
        for &victim in registry.tier_order_asc() {
            if registry.spec(victim).tier >= tier {
                return None; // ascending order: nothing below remains
            }
            if !self.running(victim).is_empty() {
                if let Some(id) = self.preempt_last_of(victim, discard) {
                    return Some(id);
                }
            }
        }
        None
    }

    /// Re-admit the *front* (oldest-progress) preempted request of
    /// `class` — the caller already re-allocated its context. Returns the
    /// phase it resumes in, or `None` (with an anomaly recorded) when the
    /// deque is empty or the popped id has no table entry — both are
    /// finish/abort races the serving loop survives instead of panicking
    /// over.
    pub fn resume_front_of(&mut self, class: Class) -> Option<Phase> {
        let Some(id) = self.preempted_mut(class).pop_front() else {
            // lint: allow(alloc, reason=cold anomaly ledger)
            self.anomalies.push(format!(
                "resume for class {} with an empty preempted deque",
                class.index()
            ));
            return None;
        };
        let Some(req) = self.requests.get_mut(&id) else {
            // lint: allow(alloc, reason=cold anomaly ledger)
            self.anomalies.push(format!(
                "preempted request {id} is missing from the table (finish/abort race)"
            ));
            return None;
        };
        debug_assert_eq!(req.phase, Phase::Preempted);
        req.phase = if req.prefill_done() { Phase::Decode } else { Phase::Prefill };
        let phase = req.phase;
        let req_class = req.class;
        self.recorder.record(
            EventKind::Resume,
            id,
            req_class.index() as u16,
            if phase == Phase::Decode { 1.0 } else { 0.0 },
            0.0,
            0.0,
        );
        self.counts.add(req_class, phase);
        self.running_mut(class).push(id);
        Some(phase)
    }

    /// Classic spelling: resume the default harvest class's front
    /// preempted request.
    pub fn resume_front_preempted(&mut self) -> Option<Phase> {
        self.resume_front_of(Class::OFFLINE)
    }

    /// Abort every queued, running, and preempted request, releasing all
    /// KV blocks. Returns the ids that were running *or* preempted —
    /// backends hold per-request resources (e.g. sequence slots) for both,
    /// since preempted requests only get reconciled lazily on the next
    /// execute, which never comes after an abort. Used by the server when
    /// the execution backend fails: the engine must not keep re-scheduling
    /// a doomed batch.
    pub fn abort_all(&mut self) -> Vec<RequestId> {
        let torn_down: Vec<RequestId> = self
            .runs
            .iter()
            .flat_map(|set| set.iter())
            .chain(self.preempted_by_class.iter().flat_map(|p| p.iter().copied()))
            .collect();
        // Only running requests hold blocks (preemption already released
        // theirs); release() is a no-op for unallocated ids.
        for &id in &torn_down {
            self.blocks.release(id);
            let class = match self.requests.get(&id) {
                Some(r) => r.class.index() as u16,
                None => 0,
            };
            self.recorder.record(EventKind::Abort, id, class, 1.0, 0.0, 0.0);
        }
        for set in &mut self.runs {
            set.clear();
        }
        for p in &mut self.preempted_by_class {
            p.clear();
        }
        self.requests.clear();
        for q in &mut self.queues {
            q.clear();
        }
        self.counts = PhaseCounts::default();
        torn_down
    }

    /// Abort a single request wherever it lives — class queue, running
    /// set, or preempted deque — releasing any KV blocks it holds. The
    /// per-request spelling of [`abort_all`](Self::abort_all): the serving
    /// layer uses it to shed deadline-expired or client-abandoned work so
    /// a timed-out request frees its blocks and batch slot instead of
    /// decoding for a client that is gone.
    ///
    /// Returns `Some(true)` when the request was live (running or
    /// preempted — the backend holds per-request resources for both and
    /// must be told via `on_removed`), `Some(false)` when it was still
    /// waiting in a class queue (the backend never saw it), and `None`
    /// when the id is unknown (already finished, or a cancel/finish race —
    /// a runtime condition, not an error).
    pub fn abort_one(&mut self, id: RequestId) -> Option<bool> {
        if let Some(req) = self.requests.get(&id) {
            let (class, phase) = (req.class, req.phase);
            if phase == Phase::Preempted {
                // Preempted requests hold no blocks; drop the deque slot.
                let deque = self.preempted_mut(class);
                if let Some(pos) = deque.iter().position(|&x| x == id) {
                    deque.remove(pos);
                }
            } else {
                self.blocks.release(id);
                self.running_mut(class).remove(id);
                self.counts.sub(class, phase);
            }
            self.requests.remove(&id);
            self.recorder.record(EventKind::Abort, id, class.index() as u16, 1.0, 0.0, 0.0);
            return Some(true);
        }
        // Not live — it may still be waiting. Queued requests hold no
        // blocks and have no table entry; dropping the queue slot is the
        // whole teardown. Removal does not disturb the prefix queue's LCP
        // baseline (see `ClassQueue::remove`).
        for q in &mut self.queues {
            if let Some(r) = q.remove(id) {
                self.recorder.record(EventKind::Abort, id, r.class.index() as u16, 0.0, 0.0, 0.0);
                return Some(false);
            }
        }
        None
    }

    /// Sanity invariants used by tests: every running id has a request and
    /// an allocation; no id is in two places at once; queued requests are
    /// not also tracked in the table; the phase census matches the sets;
    /// no runtime anomalies were recorded.
    pub fn check_invariants(&self) -> Result<(), String> {
        if let Some(a) = self.anomalies.first() {
            return Err(format!("{} runtime anomalies, first: {a}", self.anomalies.len()));
        }
        let mut seen: HashSet<RequestId> = HashSet::new();
        let mut recount = PhaseCounts::default();
        for id in self.runs.iter().flat_map(|set| set.iter()) {
            if !seen.insert(id) {
                return Err(format!("{id} in two running sets"));
            }
            let r = self
                .requests
                .get(&id)
                .ok_or_else(|| format!("running {id} missing from table"))?;
            if !self.blocks.is_allocated(id) {
                return Err(format!("running {id} has no blocks"));
            }
            if matches!(r.phase, Phase::Waiting | Phase::Finished | Phase::Preempted) {
                return Err(format!("running {id} in phase {:?}", r.phase));
            }
            recount.add(r.class, r.phase);
        }
        for (ci, pre) in self.preempted_by_class.iter().enumerate() {
            for &id in pre {
                if !seen.insert(id) {
                    return Err(format!("{id} both running and preempted"));
                }
                if self.blocks.is_allocated(id) {
                    return Err(format!("preempted {id} still holds blocks"));
                }
                let r = self
                    .requests
                    .get(&id)
                    .ok_or_else(|| format!("preempted {id} missing from table"))?;
                if r.class.index() != ci {
                    return Err(format!("preempted {id} in the wrong class deque"));
                }
            }
        }
        if recount != self.counts {
            return Err(format!(
                "phase census drift: counted {recount:?}, tracked {:?}",
                self.counts
            ));
        }
        for q in &self.queues {
            for id in q.ids() {
                if self.requests.contains_key(&id) {
                    return Err(format!("queued {id} also in the request table"));
                }
                if !seen.insert(id) {
                    return Err(format!("queued {id} also running/preempted"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queues::OfflinePolicy;

    fn state() -> EngineState {
        EngineState::new(OfflinePolicy::Fcfs, 64, 16, 0)
    }

    fn running(state: &mut EngineState, id: RequestId, class: Class, prompt: usize, out: usize) {
        let mut r = Request::new(id, class, 0.0, prompt, out);
        r.phase = Phase::Decode;
        r.prefilled = prompt;
        state.blocks.allocate(id, r.context_len().max(1), &[]).unwrap();
        state.insert_running(r);
    }

    #[test]
    fn enqueue_routes_by_class() {
        let mut s = state();
        s.enqueue(Request::new(1, Class::ONLINE, 0.0, 4, 4));
        s.enqueue(Request::new(2, Class::OFFLINE, 0.0, 4, 4));
        assert_eq!(s.queue(Class::ONLINE).len(), 1);
        assert_eq!(s.queue(Class::OFFLINE).len(), 1);
        assert_eq!(s.total_waiting(), 2);
        s.check_invariants().unwrap();
    }

    #[test]
    fn enqueue_stamps_registry_priority() {
        let mut s = state();
        s.enqueue(Request::new(1, Class::ONLINE, 0.0, 4, 4));
        s.enqueue(Request::new(2, Class::OFFLINE, 0.0, 4, 4));
        assert_eq!(s.queue_mut(Class::ONLINE).peek_next().unwrap().priority, 100);
        assert_eq!(s.queue_mut(Class::OFFLINE).peek_next().unwrap().priority, 0);
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn enqueue_rejects_unregistered_class() {
        let mut s = state();
        s.enqueue(Request::new(1, Class(7), 0.0, 4, 4));
    }

    #[test]
    fn finish_releases_everything() {
        let mut s = state();
        running(&mut s, 1, Class::ONLINE, 16, 2);
        assert_eq!(s.counts.decode(Class::ONLINE), 1);
        s.check_invariants().unwrap();
        s.finish(1);
        assert_eq!(s.num_running(), 0);
        assert_eq!(s.counts, PhaseCounts::default());
        assert_eq!(s.blocks.used_blocks(), 0);
        assert_eq!(s.finished.len(), 1);
        assert_eq!(s.finished[0].phase, Phase::Finished);
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempt_preserve_moves_to_preempted() {
        let mut s = state();
        let mut r = Request::new(5, Class::OFFLINE, 0.0, 16, 4);
        r.phase = Phase::Decode;
        r.prefilled = 16;
        r.generated = 2;
        s.blocks.allocate(5, 18, &[]).unwrap();
        s.insert_running(r);
        let got = s.preempt_last_offline(false);
        assert_eq!(got, Some(5));
        assert_eq!(s.preempted(Class::OFFLINE), &vec![5]);
        assert_eq!(s.requests[&5].generated, 2, "state preserved");
        assert_eq!(s.blocks.used_blocks(), 0);
        assert_eq!(s.counts, PhaseCounts::default());
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempt_discard_requeues() {
        let mut s = state();
        let mut r = Request::new(5, Class::OFFLINE, 0.0, 16, 4);
        r.phase = Phase::Decode;
        r.prefilled = 16;
        r.generated = 2;
        s.blocks.allocate(5, 18, &[]).unwrap();
        s.insert_running(r);
        s.preempt_last_offline(true);
        assert!(s.preempted(Class::OFFLINE).is_empty());
        assert_eq!(s.queue(Class::OFFLINE).len(), 1, "discarded request requeued");
        assert!(!s.requests.contains_key(&5));
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempt_on_empty_is_none() {
        let mut s = state();
        assert_eq!(s.preempt_last_offline(false), None);
    }

    #[test]
    fn preempt_race_records_anomaly_instead_of_panicking() {
        let mut s = state();
        running(&mut s, 9, Class::OFFLINE, 16, 4);
        // Simulate a finish/abort race: the table entry vanishes while the
        // running set still names the id.
        s.requests.remove(&9);
        s.counts = PhaseCounts::default();
        assert_eq!(s.preempt_last_of(Class::OFFLINE, false), None, "no panic");
        assert_eq!(s.anomalies.len(), 1);
        assert!(s.anomalies[0].contains('9'), "anomaly names the id: {}", s.anomalies[0]);
        assert!(s.check_invariants().is_err(), "anomalies surface in invariant checks");
    }

    #[test]
    fn preempt_lowest_below_respects_tiers() {
        let mut s = state();
        running(&mut s, 1, Class::ONLINE, 16, 4);
        running(&mut s, 2, Class::OFFLINE, 16, 4);
        running(&mut s, 3, Class::OFFLINE, 16, 4);
        // Online sits at tier 1: the victim is the newest offline request.
        assert_eq!(s.preempt_lowest_below(1, false), Some(3));
        // Offline is the bottom tier: nothing below it.
        assert_eq!(s.preempt_lowest_below(0, false), None);
        assert!(s.running(Class::ONLINE).contains(1), "same tier never preempted");
        s.check_invariants().unwrap();
    }

    #[test]
    fn resume_front_restores_counts_and_order() {
        let mut s = state();
        for id in [5, 6] {
            let mut r = Request::new(id, Class::OFFLINE, 0.0, 16, 4);
            r.phase = Phase::Decode;
            r.prefilled = 16;
            s.blocks.allocate(id, 17, &[]).unwrap();
            s.insert_running(r);
        }
        s.preempt_last_offline(false); // 6
        s.preempt_last_offline(false); // 5
        assert_eq!(s.preempted(Class::OFFLINE), &vec![6, 5]);
        s.blocks.allocate(6, 17, &[]).unwrap();
        let phase = s.resume_front_preempted();
        assert_eq!(phase, Some(Phase::Decode));
        assert_eq!(*s.running(Class::OFFLINE), vec![6]);
        assert_eq!(s.preempted(Class::OFFLINE), &vec![5]);
        assert_eq!(s.counts.decode(Class::OFFLINE), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn advance_transitions_update_census() {
        let mut s = state();
        let mut r = Request::new(9, Class::ONLINE, 0.0, 8, 2);
        r.phase = Phase::Prefill;
        s.blocks.allocate(9, 8, &[]).unwrap();
        s.insert_running(r);
        assert_eq!(s.counts.prefill(Class::ONLINE), 1);
        assert!(!s.advance_prefill(9, 4), "prompt not done yet");
        assert!(s.advance_prefill(9, 4), "prompt completed");
        assert_eq!(s.counts.prefill(Class::ONLINE), 0);
        assert_eq!(s.counts.decode(Class::ONLINE), 1);
        assert!(!s.advance_decode(9));
        assert!(s.advance_decode(9), "output budget reached");
        s.finish(9);
        assert_eq!(s.counts, PhaseCounts::default());
        s.check_invariants().unwrap();
    }

    #[test]
    fn abort_all_clears_every_set() {
        let mut s = state();
        running(&mut s, 1, Class::ONLINE, 16, 4);
        running(&mut s, 2, Class::OFFLINE, 16, 4);
        s.preempt_last_offline(false);
        s.enqueue(Request::new(3, Class::ONLINE, 0.0, 4, 4));
        s.enqueue(Request::new(4, Class::OFFLINE, 0.0, 4, 4));
        let aborted = s.abort_all();
        assert_eq!(aborted, vec![1, 2], "running and preempted ids both reported");
        assert_eq!(s.num_running(), 0);
        assert_eq!(s.total_preempted(), 0);
        assert_eq!(s.total_waiting(), 0);
        assert_eq!(s.blocks.used_blocks(), 0);
        assert_eq!(s.counts, PhaseCounts::default());
        s.check_invariants().unwrap();
    }

    #[test]
    fn abort_one_tears_down_each_lifecycle_stage() {
        let mut s = state();
        running(&mut s, 1, Class::ONLINE, 16, 4);
        running(&mut s, 2, Class::OFFLINE, 16, 4);
        s.preempt_last_offline(false);
        s.enqueue(Request::new(3, Class::ONLINE, 0.0, 4, 4));

        // Running: blocks released, census decremented, table cleared.
        assert_eq!(s.abort_one(1), Some(true));
        assert_eq!(s.num_running(), 0);
        assert_eq!(s.blocks.used_blocks(), 0);
        assert!(!s.requests.contains_key(&1));
        s.check_invariants().unwrap();

        // Preempted: deque slot and table entry dropped (no blocks held).
        assert_eq!(s.abort_one(2), Some(true));
        assert_eq!(s.total_preempted(), 0);
        assert!(!s.requests.contains_key(&2));
        s.check_invariants().unwrap();

        // Waiting: queue slot dropped; the backend never saw it.
        assert_eq!(s.abort_one(3), Some(false));
        assert_eq!(s.total_waiting(), 0);
        s.check_invariants().unwrap();

        // Unknown id: a cancel/finish race, not an error.
        assert_eq!(s.abort_one(99), None);
        assert_eq!(s.counts, PhaseCounts::default());
        s.check_invariants().unwrap();
    }

    #[test]
    fn abort_one_removes_from_prefix_queue() {
        let mut s = EngineState::new(OfflinePolicy::Psm, 64, 16, 0);
        s.enqueue(Request::new(1, Class::OFFLINE, 0.0, 4, 4).with_prompt(vec![1, 2, 3, 4]));
        s.enqueue(Request::new(2, Class::OFFLINE, 0.0, 4, 4).with_prompt(vec![1, 2, 3, 5]));
        assert_eq!(s.abort_one(1), Some(false));
        assert_eq!(s.queue(Class::OFFLINE).len(), 1);
        assert_eq!(s.abort_one(1), None, "second abort is a no-op");
        s.check_invariants().unwrap();
    }

    #[test]
    fn recorder_captures_lifecycle_events_with_audit() {
        let mut s = state();
        s.recorder.now_ms = 5.0;
        s.enqueue(Request::new(1, Class::OFFLINE, 0.0, 16, 4));
        running(&mut s, 2, Class::OFFLINE, 16, 4);
        // The scheduler stages its decision inputs before preempting.
        s.recorder.audit_a = 1.0;
        s.recorder.audit_b = 42.0;
        s.preempt_last_offline(false);
        s.blocks.allocate(2, 17, &[]).unwrap();
        s.resume_front_preempted();
        s.advance_decode(2);
        s.advance_decode(2);
        s.finish(2);
        s.abort_one(1);
        let mut events = Vec::new();
        s.recorder.for_each(|e| events.push(*e));
        let kinds: Vec<EventKind> = events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::Admit,
                EventKind::Preempt,
                EventKind::Resume,
                EventKind::Finish,
                EventKind::Abort,
            ]
        );
        let p = events[1];
        assert_eq!(p.id, 2);
        assert_eq!(p.class, 1);
        assert_eq!(p.a, 1.0, "audit: preemptor tier");
        assert_eq!(p.b, 42.0, "audit: residual budget");
        assert_eq!(p.c, 0.0, "preserve, not discard");
        assert_eq!(p.t_ms, 5.0, "virtual-clock stamp");
        assert_eq!(events[3].a, 2.0, "finish carries generated tokens");
        assert_eq!(events[4].a, 0.0, "queued abort: backend never saw it");
        s.check_invariants().unwrap();
    }

    #[test]
    fn invariants_reject_queue_table_overlap() {
        let mut s = state();
        running(&mut s, 7, Class::ONLINE, 8, 2);
        // Simulate a duplication bug: the running request also re-enters
        // the queue.
        s.enqueue(Request::new(7, Class::ONLINE, 0.0, 8, 2));
        assert!(s.check_invariants().is_err());
    }

    #[test]
    fn invariants_reject_census_drift() {
        let mut s = state();
        running(&mut s, 7, Class::ONLINE, 8, 2);
        s.counts = PhaseCounts::default(); // simulate drift
        assert!(s.check_invariants().is_err());
    }

    #[test]
    fn interactive_pending_counts_preempted_work() {
        use crate::coordinator::classes::{AdmissionPolicy, ClassRegistry, ClassSpec};
        // chat (interactive, top) above completion (interactive, mid):
        // a preempted completion request is still in-flight interactive
        // work — the replay loops must not end the run around it.
        let mk = |name: &str, tier: u8| ClassSpec {
            name: name.into(),
            tier,
            ttft_slo_ms: Some(500.0),
            tbt_slo_ms: None,
            latency_budget: Some(1.0),
            preempt_priority: tier,
            admission: AdmissionPolicy::Fcfs,
            starvation_age_s: None,
        };
        let reg = Arc::new(ClassRegistry::new(vec![mk("chat", 2), mk("completion", 1)]).unwrap());
        let mut s = EngineState::with_registry(reg, OfflinePolicy::Fcfs, 64, 16, 0);
        assert!(!s.interactive_pending());
        running(&mut s, 1, Class(1), 16, 4);
        assert!(s.interactive_pending());
        s.preempt_lowest_below(2, false).unwrap();
        assert!(s.running(Class(1)).is_empty());
        assert!(
            s.interactive_pending(),
            "preempted interactive work must keep the run alive"
        );
        s.check_invariants().unwrap();
    }

    #[test]
    fn four_class_registry_isolates_queues_and_tiers() {
        use crate::coordinator::classes::{AdmissionPolicy, ClassRegistry, ClassSpec};
        let spec = |name: &str, tier: u8, admission: AdmissionPolicy| ClassSpec {
            name: name.into(),
            tier,
            ttft_slo_ms: Some(500.0),
            tbt_slo_ms: None,
            latency_budget: Some(1.0),
            preempt_priority: tier * 10,
            admission,
            starvation_age_s: None,
        };
        let reg = Arc::new(
            ClassRegistry::new(vec![
                spec("chat", 3, AdmissionPolicy::Fcfs),
                spec("completion", 2, AdmissionPolicy::Fcfs),
                spec("summarize", 1, AdmissionPolicy::LongestPrefix),
                spec("batch", 0, AdmissionPolicy::RateCapped { qps: 1.0 }),
            ])
            .unwrap(),
        );
        let mut s = EngineState::with_registry(reg, OfflinePolicy::Psm, 256, 16, 0);
        for i in 0..4u16 {
            s.enqueue(Request::new(i as u64, Class(i), 0.0, 8, 2));
        }
        for i in 0..4u16 {
            assert_eq!(s.queue(Class(i)).len(), 1, "class {i}");
        }
        assert_eq!(s.queue_mut(Class(0)).peek_next().unwrap().priority, 30);
        // Tier-2 work can only claim victims from tiers 0/1.
        running(&mut s, 10, Class(2), 16, 2);
        running(&mut s, 11, Class(3), 16, 2);
        assert_eq!(s.preempt_lowest_below(2, false), Some(11), "lowest tier first");
        assert_eq!(s.preempt_lowest_below(2, false), Some(10));
        assert_eq!(s.preempt_lowest_below(2, false), None);
        s.check_invariants().unwrap();
    }
}
