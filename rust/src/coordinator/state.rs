//! Shared engine state the scheduler operates on: queues, running sets,
//! preempted set, the block manager, and the request table.

use super::block_manager::{chain_hashes, BlockManager};
use super::queues::{OfflinePolicy, OfflineQueue, OnlineQueue};
use super::request::{Class, Phase, Request, RequestId};
use std::collections::HashMap;

/// All mutable serving state of one engine instance.
pub struct EngineState {
    /// Every request known to the instance (waiting, running, preempted).
    /// Finished requests are moved to `finished`.
    pub requests: HashMap<RequestId, Request>,
    pub online_queue: OnlineQueue,
    pub offline_queue: OfflineQueue,
    /// Running online requests in admission order.
    pub running_online: Vec<RequestId>,
    /// Running offline requests — kept in their scheduling (DFS) order, per
    /// Alg. 3 ("running requests keep their original DFS order").
    pub running_offline: Vec<RequestId>,
    /// Offline requests preempted with preserved state, newest last.
    /// Re-admitted (LIFO) before fresh queue requests.
    pub preempted_offline: Vec<RequestId>,
    pub blocks: BlockManager,
    pub finished: Vec<Request>,
    /// Keep finished request bodies (tests want them; long sims can turn
    /// this off to bound memory).
    pub keep_finished: bool,
    /// Honor prefix-cache hits as skipped prefill work. True for the
    /// simulation backend; the real PJRT backend keeps a *per-slot* KV
    /// layout where cross-request row reuse is physically impossible, so
    /// it runs with this off (block sharing then degrades to plain
    /// accounting with empty hash chains).
    pub prefix_caching: bool,
}

impl EngineState {
    pub fn new(policy: OfflinePolicy, num_blocks: usize, block_size: usize, seed: u64) -> Self {
        EngineState {
            requests: HashMap::new(),
            online_queue: OnlineQueue::new(),
            offline_queue: OfflineQueue::new(policy, seed),
            running_online: Vec::new(),
            running_offline: Vec::new(),
            preempted_offline: Vec::new(),
            blocks: BlockManager::new(num_blocks, block_size),
            finished: Vec::new(),
            keep_finished: true,
            prefix_caching: true,
        }
    }

    /// Admit an arriving request into its class queue.
    pub fn enqueue(&mut self, req: Request) {
        match req.class {
            Class::Online => self.online_queue.push(req),
            Class::Offline => self.offline_queue.push(req),
        }
    }

    pub fn req(&self, id: RequestId) -> &Request {
        &self.requests[&id]
    }

    pub fn req_mut(&mut self, id: RequestId) -> &mut Request {
        self.requests.get_mut(&id).expect("request exists")
    }

    /// Total requests currently running (both classes).
    pub fn num_running(&self) -> usize {
        self.running_online.len() + self.running_offline.len()
    }

    /// KV hash chain for a request's prompt (prefix-cache key). Empty
    /// when prefix caching is disabled (real backend).
    pub fn prompt_chain(&self, req: &Request) -> Vec<u64> {
        if !self.prefix_caching {
            return Vec::new();
        }
        chain_hashes(&req.prompt, self.blocks.block_size())
    }

    /// Move a running request to `finished`, releasing its blocks.
    pub fn finish(&mut self, id: RequestId) {
        self.blocks.release(id);
        self.running_online.retain(|&x| x != id);
        self.running_offline.retain(|&x| x != id);
        if let Some(mut r) = self.requests.remove(&id) {
            r.phase = Phase::Finished;
            if self.keep_finished {
                self.finished.push(r);
            }
        }
    }

    /// Preempt one running offline request (the most recently admitted,
    /// vLLM-style LIFO so earlier requests keep progress), releasing its
    /// blocks. Returns the id, or None if nothing can be preempted.
    pub fn preempt_last_offline(&mut self, discard: bool) -> Option<RequestId> {
        let id = self.running_offline.pop()?;
        self.blocks.release(id);
        let req = self.requests.get_mut(&id).expect("running request exists");
        if discard {
            req.preempt_discard();
            // discarded state returns to the offline queue for rescheduling
            let req = self.requests.remove(&id).unwrap();
            self.offline_queue.push(req);
        } else {
            req.preempt_preserve();
            self.preempted_offline.push(id);
        }
        Some(id)
    }

    /// Sanity invariant used by tests: every running id has a request and
    /// an allocation; no id is in two places at once.
    pub fn check_invariants(&self) -> Result<(), String> {
        for &id in self.running_online.iter().chain(&self.running_offline) {
            let r = self
                .requests
                .get(&id)
                .ok_or_else(|| format!("running {id} missing from table"))?;
            if !self.blocks.is_allocated(id) {
                return Err(format!("running {id} has no blocks"));
            }
            if matches!(r.phase, Phase::Waiting | Phase::Finished | Phase::Preempted) {
                return Err(format!("running {id} in phase {:?}", r.phase));
            }
        }
        for &id in &self.preempted_offline {
            if self.blocks.is_allocated(id) {
                return Err(format!("preempted {id} still holds blocks"));
            }
            if self.running_offline.contains(&id) {
                return Err(format!("{id} both running and preempted"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queues::OfflinePolicy;

    fn state() -> EngineState {
        EngineState::new(OfflinePolicy::Fcfs, 64, 16, 0)
    }

    #[test]
    fn enqueue_routes_by_class() {
        let mut s = state();
        s.enqueue(Request::new(1, Class::Online, 0.0, 4, 4));
        s.enqueue(Request::new(2, Class::Offline, 0.0, 4, 4));
        assert_eq!(s.online_queue.len(), 1);
        assert_eq!(s.offline_queue.len(), 1);
    }

    #[test]
    fn finish_releases_everything() {
        let mut s = state();
        let mut r = Request::new(1, Class::Online, 0.0, 16, 2);
        r.phase = Phase::Decode;
        r.prefilled = 16;
        s.blocks.allocate(1, 16, &[]).unwrap();
        s.requests.insert(1, r);
        s.running_online.push(1);
        s.check_invariants().unwrap();
        s.finish(1);
        assert_eq!(s.num_running(), 0);
        assert_eq!(s.blocks.used_blocks(), 0);
        assert_eq!(s.finished.len(), 1);
        assert_eq!(s.finished[0].phase, Phase::Finished);
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempt_preserve_moves_to_preempted() {
        let mut s = state();
        let mut r = Request::new(5, Class::Offline, 0.0, 16, 4);
        r.phase = Phase::Decode;
        r.prefilled = 16;
        r.generated = 2;
        s.blocks.allocate(5, 18, &[]).unwrap();
        s.requests.insert(5, r);
        s.running_offline.push(5);
        let got = s.preempt_last_offline(false);
        assert_eq!(got, Some(5));
        assert_eq!(s.preempted_offline, vec![5]);
        assert_eq!(s.requests[&5].generated, 2, "state preserved");
        assert_eq!(s.blocks.used_blocks(), 0);
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempt_discard_requeues() {
        let mut s = state();
        let mut r = Request::new(5, Class::Offline, 0.0, 16, 4);
        r.phase = Phase::Decode;
        r.prefilled = 16;
        r.generated = 2;
        s.blocks.allocate(5, 18, &[]).unwrap();
        s.requests.insert(5, r);
        s.running_offline.push(5);
        s.preempt_last_offline(true);
        assert!(s.preempted_offline.is_empty());
        assert_eq!(s.offline_queue.len(), 1, "discarded request requeued");
        assert!(!s.requests.contains_key(&5));
    }

    #[test]
    fn preempt_on_empty_is_none() {
        let mut s = state();
        assert_eq!(s.preempt_last_offline(false), None);
    }
}
