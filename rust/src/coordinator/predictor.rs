//! The HyGen latency predictor (§4.2, Appendix B).
//!
//! A linear-regression model over batch-composition features
//! `[1, S_p, S_d, S_p², S_d², N_p, N_d]` predicting batch execution time in
//! milliseconds. Fit by normal equations with a tiny ridge term (7×7
//! Gaussian elimination — the paper reports ~15 ms training for 80k samples
//! and ~18 µs per prediction; ours is comfortably under both, see
//! `rust/benches/predictor.rs`).
//!
//! Besides `predict`, the scheduler needs two derived queries (Alg. 1):
//! * [`LatencyPredictor::decode_cost`] — marginal latency of adding one
//!   decode request to a partial batch, and
//! * [`LatencyPredictor::max_prefill_tokens`] — the largest prefill chunk
//!   that fits the remaining latency/chunk/memory budget (the paper's
//!   `PREDICTOR.get_max_tokens`).

use super::batch::{Features, NUM_FEATURES};
use crate::util::json::Json;
use crate::util::stats::mape;

#[derive(Debug, Clone, PartialEq)]
pub struct LatencyPredictor {
    pub coef: [f64; NUM_FEATURES],
}

/// One training sample: observed execution time of a batch composition.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub features: Features,
    pub latency_ms: f64,
}

impl LatencyPredictor {
    /// A conservative placeholder used before profiling data exists; the
    /// coefficients are roughly an A100-class decode/prefill cost so early
    /// scheduling decisions are sane rather than degenerate.
    pub fn default_seed() -> LatencyPredictor {
        LatencyPredictor {
            //      bias    sp       sd      sp^2    sd^2    np     nd
            coef: [4.0, 0.035, 0.02, 1.2e-5, 0.0, 0.4, 0.05],
        }
    }

    /// Least-squares fit via normal equations `(XᵀX + λI) w = Xᵀy`.
    ///
    /// λ is a tiny ridge (1e-6, scaled by the diagonal) that keeps the
    /// system well-posed when a feature is constant across samples (e.g.
    /// profiling runs with no decode requests).
    pub fn fit(samples: &[Sample]) -> LatencyPredictor {
        assert!(!samples.is_empty(), "cannot fit on zero samples");
        let n = NUM_FEATURES;
        let mut xtx = [[0.0f64; NUM_FEATURES]; NUM_FEATURES];
        let mut xty = [0.0f64; NUM_FEATURES];
        for s in samples {
            let x = s.features.design();
            for i in 0..n {
                xty[i] += x[i] * s.latency_ms;
                for j in 0..n {
                    xtx[i][j] += x[i] * x[j];
                }
            }
        }
        for (i, row) in xtx.iter_mut().enumerate() {
            row[i] += 1e-6 * (row[i].abs() + 1.0);
        }
        let coef = solve_7x7(xtx, xty);
        LatencyPredictor { coef }
    }

    /// Predicted execution time (ms) of a batch with the given features.
    /// Clamped at 0 — a regression extrapolation must never go negative.
    #[inline]
    pub fn predict(&self, f: &Features) -> f64 {
        let x = f.design();
        let mut y = 0.0;
        for i in 0..NUM_FEATURES {
            y += self.coef[i] * x[i];
        }
        y.max(0.0)
    }

    /// Marginal cost (ms) of adding one decode request to `batch`.
    /// This is the `t_req` of Alg. 1 line 7.
    #[inline]
    pub fn decode_cost(&self, batch: &Features) -> f64 {
        (self.predict(&batch.with_decode()) - self.predict(batch)).max(0.0)
    }

    /// The paper's `get_max_tokens`: largest prefill chunk `l` such that
    /// adding `(l tokens, 1 prefill request)` to `batch` keeps the marginal
    /// latency within `budget_ms`, `l <= chunk_remaining` (token budget)
    /// and `l <= mem_tokens` (KV blocks) and `l <= want` (prompt left).
    ///
    /// Returns `(l, t_req)`; `l == 0` means "does not fit".
    ///
    /// The marginal cost in `l` is quadratic:
    /// `cost(l) = c_sp·l + c_sp2·((S_p+l)² − S_p²) + c_np`,
    /// monotone for the physically meaningful coefficient signs; we solve
    /// in closed form and verify by evaluation so pathological fitted
    /// coefficients degrade gracefully instead of violating the budget.
    pub fn max_prefill_tokens(
        &self,
        batch: &Features,
        budget_ms: f64,
        chunk_remaining: usize,
        mem_tokens: usize,
        want: usize,
    ) -> (usize, f64) {
        let cap = chunk_remaining.min(mem_tokens).min(want);
        if cap == 0 || budget_ms <= 0.0 {
            return (0, 0.0);
        }
        let cost = |l: usize| -> f64 {
            (self.predict(&batch.with_prefill(l)) - self.predict(batch)).max(0.0)
        };
        // Fast path: everything fits.
        let full = cost(cap);
        if full <= budget_ms {
            return (cap, full);
        }
        // Closed-form candidate from the quadratic, then verify/adjust.
        let c_sp = self.coef[1];
        let c_sp2 = self.coef[3];
        let c_np = self.coef[5];
        let rem = budget_ms - c_np;
        let mut l = if rem <= 0.0 {
            0
        } else if c_sp2.abs() > 1e-18 {
            // c_sp2·l² + (c_sp + 2·c_sp2·S_p)·l − rem = 0
            let a = c_sp2;
            let b = c_sp + 2.0 * c_sp2 * batch.sp;
            let disc = b * b + 4.0 * a * rem;
            if disc < 0.0 || a <= 0.0 {
                cap
            } else {
                (((-b + disc.sqrt()) / (2.0 * a)).floor().max(0.0) as usize).min(cap)
            }
        } else if c_sp > 1e-18 {
            ((rem / c_sp).floor().max(0.0) as usize).min(cap)
        } else {
            cap
        };
        // Verification loop: closed form can be off by one (floor) or the
        // coefficients non-physical; walk down until the budget holds.
        while l > 0 && cost(l) > budget_ms {
            l -= 1;
        }
        if l == 0 {
            (0, 0.0)
        } else {
            (l, cost(l))
        }
    }

    /// Mean absolute percentage error on a held-out set (Fig. 5 metric).
    pub fn evaluate_mape(&self, samples: &[Sample]) -> f64 {
        let pred: Vec<f64> = samples.iter().map(|s| self.predict(&s.features)).collect();
        let act: Vec<f64> = samples.iter().map(|s| s.latency_ms).collect();
        mape(&pred, &act)
    }

    /// Return a copy with coefficients perturbed by `rel` relative noise —
    /// the degraded predictors of the Fig. 16 robustness ablation.
    pub fn degraded(&self, rel: f64, rng: &mut crate::util::rng::Rng) -> LatencyPredictor {
        let mut coef = self.coef;
        for c in coef.iter_mut() {
            *c *= 1.0 + rel * rng.normal();
        }
        LatencyPredictor { coef }
    }

    // ---- persistence (predictor checkpoints survive across runs) ----

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "coef",
            Json::Arr(self.coef.iter().map(|c| Json::Num(*c)).collect()),
        )])
    }

    pub fn from_json(j: &Json) -> Option<LatencyPredictor> {
        let arr = j.get("coef").as_arr()?;
        if arr.len() != NUM_FEATURES {
            return None;
        }
        let mut coef = [0.0; NUM_FEATURES];
        for (i, v) in arr.iter().enumerate() {
            coef[i] = v.as_f64()?;
        }
        Some(LatencyPredictor { coef })
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
    }

    pub fn load(path: &str) -> anyhow::Result<LatencyPredictor> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j).ok_or_else(|| anyhow::anyhow!("bad predictor checkpoint"))
    }
}

/// Solve `A x = b` for a 7×7 system by Gaussian elimination with partial
/// pivoting. A is symmetric positive definite here (XᵀX + ridge), so this
/// is numerically comfortable.
fn solve_7x7(mut a: [[f64; NUM_FEATURES]; NUM_FEATURES], mut b: [f64; NUM_FEATURES]) -> [f64; NUM_FEATURES] {
    let n = NUM_FEATURES;
    for col in 0..n {
        // pivot
        let mut piv = col;
        for row in col + 1..n {
            if a[row][col].abs() > a[piv][col].abs() {
                piv = row;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let diag = a[col][col];
        if diag.abs() < 1e-30 {
            continue; // degenerate direction: leave coefficient at 0
        }
        for row in col + 1..n {
            let factor = a[row][col] / diag;
            if factor == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= factor * a[col][k];
            }
            b[row] -= factor * b[col];
        }
    }
    let mut x = [0.0; NUM_FEATURES];
    for col in (0..n).rev() {
        let mut sum = b[col];
        for k in col + 1..n {
            sum -= a[col][k] * x[k];
        }
        x[col] = if a[col][col].abs() < 1e-30 { 0.0 } else { sum / a[col][col] };
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Ground-truth synthetic model for fit tests.
    fn synth(f: &Features) -> f64 {
        3.0 + 0.04 * f.sp + 0.015 * f.sd + 2.0e-5 * f.sp * f.sp + 0.3 * f.np + 0.08 * f.nd
    }

    fn synth_samples(n: usize, seed: u64, noise: f64) -> Vec<Sample> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut f = Features::default();
                let np = rng.range(0, 4);
                for _ in 0..np {
                    f.add_prefill(rng.range_usize(16, 1024));
                }
                for _ in 0..rng.range(0, 64) {
                    f.add_decode();
                }
                let y = synth(&f) * (1.0 + noise * rng.normal());
                Sample { features: f, latency_ms: y.max(0.1) }
            })
            .collect()
    }

    #[test]
    fn fit_recovers_synthetic_model() {
        let train = synth_samples(4000, 1, 0.0);
        let p = LatencyPredictor::fit(&train);
        let test = synth_samples(500, 2, 0.0);
        let err = p.evaluate_mape(&test);
        assert!(err < 0.5, "noise-free MAPE should be ~0, got {err}%");
    }

    #[test]
    fn fit_with_noise_stays_accurate() {
        let train = synth_samples(8000, 3, 0.02);
        let p = LatencyPredictor::fit(&train);
        let test = synth_samples(1000, 4, 0.0);
        let err = p.evaluate_mape(&test);
        assert!(err < 3.0, "2% noise -> low single-digit MAPE, got {err}%");
    }

    #[test]
    fn predict_never_negative() {
        let p = LatencyPredictor { coef: [-100.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0] };
        assert_eq!(p.predict(&Features::default()), 0.0);
    }

    #[test]
    fn decode_cost_is_marginal() {
        let p = LatencyPredictor::default_seed();
        let f = Features::default().with_prefill(256);
        let cost = p.decode_cost(&f);
        let direct = p.predict(&f.with_decode()) - p.predict(&f);
        assert!((cost - direct).abs() < 1e-12);
        assert!(cost > 0.0);
    }

    #[test]
    fn max_prefill_respects_budget_exactly() {
        let train = synth_samples(4000, 5, 0.0);
        let p = LatencyPredictor::fit(&train);
        let batch = Features::default().with_decode().with_decode();
        for budget in [0.5, 2.0, 10.0, 50.0] {
            let (l, t) = p.max_prefill_tokens(&batch, budget, 2048, 100_000, 100_000);
            assert!(t <= budget + 1e-9, "t={t} > budget={budget}");
            if l < 2048 {
                // maximality: one more token must exceed the budget
                let over = p.predict(&batch.with_prefill(l + 1)) - p.predict(&batch);
                assert!(over > budget, "l={l} not maximal for budget={budget}");
            }
        }
    }

    #[test]
    fn max_prefill_respects_caps() {
        let p = LatencyPredictor::default_seed();
        let batch = Features::default();
        let (l, _) = p.max_prefill_tokens(&batch, 1e9, 64, 100_000, 100_000);
        assert_eq!(l, 64, "chunk budget caps l");
        let (l, _) = p.max_prefill_tokens(&batch, 1e9, 2048, 10, 100_000);
        assert_eq!(l, 10, "memory caps l");
        let (l, _) = p.max_prefill_tokens(&batch, 1e9, 2048, 100_000, 7);
        assert_eq!(l, 7, "prompt remaining caps l");
        let (l, t) = p.max_prefill_tokens(&batch, 0.0, 2048, 100_000, 100_000);
        assert_eq!((l, t), (0, 0.0), "zero budget fits nothing");
    }

    #[test]
    fn zero_fit_cost_zero_budget_edge() {
        let p = LatencyPredictor::default_seed();
        // budget smaller than the per-request constant c_np: nothing fits
        let (l, _) = p.max_prefill_tokens(&Features::default(), 0.3, 512, 1000, 1000);
        assert_eq!(l, 0);
    }

    #[test]
    fn json_roundtrip() {
        let p = LatencyPredictor::default_seed();
        let q = LatencyPredictor::from_json(&p.to_json()).unwrap();
        assert_eq!(p, q);
        assert!(LatencyPredictor::from_json(&Json::Null).is_none());
    }

    #[test]
    fn degraded_increases_error() {
        let train = synth_samples(4000, 6, 0.0);
        let p = LatencyPredictor::fit(&train);
        let test = synth_samples(500, 7, 0.0);
        let base = p.evaluate_mape(&test);
        let mut rng = Rng::new(8);
        let bad = p.degraded(0.2, &mut rng);
        assert!(bad.evaluate_mape(&test) > base + 1.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wallclock timing is meaningless under the interpreter
    fn training_is_fast_enough() {
        // Paper: ~15 ms for 80k samples on CPU. Sanity-check the same order.
        let train = synth_samples(80_000, 9, 0.01);
        let t0 = std::time::Instant::now();
        let _p = LatencyPredictor::fit(&train);
        let dt = t0.elapsed();
        assert!(dt.as_millis() < 500, "training took {dt:?}");
    }
}
