//! Batch composition: what one engine iteration executes, plus the
//! feature vector the latency predictor consumes (Eq. 1 of the paper).

use super::request::{Class, RequestId};

/// One request's share of an iteration batch.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchEntry {
    pub id: RequestId,
    pub class: Class,
    /// New tokens processed this iteration: 1 for a decode step, the chunk
    /// size for a prefill chunk.
    pub n_tokens: usize,
    /// Whether this entry is a prefill chunk (else a decode step).
    pub is_prefill: bool,
    /// Predictor's marginal-latency estimate for this entry (ms).
    pub predicted_ms: f64,
}

/// A scheduled iteration batch.
#[derive(Debug, Clone, Default)]
pub struct Batch {
    pub entries: Vec<BatchEntry>,
}

impl Batch {
    pub fn new() -> Batch {
        Batch::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn push(&mut self, e: BatchEntry) {
        self.entries.push(e);
    }

    /// Empty the batch, keeping its capacity (the engine reuses one batch
    /// across iterations — the allocation-free-loop contract).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Total new tokens in the batch (the Sarathi "token budget" measure).
    pub fn total_tokens(&self) -> usize {
        self.entries.iter().map(|e| e.n_tokens).sum()
    }

    pub fn features(&self) -> Features {
        let mut f = Features::default();
        for e in &self.entries {
            if e.is_prefill {
                f.add_prefill(e.n_tokens);
            } else {
                f.add_decode();
            }
        }
        f
    }

    pub fn num_online(&self) -> usize {
        self.entries.iter().filter(|e| e.class.is_online()).count()
    }

    pub fn num_offline(&self) -> usize {
        self.entries.len() - self.num_online()
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.entries.iter().any(|e| e.id == id)
    }
}

/// Batch-composition features from the paper's latency model:
///
/// `T_batch = f(S_p, S_d, S_p^2, S_d^2, N_p, N_d)`   (Eq. 1)
///
/// where `S_p`/`S_d` are total prefill/decode tokens in the batch and
/// `N_p`/`N_d` the request counts per phase. The quadratic terms capture
/// the attention non-linearity of the prefill phase.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Features {
    pub sp: f64,
    pub sd: f64,
    pub np: f64,
    pub nd: f64,
}

/// Number of regression features (bias, sp, sd, sp^2, sd^2, np, nd).
pub const NUM_FEATURES: usize = 7;

impl Features {
    pub fn add_prefill(&mut self, tokens: usize) {
        self.sp += tokens as f64;
        self.np += 1.0;
    }

    pub fn add_decode(&mut self) {
        self.sd += 1.0;
        self.nd += 1.0;
    }

    /// Copy with one more prefill chunk of `tokens`.
    pub fn with_prefill(mut self, tokens: usize) -> Features {
        self.add_prefill(tokens);
        self
    }

    /// Copy with one more decode step.
    pub fn with_decode(mut self) -> Features {
        self.add_decode();
        self
    }

    /// The regression design vector `[1, S_p, S_d, S_p^2, S_d^2, N_p, N_d]`.
    pub fn design(&self) -> [f64; NUM_FEATURES] {
        [1.0, self.sp, self.sd, self.sp * self.sp, self.sd * self.sd, self.np, self.nd]
    }

    pub fn total_tokens(&self) -> f64 {
        self.sp + self.sd
    }

    pub fn is_empty(&self) -> bool {
        self.np == 0.0 && self.nd == 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: RequestId, class: Class, n: usize, prefill: bool) -> BatchEntry {
        BatchEntry { id, class, n_tokens: n, is_prefill: prefill, predicted_ms: 0.0 }
    }

    #[test]
    fn features_accumulate() {
        let mut b = Batch::new();
        b.push(entry(1, Class::ONLINE, 128, true));
        b.push(entry(2, Class::ONLINE, 1, false));
        b.push(entry(3, Class::OFFLINE, 1, false));
        b.push(entry(4, Class::OFFLINE, 64, true));
        let f = b.features();
        assert_eq!(f.sp, 192.0);
        assert_eq!(f.sd, 2.0);
        assert_eq!(f.np, 2.0);
        assert_eq!(f.nd, 2.0);
        assert_eq!(b.total_tokens(), 194);
        assert_eq!(b.num_online(), 2);
        assert_eq!(b.num_offline(), 2);
    }

    #[test]
    fn design_vector_layout() {
        let f = Features { sp: 3.0, sd: 2.0, np: 1.0, nd: 2.0 };
        assert_eq!(f.design(), [1.0, 3.0, 2.0, 9.0, 4.0, 1.0, 2.0]);
    }

    #[test]
    fn with_helpers_do_not_mutate_original() {
        let f = Features::default();
        let g = f.with_prefill(10).with_decode();
        assert!(f.is_empty());
        assert_eq!(g.sp, 10.0);
        assert_eq!(g.nd, 1.0);
    }

    #[test]
    fn batch_contains() {
        let mut b = Batch::new();
        b.push(entry(7, Class::ONLINE, 1, false));
        assert!(b.contains(7));
        assert!(!b.contains(8));
    }
}
