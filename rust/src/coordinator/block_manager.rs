//! Paged KV-cache block manager (vLLM-style) with hash-chain prefix
//! caching.
//!
//! The scheduler treats memory as the third budget dimension (Alg. 1's
//! `m`): every scheduled token must have a KV slot. Blocks hold
//! `block_size` tokens; full *prompt* blocks are content-addressed by a
//! rolling hash chain so requests sharing a prefix share physical blocks —
//! this is what makes PSM's "schedule prefix-sharers together" pay off.

use super::request::RequestId;
use std::collections::HashMap;

pub type BlockId = u32;

#[derive(Debug, Clone)]
struct Block {
    refcount: u32,
    /// Content hash for full, immutable prompt blocks (prefix-cacheable);
    /// None for partially-filled or decode blocks.
    hash: Option<u64>,
}

/// Per-request allocation state.
#[derive(Debug, Clone, Default)]
struct SeqAlloc {
    blocks: Vec<BlockId>,
    /// Token capacity = blocks.len() * block_size.
    tokens_used: usize,
}

#[derive(Debug)]
pub struct BlockManager {
    block_size: usize,
    blocks: Vec<Block>,
    free: Vec<BlockId>,
    /// content hash -> cached block (prefix cache).
    cache: HashMap<u64, BlockId>,
    seqs: HashMap<RequestId, SeqAlloc>,
}

/// Hash chain over token-block contents: block i's identity commits to all
/// preceding tokens, exactly like vLLM's prefix-caching key.
pub fn chain_hashes(tokens: &[u32], block_size: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() / block_size);
    let mut h: u64 = 0xcbf29ce484222325;
    for chunk in tokens.chunks(block_size) {
        if chunk.len() < block_size {
            break; // only full blocks are content-addressable
        }
        for t in chunk {
            h = (h ^ *t as u64).wrapping_mul(0x100000001b3);
        }
        out.push(h);
    }
    out
}

/// Synthetic hash chain for simulated requests: `group` identifies the
/// shared template (same group + same index ⇒ same block identity).
pub fn synthetic_chain(group: u64, shared_blocks: usize, unique_tag: u64, total_blocks: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(total_blocks);
    let mut h = group.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xabcdef;
    for i in 0..total_blocks {
        if i == shared_blocks {
            // diverge: mix in the request-unique tag from here on
            h ^= unique_tag.wrapping_mul(0xff51afd7ed558ccd) | 1;
        }
        h = (h ^ i as u64).wrapping_mul(0x100000001b3);
        out.push(h);
    }
    out
}

impl BlockManager {
    pub fn new(num_blocks: usize, block_size: usize) -> BlockManager {
        assert!(block_size > 0 && num_blocks > 0);
        BlockManager {
            block_size,
            blocks: vec![Block { refcount: 0, hash: None }; num_blocks],
            free: (0..num_blocks as BlockId).rev().collect(),
            cache: HashMap::new(),
            seqs: HashMap::new(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.blocks.len() - self.free.len()
    }

    /// Token capacity still allocatable (ignoring prefix-cache hits, so a
    /// conservative lower bound — the scheduler's memory budget `m`).
    pub fn free_tokens(&self) -> usize {
        self.free.len() * self.block_size
    }

    pub fn is_allocated(&self, id: RequestId) -> bool {
        self.seqs.contains_key(&id)
    }

    fn blocks_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    fn take_free(&mut self) -> Option<BlockId> {
        while let Some(b) = self.free.pop() {
            // A cached block may sit in the free list with refcount 0
            // (evictable). Claim it, dropping its cache entry.
            let hash = self.blocks[b as usize].hash.take();
            if let Some(h) = hash {
                self.cache.remove(&h);
            }
            debug_assert_eq!(self.blocks[b as usize].refcount, 0);
            return Some(b);
        }
        None
    }

    /// Admit a sequence: allocate blocks for `total_tokens`, reusing
    /// prefix-cache hits from `hash_chain` (one hash per *full* prompt
    /// block, in order). Returns the number of tokens satisfied from cache
    /// (the prefill work saved), or `None` if memory is insufficient —
    /// in which case nothing is allocated.
    // lint: allow(alloc, reason=admission/resume path only; steady decode grows in place)
    pub fn allocate(
        &mut self,
        id: RequestId,
        total_tokens: usize,
        hash_chain: &[u64],
    ) -> Option<usize> {
        assert!(!self.seqs.contains_key(&id), "request {id} already allocated");
        let needed = self.blocks_needed(total_tokens.max(1));
        // Count cache hits along the chain prefix (must be contiguous).
        let mut hit_blocks = Vec::new();
        for h in hash_chain.iter().take(needed) {
            match self.cache.get(h) {
                Some(&b) => hit_blocks.push(b),
                None => break,
            }
        }
        let fresh_needed = needed - hit_blocks.len();
        // Evictable cache hits (refcount 0) still sit in the free list and
        // will be resurrected out of it — count them against free capacity
        // alongside the fresh blocks.
        let evictable_hits = hit_blocks
            .iter()
            .filter(|&&b| self.blocks[b as usize].refcount == 0)
            .count();
        if fresh_needed + evictable_hits > self.free.len() {
            return None;
        }
        let mut alloc = SeqAlloc { blocks: Vec::with_capacity(needed), tokens_used: total_tokens };
        for &b in &hit_blocks {
            let blk = &mut self.blocks[b as usize];
            if blk.refcount == 0 {
                // resurrect from the evictable free list
                self.free.retain(|&x| x != b);
            }
            blk.refcount += 1;
            alloc.blocks.push(b);
        }
        for i in 0..fresh_needed {
            let b = self.take_free().expect("checked above");
            let blk = &mut self.blocks[b as usize];
            blk.refcount = 1;
            // register full prompt blocks in the prefix cache
            let chain_idx = hit_blocks.len() + i;
            blk.hash = hash_chain.get(chain_idx).copied();
            if let Some(h) = blk.hash {
                self.cache.insert(h, b);
            }
            alloc.blocks.push(b);
        }
        let cached_tokens = (hit_blocks.len() * self.block_size).min(total_tokens);
        self.seqs.insert(id, alloc);
        Some(cached_tokens)
    }

    /// Grow a sequence's capacity to hold `new_total_tokens` (decode
    /// appends). Returns false (and changes nothing) if memory is short.
    pub fn grow(&mut self, id: RequestId, new_total_tokens: usize) -> bool {
        let have = match self.seqs.get(&id) {
            Some(a) => a.blocks.len(),
            None => return false,
        };
        let need = self.blocks_needed(new_total_tokens.max(1));
        if need <= have {
            if let Some(a) = self.seqs.get_mut(&id) {
                a.tokens_used = new_total_tokens;
            }
            return true;
        }
        let extra = need - have;
        if extra > self.free.len() {
            return false;
        }
        // No temporary buffer: blocks are claimed and appended one at a
        // time (decode-path growth is at most one block per call, and the
        // hot loop must not allocate).
        for _ in 0..extra {
            let b = self.take_free().expect("checked above");
            self.blocks[b as usize].refcount = 1;
            self.blocks[b as usize].hash = None; // decode blocks: not cacheable
            self.seqs.get_mut(&id).expect("checked above").blocks.push(b);
        }
        let a = self.seqs.get_mut(&id).expect("checked above");
        a.tokens_used = new_total_tokens;
        true
    }

    /// Release a sequence's blocks. Cached (hashed) blocks go to the free
    /// list but stay in the prefix cache until reclaimed — so a later
    /// prefix-sharing request can still hit them.
    pub fn release(&mut self, id: RequestId) {
        let Some(alloc) = self.seqs.remove(&id) else { return };
        for b in alloc.blocks {
            let blk = &mut self.blocks[b as usize];
            debug_assert!(blk.refcount > 0);
            blk.refcount -= 1;
            if blk.refcount == 0 {
                // Evictable: hashed blocks keep their cache entry until the
                // block is actually reused by take_free().
                self.free.push(b);
            }
        }
    }

    /// Tokens currently allocated for `id` (0 if unknown).
    pub fn tokens_of(&self, id: RequestId) -> usize {
        self.seqs.get(&id).map(|a| a.tokens_used).unwrap_or(0)
    }

    /// Number of live (allocated) sequences.
    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Prefix-cache entries currently addressable.
    pub fn cache_entries(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut bm = BlockManager::new(16, 16);
        assert_eq!(bm.free_tokens(), 256);
        let cached = bm.allocate(1, 100, &[]).unwrap();
        assert_eq!(cached, 0);
        assert_eq!(bm.used_blocks(), 7); // ceil(100/16)
        assert_eq!(bm.tokens_of(1), 100);
        bm.release(1);
        assert_eq!(bm.used_blocks(), 0);
        assert_eq!(bm.num_seqs(), 0);
    }

    #[test]
    fn allocation_fails_atomically_when_full() {
        let mut bm = BlockManager::new(4, 16);
        assert!(bm.allocate(1, 48, &[]).is_some()); // 3 blocks
        assert!(bm.allocate(2, 32, &[]).is_none()); // needs 2, only 1 free
        assert_eq!(bm.free_blocks(), 1, "failed alloc must not leak");
        assert!(!bm.is_allocated(2));
    }

    #[test]
    fn grow_for_decode() {
        let mut bm = BlockManager::new(4, 16);
        bm.allocate(1, 16, &[]).unwrap();
        assert!(bm.grow(1, 17)); // crosses into a 2nd block
        assert_eq!(bm.used_blocks(), 2);
        assert!(bm.grow(1, 64));
        assert_eq!(bm.used_blocks(), 4);
        assert!(!bm.grow(1, 65), "out of blocks");
        assert_eq!(bm.tokens_of(1), 64);
    }

    #[test]
    fn grow_unknown_request_fails() {
        let mut bm = BlockManager::new(4, 16);
        assert!(!bm.grow(9, 10));
    }

    #[test]
    fn prefix_cache_shares_blocks() {
        let mut bm = BlockManager::new(16, 16);
        let tokens_a: Vec<u32> = (0..64).collect(); // 4 full blocks
        let chain_a = chain_hashes(&tokens_a, 16);
        assert_eq!(chain_a.len(), 4);
        bm.allocate(1, 64, &chain_a).unwrap();
        assert_eq!(bm.used_blocks(), 4);

        // same first 32 tokens, then diverges
        let mut tokens_b: Vec<u32> = (0..32).collect();
        tokens_b.extend(100..132u32);
        let chain_b = chain_hashes(&tokens_b, 16);
        let cached = bm.allocate(2, 64, &chain_b).unwrap();
        assert_eq!(cached, 32, "two shared blocks = 32 tokens saved");
        assert_eq!(bm.used_blocks(), 6, "only 2 fresh blocks for request 2");
    }

    #[test]
    fn cache_survives_release_until_eviction() {
        let mut bm = BlockManager::new(8, 16);
        let tokens: Vec<u32> = (0..64).collect();
        let chain = chain_hashes(&tokens, 16);
        bm.allocate(1, 64, &chain).unwrap();
        bm.release(1);
        assert_eq!(bm.free_blocks(), 8, "all blocks evictable");
        // New request with the same prefix: full cache hit.
        let cached = bm.allocate(2, 64, &chain).unwrap();
        assert_eq!(cached, 64);
        bm.release(2);
        // Fill memory with unrelated sequences -> cache evicted.
        bm.allocate(3, 128, &[]).unwrap();
        bm.release(3);
        let cached = bm.allocate(4, 64, &chain).unwrap();
        assert_eq!(cached, 0, "cache entries were reclaimed");
    }

    #[test]
    fn refcount_protects_shared_blocks() {
        let mut bm = BlockManager::new(8, 16);
        let tokens: Vec<u32> = (0..64).collect();
        let chain = chain_hashes(&tokens, 16);
        bm.allocate(1, 64, &chain).unwrap();
        bm.allocate(2, 64, &chain).unwrap(); // full share
        assert_eq!(bm.used_blocks(), 4);
        bm.release(1);
        assert_eq!(bm.used_blocks(), 4, "request 2 still holds them");
        bm.release(2);
        assert_eq!(bm.used_blocks(), 0);
    }

    #[test]
    fn chain_hashes_properties() {
        let a: Vec<u32> = (0..64).collect();
        let b: Vec<u32> = (0..64).collect();
        assert_eq!(chain_hashes(&a, 16), chain_hashes(&b, 16));
        let mut c = a.clone();
        c[0] = 999; // first token differs -> entire chain differs
        let ha = chain_hashes(&a, 16);
        let hc = chain_hashes(&c, 16);
        assert!(ha.iter().zip(&hc).all(|(x, y)| x != y));
        // partial last block is not hashed
        assert_eq!(chain_hashes(&a[..60], 16).len(), 3);
    }

    #[test]
    fn synthetic_chain_shares_exactly_prefix() {
        let x = synthetic_chain(7, 3, 100, 6);
        let y = synthetic_chain(7, 3, 200, 6);
        assert_eq!(&x[..3], &y[..3]);
        assert!(x[3..].iter().zip(&y[3..]).all(|(a, b)| a != b));
        let z = synthetic_chain(8, 3, 100, 6);
        assert!(x.iter().zip(&z).all(|(a, b)| a != b), "different groups never share");
    }

    #[test]
    fn zero_token_allocation_takes_one_block() {
        let mut bm = BlockManager::new(4, 16);
        bm.allocate(1, 0, &[]).unwrap();
        assert_eq!(bm.used_blocks(), 1);
    }
}
