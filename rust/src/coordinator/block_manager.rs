//! Paged KV-cache block manager (vLLM-style) with hash-chain prefix
//! caching and tiered-LRU eviction.
//!
//! The scheduler treats memory as the third budget dimension (Alg. 1's
//! `m`): every scheduled token must have a KV slot. Blocks hold
//! `block_size` tokens; full *prompt* blocks are content-addressed by a
//! rolling hash chain so requests sharing a prefix share physical blocks —
//! this is what makes PSM's "schedule prefix-sharers together" pay off.
//!
//! ## Recycling core (intrusive lists, all O(1))
//!
//! Free capacity lives on intrusive doubly-linked lists stored *inline*
//! in the `Block` array (`prev`/`next` indices, `u32::MAX` = nil), so no
//! recycling operation allocates or scans:
//!
//! * **untracked list** — never-hashed blocks (fresh pool, released
//!   decode blocks). LIFO: the most recently released block is reused
//!   first.
//! * **per-tier LRU lists** — refcount-0 *cached* blocks, one list per
//!   producing tier bucket (`tier.min(MAX_CLASSES-1)`). A block is
//!   appended at the tail on release, so each list's head is its
//!   least-recently-released member and LRU order *is* release order.
//!
//! `take_free` consumes the untracked list first; only when it is empty
//! does it evict a cached block, chosen by [`EvictionPolicy`]:
//! lowest producing tier first, then LRU within the tier (`TierLru`,
//! the default — offline-produced prefixes die before online ones), or
//! globally least-recently-released (`Lru`, a min over the ≤8 list
//! heads' release stamps — still O(1)). Resurrecting a refcount-0 cache
//! hit is a single unlink; the old `Vec::retain` free-list scan is gone.
//!
//! Per-request block Vecs are pooled (`release` returns them with
//! capacity intact), so steady-state admission churn does not allocate
//! once the pool is warm. Per-class hit/miss/eviction/resurrection
//! counters ([`BlockCacheStats`]) feed `Metrics`/`/metrics`, and a small
//! direct-mapped probe table summarises which prefix families are
//! resident for the cluster router's `cached_prefix_tokens` signal.

use super::classes::MAX_CLASSES;
use super::request::RequestId;
use std::collections::HashMap;

pub type BlockId = u32;

/// Nil link in the intrusive lists.
const NIL: u32 = u32::MAX;
/// `Block::list`: not on any free list (referenced by ≥1 sequence).
const LIST_NONE: u8 = u8::MAX;
/// `Block::list`: on the untracked (never-hashed) free list.
const LIST_UNTRACKED: u8 = u8::MAX - 1;

/// Slots in the direct-mapped prefix-family probe table (keyed by the
/// chain's root hash). Small and `Copy` so `ReplicaSnapshot` can carry
/// it verbatim.
pub const PROBE_SLOTS: usize = 16;

/// How `take_free` picks an eviction victim among refcount-0 cached
/// blocks (the untracked list is always consumed first).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictionPolicy {
    /// Lowest producing tier first, LRU within the tier (default):
    /// harvest-class prefixes are sacrificed before interactive ones.
    #[default]
    TierLru,
    /// Globally least-recently-released regardless of tier.
    Lru,
}

impl EvictionPolicy {
    pub fn name(self) -> &'static str {
        match self {
            EvictionPolicy::TierLru => "tier-lru",
            EvictionPolicy::Lru => "lru",
        }
    }

    pub fn parse(s: &str) -> Option<EvictionPolicy> {
        match s {
            "tier-lru" => Some(EvictionPolicy::TierLru),
            "lru" => Some(EvictionPolicy::Lru),
            _ => None,
        }
    }
}

/// Per-class prefix-cache counters (monotonic absolutes; the metrics
/// layer snapshots them each engine step and `absorb` sums replicas).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BlockCacheStats {
    /// Full prompt blocks served from cache at admission.
    pub hits: u64,
    /// Cacheable prompt blocks that had to be freshly written.
    pub misses: u64,
    /// Cached blocks reclaimed for fresh allocations, charged to the
    /// class that last produced/consumed the victim.
    pub evictions: u64,
    /// Refcount-0 cached blocks revived off a free list by a new sharer.
    pub resurrections: u64,
    /// Prompt tokens satisfied from cache (prefill work saved).
    pub cached_tokens: u64,
}

/// Read-only view of one block's bookkeeping (property-test probe).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockView {
    pub refcount: u32,
    pub hash: Option<u64>,
    /// True when the block sits on some free/LRU list.
    pub listed: bool,
    /// True when it sits on the untracked (never-hashed) list.
    pub untracked: bool,
    /// Producing class index / tier bucket (meaningful for cached blocks).
    pub class: u8,
    pub tier: u8,
}

#[derive(Debug, Clone)]
struct Block {
    refcount: u32,
    /// Content hash for full, immutable prompt blocks (prefix-cacheable);
    /// None for partially-filled or decode blocks.
    hash: Option<u64>,
    /// Class index that last produced or consumed this cached block
    /// (eviction accounting).
    class: u8,
    /// Producing tier bucket — selects the LRU list the block joins when
    /// it becomes evictable.
    tier: u8,
    /// Which list the block is on: `LIST_NONE`, `LIST_UNTRACKED`, or a
    /// tier bucket index.
    list: u8,
    prev: u32,
    next: u32,
    /// Monotonic release stamp (global LRU tie-break across buckets).
    stamp: u64,
}

impl Block {
    fn fresh() -> Block {
        Block {
            refcount: 0,
            hash: None,
            class: 0,
            tier: 0,
            list: LIST_NONE,
            prev: NIL,
            next: NIL,
            stamp: 0,
        }
    }
}

/// One intrusive list's endpoints.
#[derive(Debug, Clone, Copy)]
struct ListHead {
    head: u32,
    tail: u32,
    len: usize,
}

impl ListHead {
    const EMPTY: ListHead = ListHead { head: NIL, tail: NIL, len: 0 };
}

/// Per-request allocation state.
#[derive(Debug, Clone, Default)]
struct SeqAlloc {
    blocks: Vec<BlockId>,
    /// Token capacity = blocks.len() * block_size.
    tokens_used: usize,
}

#[derive(Debug)]
pub struct BlockManager {
    block_size: usize,
    blocks: Vec<Block>,
    /// Never-hashed free blocks (LIFO).
    untracked: ListHead,
    /// Refcount-0 cached blocks, one LRU list per producing tier bucket
    /// (head = least recently released).
    lru: [ListHead; MAX_CLASSES],
    /// Total blocks on any free list (untracked + all LRU lists).
    free_count: usize,
    eviction: EvictionPolicy,
    /// content hash -> cached block (prefix cache).
    cache: HashMap<u64, BlockId>,
    seqs: HashMap<RequestId, SeqAlloc>,
    /// Recycled per-request block Vecs (capacity kept across requests so
    /// steady-state admission does not allocate).
    pool: Vec<Vec<BlockId>>,
    stats: [BlockCacheStats; MAX_CLASSES],
    /// Direct-mapped prefix-family residency summary:
    /// (root chain hash, resident prefix tokens). Slot 0-fingerprint =
    /// empty. Consumed by `ReplicaSnapshot::cached_prefix_tokens`.
    probe: [(u64, u32); PROBE_SLOTS],
    next_stamp: u64,
    peak_used: usize,
}

/// Hash chain over token-block contents into a caller-owned scratch
/// buffer: block i's identity commits to all preceding tokens, exactly
/// like vLLM's prefix-caching key. Clears `out` first; with warmed
/// capacity this is allocation-free on the admission path.
// lint: alloc-free
pub fn chain_hashes_into(tokens: &[u32], block_size: usize, out: &mut Vec<u64>) {
    out.clear();
    let mut h: u64 = 0xcbf29ce484222325;
    for chunk in tokens.chunks(block_size) {
        if chunk.len() < block_size {
            break; // only full blocks are content-addressable
        }
        for t in chunk {
            h = (h ^ *t as u64).wrapping_mul(0x100000001b3);
        }
        out.push(h);
    }
}

/// Allocating convenience wrapper around [`chain_hashes_into`] for tests
/// and cold paths.
// lint: allow(alloc, reason=cold-path wrapper; admissions use chain_hashes_into with a reused scratch)
pub fn chain_hashes(tokens: &[u32], block_size: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() / block_size.max(1));
    chain_hashes_into(tokens, block_size, &mut out);
    out
}

/// Synthetic hash chain for simulated requests: `group` identifies the
/// shared template (same group + same index ⇒ same block identity).
pub fn synthetic_chain(group: u64, shared_blocks: usize, unique_tag: u64, total_blocks: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(total_blocks);
    let mut h = group.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xabcdef;
    for i in 0..total_blocks {
        if i == shared_blocks {
            // diverge: mix in the request-unique tag from here on
            h ^= unique_tag.wrapping_mul(0xff51afd7ed558ccd) | 1;
        }
        h = (h ^ i as u64).wrapping_mul(0x100000001b3);
        out.push(h);
    }
    out
}

impl BlockManager {
    pub fn new(num_blocks: usize, block_size: usize) -> BlockManager {
        assert!(block_size > 0 && num_blocks > 0);
        let mut bm = BlockManager {
            block_size,
            blocks: vec![Block::fresh(); num_blocks],
            untracked: ListHead::EMPTY,
            lru: [ListHead::EMPTY; MAX_CLASSES],
            free_count: 0,
            eviction: EvictionPolicy::default(),
            cache: HashMap::new(),
            seqs: HashMap::new(),
            pool: Vec::new(),
            stats: [BlockCacheStats::default(); MAX_CLASSES],
            probe: [(0, 0); PROBE_SLOTS],
            next_stamp: 0,
            peak_used: 0,
        };
        // Seed the untracked list in ascending id order (matching the old
        // free-stack pop order for fresh allocations).
        for b in (0..num_blocks as BlockId).rev() {
            bm.push_front(LIST_UNTRACKED, b);
        }
        bm
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free_count
    }

    pub fn used_blocks(&self) -> usize {
        self.blocks.len() - self.free_count
    }

    /// High-water mark of `used_blocks` (effective-KV-capacity reporting).
    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    /// Token capacity still allocatable (ignoring prefix-cache hits, so a
    /// conservative lower bound — the scheduler's memory budget `m`).
    pub fn free_tokens(&self) -> usize {
        self.free_count * self.block_size
    }

    pub fn is_allocated(&self, id: RequestId) -> bool {
        self.seqs.contains_key(&id)
    }

    pub fn eviction_policy(&self) -> EvictionPolicy {
        self.eviction
    }

    pub fn set_eviction_policy(&mut self, p: EvictionPolicy) {
        self.eviction = p;
    }

    /// Per-class prefix-cache counters (monotonic absolutes).
    pub fn cache_stats(&self) -> &[BlockCacheStats; MAX_CLASSES] {
        &self.stats
    }

    /// Prefix-family residency summary for cluster snapshots.
    pub fn prefix_probe(&self) -> &[(u64, u32); PROBE_SLOTS] {
        &self.probe
    }

    fn blocks_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    // ---- intrusive list plumbing (all O(1)) ----

    fn heads_mut(&mut self, list: u8) -> &mut ListHead {
        if list == LIST_UNTRACKED {
            &mut self.untracked
        } else {
            &mut self.lru[list as usize]
        }
    }

    /// Append to `list`'s tail (MRU end of an LRU list).
    fn push_back(&mut self, list: u8, b: BlockId) {
        debug_assert_eq!(self.blocks[b as usize].list, LIST_NONE);
        let old_tail = self.heads_mut(list).tail;
        {
            let blk = &mut self.blocks[b as usize];
            blk.list = list;
            blk.prev = old_tail;
            blk.next = NIL;
        }
        if old_tail != NIL {
            self.blocks[old_tail as usize].next = b;
        }
        let h = self.heads_mut(list);
        if h.head == NIL {
            h.head = b;
        }
        h.tail = b;
        h.len += 1;
        self.free_count += 1;
    }

    /// Prepend to `list`'s head (LIFO reuse for untracked blocks).
    fn push_front(&mut self, list: u8, b: BlockId) {
        debug_assert_eq!(self.blocks[b as usize].list, LIST_NONE);
        let old_head = self.heads_mut(list).head;
        {
            let blk = &mut self.blocks[b as usize];
            blk.list = list;
            blk.prev = NIL;
            blk.next = old_head;
        }
        if old_head != NIL {
            self.blocks[old_head as usize].prev = b;
        }
        let h = self.heads_mut(list);
        if h.tail == NIL {
            h.tail = b;
        }
        h.head = b;
        h.len += 1;
        self.free_count += 1;
    }

    /// Remove `b` from whichever list it is on.
    fn unlink(&mut self, b: BlockId) {
        let (list, prev, next) = {
            let blk = &self.blocks[b as usize];
            (blk.list, blk.prev, blk.next)
        };
        debug_assert_ne!(list, LIST_NONE, "unlink of an unlisted block");
        if prev != NIL {
            self.blocks[prev as usize].next = next;
        } else {
            self.heads_mut(list).head = next;
        }
        if next != NIL {
            self.blocks[next as usize].prev = prev;
        } else {
            self.heads_mut(list).tail = prev;
        }
        {
            let blk = &mut self.blocks[b as usize];
            blk.list = LIST_NONE;
            blk.prev = NIL;
            blk.next = NIL;
        }
        self.heads_mut(list).len -= 1;
        self.free_count -= 1;
    }

    /// Eviction victim among refcount-0 cached blocks, per policy.
    fn pick_victim(&self) -> Option<BlockId> {
        match self.eviction {
            // Lowest tier bucket with an evictable block; its head is the
            // least recently released member.
            EvictionPolicy::TierLru => self.lru.iter().find(|h| h.head != NIL).map(|h| h.head),
            // Oldest release stamp across the ≤MAX_CLASSES list heads.
            EvictionPolicy::Lru => {
                let mut best = NIL;
                let mut best_stamp = u64::MAX;
                for h in &self.lru {
                    if h.head != NIL {
                        let s = self.blocks[h.head as usize].stamp;
                        if s < best_stamp {
                            best_stamp = s;
                            best = h.head;
                        }
                    }
                }
                if best == NIL { None } else { Some(best) }
            }
        }
    }

    /// Claim a free block: untracked pool first, then evict a cached
    /// block per the eviction policy. O(1) either way.
    fn take_free(&mut self) -> Option<BlockId> {
        if self.untracked.head != NIL {
            let b = self.untracked.head;
            self.unlink(b);
            debug_assert_eq!(self.blocks[b as usize].refcount, 0);
            return Some(b);
        }
        let victim = self.pick_victim()?;
        self.unlink(victim);
        debug_assert_eq!(self.blocks[victim as usize].refcount, 0);
        let hash = self.blocks[victim as usize].hash.take();
        let class = self.blocks[victim as usize].class as usize;
        if let Some(h) = hash {
            // The entry may have been shadowed by a newer block with the
            // same hash; only drop it when it still points at the victim.
            if self.cache.get(&h) == Some(&victim) {
                self.cache.remove(&h);
            }
            self.probe_invalidate(h);
        }
        self.stats[class.min(MAX_CLASSES - 1)].evictions += 1;
        Some(victim)
    }

    fn probe_invalidate(&mut self, h: u64) {
        let slot = (h % PROBE_SLOTS as u64) as usize;
        if self.probe[slot].0 == h {
            self.probe[slot] = (0, 0);
        }
    }

    /// Admit a sequence: allocate blocks for `total_tokens`, reusing
    /// prefix-cache hits from `hash_chain` (one hash per *full* prompt
    /// block, in order). Returns the number of tokens satisfied from
    /// cache (the prefill work saved), or `None` if memory is
    /// insufficient — in which case nothing is allocated. Untagged
    /// convenience form: attributes to class 0 / tier 0.
    pub fn allocate(&mut self, id: RequestId, total_tokens: usize, hash_chain: &[u64]) -> Option<usize> {
        self.allocate_tagged(id, total_tokens, hash_chain, 0, 0)
    }

    /// Tagged admission: `class` attributes hit/miss/eviction counters
    /// and `tier` selects the LRU bucket the blocks join once evictable
    /// (hot shared blocks inherit their latest consumer's tags, so a
    /// prefix re-used by an interactive class is protected accordingly).
    // lint: allow(alloc, reason=admission/resume path only; the blocks Vec comes from the per-manager pool and only reserves on cold start)
    pub fn allocate_tagged(
        &mut self,
        id: RequestId,
        total_tokens: usize,
        hash_chain: &[u64],
        class: usize,
        tier: u8,
    ) -> Option<usize> {
        assert!(!self.seqs.contains_key(&id), "request {id} already allocated");
        let class_idx = class.min(MAX_CLASSES - 1);
        let tier_bucket = (tier as usize).min(MAX_CLASSES - 1) as u8;
        let needed = self.blocks_needed(total_tokens.max(1));
        // Pass 1: count contiguous chain hits — no side effects, no
        // buffer (cache lookups are repeated in pass 2, which is O(blocks
        // touched), not O(free list)).
        let mut n_hits = 0usize;
        let mut evictable_hits = 0usize;
        for h in hash_chain.iter().take(needed) {
            match self.cache.get(h) {
                Some(&b) => {
                    if self.blocks[b as usize].refcount == 0 {
                        evictable_hits += 1;
                    }
                    n_hits += 1;
                }
                None => break,
            }
        }
        let fresh_needed = needed - n_hits;
        // Evictable cache hits (refcount 0) sit on the LRU lists and will
        // be resurrected out of them — count them against free capacity
        // alongside the fresh blocks.
        if fresh_needed + evictable_hits > self.free_count {
            return None;
        }
        let mut seq_blocks = self.pool.pop().unwrap_or_default();
        seq_blocks.clear();
        seq_blocks.reserve(needed);
        // Pass 2a: claim the hits. Resurrection is a single unlink.
        for h in hash_chain.iter().take(n_hits) {
            let b = *self.cache.get(h).expect("hit counted in pass 1");
            if self.blocks[b as usize].refcount == 0 {
                self.unlink(b);
                self.stats[class_idx].resurrections += 1;
            }
            let blk = &mut self.blocks[b as usize];
            blk.refcount += 1;
            blk.class = class_idx as u8;
            blk.tier = tier_bucket;
            seq_blocks.push(b);
        }
        // Pass 2b: fresh blocks (may evict cold cached blocks).
        for i in 0..fresh_needed {
            let b = self.take_free().expect("feasibility checked above");
            let chain_idx = n_hits + i;
            let h = hash_chain.get(chain_idx).copied();
            {
                let blk = &mut self.blocks[b as usize];
                blk.refcount = 1;
                blk.hash = h;
                blk.class = class_idx as u8;
                blk.tier = tier_bucket;
            }
            if let Some(h) = h {
                // register full prompt blocks in the prefix cache
                self.cache.insert(h, b);
            }
            seq_blocks.push(b);
        }
        let cached_tokens = (n_hits * self.block_size).min(total_tokens);
        let st = &mut self.stats[class_idx];
        st.hits += n_hits as u64;
        st.misses += (hash_chain.len().min(needed) - n_hits) as u64;
        st.cached_tokens += cached_tokens as u64;
        // Probe summary: the family keyed by the chain root is resident
        // up to every full prompt block this admission touched.
        if let Some(&fp) = hash_chain.first() {
            if fp != 0 {
                let resident = (hash_chain.len().min(needed) * self.block_size).min(total_tokens);
                self.probe[(fp % PROBE_SLOTS as u64) as usize] = (fp, resident as u32);
            }
        }
        self.seqs.insert(id, SeqAlloc { blocks: seq_blocks, tokens_used: total_tokens });
        self.peak_used = self.peak_used.max(self.used_blocks());
        Some(cached_tokens)
    }

    /// Grow a sequence's capacity to hold `new_total_tokens` (decode
    /// appends). Returns false (and changes nothing) if memory is short.
    pub fn grow(&mut self, id: RequestId, new_total_tokens: usize) -> bool {
        let have = match self.seqs.get(&id) {
            Some(a) => a.blocks.len(),
            None => return false,
        };
        let need = self.blocks_needed(new_total_tokens.max(1));
        if need <= have {
            if let Some(a) = self.seqs.get_mut(&id) {
                a.tokens_used = new_total_tokens;
            }
            return true;
        }
        let extra = need - have;
        if extra > self.free_count {
            return false;
        }
        // No temporary buffer: blocks are claimed and appended one at a
        // time (decode-path growth is at most one block per call, and the
        // hot loop must not allocate).
        for _ in 0..extra {
            let b = self.take_free().expect("checked above");
            self.blocks[b as usize].refcount = 1;
            self.blocks[b as usize].hash = None; // decode blocks: not cacheable
            self.seqs.get_mut(&id).expect("checked above").blocks.push(b);
        }
        let a = self.seqs.get_mut(&id).expect("checked above");
        a.tokens_used = new_total_tokens;
        self.peak_used = self.peak_used.max(self.used_blocks());
        true
    }

    /// Release a sequence's blocks. Cached (hashed) blocks join their
    /// tier bucket's LRU tail (stamped, so LRU order = release order) and
    /// stay addressable in the prefix cache until evicted; unhashed
    /// blocks return to the untracked pool. The request's block Vec is
    /// recycled into the pool with its capacity intact.
    // lint: alloc-free
    pub fn release(&mut self, id: RequestId) {
        let Some(mut alloc) = self.seqs.remove(&id) else { return };
        for i in 0..alloc.blocks.len() {
            let b = alloc.blocks[i];
            let idx = b as usize;
            debug_assert!(self.blocks[idx].refcount > 0);
            self.blocks[idx].refcount -= 1;
            if self.blocks[idx].refcount == 0 {
                if self.blocks[idx].hash.is_some() {
                    let bucket = self.blocks[idx].tier.min(MAX_CLASSES as u8 - 1);
                    self.blocks[idx].stamp = self.next_stamp;
                    self.next_stamp += 1;
                    self.push_back(bucket, b);
                } else {
                    self.push_front(LIST_UNTRACKED, b);
                }
            }
        }
        alloc.blocks.clear();
        self.pool.push(alloc.blocks);
    }

    /// Tokens currently allocated for `id` (0 if unknown).
    pub fn tokens_of(&self, id: RequestId) -> usize {
        self.seqs.get(&id).map(|a| a.tokens_used).unwrap_or(0)
    }

    /// Number of live (allocated) sequences.
    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Prefix-cache entries currently addressable.
    pub fn cache_entries(&self) -> usize {
        self.cache.len()
    }

    // ---- read-only probes for the property suite / tests ----

    /// Bookkeeping view of one block.
    pub fn block_view(&self, b: BlockId) -> Option<BlockView> {
        self.blocks.get(b as usize).map(|blk| BlockView {
            refcount: blk.refcount,
            hash: blk.hash,
            listed: blk.list != LIST_NONE,
            untracked: blk.list == LIST_UNTRACKED,
            class: blk.class,
            tier: blk.tier,
        })
    }

    /// Current cache mapping for a hash (tests enumerate their own hash
    /// universe; the manager never iterates the map).
    pub fn cache_lookup(&self, h: u64) -> Option<BlockId> {
        self.cache.get(&h).copied()
    }

    /// Walk one tier bucket's LRU list head→tail (LRU→MRU) into `out`.
    pub fn lru_order(&self, bucket: usize, out: &mut Vec<BlockId>) {
        out.clear();
        if bucket >= MAX_CLASSES {
            return;
        }
        let mut b = self.lru[bucket].head;
        while b != NIL {
            out.push(b);
            b = self.blocks[b as usize].next;
        }
    }

    /// Walk the untracked free list head→tail into `out`.
    pub fn untracked_order(&self, out: &mut Vec<BlockId>) {
        out.clear();
        let mut b = self.untracked.head;
        while b != NIL {
            out.push(b);
            b = self.blocks[b as usize].next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut bm = BlockManager::new(16, 16);
        assert_eq!(bm.free_tokens(), 256);
        let cached = bm.allocate(1, 100, &[]).unwrap();
        assert_eq!(cached, 0);
        assert_eq!(bm.used_blocks(), 7); // ceil(100/16)
        assert_eq!(bm.tokens_of(1), 100);
        bm.release(1);
        assert_eq!(bm.used_blocks(), 0);
        assert_eq!(bm.num_seqs(), 0);
    }

    #[test]
    fn allocation_fails_atomically_when_full() {
        let mut bm = BlockManager::new(4, 16);
        assert!(bm.allocate(1, 48, &[]).is_some()); // 3 blocks
        assert!(bm.allocate(2, 32, &[]).is_none()); // needs 2, only 1 free
        assert_eq!(bm.free_blocks(), 1, "failed alloc must not leak");
        assert!(!bm.is_allocated(2));
    }

    #[test]
    fn grow_for_decode() {
        let mut bm = BlockManager::new(4, 16);
        bm.allocate(1, 16, &[]).unwrap();
        assert!(bm.grow(1, 17)); // crosses into a 2nd block
        assert_eq!(bm.used_blocks(), 2);
        assert!(bm.grow(1, 64));
        assert_eq!(bm.used_blocks(), 4);
        assert!(!bm.grow(1, 65), "out of blocks");
        assert_eq!(bm.tokens_of(1), 64);
    }

    #[test]
    fn grow_unknown_request_fails() {
        let mut bm = BlockManager::new(4, 16);
        assert!(!bm.grow(9, 10));
    }

    #[test]
    fn prefix_cache_shares_blocks() {
        let mut bm = BlockManager::new(16, 16);
        let tokens_a: Vec<u32> = (0..64).collect(); // 4 full blocks
        let chain_a = chain_hashes(&tokens_a, 16);
        assert_eq!(chain_a.len(), 4);
        bm.allocate(1, 64, &chain_a).unwrap();
        assert_eq!(bm.used_blocks(), 4);

        // same first 32 tokens, then diverges
        let mut tokens_b: Vec<u32> = (0..32).collect();
        tokens_b.extend(100..132u32);
        let chain_b = chain_hashes(&tokens_b, 16);
        let cached = bm.allocate(2, 64, &chain_b).unwrap();
        assert_eq!(cached, 32, "two shared blocks = 32 tokens saved");
        assert_eq!(bm.used_blocks(), 6, "only 2 fresh blocks for request 2");
    }

    #[test]
    fn cache_survives_release_until_eviction() {
        let mut bm = BlockManager::new(8, 16);
        let tokens: Vec<u32> = (0..64).collect();
        let chain = chain_hashes(&tokens, 16);
        bm.allocate(1, 64, &chain).unwrap();
        bm.release(1);
        assert_eq!(bm.free_blocks(), 8, "all blocks evictable");
        // New request with the same prefix: full cache hit.
        let cached = bm.allocate(2, 64, &chain).unwrap();
        assert_eq!(cached, 64);
        bm.release(2);
        // Fill memory with unrelated sequences -> cache evicted.
        bm.allocate(3, 128, &[]).unwrap();
        bm.release(3);
        let cached = bm.allocate(4, 64, &chain).unwrap();
        assert_eq!(cached, 0, "cache entries were reclaimed");
    }

    #[test]
    fn refcount_protects_shared_blocks() {
        let mut bm = BlockManager::new(8, 16);
        let tokens: Vec<u32> = (0..64).collect();
        let chain = chain_hashes(&tokens, 16);
        bm.allocate(1, 64, &chain).unwrap();
        bm.allocate(2, 64, &chain).unwrap(); // full share
        assert_eq!(bm.used_blocks(), 4);
        bm.release(1);
        assert_eq!(bm.used_blocks(), 4, "request 2 still holds them");
        bm.release(2);
        assert_eq!(bm.used_blocks(), 0);
    }

    #[test]
    fn chain_hashes_properties() {
        let a: Vec<u32> = (0..64).collect();
        let b: Vec<u32> = (0..64).collect();
        assert_eq!(chain_hashes(&a, 16), chain_hashes(&b, 16));
        let mut c = a.clone();
        c[0] = 999; // first token differs -> entire chain differs
        let ha = chain_hashes(&a, 16);
        let hc = chain_hashes(&c, 16);
        assert!(ha.iter().zip(&hc).all(|(x, y)| x != y));
        // partial last block is not hashed
        assert_eq!(chain_hashes(&a[..60], 16).len(), 3);
    }

    #[test]
    fn chain_hashes_into_matches_wrapper_and_reuses_scratch() {
        let a: Vec<u32> = (0..64).collect();
        let mut scratch = Vec::with_capacity(8);
        chain_hashes_into(&a, 16, &mut scratch);
        assert_eq!(scratch, chain_hashes(&a, 16));
        let cap = scratch.capacity();
        chain_hashes_into(&a[..32], 16, &mut scratch);
        assert_eq!(scratch.len(), 2);
        assert_eq!(scratch.capacity(), cap, "scratch is cleared, not reallocated");
    }

    #[test]
    fn synthetic_chain_shares_exactly_prefix() {
        let x = synthetic_chain(7, 3, 100, 6);
        let y = synthetic_chain(7, 3, 200, 6);
        assert_eq!(&x[..3], &y[..3]);
        assert!(x[3..].iter().zip(&y[3..]).all(|(a, b)| a != b));
        let z = synthetic_chain(8, 3, 100, 6);
        assert!(x.iter().zip(&z).all(|(a, b)| a != b), "different groups never share");
    }

    #[test]
    fn zero_token_allocation_takes_one_block() {
        let mut bm = BlockManager::new(4, 16);
        bm.allocate(1, 0, &[]).unwrap();
        assert_eq!(bm.used_blocks(), 1);
    }

    #[test]
    fn lru_order_is_release_order() {
        let mut bm = BlockManager::new(16, 16);
        let a = chain_hashes(&(0..32).collect::<Vec<u32>>(), 16);
        let b = chain_hashes(&(100..132).collect::<Vec<u32>>(), 16);
        bm.allocate(1, 32, &a).unwrap();
        bm.allocate(2, 32, &b).unwrap();
        bm.release(1); // a's blocks released first -> nearer the LRU head
        bm.release(2);
        let mut order = Vec::new();
        bm.lru_order(0, &mut order);
        assert_eq!(order.len(), 4);
        let first_two: Vec<Option<u64>> =
            order[..2].iter().map(|&x| bm.block_view(x).unwrap().hash).collect();
        assert_eq!(first_two, a.iter().map(|&h| Some(h)).collect::<Vec<_>>());
    }

    #[test]
    fn tier_lru_evicts_lowest_tier_first() {
        let mut bm = BlockManager::new(4, 16);
        let low = chain_hashes(&(0..32).collect::<Vec<u32>>(), 16);
        let high = chain_hashes(&(100..132).collect::<Vec<u32>>(), 16);
        bm.allocate_tagged(1, 32, &low, 1, 0).unwrap(); // offline-ish, tier 0
        bm.allocate_tagged(2, 32, &high, 0, 1).unwrap(); // online-ish, tier 1
        bm.release(1);
        bm.release(2);
        // One fresh unhashed block forces exactly one eviction: the tier-0
        // (low) prefix must die first even though it shares LRU age.
        bm.allocate(3, 16, &[]).unwrap();
        assert!(bm.cache_lookup(low[0]).is_none(), "tier-0 block evicted first");
        assert!(bm.cache_lookup(high[0]).is_some(), "tier-1 blocks survive");
        assert_eq!(bm.cache_stats()[1].evictions, 1, "charged to the producing class");
    }

    #[test]
    fn plain_lru_ignores_tiers() {
        let mut bm = BlockManager::new(4, 16);
        bm.set_eviction_policy(EvictionPolicy::Lru);
        assert_eq!(bm.eviction_policy(), EvictionPolicy::Lru);
        let high = chain_hashes(&(100..132).collect::<Vec<u32>>(), 16);
        let low = chain_hashes(&(0..32).collect::<Vec<u32>>(), 16);
        bm.allocate_tagged(1, 32, &high, 0, 1).unwrap(); // tier 1, released FIRST
        bm.allocate_tagged(2, 32, &low, 1, 0).unwrap(); // tier 0, released second
        bm.release(1);
        bm.release(2);
        bm.allocate(3, 16, &[]).unwrap();
        assert!(bm.cache_lookup(high[0]).is_none(), "globally oldest dies first");
        assert!(bm.cache_lookup(low[0]).is_some());
    }

    #[test]
    fn stats_count_hits_misses_resurrections() {
        let mut bm = BlockManager::new(16, 16);
        let chain = chain_hashes(&(0..64).collect::<Vec<u32>>(), 16);
        bm.allocate_tagged(1, 64, &chain, 0, 1).unwrap();
        assert_eq!(bm.cache_stats()[0].misses, 4);
        assert_eq!(bm.cache_stats()[0].hits, 0);
        // Live share: hits without resurrection.
        bm.allocate_tagged(2, 64, &chain, 0, 1).unwrap();
        assert_eq!(bm.cache_stats()[0].hits, 4);
        assert_eq!(bm.cache_stats()[0].resurrections, 0);
        assert_eq!(bm.cache_stats()[0].cached_tokens, 64);
        bm.release(1);
        bm.release(2);
        // Cold share: every hit resurrects an evictable block.
        bm.allocate_tagged(3, 64, &chain, 0, 1).unwrap();
        assert_eq!(bm.cache_stats()[0].hits, 8);
        assert_eq!(bm.cache_stats()[0].resurrections, 4);
    }

    #[test]
    fn probe_tracks_family_residency_until_root_eviction() {
        let mut bm = BlockManager::new(4, 16);
        let chain = chain_hashes(&(0..32).collect::<Vec<u32>>(), 16);
        bm.allocate(1, 32, &chain).unwrap();
        let slot = (chain[0] % PROBE_SLOTS as u64) as usize;
        assert_eq!(bm.prefix_probe()[slot], (chain[0], 32));
        bm.release(1);
        // Churn through enough fresh blocks to evict the whole family.
        bm.allocate(2, 64, &[]).unwrap();
        assert_eq!(bm.prefix_probe()[slot], (0, 0), "root eviction clears the probe");
    }

    #[test]
    fn peak_used_blocks_high_water_mark() {
        let mut bm = BlockManager::new(8, 16);
        bm.allocate(1, 96, &[]).unwrap(); // 6 blocks
        bm.release(1);
        bm.allocate(2, 16, &[]).unwrap(); // 1 block
        assert_eq!(bm.used_blocks(), 1);
        assert_eq!(bm.peak_used_blocks(), 6);
    }

    #[test]
    fn pooled_vecs_are_reused_across_admissions() {
        let mut bm = BlockManager::new(8, 16);
        bm.allocate(1, 64, &[]).unwrap();
        bm.release(1);
        // Same-size readmission must reuse the pooled Vec (no growth);
        // indirectly observable: the free structure stays consistent.
        bm.allocate(2, 64, &[]).unwrap();
        assert_eq!(bm.used_blocks(), 4);
        bm.release(2);
        assert_eq!(bm.free_blocks(), 8);
        let mut order = Vec::new();
        bm.untracked_order(&mut order);
        assert_eq!(order.len(), 8, "every block is back on the untracked list");
    }
}
