//! Serving metrics: TTFT/TBT sample collection per class, throughput
//! accounting (TPS/QPS), and windowed temporal series (Fig. 8's breakdown,
//! the `/metrics` endpoint, and every figure harness).
//!
//! Per-request bookkeeping lives in one dense slab indexed by
//! [`RequestId`] (ids are allocated monotonically from 1 by the engine),
//! replacing the previous three `HashMap`s that each cost a probe *per
//! generated token*. A slot is written at arrival, updated per token, and
//! marked finished — never removed mid-run, so the steady-state token
//! path is a single bounds-checked index with zero hashing and zero
//! allocation (the slab only grows at admission time, amortized).

use super::request::{Class, RequestId, Slo, SloMetric};
use crate::util::json::Json;
use crate::util::stats::{Summary, WindowSeries};

/// Aggregated latency/throughput report for one run.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    pub mean_ttft_ms: f64,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub mean_tbt_ms: f64,
    pub p50_tbt_ms: f64,
    pub p99_tbt_ms: f64,
    pub online_finished: usize,
    pub offline_finished: usize,
    pub online_tps: f64,
    pub offline_tps: f64,
    pub total_tps: f64,
    pub online_qps: f64,
    pub offline_qps: f64,
    pub duration_s: f64,
}

impl Report {
    /// Value of one of the four statistical SLO metrics (online class).
    pub fn metric(&self, m: SloMetric) -> f64 {
        match m {
            SloMetric::MeanTtft => self.mean_ttft_ms,
            SloMetric::P99Ttft => self.p99_ttft_ms,
            SloMetric::MeanTbt => self.mean_tbt_ms,
            SloMetric::P99Tbt => self.p99_tbt_ms,
        }
    }

    pub fn meets(&self, slo: &Slo) -> bool {
        self.metric(slo.metric) <= slo.limit_ms
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean_ttft_ms", self.mean_ttft_ms.into()),
            ("p50_ttft_ms", self.p50_ttft_ms.into()),
            ("p99_ttft_ms", self.p99_ttft_ms.into()),
            ("mean_tbt_ms", self.mean_tbt_ms.into()),
            ("p50_tbt_ms", self.p50_tbt_ms.into()),
            ("p99_tbt_ms", self.p99_tbt_ms.into()),
            ("online_finished", self.online_finished.into()),
            ("offline_finished", self.offline_finished.into()),
            ("online_tps", self.online_tps.into()),
            ("offline_tps", self.offline_tps.into()),
            ("total_tps", self.total_tps.into()),
            ("online_qps", self.online_qps.into()),
            ("offline_qps", self.offline_qps.into()),
            ("duration_s", self.duration_s.into()),
        ])
    }
}

/// One request's bookkeeping slot in the dense slab.
#[derive(Debug, Clone, Copy)]
struct ReqSlot {
    class: Class,
    /// Arrival time (s).
    arrival: f64,
    /// Time of the most recent token (meaningful once `seen_first`).
    last_token: f64,
    seen_first: bool,
    finished: bool,
    /// An id is live between `on_arrival` and `on_finish`; untouched
    /// slots (never-arrived ids) ignore token/finish events.
    occupied: bool,
}

impl Default for ReqSlot {
    fn default() -> Self {
        ReqSlot {
            class: Class::Online,
            arrival: 0.0,
            last_token: 0.0,
            seen_first: false,
            finished: false,
            occupied: false,
        }
    }
}

/// Streaming collector the engine feeds as tokens are produced.
///
/// TTFT and TBT are **online-class** metrics (the SLO-bound side);
/// throughput is tracked per class. Times are in seconds.
#[derive(Debug)]
pub struct Metrics {
    ttft: Summary,
    tbt: Summary,
    /// Dense per-request slab, indexed by `RequestId`.
    slots: Vec<ReqSlot>,
    online_tokens: u64,
    offline_tokens: u64,
    online_finished: usize,
    offline_finished: usize,
    /// Temporal series (window = 1s by default) for Fig. 8-style plots.
    pub online_tps_series: WindowSeries,
    pub offline_tps_series: WindowSeries,
    pub online_qps_series: WindowSeries,
    end_time: f64,
}

impl Metrics {
    pub fn new(window_s: f64) -> Metrics {
        Metrics {
            ttft: Summary::new(),
            tbt: Summary::new(),
            slots: Vec::new(),
            online_tokens: 0,
            offline_tokens: 0,
            online_finished: 0,
            offline_finished: 0,
            online_tps_series: WindowSeries::new(window_s),
            offline_tps_series: WindowSeries::new(window_s),
            online_qps_series: WindowSeries::new(window_s),
            end_time: 0.0,
        }
    }

    /// Pre-size internal storage so a bounded measurement window is
    /// allocation-free: slab slots for ids below `max_id`, capacity for
    /// `extra_samples` more TTFT/TBT samples, and series bucket capacity
    /// out to `horizon_s`. Used by the steady-state allocation probe.
    pub fn preallocate(&mut self, max_id: RequestId, extra_samples: usize, horizon_s: f64) {
        let want = max_id as usize + 1;
        if want > self.slots.len() {
            self.slots.resize(want, ReqSlot::default());
        }
        self.ttft.reserve(extra_samples);
        self.tbt.reserve(extra_samples);
        self.online_tps_series.reserve_until(horizon_s);
        self.offline_tps_series.reserve_until(horizon_s);
        self.online_qps_series.reserve_until(horizon_s);
    }

    /// Request entered the system (its queue) at time `t`. Re-arrival of
    /// an already-used id (id reuse across logical runs) resets its slot.
    pub fn on_arrival(&mut self, id: RequestId, class: Class, t: f64) {
        let idx = id as usize;
        if idx >= self.slots.len() {
            self.slots.resize(idx + 1, ReqSlot::default());
        }
        self.slots[idx] = ReqSlot {
            class,
            arrival: t,
            last_token: 0.0,
            seen_first: false,
            finished: false,
            occupied: true,
        };
        if class.is_online() {
            self.online_qps_series.record(t, 1.0);
        }
        self.end_time = self.end_time.max(t);
    }

    /// `n` output tokens became visible at time `t` (a decode step yields
    /// 1; the final prefill chunk yields the first token). Tokens for
    /// unknown or already-finished ids are ignored.
    pub fn on_tokens(&mut self, id: RequestId, t: f64, n: usize) {
        let Some(slot) = self.slots.get_mut(id as usize) else { return };
        if !slot.occupied || slot.finished {
            return;
        }
        self.end_time = self.end_time.max(t);
        if !slot.seen_first {
            slot.seen_first = true;
            if slot.class.is_online() {
                self.ttft.add((t - slot.arrival) * 1e3);
            }
        } else if slot.class.is_online() {
            self.tbt.add((t - slot.last_token) * 1e3);
        }
        slot.last_token = t;
        match slot.class {
            Class::Online => {
                self.online_tokens += n as u64;
                self.online_tps_series.record(t, n as f64);
            }
            Class::Offline => {
                self.offline_tokens += n as u64;
                self.offline_tps_series.record(t, n as f64);
            }
        }
    }

    /// Request completed at time `t`. Double-finish and unknown ids are
    /// ignored (the slot stays in the slab, marked finished, so late
    /// token events for the id are dropped rather than miscounted).
    pub fn on_finish(&mut self, id: RequestId, t: f64) {
        let Some(slot) = self.slots.get_mut(id as usize) else { return };
        if !slot.occupied || slot.finished {
            return;
        }
        slot.finished = true;
        self.end_time = self.end_time.max(t);
        match slot.class {
            Class::Online => self.online_finished += 1,
            Class::Offline => self.offline_finished += 1,
        }
    }

    /// Merge another collector's latency samples and counters into this
    /// one — cluster-wide aggregation over per-replica collectors. The
    /// merged percentiles are exact (sample-by-sample via
    /// [`Summary::merge`], no full sort), not an average of averages.
    /// Temporal series and the per-request slab are *not* merged (they
    /// are replica-local views).
    pub fn absorb(&mut self, other: &Metrics) {
        self.ttft.merge(&other.ttft);
        self.tbt.merge(&other.tbt);
        self.online_tokens += other.online_tokens;
        self.offline_tokens += other.offline_tokens;
        self.online_finished += other.online_finished;
        self.offline_finished += other.offline_finished;
        self.end_time = self.end_time.max(other.end_time);
    }

    pub fn online_token_count(&self) -> u64 {
        self.online_tokens
    }

    pub fn offline_token_count(&self) -> u64 {
        self.offline_tokens
    }

    /// Build the aggregate report over `[0, duration_s]` (defaults to the
    /// last observed event time).
    pub fn report(&mut self, duration_s: Option<f64>) -> Report {
        let d = duration_s.unwrap_or(self.end_time).max(1e-9);
        Report {
            mean_ttft_ms: self.ttft.mean(),
            p50_ttft_ms: self.ttft.p50(),
            p99_ttft_ms: self.ttft.p99(),
            mean_tbt_ms: self.tbt.mean(),
            p50_tbt_ms: self.tbt.p50(),
            p99_tbt_ms: self.tbt.p99(),
            online_finished: self.online_finished,
            offline_finished: self.offline_finished,
            online_tps: self.online_tokens as f64 / d,
            offline_tps: self.offline_tokens as f64 / d,
            total_tps: (self.online_tokens + self.offline_tokens) as f64 / d,
            online_qps: self.online_finished as f64 / d,
            offline_qps: self.offline_finished as f64 / d,
            duration_s: d,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_and_tbt_online_only() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Online, 0.0);
        m.on_arrival(2, Class::Offline, 0.0);
        m.on_tokens(1, 0.050, 1); // TTFT 50ms
        m.on_tokens(1, 0.080, 1); // TBT 30ms
        m.on_tokens(1, 0.120, 1); // TBT 40ms
        m.on_tokens(2, 1.0, 1); // offline: no TTFT/TBT samples
        m.on_tokens(2, 2.0, 1);
        m.on_finish(1, 0.120);
        let r = m.report(Some(2.0));
        assert!((r.mean_ttft_ms - 50.0).abs() < 1e-9);
        assert!((r.mean_tbt_ms - 35.0).abs() < 1e-9);
        assert_eq!(r.online_finished, 1);
        assert_eq!(r.offline_finished, 0);
        assert!((r.online_tps - 1.5).abs() < 1e-9);
        assert!((r.offline_tps - 1.0).abs() < 1e-9);
    }

    #[test]
    fn prefill_chunk_tokens_counted_in_tps() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Offline, 0.0);
        m.on_tokens(1, 0.5, 4); // e.g. speculative/multi-token event
        let r = m.report(Some(1.0));
        assert!((r.offline_tps - 4.0).abs() < 1e-9);
    }

    #[test]
    fn report_metric_and_slo() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Online, 0.0);
        m.on_tokens(1, 0.040, 1);
        let r = m.report(Some(1.0));
        assert_eq!(r.metric(SloMetric::MeanTtft), r.mean_ttft_ms);
        assert!(r.meets(&Slo::new(SloMetric::MeanTtft, 41.0)));
        assert!(!r.meets(&Slo::new(SloMetric::MeanTtft, 39.0)));
    }

    #[test]
    fn unknown_request_token_ignored() {
        let mut m = Metrics::new(1.0);
        m.on_tokens(99, 1.0, 1); // no arrival recorded
        m.on_finish(99, 1.0);
        let r = m.report(Some(1.0));
        assert_eq!(r.total_tps, 0.0);
        assert_eq!(r.online_finished, 0);
    }

    #[test]
    fn qps_series_counts_arrivals() {
        let mut m = Metrics::new(10.0);
        for i in 0..30 {
            m.on_arrival(i, Class::Online, i as f64);
        }
        let rates = m.online_qps_series.rates();
        assert_eq!(rates.len(), 3);
        assert!((rates[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn json_report_has_fields() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Online, 0.0);
        m.on_tokens(1, 0.1, 1);
        let j = m.report(Some(1.0)).to_json();
        assert!(j.get("mean_ttft_ms").as_f64().is_some());
        assert!(j.get("total_tps").as_f64().is_some());
    }

    #[test]
    fn slab_id_reuse_resets_slot() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(5, Class::Online, 0.0);
        m.on_tokens(5, 0.010, 1);
        m.on_finish(5, 0.010);
        // Same id arrives again (logical id reuse): fresh TTFT baseline,
        // fresh finished state.
        m.on_arrival(5, Class::Offline, 1.0);
        m.on_tokens(5, 1.5, 1);
        m.on_finish(5, 1.5);
        let r = m.report(Some(2.0));
        assert_eq!(r.online_finished, 1);
        assert_eq!(r.offline_finished, 1);
        assert!((r.mean_ttft_ms - 10.0).abs() < 1e-9, "second life took no TTFT sample");
    }

    #[test]
    fn slab_out_of_order_and_double_finish() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Online, 0.0);
        m.on_arrival(2, Class::Online, 0.0);
        m.on_tokens(2, 0.020, 1);
        m.on_tokens(1, 0.030, 1);
        // Out-of-order finish: 2 before 1; then double-finish 2.
        m.on_finish(2, 0.020);
        m.on_finish(2, 0.025);
        m.on_finish(1, 0.030);
        // Tokens after finish are dropped, not miscounted.
        m.on_tokens(2, 0.050, 1);
        let r = m.report(Some(1.0));
        assert_eq!(r.online_finished, 2, "double-finish must not double-count");
        assert_eq!(m.online_token_count(), 2, "post-finish token dropped");
    }

    #[test]
    fn absorb_merges_samples_and_counters() {
        let mut a = Metrics::new(1.0);
        a.on_arrival(1, Class::Online, 0.0);
        a.on_tokens(1, 0.010, 1);
        a.on_tokens(1, 0.030, 1);
        a.on_finish(1, 0.030);
        let mut b = Metrics::new(1.0);
        b.on_arrival(1, Class::Online, 0.0);
        b.on_tokens(1, 0.050, 1);
        b.on_arrival(2, Class::Offline, 0.0);
        b.on_tokens(2, 0.5, 3);
        b.on_finish(2, 0.5);
        let mut agg = Metrics::new(1.0);
        agg.absorb(&a);
        agg.absorb(&b);
        let r = agg.report(Some(1.0));
        assert_eq!(r.online_finished, 1);
        assert_eq!(r.offline_finished, 1);
        // TTFT samples 10 ms and 50 ms: exact merged mean/median, not an
        // average of per-replica aggregates.
        assert!((r.mean_ttft_ms - 30.0).abs() < 1e-9);
        assert!((r.p50_ttft_ms - 30.0).abs() < 1e-9);
        assert!((r.online_tps - 3.0).abs() < 1e-9);
        assert!((r.offline_tps - 3.0).abs() < 1e-9);
    }

    #[test]
    fn preallocate_prevents_slab_growth() {
        let mut m = Metrics::new(1.0);
        m.preallocate(128, 16, 60.0);
        let cap = m.slots.capacity();
        for id in 0..100u64 {
            m.on_arrival(id, Class::Offline, 0.0);
            m.on_tokens(id, 0.5, 1);
        }
        assert_eq!(m.slots.capacity(), cap, "slab pre-sized, no growth");
        assert_eq!(m.report(Some(1.0)).offline_tps, 100.0);
    }
}
