//! Serving metrics: per-class TTFT/TBT sample collection, per-class
//! throughput accounting (TPS/QPS), and windowed temporal series (Fig.
//! 8's breakdown, the `/metrics` endpoint, and every figure harness).
//!
//! Everything is **class-indexed**: one `ClassAgg` slot per SLO class
//! holds that class's latency summaries, token/finish counters, and
//! temporal series. Latency (TTFT/TBT) sampling is opt-in per class —
//! the flagship class 0 is tracked by default (the paper's online
//! metrics), harvest classes only when
//! [`Metrics::set_track_latency`] enables them (e.g. the `multi-slo`
//! experiment tracks every class with a declared SLO). Untracked classes
//! skip the sample vectors entirely, which keeps the steady-state decode
//! loop allocation-free (see `tests/alloc_free_loop.rs`).
//!
//! Per-request bookkeeping lives in one dense slab indexed by
//! [`RequestId`] (ids are allocated monotonically from 1 by the engine).
//! A slot is written at arrival, updated per token, and marked finished —
//! never removed mid-run, so the steady-state token path is a single
//! bounds-checked index with zero hashing and zero allocation (the slab
//! only grows at admission time, amortized).

use super::block_manager::BlockCacheStats;
use super::classes::MAX_CLASSES;
use super::request::{Class, RequestId, Slo, SloMetric};
use crate::obs::histogram::{shape_bucket, Histogram, SignedHistogram, PRED_SHAPES};
use crate::util::json::Json;
use crate::util::stats::{Summary, WindowSeries};

/// Per-class aggregate report block.
///
/// Latency carries a **dual representation**: the `mean/p50/p99` fields
/// come from exact per-sample [`Summary`]s (tracked classes only — they
/// pin the paper figures bit-for-bit), while `ttft_hist`/`tbt_hist` are
/// bounded 64-bucket histograms observed for *every* class. The
/// histograms are what merges correctly across replicas (bucket-wise
/// add), so `/metrics` aggregation and trace tooling read those.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassReport {
    pub finished: usize,
    pub tps: f64,
    pub qps: f64,
    pub mean_ttft_ms: f64,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub mean_tbt_ms: f64,
    pub p50_tbt_ms: f64,
    pub p99_tbt_ms: f64,
    pub ttft_hist: Histogram,
    pub tbt_hist: Histogram,
    /// Prefix-cache counters for admissions issued by this class (hits /
    /// misses are per *block*, `cached_tokens` is the prefill work the
    /// cache saved). Absolute since run start; replica-additive.
    pub cache: BlockCacheStats,
}

impl ClassReport {
    pub fn to_json(&self, class_index: usize) -> Json {
        Json::obj(vec![
            ("class", Json::from(class_index)),
            ("finished", self.finished.into()),
            ("tps", self.tps.into()),
            ("qps", self.qps.into()),
            ("mean_ttft_ms", self.mean_ttft_ms.into()),
            ("p50_ttft_ms", self.p50_ttft_ms.into()),
            ("p99_ttft_ms", self.p99_ttft_ms.into()),
            ("mean_tbt_ms", self.mean_tbt_ms.into()),
            ("p50_tbt_ms", self.p50_tbt_ms.into()),
            ("p99_tbt_ms", self.p99_tbt_ms.into()),
            ("ttft_hist", self.ttft_hist.to_json()),
            ("tbt_hist", self.tbt_hist.to_json()),
            ("cache_hit_blocks", self.cache.hits.into()),
            ("cache_miss_blocks", self.cache.misses.into()),
            ("cache_evictions", self.cache.evictions.into()),
            ("cache_resurrections", self.cache.resurrections.into()),
            ("cached_tokens", self.cache.cached_tokens.into()),
        ])
    }
}

/// Aggregated latency/throughput report for one run.
///
/// The flat fields are the classic two-class view every experiment reads:
/// top-level latency numbers are the **flagship class 0** (the paper's
/// online metrics), `online_*` is class 0, `offline_*` sums classes
/// 1..N. The dense per-class blocks live in `classes`.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    pub mean_ttft_ms: f64,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub mean_tbt_ms: f64,
    pub p50_tbt_ms: f64,
    pub p99_tbt_ms: f64,
    pub online_finished: usize,
    pub offline_finished: usize,
    pub online_tps: f64,
    pub offline_tps: f64,
    pub total_tps: f64,
    pub online_qps: f64,
    pub offline_qps: f64,
    pub duration_s: f64,
    /// Per-iteration batch-latency histogram (all classes pooled).
    pub batch_latency_hist: Histogram,
    /// Signed (predicted − actual) batch-latency error per batch-shape
    /// bucket (octave of batch size). Empty vec in stub reports.
    pub predictor_error: Vec<SignedHistogram>,
    /// Dense per-class blocks, indexed by [`Class`].
    pub classes: Vec<ClassReport>,
}

impl Report {
    /// Value of one of the four statistical SLO metrics (flagship class).
    pub fn metric(&self, m: SloMetric) -> f64 {
        match m {
            SloMetric::MeanTtft => self.mean_ttft_ms,
            SloMetric::P99Ttft => self.p99_ttft_ms,
            SloMetric::MeanTbt => self.mean_tbt_ms,
            SloMetric::P99Tbt => self.p99_tbt_ms,
        }
    }

    /// One class's value of an SLO metric (per-tier attainment checks).
    pub fn class_metric(&self, class: Class, m: SloMetric) -> f64 {
        let c = &self.classes[class.index()];
        match m {
            SloMetric::MeanTtft => c.mean_ttft_ms,
            SloMetric::P99Ttft => c.p99_ttft_ms,
            SloMetric::MeanTbt => c.mean_tbt_ms,
            SloMetric::P99Tbt => c.p99_tbt_ms,
        }
    }

    pub fn meets(&self, slo: &Slo) -> bool {
        self.metric(slo.metric) <= slo.limit_ms
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean_ttft_ms", self.mean_ttft_ms.into()),
            ("p50_ttft_ms", self.p50_ttft_ms.into()),
            ("p99_ttft_ms", self.p99_ttft_ms.into()),
            ("mean_tbt_ms", self.mean_tbt_ms.into()),
            ("p50_tbt_ms", self.p50_tbt_ms.into()),
            ("p99_tbt_ms", self.p99_tbt_ms.into()),
            ("online_finished", self.online_finished.into()),
            ("offline_finished", self.offline_finished.into()),
            ("online_tps", self.online_tps.into()),
            ("offline_tps", self.offline_tps.into()),
            ("total_tps", self.total_tps.into()),
            ("online_qps", self.online_qps.into()),
            ("offline_qps", self.offline_qps.into()),
            ("duration_s", self.duration_s.into()),
            ("batch_latency_hist", self.batch_latency_hist.to_json()),
            (
                "predictor_error",
                Json::Arr(
                    self.predictor_error
                        .iter()
                        .enumerate()
                        .map(|(i, h)| {
                            let mut j = h.to_json();
                            if let Json::Obj(m) = &mut j {
                                m.insert("shape".to_string(), Json::from(i));
                            }
                            j
                        })
                        .collect(),
                ),
            ),
            (
                "classes",
                Json::Arr(
                    self.classes.iter().enumerate().map(|(i, c)| c.to_json(i)).collect(),
                ),
            ),
        ])
    }
}

/// One request's bookkeeping slot in the dense slab.
#[derive(Debug, Clone, Copy)]
struct ReqSlot {
    class: Class,
    /// Arrival time (s).
    arrival: f64,
    /// Time of the most recent token (meaningful once `seen_first`).
    last_token: f64,
    seen_first: bool,
    finished: bool,
    /// An id is live between `on_arrival` and `on_finish`; untouched
    /// slots (never-arrived ids) ignore token/finish events.
    occupied: bool,
}

impl Default for ReqSlot {
    fn default() -> Self {
        ReqSlot {
            class: Class::ONLINE,
            arrival: 0.0,
            last_token: 0.0,
            seen_first: false,
            finished: false,
            occupied: false,
        }
    }
}

/// One class's aggregate state.
#[derive(Debug)]
struct ClassAgg {
    ttft: Summary,
    tbt: Summary,
    tokens: u64,
    finished: usize,
    /// Collect exact TTFT/TBT samples for this class (see the module
    /// docs). The bounded histograms below are always fed — they are
    /// fixed-size, so they never allocate on the token path.
    track_latency: bool,
    ttft_hist: Histogram,
    tbt_hist: Histogram,
    tps_series: WindowSeries,
    qps_series: WindowSeries,
    /// Local prefix-cache counters, overwritten wholesale by
    /// [`Metrics::set_cache_stats`] (the block manager owns the truth).
    cache: BlockCacheStats,
    /// Cache counters merged in from other replicas via [`Metrics::absorb`]
    /// — kept apart from `cache` so a later `set_cache_stats` overwrite
    /// (absolute local counters) cannot erase absorbed remote ones.
    cache_absorbed: BlockCacheStats,
}

impl ClassAgg {
    fn new(window_s: f64, track_latency: bool) -> ClassAgg {
        ClassAgg {
            ttft: Summary::new(),
            tbt: Summary::new(),
            tokens: 0,
            finished: 0,
            track_latency,
            ttft_hist: Histogram::new(),
            tbt_hist: Histogram::new(),
            tps_series: WindowSeries::new(window_s),
            qps_series: WindowSeries::new(window_s),
            cache: BlockCacheStats::default(),
            cache_absorbed: BlockCacheStats::default(),
        }
    }

    fn cache_total(&self) -> BlockCacheStats {
        BlockCacheStats {
            hits: self.cache.hits + self.cache_absorbed.hits,
            misses: self.cache.misses + self.cache_absorbed.misses,
            evictions: self.cache.evictions + self.cache_absorbed.evictions,
            resurrections: self.cache.resurrections + self.cache_absorbed.resurrections,
            cached_tokens: self.cache.cached_tokens + self.cache_absorbed.cached_tokens,
        }
    }

    fn report(&mut self, d: f64) -> ClassReport {
        ClassReport {
            finished: self.finished,
            tps: self.tokens as f64 / d,
            qps: self.finished as f64 / d,
            mean_ttft_ms: self.ttft.mean(),
            p50_ttft_ms: self.ttft.p50(),
            p99_ttft_ms: self.ttft.p99(),
            mean_tbt_ms: self.tbt.mean(),
            p50_tbt_ms: self.tbt.p50(),
            p99_tbt_ms: self.tbt.p99(),
            ttft_hist: self.ttft_hist,
            tbt_hist: self.tbt_hist,
            cache: self.cache_total(),
        }
    }
}

/// Streaming collector the engine feeds as tokens are produced.
///
/// Times are in seconds. Class slots are created on demand (the default
/// two are pre-created), so the collector works with any registry size
/// without carrying the registry itself.
#[derive(Debug)]
pub struct Metrics {
    classes: Vec<ClassAgg>,
    /// Dense per-request slab, indexed by `RequestId`.
    slots: Vec<ReqSlot>,
    window_s: f64,
    end_time: f64,
    /// Per-iteration batch-latency histogram (fed by `on_batch`).
    batch_latency: Histogram,
    /// Signed predictor error (predicted − actual, ms) per batch-shape
    /// bucket — fixed-size, allocation-free on the step path.
    pred_err: [SignedHistogram; PRED_SHAPES],
}

impl Metrics {
    pub fn new(window_s: f64) -> Metrics {
        Metrics {
            // Flagship class 0 tracks latency by default (the paper's
            // online TTFT/TBT); the harvest slot does not.
            classes: vec![ClassAgg::new(window_s, true), ClassAgg::new(window_s, false)],
            slots: Vec::new(),
            window_s,
            end_time: 0.0,
            batch_latency: Histogram::new(),
            pred_err: [SignedHistogram::new(); PRED_SHAPES],
        }
    }

    fn ensure_class(&mut self, class: Class) {
        while self.classes.len() <= class.index() {
            self.classes.push(ClassAgg::new(self.window_s, false));
        }
    }

    /// Opt a class in (or out) of TTFT/TBT sample collection. Enable this
    /// for every class with a declared SLO *before* the run; flipping it
    /// mid-run simply starts/stops sampling.
    pub fn set_track_latency(&mut self, class: Class, track: bool) {
        self.ensure_class(class);
        self.classes[class.index()].track_latency = track;
    }

    /// Number of class slots currently materialized.
    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// Per-class output-TPS series (Fig. 8's temporal breakdown).
    pub fn tps_series(&self, class: Class) -> &WindowSeries {
        &self.classes[class.index()].tps_series
    }

    /// Per-class arrival-QPS series.
    pub fn qps_series(&self, class: Class) -> &WindowSeries {
        &self.classes[class.index()].qps_series
    }

    /// Pre-size internal storage so a bounded measurement window is
    /// allocation-free: slab slots for ids below `max_id`, capacity for
    /// `extra_samples` more TTFT/TBT samples per latency-tracked class,
    /// and series bucket capacity out to `horizon_s` for every class.
    /// Used by the steady-state allocation probe.
    pub fn preallocate(&mut self, max_id: RequestId, extra_samples: usize, horizon_s: f64) {
        let want = max_id as usize + 1;
        if want > self.slots.len() {
            self.slots.resize(want, ReqSlot::default());
        }
        for agg in &mut self.classes {
            if agg.track_latency {
                agg.ttft.reserve(extra_samples);
                agg.tbt.reserve(extra_samples);
            }
            agg.tps_series.reserve_until(horizon_s);
            agg.qps_series.reserve_until(horizon_s);
        }
    }

    /// Request entered the system (its queue) at time `t`. Re-arrival of
    /// an already-used id (id reuse across logical runs) resets its slot.
    pub fn on_arrival(&mut self, id: RequestId, class: Class, t: f64) {
        self.ensure_class(class);
        let idx = id as usize;
        if idx >= self.slots.len() {
            self.slots.resize(idx + 1, ReqSlot::default());
        }
        self.slots[idx] = ReqSlot {
            class,
            arrival: t,
            last_token: 0.0,
            seen_first: false,
            finished: false,
            occupied: true,
        };
        self.classes[class.index()].qps_series.record(t, 1.0);
        self.end_time = self.end_time.max(t);
    }

    /// `n` output tokens became visible at time `t` (a decode step yields
    /// 1; the final prefill chunk yields the first token). Tokens for
    /// unknown or already-finished ids are ignored.
    // lint: alloc-free
    pub fn on_tokens(&mut self, id: RequestId, t: f64, n: usize) {
        let Some(slot) = self.slots.get_mut(id as usize) else { return };
        if !slot.occupied || slot.finished {
            return;
        }
        self.end_time = self.end_time.max(t);
        let agg = &mut self.classes[slot.class.index()];
        if !slot.seen_first {
            slot.seen_first = true;
            if agg.track_latency {
                agg.ttft.add((t - slot.arrival) * 1e3);
            }
            agg.ttft_hist.observe((t - slot.arrival) * 1e3);
        } else {
            if agg.track_latency {
                agg.tbt.add((t - slot.last_token) * 1e3);
            }
            agg.tbt_hist.observe((t - slot.last_token) * 1e3);
        }
        slot.last_token = t;
        agg.tokens += n as u64;
        agg.tps_series.record(t, n as f64);
    }

    /// One engine iteration executed: record the actual batch latency and
    /// the signed predictor error in the shape bucket of `batch_size`.
    // lint: alloc-free
    pub fn on_batch(&mut self, batch_size: usize, predicted_ms: f64, actual_ms: f64) {
        self.batch_latency.observe(actual_ms);
        if let Some(h) = self.pred_err.get_mut(shape_bucket(batch_size)) {
            h.observe(predicted_ms - actual_ms);
        }
    }

    /// Request completed at time `t`. Double-finish and unknown ids are
    /// ignored (the slot stays in the slab, marked finished, so late
    /// token events for the id are dropped rather than miscounted).
    // lint: alloc-free
    pub fn on_finish(&mut self, id: RequestId, t: f64) {
        let Some(slot) = self.slots.get_mut(id as usize) else { return };
        if !slot.occupied || slot.finished {
            return;
        }
        slot.finished = true;
        self.end_time = self.end_time.max(t);
        self.classes[slot.class.index()].finished += 1;
    }

    /// Overwrite the local per-class prefix-cache counters with the block
    /// manager's absolute counters (called once per engine step; the
    /// manager's counters are monotone, so overwrite ≡ latest snapshot).
    /// Only classes the collector has materialized are touched — the
    /// manager's fixed-size array covers every addressable class, and the
    /// steady-state decode loop must not grow the class vec here.
    // lint: alloc-free
    pub fn set_cache_stats(&mut self, stats: &[BlockCacheStats; MAX_CLASSES]) {
        for (agg, s) in self.classes.iter_mut().zip(stats.iter()) {
            agg.cache = *s;
        }
    }

    /// Merge another collector's latency samples and counters into this
    /// one — cluster-wide aggregation over per-replica collectors, class
    /// by class. The merged percentiles are exact (sample-by-sample via
    /// [`Summary::merge`], no full sort), not an average of averages.
    /// Temporal series and the per-request slab are *not* merged (they
    /// are replica-local views).
    pub fn absorb(&mut self, other: &Metrics) {
        for (i, o) in other.classes.iter().enumerate() {
            self.ensure_class(Class(i as u16));
            let agg = &mut self.classes[i];
            agg.ttft.merge(&o.ttft);
            agg.tbt.merge(&o.tbt);
            agg.ttft_hist.merge(&o.ttft_hist);
            agg.tbt_hist.merge(&o.tbt_hist);
            agg.tokens += o.tokens;
            agg.finished += o.finished;
            let oc = o.cache_total();
            agg.cache_absorbed.hits += oc.hits;
            agg.cache_absorbed.misses += oc.misses;
            agg.cache_absorbed.evictions += oc.evictions;
            agg.cache_absorbed.resurrections += oc.resurrections;
            agg.cache_absorbed.cached_tokens += oc.cached_tokens;
        }
        self.batch_latency.merge(&other.batch_latency);
        for (h, oh) in self.pred_err.iter_mut().zip(other.pred_err.iter()) {
            h.merge(oh);
        }
        self.end_time = self.end_time.max(other.end_time);
    }

    /// Output tokens of the flagship class (class 0).
    pub fn online_token_count(&self) -> u64 {
        self.classes[0].tokens
    }

    /// Output tokens of every class beyond the flagship.
    pub fn offline_token_count(&self) -> u64 {
        self.classes[1..].iter().map(|c| c.tokens).sum()
    }

    /// Output tokens of one class.
    pub fn class_token_count(&self, class: Class) -> u64 {
        self.classes[class.index()].tokens
    }

    /// Build the aggregate report over `[0, duration_s]` (defaults to the
    /// last observed event time).
    pub fn report(&mut self, duration_s: Option<f64>) -> Report {
        let d = duration_s.unwrap_or(self.end_time).max(1e-9);
        let classes: Vec<ClassReport> = self.classes.iter_mut().map(|c| c.report(d)).collect();
        let flag = classes[0].clone();
        let offline_finished: usize = classes[1..].iter().map(|c| c.finished).sum();
        let offline_tps: f64 = classes[1..].iter().map(|c| c.tps).sum();
        let offline_qps: f64 = classes[1..].iter().map(|c| c.qps).sum();
        let total_tps: f64 = classes.iter().map(|c| c.tps).sum();
        Report {
            mean_ttft_ms: flag.mean_ttft_ms,
            p50_ttft_ms: flag.p50_ttft_ms,
            p99_ttft_ms: flag.p99_ttft_ms,
            mean_tbt_ms: flag.mean_tbt_ms,
            p50_tbt_ms: flag.p50_tbt_ms,
            p99_tbt_ms: flag.p99_tbt_ms,
            online_finished: flag.finished,
            offline_finished,
            online_tps: flag.tps,
            offline_tps,
            total_tps,
            online_qps: flag.qps,
            offline_qps,
            duration_s: d,
            batch_latency_hist: self.batch_latency,
            predictor_error: self.pred_err.to_vec(),
            classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_and_tbt_online_only() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::ONLINE, 0.0);
        m.on_arrival(2, Class::OFFLINE, 0.0);
        m.on_tokens(1, 0.050, 1); // TTFT 50ms
        m.on_tokens(1, 0.080, 1); // TBT 30ms
        m.on_tokens(1, 0.120, 1); // TBT 40ms
        m.on_tokens(2, 1.0, 1); // offline: no TTFT/TBT samples by default
        m.on_tokens(2, 2.0, 1);
        m.on_finish(1, 0.120);
        let r = m.report(Some(2.0));
        assert!((r.mean_ttft_ms - 50.0).abs() < 1e-9);
        assert!((r.mean_tbt_ms - 35.0).abs() < 1e-9);
        assert_eq!(r.online_finished, 1);
        assert_eq!(r.offline_finished, 0);
        assert!((r.online_tps - 1.5).abs() < 1e-9);
        assert!((r.offline_tps - 1.0).abs() < 1e-9);
        assert_eq!(r.classes.len(), 2);
        assert_eq!(r.classes[0].mean_ttft_ms, r.mean_ttft_ms);
        assert_eq!(r.classes[1].mean_ttft_ms, 0.0, "untracked class takes no samples");
    }

    #[test]
    fn tracked_class_collects_latency_samples() {
        let mut m = Metrics::new(1.0);
        m.set_track_latency(Class::OFFLINE, true);
        m.on_arrival(1, Class::OFFLINE, 0.0);
        m.on_tokens(1, 0.040, 1);
        m.on_tokens(1, 0.070, 1);
        m.on_finish(1, 0.070);
        let r = m.report(Some(1.0));
        assert!((r.classes[1].mean_ttft_ms - 40.0).abs() < 1e-9);
        assert!((r.classes[1].mean_tbt_ms - 30.0).abs() < 1e-9);
        assert_eq!(r.mean_ttft_ms, 0.0, "flagship untouched");
        assert_eq!(r.class_metric(Class::OFFLINE, SloMetric::MeanTtft), 40.0);
    }

    #[test]
    fn third_class_slot_created_on_demand() {
        let mut m = Metrics::new(1.0);
        m.set_track_latency(Class(2), true);
        m.on_arrival(7, Class(2), 0.0);
        m.on_tokens(7, 0.025, 1);
        m.on_finish(7, 0.025);
        let r = m.report(Some(1.0));
        assert_eq!(r.classes.len(), 3);
        assert_eq!(r.classes[2].finished, 1);
        assert!((r.classes[2].mean_ttft_ms - 25.0).abs() < 1e-9);
        assert_eq!(r.offline_finished, 1, "classes 1..N sum into the offline view");
        assert!((r.offline_tps - 1.0).abs() < 1e-9);
    }

    #[test]
    fn prefill_chunk_tokens_counted_in_tps() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::OFFLINE, 0.0);
        m.on_tokens(1, 0.5, 4); // e.g. speculative/multi-token event
        let r = m.report(Some(1.0));
        assert!((r.offline_tps - 4.0).abs() < 1e-9);
    }

    #[test]
    fn report_metric_and_slo() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::ONLINE, 0.0);
        m.on_tokens(1, 0.040, 1);
        let r = m.report(Some(1.0));
        assert_eq!(r.metric(SloMetric::MeanTtft), r.mean_ttft_ms);
        assert!(r.meets(&Slo::new(SloMetric::MeanTtft, 41.0)));
        assert!(!r.meets(&Slo::new(SloMetric::MeanTtft, 39.0)));
    }

    #[test]
    fn unknown_request_token_ignored() {
        let mut m = Metrics::new(1.0);
        m.on_tokens(99, 1.0, 1); // no arrival recorded
        m.on_finish(99, 1.0);
        let r = m.report(Some(1.0));
        assert_eq!(r.total_tps, 0.0);
        assert_eq!(r.online_finished, 0);
    }

    #[test]
    fn qps_series_counts_arrivals() {
        let mut m = Metrics::new(10.0);
        for i in 0..30 {
            m.on_arrival(i, Class::ONLINE, i as f64);
        }
        let rates = m.qps_series(Class::ONLINE).rates();
        assert_eq!(rates.len(), 3);
        assert!((rates[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn json_report_has_fields() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::ONLINE, 0.0);
        m.on_tokens(1, 0.1, 1);
        let j = m.report(Some(1.0)).to_json();
        assert!(j.get("mean_ttft_ms").as_f64().is_some());
        assert!(j.get("total_tps").as_f64().is_some());
        let classes = j.get("classes").as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        assert!(classes[0].get("p99_ttft_ms").as_f64().is_some());
        assert_eq!(classes[1].get("class").as_u64(), Some(1));
    }

    #[test]
    fn slab_id_reuse_resets_slot() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(5, Class::ONLINE, 0.0);
        m.on_tokens(5, 0.010, 1);
        m.on_finish(5, 0.010);
        // Same id arrives again (logical id reuse): fresh TTFT baseline,
        // fresh finished state.
        m.on_arrival(5, Class::OFFLINE, 1.0);
        m.on_tokens(5, 1.5, 1);
        m.on_finish(5, 1.5);
        let r = m.report(Some(2.0));
        assert_eq!(r.online_finished, 1);
        assert_eq!(r.offline_finished, 1);
        assert!((r.mean_ttft_ms - 10.0).abs() < 1e-9, "second life took no TTFT sample");
    }

    #[test]
    fn slab_out_of_order_and_double_finish() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::ONLINE, 0.0);
        m.on_arrival(2, Class::ONLINE, 0.0);
        m.on_tokens(2, 0.020, 1);
        m.on_tokens(1, 0.030, 1);
        // Out-of-order finish: 2 before 1; then double-finish 2.
        m.on_finish(2, 0.020);
        m.on_finish(2, 0.025);
        m.on_finish(1, 0.030);
        // Tokens after finish are dropped, not miscounted.
        m.on_tokens(2, 0.050, 1);
        let r = m.report(Some(1.0));
        assert_eq!(r.online_finished, 2, "double-finish must not double-count");
        assert_eq!(m.online_token_count(), 2, "post-finish token dropped");
    }

    #[test]
    fn absorb_merges_samples_and_counters() {
        let mut a = Metrics::new(1.0);
        a.on_arrival(1, Class::ONLINE, 0.0);
        a.on_tokens(1, 0.010, 1);
        a.on_tokens(1, 0.030, 1);
        a.on_finish(1, 0.030);
        let mut b = Metrics::new(1.0);
        b.on_arrival(1, Class::ONLINE, 0.0);
        b.on_tokens(1, 0.050, 1);
        b.on_arrival(2, Class::OFFLINE, 0.0);
        b.on_tokens(2, 0.5, 3);
        b.on_finish(2, 0.5);
        let mut agg = Metrics::new(1.0);
        agg.absorb(&a);
        agg.absorb(&b);
        let r = agg.report(Some(1.0));
        assert_eq!(r.online_finished, 1);
        assert_eq!(r.offline_finished, 1);
        // TTFT samples 10 ms and 50 ms: exact merged mean/median, not an
        // average of per-replica aggregates.
        assert!((r.mean_ttft_ms - 30.0).abs() < 1e-9);
        assert!((r.p50_ttft_ms - 30.0).abs() < 1e-9);
        assert!((r.online_tps - 3.0).abs() < 1e-9);
        assert!((r.offline_tps - 3.0).abs() < 1e-9);
    }

    #[test]
    fn histograms_fed_for_untracked_classes_too() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::OFFLINE, 0.0);
        m.on_tokens(1, 0.040, 1); // TTFT 40ms
        m.on_tokens(1, 0.070, 1); // TBT 30ms
        let r = m.report(Some(1.0));
        // Exact summaries stay empty (untracked)...
        assert_eq!(r.classes[1].mean_ttft_ms, 0.0);
        // ...but the bounded histograms observed both samples.
        assert_eq!(r.classes[1].ttft_hist.count(), 1);
        assert_eq!(r.classes[1].tbt_hist.count(), 1);
        let width = crate::obs::Histogram::bucket_width_ms(40.0);
        assert!((r.classes[1].ttft_hist.p50() - 40.0).abs() <= width);
    }

    #[test]
    fn hist_quantiles_agree_with_exact_summaries() {
        let mut m = Metrics::new(1.0);
        for i in 0..200u64 {
            m.on_arrival(i, Class::ONLINE, 0.0);
            // TTFTs spread 1..200 ms.
            m.on_tokens(i, (i + 1) as f64 * 1e-3, 1);
        }
        let r = m.report(Some(1.0));
        for (hist, exact) in
            [(r.classes[0].ttft_hist.p50(), r.p50_ttft_ms), (r.classes[0].ttft_hist.p99(), r.p99_ttft_ms)]
        {
            let width = crate::obs::Histogram::bucket_width_ms(exact);
            assert!((hist - exact).abs() <= width, "hist {hist} vs exact {exact} (±{width})");
        }
    }

    #[test]
    fn on_batch_tracks_latency_and_signed_error() {
        let mut m = Metrics::new(1.0);
        m.on_batch(4, 10.0, 12.0); // under-prediction: error −2
        m.on_batch(4, 10.0, 12.0);
        m.on_batch(64, 50.0, 45.0); // over-prediction: +5, different shape
        let r = m.report(Some(1.0));
        assert_eq!(r.batch_latency_hist.count(), 3);
        let shape4 = &r.predictor_error[crate::obs::shape_bucket(4)];
        assert_eq!(shape4.count(), 2);
        assert!(shape4.p50() < 0.0, "shape-4 bias negative: {}", shape4.p50());
        let shape64 = &r.predictor_error[crate::obs::shape_bucket(64)];
        assert_eq!(shape64.count(), 1);
        assert!(shape64.p50() > 0.0);
        // JSON export carries both.
        let j = r.to_json();
        assert!(j.get("batch_latency_hist").get("count").as_u64().is_some());
        let pe = j.get("predictor_error").as_arr().unwrap();
        assert_eq!(pe.len(), crate::obs::PRED_SHAPES);
        assert!(pe[0].get("shape").as_u64().is_some());
        assert!(j.get("classes").as_arr().unwrap()[0].get("ttft_hist").get("p99_ms").as_f64().is_some());
    }

    #[test]
    fn absorb_merges_histograms_bucket_wise() {
        let mut a = Metrics::new(1.0);
        let mut b = Metrics::new(1.0);
        // Disjoint populations: replica A fast (10ms), replica B slow (100ms).
        for i in 0..10u64 {
            a.on_arrival(i, Class::ONLINE, 0.0);
            a.on_tokens(i, 0.010, 1);
            b.on_arrival(i, Class::ONLINE, 0.0);
            b.on_tokens(i, 0.100, 1);
        }
        a.on_batch(8, 5.0, 6.0);
        b.on_batch(8, 5.0, 4.0);
        let mut agg = Metrics::new(1.0);
        agg.absorb(&a);
        agg.absorb(&b);
        let r = agg.report(Some(1.0));
        let h = &r.classes[0].ttft_hist;
        assert_eq!(h.count(), 20);
        // Pooled p50 sits at the fast population's edge, far below the
        // worst-replica value (100ms) the old aggregation would report.
        assert!(h.p50() < 50.0, "pooled p50 {} must not be worst-replica", h.p50());
        assert!(h.p99() > 50.0);
        assert_eq!(r.batch_latency_hist.count(), 2);
        assert_eq!(r.predictor_error[3].count(), 2, "shape bucket for size 8");
    }

    #[test]
    fn preallocate_prevents_slab_growth() {
        let mut m = Metrics::new(1.0);
        m.preallocate(128, 16, 60.0);
        let cap = m.slots.capacity();
        for id in 0..100u64 {
            m.on_arrival(id, Class::OFFLINE, 0.0);
            m.on_tokens(id, 0.5, 1);
        }
        assert_eq!(m.slots.capacity(), cap, "slab pre-sized, no growth");
        assert_eq!(m.report(Some(1.0)).offline_tps, 100.0);
    }

    #[test]
    fn cache_stats_overwrite_and_absorb() {
        let stats = |hits: u64, tok: u64| {
            let mut s = [BlockCacheStats::default(); MAX_CLASSES];
            s[0] = BlockCacheStats {
                hits,
                misses: 2,
                evictions: 1,
                resurrections: hits,
                cached_tokens: tok,
            };
            s
        };
        let mut a = Metrics::new(1.0);
        // Two snapshots: overwrite semantics means the latest wins, not
        // the sum (the block manager's counters are already cumulative).
        a.set_cache_stats(&stats(3, 48));
        a.set_cache_stats(&stats(5, 80));
        let r = a.report(Some(1.0));
        assert_eq!(r.classes[0].cache.hits, 5);
        assert_eq!(r.classes[0].cache.cached_tokens, 80);
        assert_eq!(r.classes[1].cache, BlockCacheStats::default());

        // Absorb adds across replicas, and a later local overwrite must
        // not erase the absorbed remote counters.
        let mut b = Metrics::new(1.0);
        b.set_cache_stats(&stats(7, 112));
        a.absorb(&b);
        a.set_cache_stats(&stats(5, 80));
        let r = a.report(Some(1.0));
        assert_eq!(r.classes[0].cache.hits, 12);
        assert_eq!(r.classes[0].cache.cached_tokens, 192);
        let j = r.to_json();
        let c0 = &j.get("classes").as_arr().unwrap()[0];
        assert_eq!(c0.get("cache_hit_blocks").as_u64(), Some(12));
        assert_eq!(c0.get("cached_tokens").as_u64(), Some(192));
    }
}
