//! Serving metrics: TTFT/TBT sample collection per class, throughput
//! accounting (TPS/QPS), and windowed temporal series (Fig. 8's breakdown,
//! the `/metrics` endpoint, and every figure harness).

use super::request::{Class, RequestId, Slo, SloMetric};
use crate::util::json::Json;
use crate::util::stats::{Summary, WindowSeries};
use std::collections::HashMap;

/// Aggregated latency/throughput report for one run.
#[derive(Debug, Clone)]
pub struct Report {
    pub mean_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub mean_tbt_ms: f64,
    pub p99_tbt_ms: f64,
    pub online_finished: usize,
    pub offline_finished: usize,
    pub online_tps: f64,
    pub offline_tps: f64,
    pub total_tps: f64,
    pub online_qps: f64,
    pub offline_qps: f64,
    pub duration_s: f64,
}

impl Report {
    /// Value of one of the four statistical SLO metrics (online class).
    pub fn metric(&self, m: SloMetric) -> f64 {
        match m {
            SloMetric::MeanTtft => self.mean_ttft_ms,
            SloMetric::P99Ttft => self.p99_ttft_ms,
            SloMetric::MeanTbt => self.mean_tbt_ms,
            SloMetric::P99Tbt => self.p99_tbt_ms,
        }
    }

    pub fn meets(&self, slo: &Slo) -> bool {
        self.metric(slo.metric) <= slo.limit_ms
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean_ttft_ms", self.mean_ttft_ms.into()),
            ("p99_ttft_ms", self.p99_ttft_ms.into()),
            ("mean_tbt_ms", self.mean_tbt_ms.into()),
            ("p99_tbt_ms", self.p99_tbt_ms.into()),
            ("online_finished", self.online_finished.into()),
            ("offline_finished", self.offline_finished.into()),
            ("online_tps", self.online_tps.into()),
            ("offline_tps", self.offline_tps.into()),
            ("total_tps", self.total_tps.into()),
            ("online_qps", self.online_qps.into()),
            ("offline_qps", self.offline_qps.into()),
            ("duration_s", self.duration_s.into()),
        ])
    }
}

/// Streaming collector the engine feeds as tokens are produced.
///
/// TTFT and TBT are **online-class** metrics (the SLO-bound side);
/// throughput is tracked per class. Times are in seconds.
#[derive(Debug)]
pub struct Metrics {
    ttft: Summary,
    tbt: Summary,
    // request bookkeeping
    arrival: HashMap<RequestId, (Class, f64)>,
    last_token: HashMap<RequestId, f64>,
    first_token_seen: HashMap<RequestId, bool>,
    online_tokens: u64,
    offline_tokens: u64,
    online_finished: usize,
    offline_finished: usize,
    /// Temporal series (window = 1s by default) for Fig. 8-style plots.
    pub online_tps_series: WindowSeries,
    pub offline_tps_series: WindowSeries,
    pub online_qps_series: WindowSeries,
    end_time: f64,
}

impl Metrics {
    pub fn new(window_s: f64) -> Metrics {
        Metrics {
            ttft: Summary::new(),
            tbt: Summary::new(),
            arrival: HashMap::new(),
            last_token: HashMap::new(),
            first_token_seen: HashMap::new(),
            online_tokens: 0,
            offline_tokens: 0,
            online_finished: 0,
            offline_finished: 0,
            online_tps_series: WindowSeries::new(window_s),
            offline_tps_series: WindowSeries::new(window_s),
            online_qps_series: WindowSeries::new(window_s),
            end_time: 0.0,
        }
    }

    /// Request entered the system (its queue) at time `t`.
    pub fn on_arrival(&mut self, id: RequestId, class: Class, t: f64) {
        self.arrival.insert(id, (class, t));
        if class.is_online() {
            self.online_qps_series.record(t, 1.0);
        }
        self.end_time = self.end_time.max(t);
    }

    /// `n` output tokens became visible at time `t` (a decode step yields
    /// 1; the final prefill chunk yields the first token).
    pub fn on_tokens(&mut self, id: RequestId, t: f64, n: usize) {
        let Some(&(class, arrived)) = self.arrival.get(&id) else { return };
        self.end_time = self.end_time.max(t);
        let first_seen = self.first_token_seen.get(&id).copied().unwrap_or(false);
        if !first_seen {
            if class.is_online() {
                self.ttft.add((t - arrived) * 1e3);
            }
            self.first_token_seen.insert(id, true);
        } else if class.is_online() {
            if let Some(&last) = self.last_token.get(&id) {
                self.tbt.add((t - last) * 1e3);
            }
        }
        self.last_token.insert(id, t);
        match class {
            Class::Online => {
                self.online_tokens += n as u64;
                self.online_tps_series.record(t, n as f64);
            }
            Class::Offline => {
                self.offline_tokens += n as u64;
                self.offline_tps_series.record(t, n as f64);
            }
        }
    }

    pub fn on_finish(&mut self, id: RequestId, t: f64) {
        self.end_time = self.end_time.max(t);
        if let Some((class, _)) = self.arrival.get(&id) {
            match class {
                Class::Online => self.online_finished += 1,
                Class::Offline => self.offline_finished += 1,
            }
        }
        self.last_token.remove(&id);
        self.first_token_seen.remove(&id);
    }

    pub fn online_token_count(&self) -> u64 {
        self.online_tokens
    }

    pub fn offline_token_count(&self) -> u64 {
        self.offline_tokens
    }

    /// Build the aggregate report over `[0, duration_s]` (defaults to the
    /// last observed event time).
    pub fn report(&mut self, duration_s: Option<f64>) -> Report {
        let d = duration_s.unwrap_or(self.end_time).max(1e-9);
        Report {
            mean_ttft_ms: self.ttft.mean(),
            p99_ttft_ms: self.ttft.p99(),
            mean_tbt_ms: self.tbt.mean(),
            p99_tbt_ms: self.tbt.p99(),
            online_finished: self.online_finished,
            offline_finished: self.offline_finished,
            online_tps: self.online_tokens as f64 / d,
            offline_tps: self.offline_tokens as f64 / d,
            total_tps: (self.online_tokens + self.offline_tokens) as f64 / d,
            online_qps: self.online_finished as f64 / d,
            offline_qps: self.offline_finished as f64 / d,
            duration_s: d,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_and_tbt_online_only() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Online, 0.0);
        m.on_arrival(2, Class::Offline, 0.0);
        m.on_tokens(1, 0.050, 1); // TTFT 50ms
        m.on_tokens(1, 0.080, 1); // TBT 30ms
        m.on_tokens(1, 0.120, 1); // TBT 40ms
        m.on_tokens(2, 1.0, 1); // offline: no TTFT/TBT samples
        m.on_tokens(2, 2.0, 1);
        m.on_finish(1, 0.120);
        let r = m.report(Some(2.0));
        assert!((r.mean_ttft_ms - 50.0).abs() < 1e-9);
        assert!((r.mean_tbt_ms - 35.0).abs() < 1e-9);
        assert_eq!(r.online_finished, 1);
        assert_eq!(r.offline_finished, 0);
        assert!((r.online_tps - 1.5).abs() < 1e-9);
        assert!((r.offline_tps - 1.0).abs() < 1e-9);
    }

    #[test]
    fn prefill_chunk_tokens_counted_in_tps() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Offline, 0.0);
        m.on_tokens(1, 0.5, 4); // e.g. speculative/multi-token event
        let r = m.report(Some(1.0));
        assert!((r.offline_tps - 4.0).abs() < 1e-9);
    }

    #[test]
    fn report_metric_and_slo() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Online, 0.0);
        m.on_tokens(1, 0.040, 1);
        let r = m.report(Some(1.0));
        assert_eq!(r.metric(SloMetric::MeanTtft), r.mean_ttft_ms);
        assert!(r.meets(&Slo::new(SloMetric::MeanTtft, 41.0)));
        assert!(!r.meets(&Slo::new(SloMetric::MeanTtft, 39.0)));
    }

    #[test]
    fn unknown_request_token_ignored() {
        let mut m = Metrics::new(1.0);
        m.on_tokens(99, 1.0, 1); // no arrival recorded
        let r = m.report(Some(1.0));
        assert_eq!(r.total_tps, 0.0);
    }

    #[test]
    fn qps_series_counts_arrivals() {
        let mut m = Metrics::new(10.0);
        for i in 0..30 {
            m.on_arrival(i, Class::Online, i as f64);
        }
        let rates = m.online_qps_series.rates();
        assert_eq!(rates.len(), 3);
        assert!((rates[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn json_report_has_fields() {
        let mut m = Metrics::new(1.0);
        m.on_arrival(1, Class::Online, 0.0);
        m.on_tokens(1, 0.1, 1);
        let j = m.report(Some(1.0)).to_json();
        assert!(j.get("mean_ttft_ms").as_f64().is_some());
        assert!(j.get("total_tps").as_f64().is_some());
    }
}
