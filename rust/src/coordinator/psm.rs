//! Prefix-Sharing Maximization (§4.3, Alg. 3): a compressed prefix trie
//! over prompt tokens; offline requests are consumed in the trie's DFS
//! order so consecutive scheduled requests share the longest possible
//! prefixes (KV-cache reuse through the block manager's prefix cache).
//!
//! Insert/remove are O(L). `next_request` is O(1) amortized against a
//! cached DFS order that is rebuilt lazily — mirroring the paper's
//! "pre-processed list derived from the prefix tree, synced up
//! asynchronously" (Appendix A.4).
//!
//! With the N-class SLO registry, every `longest-prefix` class owns its
//! *own* trie (one [`OfflineQueue`](super::queues::OfflineQueue) per
//! class): per-class backlogs never interleave their DFS orders, and a
//! tolerant summarization class cannot dilute the batch class's prefix
//! families (or vice versa).

use super::request::RequestId;
use std::collections::BTreeMap;

type NodeId = u32;

#[derive(Debug, Default)]
struct Node {
    /// Outgoing edges keyed by first token — BTreeMap gives a
    /// deterministic DFS order.
    edges: BTreeMap<u32, Edge>,
    /// Requests whose prompt terminates exactly at this node.
    requests: Vec<RequestId>,
    parent: Option<(NodeId, u32)>, // (parent node, first token of edge in)
}

#[derive(Debug)]
struct Edge {
    label: Vec<u32>,
    child: NodeId,
}

/// Compressed (radix) prefix trie with DFS-order consumption.
#[derive(Debug)]
pub struct PrefixTree {
    nodes: Vec<Node>,
    free: Vec<NodeId>,
    len: usize,
    /// Cached DFS order; `dirty` forces a rebuild on next access.
    dfs_cache: Vec<RequestId>,
    dfs_pos: usize,
    dirty: bool,
    /// id -> node holding it (for O(L)-free removal bookkeeping).
    locations: BTreeMap<RequestId, NodeId>,
}

impl Default for PrefixTree {
    fn default() -> Self {
        Self::new()
    }
}

impl PrefixTree {
    pub fn new() -> PrefixTree {
        PrefixTree {
            nodes: vec![Node::default()],
            free: Vec::new(),
            len: 0,
            dfs_cache: Vec::new(),
            dfs_pos: 0,
            dirty: false,
            locations: BTreeMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn alloc_node(&mut self, parent: Option<(NodeId, u32)>) -> NodeId {
        if let Some(id) = self.free.pop() {
            self.nodes[id as usize] = Node { parent, ..Default::default() };
            id
        } else {
            self.nodes.push(Node { parent, ..Default::default() });
            (self.nodes.len() - 1) as NodeId
        }
    }

    /// Insert a request keyed by its prompt tokens. O(|prompt|).
    pub fn insert(&mut self, id: RequestId, prompt: &[u32]) {
        assert!(!self.locations.contains_key(&id), "duplicate insert of request {id}");
        let mut node = 0 as NodeId;
        let mut rest = prompt;
        loop {
            if rest.is_empty() {
                break;
            }
            let first = rest[0];
            let Some(edge) = self.nodes[node as usize].edges.get(&first) else {
                // no edge: attach the whole remainder as one edge
                let child = self.alloc_node(Some((node, first)));
                self.nodes[node as usize]
                    .edges
                    .insert(first, Edge { label: rest.to_vec(), child });
                node = child;
                rest = &[];
                break;
            };
            let label = edge.label.clone();
            let child = edge.child;
            let common = lcp(&label, rest);
            if common == label.len() {
                // full edge match: descend
                node = child;
                rest = &rest[common..];
            } else {
                // split the edge at `common`
                let mid = self.alloc_node(Some((node, first)));
                let (head, tail) = label.split_at(common);
                // node -> mid (head)
                self.nodes[node as usize]
                    .edges
                    .insert(first, Edge { label: head.to_vec(), child: mid });
                // mid -> old child (tail)
                self.nodes[child as usize].parent = Some((mid, tail[0]));
                self.nodes[mid as usize]
                    .edges
                    .insert(tail[0], Edge { label: tail.to_vec(), child });
                node = mid;
                rest = &rest[common..];
                // loop continues; next iteration either attaches remainder
                // or terminates here
            }
        }
        let _ = rest;
        self.nodes[node as usize].requests.push(id);
        self.locations.insert(id, node);
        self.len += 1;
        self.dirty = true;
    }

    /// Remove a request (by id). O(L) worst case for path cleanup.
    /// Returns true if it was present.
    pub fn remove(&mut self, id: RequestId) -> bool {
        let Some(node) = self.locations.remove(&id) else { return false };
        let reqs = &mut self.nodes[node as usize].requests;
        let Some(pos) = reqs.iter().position(|&r| r == id) else { return false };
        reqs.swap_remove(pos);
        self.len -= 1;
        self.prune(node);
        // Removal never changes relative DFS order of the survivors, so the
        // cache stays valid — dead ids are skipped on read.
        true
    }

    /// Prune empty leaf chains and merge single-child pass-through nodes.
    fn prune(&mut self, mut node: NodeId) {
        loop {
            if node == 0 {
                return;
            }
            let n = &self.nodes[node as usize];
            if !n.requests.is_empty() {
                return;
            }
            match n.edges.len() {
                0 => {
                    // empty leaf: detach from parent
                    let (parent, tok) = n.parent.expect("non-root has parent");
                    self.nodes[parent as usize].edges.remove(&tok);
                    self.free.push(node);
                    node = parent;
                }
                1 => {
                    // pass-through: merge the single child edge into parent
                    let (parent, ptok) = n.parent.expect("non-root has parent");
                    let (_ctok, Edge { label: clabel, child }) =
                        self.nodes[node as usize].edges.pop_first().unwrap();
                    let parent_edge =
                        self.nodes[parent as usize].edges.get_mut(&ptok).unwrap();
                    parent_edge.label.extend_from_slice(&clabel);
                    parent_edge.child = child;
                    self.nodes[child as usize].parent = Some((parent, ptok));
                    self.free.push(node);
                    return;
                }
                _ => return,
            }
        }
    }

    fn rebuild_dfs(&mut self) {
        self.dfs_cache.clear();
        self.dfs_pos = 0;
        // iterative DFS; shorter (ancestor) requests come before their
        // extensions, siblings in token order.
        let mut stack: Vec<NodeId> = vec![0];
        while let Some(n) = stack.pop() {
            let node = &self.nodes[n as usize];
            let mut reqs = node.requests.clone();
            reqs.sort_unstable(); // deterministic within a node
            self.dfs_cache.extend(reqs);
            // push children in reverse so smallest token pops first
            for edge in node.edges.values().rev() {
                stack.push(edge.child);
            }
        }
        self.dirty = false;
    }

    /// Peek the next request in DFS order without removing it. O(1)
    /// amortized (lazy rebuild after inserts).
    pub fn peek_next(&mut self) -> Option<RequestId> {
        if self.len == 0 {
            return None;
        }
        if self.dirty {
            self.rebuild_dfs();
        }
        while self.dfs_pos < self.dfs_cache.len() {
            let id = self.dfs_cache[self.dfs_pos];
            if self.locations.contains_key(&id) {
                return Some(id);
            }
            self.dfs_pos += 1; // skip removed ids
        }
        // cache exhausted but len > 0 can't happen unless dirty
        debug_assert!(self.len == 0 || self.dirty);
        if self.dirty {
            self.rebuild_dfs();
            return self.peek_next();
        }
        None
    }

    /// Pop the next request in DFS order.
    pub fn pop_next(&mut self) -> Option<RequestId> {
        let id = self.peek_next()?;
        self.remove(id);
        Some(id)
    }

    /// Full DFS order snapshot (tests/inspection).
    pub fn dfs_order(&mut self) -> Vec<RequestId> {
        if self.dirty {
            self.rebuild_dfs();
        }
        self.dfs_cache
            .iter()
            .copied()
            .filter(|id| self.locations.contains_key(id))
            .collect()
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.locations.contains_key(&id)
    }
}

/// Longest common prefix length of two token slices.
pub fn lcp(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<u32> {
        s.bytes().map(|b| b as u32).collect()
    }

    #[test]
    fn paper_example_reorders_by_prefix() {
        // Queue: (What is ML, How to code, What is AI, How to debug)
        // PSM order groups the "What is" and "How to" families.
        let mut t = PrefixTree::new();
        t.insert(1, &toks("What is ML"));
        t.insert(2, &toks("How to code"));
        t.insert(3, &toks("What is AI"));
        t.insert(4, &toks("How to debug"));
        let order = t.dfs_order();
        // 'H' < 'W' puts the How-to family first ("code" < "debug");
        // within What-is, "AI" < "ML". Families are contiguous — that is
        // the prefix-sharing win.
        assert_eq!(order, vec![2, 4, 3, 1]);
    }

    #[test]
    fn pop_consumes_in_dfs_order() {
        let mut t = PrefixTree::new();
        t.insert(1, &toks("aaa"));
        t.insert(2, &toks("aab"));
        t.insert(3, &toks("zzz"));
        assert_eq!(t.pop_next(), Some(1));
        assert_eq!(t.pop_next(), Some(2));
        assert_eq!(t.pop_next(), Some(3));
        assert_eq!(t.pop_next(), None);
        assert!(t.is_empty());
    }

    #[test]
    fn prefix_of_another_comes_first() {
        let mut t = PrefixTree::new();
        t.insert(1, &toks("abcdef"));
        t.insert(2, &toks("abc"));
        assert_eq!(t.dfs_order(), vec![2, 1], "ancestor (prefix) before extension");
    }

    #[test]
    fn duplicate_prompts_coexist() {
        let mut t = PrefixTree::new();
        t.insert(1, &toks("same"));
        t.insert(2, &toks("same"));
        assert_eq!(t.len(), 2);
        assert_eq!(t.pop_next(), Some(1));
        assert_eq!(t.pop_next(), Some(2));
    }

    #[test]
    fn empty_prompt_handled() {
        let mut t = PrefixTree::new();
        t.insert(1, &[]);
        t.insert(2, &toks("x"));
        assert_eq!(t.dfs_order(), vec![1, 2]);
        assert!(t.remove(1));
        assert_eq!(t.pop_next(), Some(2));
    }

    #[test]
    fn remove_then_reuse_structure() {
        let mut t = PrefixTree::new();
        t.insert(1, &toks("hello world"));
        t.insert(2, &toks("hello there"));
        assert!(t.remove(1));
        assert!(!t.remove(1), "double remove is a no-op");
        assert_eq!(t.len(), 1);
        assert_eq!(t.pop_next(), Some(2));
        // tree is reusable after full drain
        t.insert(3, &toks("hello again"));
        assert_eq!(t.pop_next(), Some(3));
    }

    #[test]
    fn interleaved_insert_peek_remove() {
        let mut t = PrefixTree::new();
        t.insert(10, &toks("bb"));
        assert_eq!(t.peek_next(), Some(10));
        t.insert(5, &toks("aa")); // earlier in DFS than current peek
        assert_eq!(t.peek_next(), Some(5), "insert invalidates cached order");
        assert_eq!(t.pop_next(), Some(5));
        assert_eq!(t.pop_next(), Some(10));
    }

    #[test]
    fn edge_split_cases() {
        let mut t = PrefixTree::new();
        t.insert(1, &toks("abcd"));
        t.insert(2, &toks("abxy")); // splits edge at "ab"
        t.insert(3, &toks("ab")); // terminates exactly at split point
        assert_eq!(t.dfs_order(), vec![3, 1, 2]);
        assert!(t.remove(3));
        assert_eq!(t.dfs_order(), vec![1, 2]);
    }

    #[test]
    fn lcp_works() {
        assert_eq!(lcp(&[1, 2, 3], &[1, 2, 9]), 2);
        assert_eq!(lcp(&[], &[1]), 0);
        assert_eq!(lcp(&[5], &[5]), 1);
    }

    #[test]
    fn large_family_grouping() {
        // Two template families interleaved on insert; DFS groups them.
        let mut t = PrefixTree::new();
        for i in 0..50u64 {
            let fam = if i % 2 == 0 { "What is topic " } else { "Summarize doc " };
            let prompt: Vec<u32> =
                toks(fam).into_iter().chain(toks(&format!("{i:03}"))).collect();
            t.insert(i, &prompt);
        }
        let order = t.dfs_order();
        // All odd ids (S... family, 'S' < 'W') first, then all even.
        let first_half: Vec<_> = order[..25].to_vec();
        assert!(first_half.iter().all(|id| id % 2 == 1), "families grouped: {order:?}");
    }
}
