//! Class-indexed queue architecture (§4.1, generalized): every SLO class
//! owns one waiting queue — a plain FCFS deque ([`FcfsQueue`]) or a
//! prefix-policy queue ([`OfflineQueue`]: FCFS / PSM / fairness-extended
//! PSM) — behind the uniform [`ClassQueue`] interface. The paper's dual
//! queues are the two-class default.
//!
//! Queues own waiting [`Request`]s; the scheduler peeks candidates in
//! policy order, tries to fit them against its latency/chunk/memory
//! budgets, and pops only what it actually schedules.

use super::fairness::FairPsm;
use super::psm::PrefixTree;
use super::request::{Request, RequestId};
use std::collections::{HashMap, VecDeque};

/// Plain FCFS queue (the classic online queue).
#[derive(Debug, Default)]
pub struct FcfsQueue {
    q: VecDeque<Request>,
}

impl FcfsQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, req: Request) {
        self.q.push_back(req);
    }

    /// Re-admit at the front (e.g. a request that could not be fully
    /// scheduled keeps its FCFS position).
    pub fn push_front(&mut self, req: Request) {
        self.q.push_front(req);
    }

    pub fn peek(&self) -> Option<&Request> {
        self.q.front()
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.q.pop_front()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Ids of all waiting requests, front to back (invariant checks).
    pub fn ids(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.q.iter().map(|r| r.id)
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.q.iter().any(|r| r.id == id)
    }

    /// Remove a specific request (cluster reclaim, client cancel). O(n) —
    /// off the scheduling hot path.
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        let pos = self.q.iter().position(|r| r.id == id)?;
        self.q.remove(pos)
    }

    /// Drop every waiting request (server abort path).
    pub fn clear(&mut self) {
        self.q.clear();
    }
}

/// Offline queue ordering policies (the §4.3 design space).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OfflinePolicy {
    /// Arrival order (the no-PSM baseline).
    Fcfs,
    /// Prefix-Sharing Maximization: DFS order of the prefix trie (Alg. 3).
    Psm,
    /// PSM + freshness mixing with the given utility ratio (Alg. 4).
    PsmFair { utility_ratio: f64 },
}

impl OfflinePolicy {
    pub fn parse(s: &str, utility_ratio: f64) -> Option<OfflinePolicy> {
        match s {
            "fcfs" => Some(OfflinePolicy::Fcfs),
            "psm" => Some(OfflinePolicy::Psm),
            "psm-fair" => Some(OfflinePolicy::PsmFair { utility_ratio }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OfflinePolicy::Fcfs => "fcfs",
            OfflinePolicy::Psm => "psm",
            OfflinePolicy::PsmFair { .. } => "psm-fair",
        }
    }
}

enum Order {
    Fcfs(VecDeque<RequestId>),
    Psm(PrefixTree),
    Fair(FairPsm),
}

/// The offline queue: request storage + one of the ordering structures.
pub struct OfflineQueue {
    reqs: HashMap<RequestId, Request>,
    order: Order,
    policy: OfflinePolicy,
    /// Prompt of the most recently popped request — the PSM prefix-sharing
    /// context for "deduct shared prefix between consecutive requests".
    last_prompt: Vec<u32>,
}

impl OfflineQueue {
    pub fn new(policy: OfflinePolicy, seed: u64) -> OfflineQueue {
        let order = match policy {
            OfflinePolicy::Fcfs => Order::Fcfs(VecDeque::new()),
            OfflinePolicy::Psm => Order::Psm(PrefixTree::new()),
            OfflinePolicy::PsmFair { utility_ratio } => {
                Order::Fair(FairPsm::new(utility_ratio, seed))
            }
        };
        OfflineQueue { reqs: HashMap::new(), order, policy, last_prompt: Vec::new() }
    }

    pub fn policy(&self) -> OfflinePolicy {
        self.policy
    }

    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    pub fn push(&mut self, req: Request) {
        match &mut self.order {
            Order::Fcfs(q) => q.push_back(req.id),
            Order::Psm(t) => t.insert(req.id, &req.prompt),
            Order::Fair(f) => f.insert(req.id, &req.prompt, req.arrival),
        }
        self.reqs.insert(req.id, req);
    }

    /// Next candidate in policy order (stable across repeated peeks).
    pub fn peek_next(&mut self) -> Option<&Request> {
        let id = match &mut self.order {
            Order::Fcfs(q) => q.front().copied(),
            Order::Psm(t) => t.peek_next(),
            Order::Fair(f) => f.peek_next(),
        }?;
        self.reqs.get(&id)
    }

    /// Pop the candidate returned by the last `peek_next`. Also computes
    /// the request's shared-prefix length vs the previously popped one
    /// (PSM's KV-reuse accounting) and stores it on the request.
    pub fn pop_next(&mut self) -> Option<Request> {
        let id = match &mut self.order {
            Order::Fcfs(q) => q.pop_front(),
            Order::Psm(t) => t.pop_next(),
            Order::Fair(f) => f.pop_next(),
        }?;
        let mut req = self.reqs.remove(&id).expect("order/storage in sync");
        req.shared_prefix_len = super::psm::lcp(&self.last_prompt, &req.prompt);
        // Reuse the context buffer instead of allocating a fresh clone of
        // every popped prompt (pops are on the admission hot path).
        self.last_prompt.clear();
        self.last_prompt.extend_from_slice(&req.prompt);
        Some(req)
    }

    /// Forget the last-popped prompt (the LCP baseline). Must be called
    /// when a popped request is returned *unscheduled* — admission undo,
    /// discard-mode preemption — otherwise the baseline is that request's
    /// own prompt and its next pop gets a bogus self-LCP credit (near-full
    /// "shared" prefix that is resident nowhere).
    pub fn reset_prefix_context(&mut self) {
        self.last_prompt.clear();
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.reqs.contains_key(&id)
    }

    /// Ids of all waiting requests, in ascending id order. Sorting makes
    /// the output independent of `HashMap` iteration order — callers are
    /// invariant checks and debug dumps, so the allocation is off the
    /// hot path and determinism is what matters.
    pub fn ids(&self) -> impl Iterator<Item = RequestId> + '_ {
        // lint: allow(map-iter, reason=hash order is erased by the sort below)
        let mut ids: Vec<RequestId> = self.reqs.keys().copied().collect();
        ids.sort_unstable();
        ids.into_iter()
    }

    /// Drop every waiting request (server abort path).
    pub fn clear(&mut self) {
        // Drain through the policy structure so its bookkeeping (trie,
        // fairness heap) empties alongside the storage map.
        while self.pop_next().is_some() {}
        debug_assert!(self.reqs.is_empty());
        // The drain walked pop_next, leaving the last drained prompt as
        // the LCP baseline — but every KV block was (or is about to be)
        // released, so nothing popped after the abort shares state with it.
        self.last_prompt.clear();
    }

    /// Remove a specific request (e.g. client cancelled).
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        let req = self.reqs.remove(&id)?;
        match &mut self.order {
            Order::Fcfs(q) => {
                q.retain(|&x| x != id);
            }
            Order::Psm(t) => {
                t.remove(id);
            }
            Order::Fair(f) => {
                f.remove(id);
            }
        }
        Some(req)
    }
}

/// One SLO class's waiting queue: either a plain FCFS deque (classes with
/// `fcfs` / `rate-capped` admission — the rate cap lives in the
/// scheduler) or a prefix-policy queue (`longest-prefix` admission;
/// boxed — the trie/fairness state is much larger than a deque). The
/// uniform interface keeps the scheduler's admission pass class-agnostic;
/// the two undo paths differ because only prefix queues carry the
/// consecutive-LCP context.
pub enum ClassQueue {
    Fcfs(FcfsQueue),
    Prefix(Box<OfflineQueue>),
}

impl ClassQueue {
    /// Wrap a prefix-policy queue.
    pub fn prefix(q: OfflineQueue) -> ClassQueue {
        ClassQueue::Prefix(Box::new(q))
    }
}

impl ClassQueue {
    pub fn len(&self) -> usize {
        match self {
            ClassQueue::Fcfs(q) => q.len(),
            ClassQueue::Prefix(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            ClassQueue::Fcfs(q) => q.is_empty(),
            ClassQueue::Prefix(q) => q.is_empty(),
        }
    }

    /// Admit an arriving request.
    pub fn push(&mut self, req: Request) {
        match self {
            ClassQueue::Fcfs(q) => q.push(req),
            ClassQueue::Prefix(q) => q.push(req),
        }
    }

    /// Next candidate in policy order (stable across repeated peeks).
    pub fn peek_next(&mut self) -> Option<&Request> {
        match self {
            ClassQueue::Fcfs(q) => q.peek(),
            ClassQueue::Prefix(q) => q.peek_next(),
        }
    }

    /// Pop the candidate the last `peek_next` returned.
    pub fn pop_next(&mut self) -> Option<Request> {
        match self {
            ClassQueue::Fcfs(q) => q.pop(),
            ClassQueue::Prefix(q) => q.pop_next(),
        }
    }

    /// Return a popped request that could not be scheduled. FCFS queues
    /// restore its head-of-line position; prefix queues re-insert it and
    /// forget the LCP baseline (its KV is resident nowhere — see
    /// [`OfflineQueue::reset_prefix_context`]).
    pub fn requeue_unscheduled(&mut self, req: Request) {
        match self {
            ClassQueue::Fcfs(q) => q.push_front(req),
            ClassQueue::Prefix(q) => {
                q.push(req);
                q.reset_prefix_context();
            }
        }
    }

    pub fn contains(&self, id: RequestId) -> bool {
        match self {
            ClassQueue::Fcfs(q) => q.contains(id),
            ClassQueue::Prefix(q) => q.contains(id),
        }
    }

    /// Remove a specific request (cluster reclaim, client cancel).
    ///
    /// Removal alone does not touch the LCP baseline: the popped-prompt
    /// context is still valid for requests that stay and pop
    /// consecutively. Callers that *re-route* removed prefix work (the
    /// cluster reclaim/migration paths) must call
    /// [`ClassQueue::reset_prefix_context`] afterwards — the detour
    /// breaks the consecutive-scheduling assumption behind the credit.
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        match self {
            ClassQueue::Fcfs(q) => q.remove(id),
            ClassQueue::Prefix(q) => q.remove(id),
        }
    }

    /// Forget the prefix queue's LCP baseline (no-op for FCFS queues).
    /// Same bug class as the self-LCP over-credit fix: whenever queue
    /// contents are mutated out-of-band (cluster reclaim pulling work
    /// back to the shared backlog, fault migration), the next pop must
    /// not claim a shared prefix against a prompt that may never be
    /// scheduled adjacently.
    pub fn reset_prefix_context(&mut self) {
        if let ClassQueue::Prefix(q) = self {
            q.reset_prefix_context();
        }
    }

    /// Ids of all waiting requests (invariant checks; order is
    /// queue-specific).
    pub fn ids(&self) -> Box<dyn Iterator<Item = RequestId> + '_> {
        match self {
            ClassQueue::Fcfs(q) => Box::new(q.ids()),
            ClassQueue::Prefix(q) => Box::new(q.ids()),
        }
    }

    /// Arrival time of the current head candidate (starvation checks).
    pub fn head_arrival(&mut self) -> Option<f64> {
        self.peek_next().map(|r| r.arrival)
    }

    /// Drop every waiting request (server abort path).
    pub fn clear(&mut self) {
        match self {
            ClassQueue::Fcfs(q) => q.clear(),
            ClassQueue::Prefix(q) => q.clear(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Class;

    fn offline(id: RequestId, prompt: &str, arrival: f64) -> Request {
        Request::new(id, Class::OFFLINE, arrival, prompt.len(), 8)
            .with_prompt(prompt.bytes().map(|b| b as u32).collect::<Vec<u32>>())
    }

    #[test]
    fn fcfs_queue_basics() {
        let mut q = FcfsQueue::new();
        q.push(Request::new(1, Class::ONLINE, 0.0, 4, 4));
        q.push(Request::new(2, Class::ONLINE, 1.0, 4, 4));
        assert_eq!(q.peek().unwrap().id, 1);
        let r = q.pop().unwrap();
        assert_eq!(r.id, 1);
        q.push_front(r);
        assert_eq!(q.pop().unwrap().id, 1, "push_front restores position");
        assert_eq!(q.len(), 1);
        assert!(q.contains(2));
        assert!(q.remove(2).is_some());
        assert!(q.remove(2).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn class_queue_uniform_interface() {
        for mut q in [
            ClassQueue::Fcfs(FcfsQueue::new()),
            ClassQueue::prefix(OfflineQueue::new(OfflinePolicy::Psm, 0)),
        ] {
            q.push(offline(1, "aaa", 0.0));
            q.push(offline(2, "aab", 1.0));
            assert_eq!(q.len(), 2);
            assert_eq!(q.head_arrival(), Some(0.0));
            let head = q.peek_next().unwrap().id;
            let popped = q.pop_next().unwrap();
            assert_eq!(popped.id, head);
            // An unscheduled pop goes back and is the next candidate again
            // (FCFS restores head-of-line; prefix re-inserts + resets LCP).
            q.requeue_unscheduled(popped);
            assert_eq!(q.len(), 2);
            assert_eq!(q.peek_next().unwrap().id, head);
            assert!(q.contains(1) && q.contains(2));
            assert_eq!(q.ids().count(), 2);
            assert!(q.remove(2).is_some());
            q.clear();
            assert!(q.is_empty());
        }
    }

    #[test]
    fn fcfs_policy_is_arrival_order() {
        let mut q = OfflineQueue::new(OfflinePolicy::Fcfs, 0);
        q.push(offline(1, "zzz", 0.0));
        q.push(offline(2, "aaa", 1.0));
        assert_eq!(q.pop_next().unwrap().id, 1);
        assert_eq!(q.pop_next().unwrap().id, 2);
    }

    #[test]
    fn psm_policy_is_dfs_order_with_shared_prefix() {
        let mut q = OfflineQueue::new(OfflinePolicy::Psm, 0);
        q.push(offline(1, "What is ML", 0.0));
        q.push(offline(2, "How to code", 1.0));
        q.push(offline(3, "What is AI", 2.0));
        q.push(offline(4, "How to debug", 3.0));
        let order: Vec<(RequestId, usize)> = std::iter::from_fn(|| {
            q.pop_next().map(|r| (r.id, r.shared_prefix_len))
        })
        .collect();
        assert_eq!(
            order.iter().map(|x| x.0).collect::<Vec<_>>(),
            vec![2, 4, 3, 1],
            "PSM groups families"
        );
        assert_eq!(order[0].1, 0);
        assert_eq!(order[1].1, "How to ".len(), "consecutive share 'How to '");
        assert_eq!(order[3].1, "What is ".len());
    }

    #[test]
    fn fcfs_has_no_prefix_wins_on_interleaved_families() {
        let mut q = OfflineQueue::new(OfflinePolicy::Fcfs, 0);
        q.push(offline(1, "What is ML", 0.0));
        q.push(offline(2, "How to code", 1.0));
        q.push(offline(3, "What is AI", 2.0));
        q.push(offline(4, "How to debug", 3.0));
        let shared: usize =
            std::iter::from_fn(|| q.pop_next().map(|r| r.shared_prefix_len)).sum();
        assert_eq!(shared, 0, "arrival order alternates families");
    }

    #[test]
    fn peek_then_pop_consistent() {
        let mut q = OfflineQueue::new(OfflinePolicy::PsmFair { utility_ratio: 0.5 }, 3);
        for i in 0..20u64 {
            q.push(offline(i, &format!("prompt {i}"), i as f64));
        }
        for _ in 0..20 {
            let peeked = q.peek_next().unwrap().id;
            assert_eq!(q.peek_next().unwrap().id, peeked);
            assert_eq!(q.pop_next().unwrap().id, peeked);
        }
        assert!(q.is_empty());
    }

    #[test]
    fn reclaim_style_remove_plus_reset_drops_the_lcp_baseline() {
        // The cluster reclaim path: pop one request (setting the LCP
        // baseline to its prompt), remove a sibling out-of-band, reset
        // the context, and push the sibling back (the backlog detour
        // re-placed it here). Without the reset the re-pushed request
        // would claim an "aaa*"-sized shared prefix against KV that was
        // never scheduled adjacently.
        let mut q = ClassQueue::prefix(OfflineQueue::new(OfflinePolicy::Psm, 0));
        q.push(offline(1, "aaaa", 0.0));
        q.push(offline(2, "aaab", 1.0));
        let first = q.pop_next().unwrap(); // baseline := first.prompt
        assert_eq!(first.shared_prefix_len, 0);
        let reclaimed = q.remove(if first.id == 1 { 2 } else { 1 }).unwrap();
        q.reset_prefix_context();
        q.push(reclaimed);
        assert_eq!(
            q.pop_next().unwrap().shared_prefix_len,
            0,
            "a request re-entering after an out-of-band detour gets no LCP credit"
        );
        // Control: the credit *does* apply on the uninterrupted path.
        let mut q = ClassQueue::prefix(OfflineQueue::new(OfflinePolicy::Psm, 0));
        q.push(offline(1, "aaaa", 0.0));
        q.push(offline(2, "aaab", 1.0));
        q.pop_next().unwrap();
        assert_eq!(q.pop_next().unwrap().shared_prefix_len, 3, "consecutive pops share 'aaa'");
    }

    #[test]
    fn remove_from_all_policies() {
        for policy in [
            OfflinePolicy::Fcfs,
            OfflinePolicy::Psm,
            OfflinePolicy::PsmFair { utility_ratio: 0.7 },
        ] {
            let mut q = OfflineQueue::new(policy, 1);
            q.push(offline(1, "abc", 0.0));
            q.push(offline(2, "abd", 1.0));
            assert!(q.remove(1).is_some());
            assert!(q.remove(1).is_none());
            assert_eq!(q.len(), 1);
            assert_eq!(q.pop_next().unwrap().id, 2);
        }
    }

    #[test]
    fn policy_parse() {
        assert_eq!(OfflinePolicy::parse("fcfs", 0.5), Some(OfflinePolicy::Fcfs));
        assert_eq!(OfflinePolicy::parse("psm", 0.5), Some(OfflinePolicy::Psm));
        assert_eq!(
            OfflinePolicy::parse("psm-fair", 0.5),
            Some(OfflinePolicy::PsmFair { utility_ratio: 0.5 })
        );
        assert_eq!(OfflinePolicy::parse("nope", 0.5), None);
    }
}
