//! The SLO-class registry: the N-class generalization of the paper's
//! online/offline dichotomy.
//!
//! Real fleets serve a *spectrum* of SLOs — interactive chat with tight
//! TTFT, code completion with tight TBT, tolerant summarization, and
//! pure-throughput batch (SLOs-Serve; ConServe's priority tiers). Every
//! layer of this system is indexed by [`ClassId`](crate::coordinator::request::ClassId)
//! into a [`ClassRegistry`] instead of matching on a two-variant enum:
//!
//! * the scheduler loops over **descending tiers** — higher tiers charge
//!   the iteration latency budget first, lower tiers drink the residual;
//! * **preemption only flows down-tier** (and LIFO within a class);
//! * each class declares its own admission policy (FCFS, longest-prefix
//!   DFS, or rate-capped FCFS), optional TTFT/TBT SLOs, a latency-budget
//!   stance (`None` = bypass the per-iteration check like the paper's
//!   online class; `Some(m)` = charged, with `m` a multiplier on the
//!   iteration budget the class tolerates — the cluster router's
//!   "tightest present class" signal), and optional starvation
//!   protection.
//!
//! The compiled-in default — [`ClassRegistry::default_two`] — is exactly
//! the paper's two-class setup, and the scheduler is behavior-preserving
//! under it (`hygen cluster-sim --check` and the fig6/fig10 CSVs are
//! byte-identical to the pre-registry code).

use crate::coordinator::request::ClassId;
use crate::util::json::Json;

/// Hard cap on registry size. Census structures ([`super::state::PhaseCounts`],
/// [`crate::cluster::ReplicaSnapshot`]) use fixed arrays of this length so
/// snapshots stay `Copy` and allocation-free on the hot path.
pub const MAX_CLASSES: usize = 8;

/// How a class's waiting queue is ordered and admitted.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionPolicy {
    /// Arrival order (the classic online queue).
    Fcfs,
    /// Prefix-sharing DFS order (the classic offline/PSM queue). The
    /// concrete ordering structure (fcfs / psm / psm-fair) remains
    /// configurable per deployment via [`OfflinePolicy`](crate::coordinator::queues::OfflinePolicy).
    LongestPrefix,
    /// FCFS with a token-bucket admission cap (HyGen*-style pacing).
    RateCapped {
        /// Admissions per second.
        qps: f64,
    },
}

impl AdmissionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Fcfs => "fcfs",
            AdmissionPolicy::LongestPrefix => "longest-prefix",
            AdmissionPolicy::RateCapped { .. } => "rate-capped",
        }
    }
}

/// One service class's declaration.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassSpec {
    /// Human-readable name (config files, `/v1/completions` `class`
    /// field, CSV columns).
    pub name: String,
    /// Scheduling tier: higher = more latency-sensitive. The scheduler
    /// visits tiers in descending order; preemption only flows strictly
    /// down-tier.
    pub tier: u8,
    /// Declared TTFT SLO (ms). Classes with a TTFT SLO are routed
    /// immediately by the cluster layer; classes without one are
    /// *elastic* — they enter the shared backlog and are placed at
    /// rebalance ticks.
    pub ttft_slo_ms: Option<f64>,
    /// Declared TBT SLO (ms), reported as per-class attainment.
    pub tbt_slo_ms: Option<f64>,
    /// Latency-budget stance. `None` = bypass: running decodes of this
    /// class are scheduled regardless of the residual per-iteration
    /// budget (the paper's online class — the budget is profiled *for*
    /// it). `Some(m)` = SLO-charged: the class only drinks residual
    /// budget, and `m` scales the iteration budget the class tolerates
    /// (`1.0` = the profiled budget; larger = more tolerant — the
    /// cluster router's "tightest present class" headroom signal; values
    /// below `1.0` additionally cap the class's own per-iteration
    /// spend).
    pub latency_budget: Option<f64>,
    /// Preemption priority stamped on requests at admission (higher
    /// wins; informational — scheduling order is governed by `tier`).
    pub preempt_priority: u8,
    pub admission: AdmissionPolicy,
    /// Starvation protection: once the head of this class's queue has
    /// waited longer than this many seconds, its admission bypasses the
    /// class's rate cap (it still respects memory and the latency
    /// budget).
    pub starvation_age_s: Option<f64>,
}

impl ClassSpec {
    /// True when this class bypasses the per-iteration latency check.
    pub fn bypasses_budget(&self) -> bool {
        self.latency_budget.is_none()
    }

    /// The class's tolerance multiplier on the iteration budget (bypass
    /// classes define the budget, i.e. tolerance 1.0).
    pub fn budget_tolerance(&self) -> f64 {
        self.latency_budget.unwrap_or(1.0)
    }

    /// Elastic classes have no TTFT SLO: the cluster layer may hold them
    /// in the shared backlog instead of placing them at arrival.
    pub fn elastic(&self) -> bool {
        self.ttft_slo_ms.is_none()
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::from(self.name.as_str())),
            ("tier", Json::from(self.tier as u64)),
            ("preempt_priority", Json::from(self.preempt_priority as u64)),
            ("admission", Json::from(self.admission.name())),
        ];
        if let AdmissionPolicy::RateCapped { qps } = self.admission {
            pairs.push(("rate_qps", Json::from(qps)));
        }
        if let Some(v) = self.ttft_slo_ms {
            pairs.push(("ttft_slo_ms", Json::from(v)));
        }
        if let Some(v) = self.tbt_slo_ms {
            pairs.push(("tbt_slo_ms", Json::from(v)));
        }
        if let Some(v) = self.latency_budget {
            pairs.push(("latency_budget", Json::from(v)));
        }
        if let Some(v) = self.starvation_age_s {
            pairs.push(("starvation_age_s", Json::from(v)));
        }
        Json::obj(pairs)
    }

    fn from_json(j: &Json) -> anyhow::Result<ClassSpec> {
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("class spec needs a string 'name'"))?
            .to_string();
        let tier = j
            .get("tier")
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("class '{name}' needs an integer 'tier'"))?;
        anyhow::ensure!(tier <= u8::MAX as u64, "class '{name}': tier out of range");
        let opt = |key: &str| -> anyhow::Result<Option<f64>> {
            match j.get(key) {
                Json::Null => Ok(None),
                v => v
                    .as_f64()
                    .filter(|x| x.is_finite() && *x >= 0.0)
                    .map(Some)
                    .ok_or_else(|| {
                        anyhow::anyhow!("class '{name}': {key} must be a non-negative number")
                    }),
            }
        };
        let admission = match j.get("admission").as_str().unwrap_or("fcfs") {
            "fcfs" => AdmissionPolicy::Fcfs,
            "longest-prefix" => AdmissionPolicy::LongestPrefix,
            "rate-capped" => {
                let qps = opt("rate_qps")?
                    .ok_or_else(|| anyhow::anyhow!("class '{name}': rate-capped needs rate_qps"))?;
                anyhow::ensure!(qps > 0.0, "class '{name}': rate_qps must be positive");
                AdmissionPolicy::RateCapped { qps }
            }
            other => anyhow::bail!("class '{name}': unknown admission '{other}'"),
        };
        let preempt_priority = match j.get("preempt_priority") {
            Json::Null => 0,
            v => v
                .as_u64()
                .filter(|x| *x <= u8::MAX as u64)
                .ok_or_else(|| anyhow::anyhow!("class '{name}': preempt_priority must be 0-255"))?
                as u8,
        };
        let ttft_slo_ms = opt("ttft_slo_ms")?;
        let tbt_slo_ms = opt("tbt_slo_ms")?;
        let latency_budget = opt("latency_budget")?;
        // A zero tolerance would make the class silently unschedulable
        // (its spend cap can never fit a token) and poison the cluster
        // headroom signal with 0 * inf = NaN. Bypass is spelled by
        // omitting the key, not by zeroing it.
        anyhow::ensure!(
            latency_budget != Some(0.0),
            "class '{name}': latency_budget must be positive (omit the key to bypass)"
        );
        let starvation_age_s = opt("starvation_age_s")?;
        Ok(ClassSpec {
            name,
            tier: tier as u8,
            ttft_slo_ms,
            tbt_slo_ms,
            latency_budget,
            preempt_priority,
            admission,
            starvation_age_s,
        })
    }
}

/// The session's class table, indexed by [`ClassId`]. Validated once at
/// construction; the scheduler and cluster layer read the precomputed
/// tier orders every iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassRegistry {
    specs: Vec<ClassSpec>,
    /// Class ids by descending tier (ties: ascending id) — the
    /// scheduler's pass order.
    order_desc: Vec<ClassId>,
    /// Class ids by ascending tier (ties: ascending id) — the preemption
    /// victim search order.
    order_asc: Vec<ClassId>,
}

impl ClassRegistry {
    pub fn new(specs: Vec<ClassSpec>) -> anyhow::Result<ClassRegistry> {
        anyhow::ensure!(!specs.is_empty(), "registry needs at least one class");
        anyhow::ensure!(
            specs.len() <= MAX_CLASSES,
            "registry supports at most {MAX_CLASSES} classes, got {}",
            specs.len()
        );
        for (i, a) in specs.iter().enumerate() {
            anyhow::ensure!(!a.name.is_empty(), "class {i} has an empty name");
            if let Some(b) = a.latency_budget {
                // Zero/negative/non-finite tolerances make the class
                // unschedulable and poison the cluster headroom signal
                // with 0 * inf = NaN; bypass is spelled `None`.
                anyhow::ensure!(
                    b.is_finite() && b > 0.0,
                    "class '{}': latency_budget must be a positive finite number \
                     (use None to bypass the budget)",
                    a.name
                );
            }
            for b in &specs[..i] {
                anyhow::ensure!(a.name != b.name, "duplicate class name '{}'", a.name);
            }
        }
        let top = specs.iter().map(|s| s.tier).max().unwrap();
        anyhow::ensure!(
            specs[0].tier == top,
            "class 0 ('{}') must be a top-tier class: the metrics/report \
             layer treats index 0 as the flagship interactive class",
            specs[0].name
        );
        let mut order_desc: Vec<ClassId> = (0..specs.len() as u16).map(ClassId).collect();
        order_desc.sort_by_key(|c| (std::cmp::Reverse(specs[c.index()].tier), c.0));
        let mut order_asc: Vec<ClassId> = (0..specs.len() as u16).map(ClassId).collect();
        order_asc.sort_by_key(|c| (specs[c.index()].tier, c.0));
        Ok(ClassRegistry { specs, order_desc, order_asc })
    }

    /// The paper's two-class setup: a budget-bypassing FCFS online class
    /// above a budget-charged longest-prefix offline class. The
    /// compiled-in default everywhere a registry is not configured.
    pub fn default_two() -> ClassRegistry {
        ClassRegistry::new(vec![
            ClassSpec {
                name: "online".into(),
                tier: 1,
                ttft_slo_ms: Some(1000.0),
                tbt_slo_ms: Some(100.0),
                latency_budget: None,
                preempt_priority: 100,
                admission: AdmissionPolicy::Fcfs,
                starvation_age_s: None,
            },
            ClassSpec {
                name: "offline".into(),
                tier: 0,
                ttft_slo_ms: None,
                tbt_slo_ms: None,
                latency_budget: Some(1.0),
                preempt_priority: 0,
                admission: AdmissionPolicy::LongestPrefix,
                starvation_age_s: None,
            },
        ])
        .expect("compiled-in default registry is valid")
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn spec(&self, c: ClassId) -> &ClassSpec {
        &self.specs[c.index()]
    }

    pub fn specs(&self) -> &[ClassSpec] {
        &self.specs
    }

    /// All class ids in index order.
    pub fn ids(&self) -> impl Iterator<Item = ClassId> + '_ {
        (0..self.specs.len() as u16).map(ClassId)
    }

    /// Class ids by descending tier (scheduler pass order).
    pub fn tier_order_desc(&self) -> &[ClassId] {
        &self.order_desc
    }

    /// Class ids by ascending tier (preemption victim search order).
    pub fn tier_order_asc(&self) -> &[ClassId] {
        &self.order_asc
    }

    /// The highest tier present in the registry.
    pub fn top_tier(&self) -> u8 {
        self.specs[self.order_desc[0].index()].tier
    }

    pub fn by_name(&self, name: &str) -> Option<ClassId> {
        self.specs
            .iter()
            .position(|s| s.name == name)
            .map(|i| ClassId(i as u16))
    }

    /// The registry as a JSON array (the `classes` config key).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.specs.iter().map(|s| s.to_json()).collect())
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ClassRegistry> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'classes' must be an array of class specs"))?;
        let specs = arr.iter().map(ClassSpec::from_json).collect::<anyhow::Result<Vec<_>>>()?;
        ClassRegistry::new(specs)
    }
}

impl Default for ClassRegistry {
    fn default() -> Self {
        ClassRegistry::default_two()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, tier: u8) -> ClassSpec {
        ClassSpec {
            name: name.into(),
            tier,
            ttft_slo_ms: None,
            tbt_slo_ms: None,
            latency_budget: Some(1.0),
            preempt_priority: 0,
            admission: AdmissionPolicy::Fcfs,
            starvation_age_s: None,
        }
    }

    #[test]
    fn default_two_matches_the_paper_shape() {
        let r = ClassRegistry::default_two();
        assert_eq!(r.len(), 2);
        assert_eq!(r.by_name("online"), Some(ClassId::ONLINE));
        assert_eq!(r.by_name("offline"), Some(ClassId::OFFLINE));
        assert!(r.spec(ClassId::ONLINE).bypasses_budget());
        assert!(!r.spec(ClassId::OFFLINE).bypasses_budget());
        assert!(!r.spec(ClassId::ONLINE).elastic());
        assert!(r.spec(ClassId::OFFLINE).elastic());
        assert_eq!(r.tier_order_desc(), &[ClassId::ONLINE, ClassId::OFFLINE]);
        assert_eq!(r.tier_order_asc(), &[ClassId::OFFLINE, ClassId::ONLINE]);
        assert_eq!(r.top_tier(), 1);
        assert_eq!(r.spec(ClassId::ONLINE).budget_tolerance(), 1.0);
        assert_eq!(r.spec(ClassId::OFFLINE).budget_tolerance(), 1.0);
    }

    #[test]
    fn tier_orders_break_ties_by_index() {
        let r = ClassRegistry::new(vec![
            spec("a", 2),
            spec("b", 0),
            spec("c", 2),
            spec("d", 1),
        ])
        .unwrap();
        let desc: Vec<u16> = r.tier_order_desc().iter().map(|c| c.0).collect();
        assert_eq!(desc, vec![0, 2, 3, 1]);
        let asc: Vec<u16> = r.tier_order_asc().iter().map(|c| c.0).collect();
        assert_eq!(asc, vec![1, 3, 0, 2]);
    }

    #[test]
    fn validation_rejects_bad_registries() {
        assert!(ClassRegistry::new(vec![]).is_err());
        assert!(
            ClassRegistry::new(vec![spec("x", 0), spec("x", 1)]).is_err(),
            "duplicate names"
        );
        assert!(
            ClassRegistry::new(vec![spec("low", 0), spec("high", 3)]).is_err(),
            "class 0 must be top-tier"
        );
        let too_many: Vec<ClassSpec> =
            (0..MAX_CLASSES + 1).map(|i| spec(&format!("c{i}"), 0)).collect();
        assert!(ClassRegistry::new(too_many).is_err());
        // The API path enforces positive finite tolerances too, not just
        // the JSON parser.
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let s = ClassSpec { latency_budget: Some(bad), ..spec("z", 0) };
            assert!(
                ClassRegistry::new(vec![s]).is_err(),
                "latency_budget {bad} must be rejected"
            );
        }
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        let r = ClassRegistry::new(vec![
            ClassSpec {
                name: "chat".into(),
                tier: 3,
                ttft_slo_ms: Some(300.0),
                tbt_slo_ms: Some(50.0),
                latency_budget: None,
                preempt_priority: 200,
                admission: AdmissionPolicy::Fcfs,
                starvation_age_s: None,
            },
            ClassSpec {
                name: "batch".into(),
                tier: 0,
                ttft_slo_ms: None,
                tbt_slo_ms: None,
                latency_budget: Some(4.0),
                preempt_priority: 0,
                admission: AdmissionPolicy::RateCapped { qps: 2.5 },
                starvation_age_s: Some(120.0),
            },
        ])
        .unwrap();
        let j = r.to_json();
        let back = ClassRegistry::from_json(&j).unwrap();
        assert_eq!(back, r);
        let j2 = ClassRegistry::default_two().to_json();
        assert_eq!(ClassRegistry::from_json(&j2).unwrap(), ClassRegistry::default_two());
    }

    #[test]
    fn json_rejects_malformed_specs() {
        let bad = Json::parse(r#"[{"tier": 1}]"#).unwrap();
        assert!(ClassRegistry::from_json(&bad).is_err(), "missing name");
        let bad = Json::parse(r#"[{"name": "x"}]"#).unwrap();
        assert!(ClassRegistry::from_json(&bad).is_err(), "missing tier");
        let bad = Json::parse(r#"[{"name": "x", "tier": 0, "admission": "magic"}]"#).unwrap();
        assert!(ClassRegistry::from_json(&bad).is_err(), "unknown admission");
        let bad =
            Json::parse(r#"[{"name": "x", "tier": 0, "admission": "rate-capped"}]"#).unwrap();
        assert!(ClassRegistry::from_json(&bad).is_err(), "rate-capped needs rate_qps");
        assert!(ClassRegistry::from_json(&Json::parse("{}").unwrap()).is_err(), "not an array");
        let bad = Json::parse(r#"[{"name": "x", "tier": 0, "latency_budget": 0}]"#).unwrap();
        assert!(
            ClassRegistry::from_json(&bad).is_err(),
            "a zero tolerance is unschedulable, not a bypass spelling"
        );
    }
}
