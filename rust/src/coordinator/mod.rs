//! Layer-3 coordination: the paper's system contribution.
//!
//! * [`request`] / [`batch`] — the request/batch domain model and the
//!   predictor feature vector (Eq. 1).
//! * [`classes`] — the SLO-class registry (tiers, budgets, admission
//!   policies); the paper's online/offline split is its two-class
//!   default.
//! * [`predictor`] — the linear-regression latency predictor (§4.2).
//! * [`profiler`] — the SLO-aware latency-budget profiler (§4.2).
//! * [`scheduler`] — the tier-loop SLO-aware scheduler (§4.1, Alg. 1–2
//!   generalized to N classes) with down-tier preemption.
//! * [`psm`] / [`fairness`] / [`queues`] — per-class queue policies:
//!   FCFS, Prefix-Sharing Maximization (Alg. 3), fairness-extended PSM
//!   (Alg. 4) behind the class-indexed queue array.
//! * [`block_manager`] — paged KV accounting with prefix caching.
//! * [`runset`] — order-preserving indexed running sets (O(1) hot path).
//! * [`state`] — the engine state the scheduler mutates.
//! * [`metrics`] — per-class TTFT/TBT/TPS accounting the SLO checks run
//!   on.

pub mod batch;
pub mod classes;
pub mod block_manager;
pub mod fairness;
pub mod metrics;
pub mod predictor;
pub mod profiler;
pub mod psm;
pub mod queues;
pub mod request;
pub mod runset;
pub mod scheduler;
pub mod state;
