//! The HyGen SLO-aware scheduler (§4.1, Alg. 1–2), generalized from the
//! paper's two phases to a **loop over descending SLO-class tiers**.
//!
//! Each engine iteration builds one hybrid batch under three budgets:
//!
//! * **latency** `t` — the profiled per-iteration latency budget (ms); the
//!   predictor charges every scheduling decision against it. `None`
//!   disables SLO-awareness (that is exactly the Sarathi++ baseline).
//! * **chunk** `c` — the Sarathi token budget per iteration.
//! * **memory** `m` — free KV blocks via the
//!   [`BlockManager`](super::block_manager::BlockManager).
//!
//! The scheduler visits the registry's classes from the highest tier
//! down. Each class runs the same four passes — running decodes, running
//! prefill chunks, preempted resumes, new admissions — parameterized by
//! its [`ClassSpec`](super::classes::ClassSpec):
//!
//! * classes whose `latency_budget` is `None` **bypass** the budget:
//!   their decodes are scheduled unconditionally (Alg. 1 line 8) and a
//!   memory stall skips one request instead of ending the pass;
//! * charged classes only drink the **residual** budget left by higher
//!   tiers, stop at the first decode that does not fit, and may carry an
//!   additional per-iteration spend cap (`latency_budget < 1.0`);
//! * **preemption flows down-tier only** (lowest tier first, LIFO within
//!   the victim class); a charged class with nothing below may
//!   self-preempt its own newest request (vLLM-style) so older decodes
//!   keep making progress, while bypass classes stall instead — evicting
//!   a peer would break that peer's SLO too;
//! * admissions follow the class queue's policy order (FCFS or PSM DFS),
//!   optionally paced by a per-class rate cap, with per-class
//!   starvation protection lifting the cap once the queue head has
//!   waited `starvation_age_s`.
//!
//! With the default two-class registry this reduces *exactly* to the
//! paper's two-phase algorithm — phase 1 = the bypass online class,
//! phase 2 = the charged offline class — and is behavior-preserving down
//! to the emitted batch order. The same struct, differently configured,
//! implements every baseline in the paper's evaluation — see
//! [`SchedulerConfig`] and `baselines/`.

use super::batch::{Batch, BatchEntry, Features};
use super::classes::{AdmissionPolicy, ClassRegistry};
use super::predictor::LatencyPredictor;
use super::request::{Class, Phase, RequestId};
use super::state::EngineState;
use crate::obs::recorder::EventKind;
use std::sync::Arc;

/// How preempted requests are handled (InferCept's taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptionMode {
    /// Keep prefill/decode progress; only KV blocks are released
    /// (swap-to-host semantics). The paper's default.
    Preserve,
    /// Drop computed state; the request re-enters its class queue and
    /// recomputes its prefill.
    Discard,
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Per-iteration latency budget in ms (from the SLO-aware profiler).
    /// `None` = SLO-unaware hybrid scheduling (Sarathi++).
    pub latency_budget_ms: Option<f64>,
    /// Token budget per iteration (Sarathi chunk size).
    pub chunk_tokens: usize,
    /// Max prefill tokens for one request in one iteration (the real
    /// engine's per-slot chunk bucket; `usize::MAX` to disable).
    pub max_chunk_per_request: usize,
    /// Max concurrently running requests (the real engine has 8 slots).
    pub max_running: usize,
    pub preemption: PreemptionMode,
    /// Schedule below-top-tier work at all (false = pure-online Sarathi:
    /// only the registry's highest tier is served).
    pub enable_offline: bool,
    /// HyGen* baseline: cap the default harvest class's admissions at
    /// this rate (req/s). Per-class caps live in the registry
    /// (`AdmissionPolicy::RateCapped`).
    pub offline_qps_cap: Option<f64>,
    /// Blocks held back from admissions so running decodes can grow.
    pub watermark_blocks: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            latency_budget_ms: Some(50.0),
            chunk_tokens: 512,
            max_chunk_per_request: usize::MAX,
            max_running: 256,
            preemption: PreemptionMode::Preserve,
            enable_offline: true,
            offline_qps_cap: None,
            watermark_blocks: 8,
        }
    }
}

/// Simple token-bucket rate limiter (HyGen*'s fixed offline QPS; the
/// registry's `rate-capped` admission policy).
#[derive(Debug, Clone)]
pub struct RateLimiter {
    rate: f64,
    tokens: f64,
    last: f64,
    burst: f64,
}

impl RateLimiter {
    pub fn new(rate: f64) -> RateLimiter {
        RateLimiter { rate, tokens: 1.0, last: 0.0, burst: 1.0_f64.max(rate) }
    }

    /// Try to consume one permit at time `now` (seconds).
    pub fn admit(&mut self, now: f64) -> bool {
        if now > self.last {
            self.tokens = (self.tokens + (now - self.last) * self.rate).min(self.burst);
            self.last = now;
        } else if now < self.last {
            // Non-monotonic clock (NTP step, cross-source timestamps):
            // re-anchor at the earlier time without granting retroactive
            // tokens, so refill resumes as the clock moves forward again
            // instead of being skipped forever.
            self.last = now;
        }
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Per-iteration scheduling statistics (observability + tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScheduleStats {
    /// Down-tier preemptions performed this iteration (same-class
    /// self-preemptions are not counted — they are a memory-rotation
    /// mechanism, not an SLO action).
    pub preemptions: usize,
    /// Pass steps where a budget-bypassing (SLO) class could not grow or
    /// admit for lack of memory.
    pub slo_stalls: usize,
    pub predicted_ms: f64,
}

pub struct HybridScheduler {
    pub cfg: SchedulerConfig,
    pub predictor: LatencyPredictor,
    /// Per-class admission limiters, built lazily from the registry (and
    /// `cfg.offline_qps_cap` for the default harvest slot).
    limiters: Vec<Option<RateLimiter>>,
    /// Address of the registry the limiter table was built for (a plain
    /// `usize` so the scheduler stays `Send`): a scheduler re-driven
    /// against a *different* registry rebuilds instead of silently
    /// keeping stale caps.
    limiters_key: usize,
    pub last_stats: ScheduleStats,
    /// Reused id buffer for the per-phase passes (no per-iteration
    /// allocation once warm).
    scratch: Vec<RequestId>,
    /// Reused prompt hash-chain buffer for admissions/resumes (no
    /// per-request allocation once warm).
    chain_scratch: Vec<u64>,
}

impl HybridScheduler {
    pub fn new(cfg: SchedulerConfig, predictor: LatencyPredictor) -> HybridScheduler {
        HybridScheduler {
            cfg,
            predictor,
            limiters: Vec::new(),
            limiters_key: 0,
            last_stats: ScheduleStats::default(),
            scratch: Vec::new(),
            chain_scratch: Vec::new(),
        }
    }

    /// Build the per-class limiter table on first use (the registry lives
    /// on the state, which `new` never sees). Rebuilt when the scheduler
    /// is driven against a different registry; a steady engine pays one
    /// pointer compare per iteration.
    fn ensure_limiters(&mut self, registry: &ClassRegistry) {
        let key = registry as *const ClassRegistry as usize;
        if self.limiters_key == key && self.limiters.len() == registry.len() {
            return;
        }
        self.limiters_key = key;
        self.limiters.clear();
        for c in registry.ids() {
            let spec = registry.spec(c);
            let lim = match spec.admission {
                AdmissionPolicy::RateCapped { qps } => Some(RateLimiter::new(qps)),
                // HyGen*'s legacy cap targets the harvest slot of the
                // classic registry. Guard on elasticity so a custom
                // registry whose index 1 is an *interactive* class never
                // inherits the cap by position.
                _ if c == Class::OFFLINE && spec.elastic() => {
                    self.cfg.offline_qps_cap.map(RateLimiter::new)
                }
                _ => None,
            };
            self.limiters.push(lim);
        }
    }

    /// Snapshot the ids of `class` members currently in `phase` into the
    /// reused scratch buffer (callers put it back when done). The
    /// [`PhaseCounts`](super::state::PhaseCounts) census lets hot
    /// iterations skip phases with no candidates without scanning.
    fn take_phase_ids(
        &mut self,
        state: &EngineState,
        class: Class,
        phase: Phase,
    ) -> Vec<RequestId> {
        let mut ids = std::mem::take(&mut self.scratch);
        ids.clear();
        ids.extend(state.running(class).iter().filter(|&id| state.req(id).phase == phase));
        ids
    }

    /// Build the next iteration batch at time `now` into the caller-owned
    /// `out`, which is cleared first and reused across iterations — the
    /// engine's hot loop is allocation-free once `out` (and the internal
    /// scratch) is warm. Mutates `state`: admissions move queue requests
    /// into the running sets (with block allocation), and memory pressure
    /// may preempt lower-tier requests.
    // lint: alloc-free
    pub fn schedule(&mut self, state: &mut EngineState, now: f64, out: &mut Batch) {
        out.clear();
        let mut stats = ScheduleStats::default();
        let mut t = self.cfg.latency_budget_ms.unwrap_or(f64::INFINITY);
        if t.is_finite() {
            // Charge the empty-batch baseline (the regression bias) so the
            // sum of marginal costs telescopes to the full batch prediction
            // and `predicted_ms <= latency_budget_ms` holds exactly.
            t -= self.predictor.predict(&Features::default());
        }
        let budget_total = t;
        let mut c = self.cfg.chunk_tokens;
        let mut feats = Features::default();

        let registry = Arc::clone(&state.registry);
        self.ensure_limiters(&registry);
        let top = registry.top_tier();
        for &class in registry.tier_order_desc() {
            if !self.cfg.enable_offline && registry.spec(class).tier != top {
                continue;
            }
            // Per-class latency spend, for sub-1.0 class budget caps. Each
            // class is visited exactly once per iteration, so a fresh
            // scalar per pass is equivalent to a class-indexed table —
            // and keeps the hot path free of slice indexing.
            let mut class_spent = 0.0f64;
            self.class_pass(
                state,
                &registry,
                class,
                now,
                out,
                &mut feats,
                &mut t,
                budget_total,
                &mut class_spent,
                &mut c,
                &mut stats,
            );
        }
        stats.predicted_ms = self.predictor.predict(&feats);
        self.last_stats = stats;
    }

    /// Allocating convenience wrapper around [`HybridScheduler::schedule`]
    /// (tests and probes; the engine reuses its own scratch batch).
    pub fn schedule_owned(&mut self, state: &mut EngineState, now: f64) -> Batch {
        let mut out = Batch::new();
        self.schedule(state, now, &mut out);
        out
    }

    /// One class's share of the iteration: decodes, prefill
    /// continuations, resumes, admissions — Alg. 1 parameterized by the
    /// class spec. See the module docs for the per-knob semantics.
    #[allow(clippy::too_many_arguments)]
    fn class_pass(
        &mut self,
        state: &mut EngineState,
        registry: &ClassRegistry,
        class: Class,
        now: f64,
        batch: &mut Batch,
        feats: &mut Features,
        t: &mut f64,
        budget_total: f64,
        class_spent: &mut f64,
        c: &mut usize,
        stats: &mut ScheduleStats,
    ) {
        let spec = registry.spec(class);
        let bypass = spec.bypasses_budget();
        let tier = spec.tier;
        let discard = self.cfg.preemption == PreemptionMode::Discard;
        // Sub-1.0 tolerances additionally cap this class's own spend
        // (tolerances >= 1.0 can never bind before the shared residual
        // does, so they are skipped — this keeps the default registry
        // float-for-float identical to the two-phase code).
        let class_cap = match spec.latency_budget {
            Some(frac) if frac < 1.0 => Some(frac * budget_total),
            _ => None,
        };
        let ci = class.index();
        let fits_cap = |spent: f64, t_req: f64| match class_cap {
            Some(cap) => spent + t_req <= cap,
            None => true,
        };
        // Latency budget visible to this class's *prefill* sizing: the
        // shared residual, additionally clamped to the class's remaining
        // spend cap (uncapped classes see the residual untouched, so the
        // default registry is float-for-float the two-phase code).
        let class_t = |spent: f64, t: f64| match class_cap {
            Some(cap) => t.min(cap - spent),
            None => t,
        };
        let starvation_age = spec.starvation_age_s;
        // Decision audit staging: any preemption recorded during this
        // pass carries the preemptor's tier and the residual budget at
        // the moment of the decision (see `Recorder`).
        state.recorder.audit_a = tier as f64;
        state.recorder.audit_b = *t;

        // 1. Running decodes. Bypass classes schedule them regardless of
        //    the latency budget (Alg. 1 line 8); charged classes stop at
        //    the first that does not fit the residual.
        if state.counts.decode(class) > 0 {
            let ids = self.take_phase_ids(state, class, Phase::Decode);
            for &id in &ids {
                if !state.running(class).contains(id) {
                    continue; // removed below by an earlier decode's growth
                }
                let t_req = self.predictor.decode_cost(feats);
                if !bypass && (t_req > *t || !fits_cap(*class_spent, t_req)) {
                    break;
                }
                let need = state.req(id).context_len() + 1;
                state.recorder.audit_b = *t;
                let mut ok = state.blocks.grow(id, need);
                while !ok {
                    if state.preempt_lowest_below(tier, discard).is_some() {
                        stats.preemptions += 1;
                        ok = state.blocks.grow(id, need);
                    } else if !bypass {
                        // Self-preemption (vLLM-style): free the *newest*
                        // running request of this class so older decodes
                        // keep making progress — without this, a full KV
                        // pool deadlocks pure-harvest work.
                        match state.running(class).last() {
                            Some(last) if last != id => {
                                state.preempt_last_of(class, discard);
                                ok = state.blocks.grow(id, need);
                            }
                            _ => break,
                        }
                    } else {
                        break;
                    }
                }
                if !ok {
                    if bypass {
                        // No lower tier left to preempt and no memory: the
                        // decode stalls one iteration. (With top-tier-only
                        // load this means the instance is over-committed.)
                        stats.slo_stalls += 1;
                        continue;
                    }
                    break;
                }
                *t -= t_req;
                *class_spent += t_req;
                feats.add_decode();
                batch.push(BatchEntry {
                    id,
                    class,
                    n_tokens: 1,
                    is_prefill: false,
                    predicted_ms: t_req,
                });
            }
            self.scratch = ids;
        }

        // 2. Prefill continuations (already admitted, mid-prompt), in the
        //    running set's preserved order.
        if state.counts.prefill(class) > 0 {
            let ids = self.take_phase_ids(state, class, Phase::Prefill);
            for &id in &ids {
                if *c == 0 || (!bypass && *t <= 0.0) {
                    break;
                }
                let want =
                    state.req(id).prefill_remaining().min(self.cfg.max_chunk_per_request);
                // Memory already allocated at admission: pass unlimited mem.
                let (l, t_req) = self.predictor.max_prefill_tokens(
                    feats,
                    class_t(*class_spent, *t),
                    *c,
                    usize::MAX,
                    want,
                );
                if l == 0 {
                    break;
                }
                *t -= t_req;
                *class_spent += t_req;
                *c -= l;
                feats.add_prefill(l);
                batch.push(BatchEntry {
                    id,
                    class,
                    n_tokens: l,
                    is_prefill: true,
                    predicted_ms: t_req,
                });
            }
            self.scratch = ids;
        }

        // 3. Resume preempted requests (FIFO — oldest progress first),
        //    re-allocating their context. Preserve semantics: no
        //    recompute; the request continues where it stopped. Like the
        //    other passes, only charged classes gate on the residual
        //    budget — a preempted bypass class must not be starved behind
        //    its own fresh admissions (pass 4 has the same `!bypass`
        //    guard).
        while let Some(&id) = state.preempted(class).front() {
            if state.num_running() >= self.cfg.max_running || (!bypass && *t <= 0.0) {
                break;
            }
            let ctx = state.req(id).context_len().max(1);
            let mut chain = std::mem::take(&mut self.chain_scratch);
            state.prompt_chain_into(state.req(id), &mut chain);
            let allocated =
                state.blocks.allocate_tagged(id, ctx, &chain, ci, tier).is_some();
            self.chain_scratch = chain;
            if !allocated {
                break; // not enough memory yet
            }
            let Some(resumed_phase) = state.resume_front_of(class) else {
                // The deque's head vanished between front() and the resume
                // (anomaly already recorded by the transition); drop the
                // speculative allocation so its blocks are not leaked.
                state.blocks.release(id);
                break;
            };
            // It also gets work this iteration if budget allows — bypass
            // classes schedule the resumed decode unconditionally, same
            // as pass 1.
            if resumed_phase == Phase::Decode {
                let t_req = self.predictor.decode_cost(feats);
                let need = state.req(id).context_len() + 1;
                let fits = bypass || (t_req <= *t && fits_cap(*class_spent, t_req));
                if fits && state.blocks.grow(id, need) {
                    *t -= t_req;
                    *class_spent += t_req;
                    feats.add_decode();
                    batch.push(BatchEntry {
                        id,
                        class,
                        n_tokens: 1,
                        is_prefill: false,
                        predicted_ms: t_req,
                    });
                }
            } else {
                let want =
                    state.req(id).prefill_remaining().min(self.cfg.max_chunk_per_request);
                let (l, t_req) = self.predictor.max_prefill_tokens(
                    feats,
                    class_t(*class_spent, *t),
                    *c,
                    usize::MAX,
                    want,
                );
                if l > 0 {
                    *t -= t_req;
                    *class_spent += t_req;
                    *c -= l;
                    feats.add_prefill(l);
                    batch.push(BatchEntry {
                        id,
                        class,
                        n_tokens: l,
                        is_prefill: true,
                        predicted_ms: t_req,
                    });
                }
            }
        }

        // 4. New admissions in queue-policy order (FCFS or PSM's DFS).
        loop {
            if *c == 0
                || state.num_running() >= self.cfg.max_running
                || (!bypass && *t <= 0.0)
            {
                break;
            }
            let Some(next) = state.queue_mut(class).peek_next() else { break };
            let prompt_len = next.prompt_len;
            // Starvation protection: once the head has waited past the
            // class threshold, its admission bypasses the rate cap below
            // (memory and the latency budget still apply).
            let starving = match starvation_age {
                Some(age) => now - next.arrival > age,
                None => false,
            };
            // Memory: the full prompt KV must fit (chunked prefill still
            // writes every prompt token's KV), modulo prefix-cache hits.
            // Higher tiers preempt down-tier work for memory; the bottom
            // tier waits.
            let watermark = self.cfg.watermark_blocks * state.blocks.block_size();
            state.recorder.audit_b = *t;
            let mut free = state.blocks.free_tokens().saturating_sub(watermark);
            while free < prompt_len {
                if state.preempt_lowest_below(tier, discard).is_none() {
                    break;
                }
                stats.preemptions += 1;
                free = state.blocks.free_tokens().saturating_sub(watermark);
            }
            if free < prompt_len {
                if bypass {
                    stats.slo_stalls += 1;
                }
                break; // head-of-line: wait for memory
            }
            // Per-class admission pacing (HyGen*'s cap / rate-capped
            // admission), lifted for a starving head.
            if !starving {
                if let Some(lim) = self.limiters.get_mut(ci).and_then(Option::as_mut) {
                    if !lim.admit(now) {
                        break;
                    }
                }
            }
            let Some(mut req) = state.queue_mut(class).pop_next() else {
                // peek_next just returned a head; a pop that disagrees is
                // a queue-implementation bug. Record it and stop admitting
                // rather than taking the serving loop down.
                // lint: allow(alloc, reason=cold anomaly ledger)
                state.anomalies.push(format!(
                    "class {ci} queue head vanished between peek and pop"
                ));
                break;
            };
            let mut chain = std::mem::take(&mut self.chain_scratch);
            state.prompt_chain_into(&req, &mut chain);
            let allocated =
                state.blocks.allocate_tagged(req.id, prompt_len.max(1), &chain, ci, tier);
            self.chain_scratch = chain;
            let cached = match allocated {
                Some(cached) => cached,
                None => {
                    // racing watermark arithmetic; requeue and stop
                    state.queue_mut(class).requeue_unscheduled(req);
                    break;
                }
            };
            if cached > 0 {
                // Flight-recorder audit: prefill work skipped via the
                // prefix cache (a = cached tokens, b = prompt length).
                state.recorder.record(
                    EventKind::CacheHit,
                    req.id,
                    ci as u16,
                    cached as f64,
                    prompt_len as f64,
                    0.0,
                );
            }
            // Prefix reuse: cache hits (real prompts) or the queue's
            // consecutive-LCP estimate (simulated prompts) skip work, but
            // at least one token must be processed to produce the first
            // logits. FCFS queues never set `shared_prefix_len`, so for
            // them this is exactly the cache-hit count.
            let reuse = if state.prefix_caching {
                cached.max(req.shared_prefix_len.min(prompt_len))
            } else {
                0
            };
            req.prefilled = reuse.min(prompt_len.saturating_sub(1));
            let want = req.prefill_remaining().min(self.cfg.max_chunk_per_request);
            let (l, t_req) = self.predictor.max_prefill_tokens(
                feats,
                class_t(*class_spent, *t),
                *c,
                usize::MAX,
                want,
            );
            if l == 0 {
                // Latency/chunk budget exhausted: undo the admission.
                state.blocks.release(req.id);
                req.prefilled = 0;
                state.queue_mut(class).requeue_unscheduled(req);
                break;
            }
            *t -= t_req;
            *class_spent += t_req;
            *c -= l;
            feats.add_prefill(l);
            req.phase = Phase::Prefill;
            // Admission audit: tier, residual budget after charging this
            // chunk, and the chunk's predicted cost — plus the queue
            // delay this request just paid.
            state.recorder.record(EventKind::QueuePop, req.id, ci as u16, tier as f64, *t, t_req);
            state.recorder.observe_queue_delay(ci, (now - req.arrival).max(0.0) * 1e3);
            batch.push(BatchEntry {
                id: req.id,
                class,
                n_tokens: l,
                is_prefill: true,
                predicted_ms: t_req,
            });
            state.insert_running(req);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::classes::{ClassRegistry, ClassSpec};
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::request::Request;

    fn mk_state(blocks: usize) -> EngineState {
        EngineState::new(OfflinePolicy::Fcfs, blocks, 16, 0)
    }

    fn sched(cfg: SchedulerConfig) -> HybridScheduler {
        HybridScheduler::new(cfg, LatencyPredictor::default_seed())
    }

    fn online(id: RequestId, prompt: usize, out: usize) -> Request {
        Request::new(id, Class::ONLINE, 0.0, prompt, out)
            .with_prompt((0..prompt as u32).map(|i| i + id as u32 * 1000).collect::<Vec<u32>>())
    }

    fn offline(id: RequestId, prompt: usize, out: usize) -> Request {
        Request::new(id, Class::OFFLINE, 0.0, prompt, out)
            .with_prompt((0..prompt as u32).map(|i| i + id as u32 * 1000).collect::<Vec<u32>>())
    }

    /// Apply a batch the way the engine would (progress only; same
    /// semantics as `Engine::apply` — the chunk that completes the prompt
    /// also emits the first output token).
    fn apply(state: &mut EngineState, batch: &Batch) {
        let mut done: Vec<RequestId> = Vec::new();
        for e in &batch.entries {
            let finished = if e.is_prefill {
                state.advance_prefill(e.id, e.n_tokens) && state.advance_decode(e.id)
            } else {
                state.advance_decode(e.id)
            };
            if finished {
                done.push(e.id);
            }
        }
        for id in done {
            state.finish(id);
        }
    }

    #[test]
    fn online_prefill_then_decode_roundtrip() {
        let mut st = mk_state(256);
        let mut s = sched(SchedulerConfig::default());
        st.enqueue(online(1, 100, 2));
        let b = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b.len(), 1);
        assert!(b.entries[0].is_prefill);
        assert_eq!(b.entries[0].n_tokens, 100, "whole prompt fits the chunk budget");
        apply(&mut st, &b);
        assert_eq!(st.requests[&1].phase, Phase::Decode);
        let b2 = s.schedule_owned(&mut st, 0.1);
        assert_eq!(b2.len(), 1);
        assert!(!b2.entries[0].is_prefill);
        apply(&mut st, &b2);
        let b3 = s.schedule_owned(&mut st, 0.2);
        apply(&mut st, &b3);
        assert!(st.finished.iter().any(|r| r.id == 1));
        st.check_invariants().unwrap();
    }

    #[test]
    fn chunked_prefill_splits_long_prompt() {
        let mut st = mk_state(1024);
        let mut s = sched(SchedulerConfig {
            chunk_tokens: 128,
            latency_budget_ms: None,
            ..SchedulerConfig::default()
        });
        st.enqueue(online(1, 300, 1));
        let b1 = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b1.entries[0].n_tokens, 128);
        apply(&mut st, &b1);
        let b2 = s.schedule_owned(&mut st, 0.1);
        assert_eq!(b2.entries[0].n_tokens, 128);
        apply(&mut st, &b2);
        let b3 = s.schedule_owned(&mut st, 0.2);
        assert_eq!(b3.entries[0].n_tokens, 44);
        apply(&mut st, &b3);
        // Completing the prompt emits the first (and, with out=1, only)
        // output token, so the request finishes at the final chunk.
        assert!(st.finished.iter().any(|r| r.id == 1));
        st.check_invariants().unwrap();
    }

    #[test]
    fn offline_fills_residual_budget_only() {
        let mut st = mk_state(1024);
        // Tight latency budget: online prefill eats most of it.
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: Some(12.0),
            chunk_tokens: 4096,
            ..SchedulerConfig::default()
        });
        st.enqueue(online(1, 200, 4));
        st.enqueue(offline(10, 400, 4));
        let b = s.schedule_owned(&mut st, 0.0);
        let online_tokens: usize =
            b.entries.iter().filter(|e| e.class.is_online()).map(|e| e.n_tokens).sum();
        let offline_tokens: usize =
            b.entries.iter().filter(|e| !e.class.is_online()).map(|e| e.n_tokens).sum();
        assert_eq!(online_tokens, 200, "online gets its full prompt first");
        // Offline only gets what the residual latency allows — and the
        // predicted total must respect the budget.
        assert!(s.last_stats.predicted_ms <= 12.0 + 1e-6, "{}", s.last_stats.predicted_ms);
        assert!(offline_tokens < 400, "offline chunk must be throttled");
    }

    #[test]
    fn slo_unaware_mode_fills_chunk_budget() {
        let mut st = mk_state(1024);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None, // Sarathi++
            chunk_tokens: 512,
            ..SchedulerConfig::default()
        });
        st.enqueue(online(1, 200, 4));
        st.enqueue(offline(10, 400, 4));
        let b = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b.total_tokens(), 512, "chunk budget fully used when SLO-unaware");
    }

    #[test]
    fn disable_offline_is_pure_online() {
        let mut st = mk_state(1024);
        let mut s = sched(SchedulerConfig { enable_offline: false, ..Default::default() });
        st.enqueue(online(1, 50, 2));
        st.enqueue(offline(10, 50, 2));
        let b = s.schedule_owned(&mut st, 0.0);
        assert!(b.entries.iter().all(|e| e.class.is_online()));
        assert_eq!(st.queue(Class::OFFLINE).len(), 1);
    }

    #[test]
    fn online_admission_preempts_offline_for_memory() {
        // 16 blocks * 16 tokens = 256 tokens of KV. Offline fills most.
        let mut st = mk_state(16);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 512,
            watermark_blocks: 0,
            ..SchedulerConfig::default()
        });
        st.enqueue(offline(10, 200, 64));
        let b = s.schedule_owned(&mut st, 0.0);
        apply(&mut st, &b);
        assert_eq!(*st.running(Class::OFFLINE), vec![10]);
        // Online request needs 200 tokens; only ~56 free -> preemption.
        st.enqueue(online(1, 200, 2));
        let b2 = s.schedule_owned(&mut st, 0.1);
        assert!(b2.entries.iter().any(|e| e.id == 1 && e.is_prefill));
        assert_eq!(s.last_stats.preemptions, 1);
        assert_eq!(st.preempted(Class::OFFLINE), &vec![10]);
        assert_eq!(st.requests[&10].prefilled, 200, "preserve keeps progress");
        st.check_invariants().unwrap();
    }

    #[test]
    fn preempted_offline_resumes_when_memory_frees() {
        let mut st = mk_state(16);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 512,
            watermark_blocks: 0,
            ..SchedulerConfig::default()
        });
        st.enqueue(offline(10, 200, 4));
        let b = s.schedule_owned(&mut st, 0.0);
        apply(&mut st, &b);
        st.enqueue(online(1, 200, 1));
        let b = s.schedule_owned(&mut st, 0.1);
        apply(&mut st, &b); // preempts 10, prefills 1
        let b = s.schedule_owned(&mut st, 0.2);
        apply(&mut st, &b); // 1 decodes once -> finished
        assert!(st.finished.iter().any(|r| r.id == 1));
        // Next iteration: 10 resumes with preserved progress.
        let b = s.schedule_owned(&mut st, 0.3);
        assert!(st.running(Class::OFFLINE).contains(10));
        assert!(st.preempted(Class::OFFLINE).is_empty());
        assert!(b.entries.iter().any(|e| e.id == 10));
        assert_eq!(st.requests[&10].prefilled, 200);
        st.check_invariants().unwrap();
    }

    #[test]
    fn discard_preemption_requeues_and_recomputes() {
        let mut st = mk_state(16);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 512,
            watermark_blocks: 0,
            preemption: PreemptionMode::Discard,
            ..SchedulerConfig::default()
        });
        st.enqueue(offline(10, 200, 4));
        let b = s.schedule_owned(&mut st, 0.0);
        apply(&mut st, &b);
        st.enqueue(online(1, 200, 2));
        let b = s.schedule_owned(&mut st, 0.1);
        apply(&mut st, &b);
        assert!(st.preempted(Class::OFFLINE).is_empty());
        assert_eq!(st.queue(Class::OFFLINE).len(), 1, "discarded -> requeued");
    }

    #[test]
    fn offline_qps_cap_limits_admissions() {
        let mut st = mk_state(4096);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 1 << 20,
            offline_qps_cap: Some(1.0), // 1 admission/s
            ..SchedulerConfig::default()
        });
        for i in 0..10 {
            st.enqueue(offline(10 + i, 32, 4));
        }
        let b = s.schedule_owned(&mut st, 0.0);
        let admissions = b.entries.iter().filter(|e| e.is_prefill).count();
        assert_eq!(admissions, 1, "token bucket starts with 1 permit");
        apply(&mut st, &b);
        // 5 seconds later: ~5 more permits accumulated (burst-capped at 1).
        let b2 = s.schedule_owned(&mut st, 5.0);
        let admissions2 = b2.entries.iter().filter(|e| e.is_prefill).count();
        assert_eq!(admissions2, 1, "burst cap 1 -> one admission per call");
    }

    #[test]
    fn max_running_bounds_admissions() {
        let mut st = mk_state(4096);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 1 << 20,
            max_running: 3,
            ..SchedulerConfig::default()
        });
        for i in 0..10 {
            st.enqueue(online(i, 16, 4));
        }
        let b = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b.len(), 3);
        assert_eq!(st.num_running(), 3);
    }

    #[test]
    fn latency_budget_respected_by_prediction() {
        let mut st = mk_state(4096);
        let budget = 25.0;
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: Some(budget),
            chunk_tokens: 1 << 20,
            ..SchedulerConfig::default()
        });
        for i in 0..50 {
            st.enqueue(offline(i, 512, 8));
        }
        let b = s.schedule_owned(&mut st, 0.0);
        assert!(!b.is_empty());
        assert!(
            s.last_stats.predicted_ms <= budget + 1e-6,
            "predicted {} > budget {budget}",
            s.last_stats.predicted_ms
        );
    }

    #[test]
    fn rate_limiter_basic() {
        let mut rl = RateLimiter::new(2.0);
        assert!(rl.admit(0.0));
        assert!(!rl.admit(0.0));
        assert!(rl.admit(0.5)); // 0.5s * 2/s = 1 token
        assert!(!rl.admit(0.5));
        assert!(rl.admit(10.0));
    }

    #[test]
    fn rate_limiter_tolerates_non_monotonic_clock() {
        let mut rl = RateLimiter::new(2.0);
        assert!(rl.admit(10.0)); // refilled to the burst cap (2) at t=10
        assert!(rl.admit(10.0)); // drain the bucket
        assert!(!rl.admit(10.0));
        // Clock steps backwards: no retroactive refill, but the anchor
        // must follow, otherwise refill is skipped forever.
        assert!(!rl.admit(4.0));
        assert!(rl.admit(4.5), "refill resumed after the backwards step");
        assert!(!rl.admit(4.5));
        assert!(rl.admit(5.0));
    }

    // ------------------------------------------------- registry-driven tests

    fn spec(name: &str, tier: u8) -> ClassSpec {
        ClassSpec {
            name: name.into(),
            tier,
            ttft_slo_ms: Some(1000.0),
            tbt_slo_ms: Some(100.0),
            latency_budget: Some(1.0),
            preempt_priority: tier,
            admission: AdmissionPolicy::Fcfs,
            starvation_age_s: None,
        }
    }

    fn four_class_registry() -> ClassRegistry {
        ClassRegistry::new(vec![
            ClassSpec { latency_budget: None, ..spec("chat", 3) },
            spec("completion", 2),
            ClassSpec {
                admission: AdmissionPolicy::LongestPrefix,
                ttft_slo_ms: None,
                latency_budget: Some(2.0),
                ..spec("summarize", 1)
            },
            ClassSpec {
                ttft_slo_ms: None,
                tbt_slo_ms: None,
                latency_budget: Some(4.0),
                ..spec("batch", 0)
            },
        ])
        .unwrap()
    }

    fn four_class_state(blocks: usize) -> EngineState {
        EngineState::with_registry(
            Arc::new(four_class_registry()),
            OfflinePolicy::Psm,
            blocks,
            16,
            0,
        )
    }

    fn req_of(class: Class, id: RequestId, prompt: usize, out: usize) -> Request {
        Request::new(id, class, 0.0, prompt, out)
            .with_prompt((0..prompt as u32).map(|i| i + id as u32 * 1000).collect::<Vec<u32>>())
    }

    #[test]
    fn four_class_batch_is_tier_ordered() {
        let mut st = four_class_state(4096);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: Some(200.0),
            chunk_tokens: 4096,
            ..SchedulerConfig::default()
        });
        for i in 0..4u16 {
            st.enqueue(req_of(Class(i), 100 + i as u64, 64, 4));
        }
        let b = s.schedule_owned(&mut st, 0.0);
        assert!(b.len() >= 2, "at least the top tiers fit");
        let tiers: Vec<u8> = b.entries.iter().map(|e| st.registry.spec(e.class).tier).collect();
        assert!(
            tiers.windows(2).all(|w| w[0] >= w[1]),
            "batch entries must be tier-descending: {tiers:?}"
        );
        st.check_invariants().unwrap();
    }

    #[test]
    fn sub_one_latency_budget_caps_class_spend() {
        // The mid class may only use 30% of the iteration budget.
        let reg = ClassRegistry::new(vec![
            ClassSpec { latency_budget: None, ..spec("chat", 3) },
            ClassSpec { latency_budget: Some(0.3), ..spec("completion", 2) },
        ])
        .unwrap();
        let mut st =
            EngineState::with_registry(Arc::new(reg), OfflinePolicy::Fcfs, 1 << 14, 16, 0);
        let budget = 40.0;
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: Some(budget),
            chunk_tokens: 1 << 20,
            ..SchedulerConfig::default()
        });
        for i in 0..40 {
            st.enqueue(req_of(Class(1), 200 + i, 256, 8));
        }
        let b = s.schedule_owned(&mut st, 0.0);
        assert!(!b.is_empty());
        let class1_ms: f64 =
            b.entries.iter().filter(|e| e.class == Class(1)).map(|e| e.predicted_ms).sum();
        // The cap is a fraction of the post-baseline budget, so compare
        // against the full budget loosely.
        assert!(
            class1_ms <= 0.3 * budget + 1e-6,
            "capped class spent {class1_ms} ms of a {budget} ms budget"
        );
    }

    #[test]
    fn rate_capped_class_with_starvation_override() {
        let reg = ClassRegistry::new(vec![
            ClassSpec { latency_budget: None, ..spec("chat", 1) },
            ClassSpec {
                admission: AdmissionPolicy::RateCapped { qps: 0.1 },
                ttft_slo_ms: None,
                starvation_age_s: Some(30.0),
                ..spec("batch", 0)
            },
        ])
        .unwrap();
        let mut st = EngineState::with_registry(Arc::new(reg), OfflinePolicy::Fcfs, 4096, 16, 0);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 1 << 20,
            ..SchedulerConfig::default()
        });
        for i in 0..5 {
            st.enqueue(req_of(Class(1), 10 + i, 32, 4));
        }
        // t=0: the bucket starts with one permit.
        let b = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b.len(), 1, "rate cap admits one");
        apply(&mut st, &b);
        // t=1: bucket empty (0.1 qps), not yet starving -> nothing admits.
        let b = s.schedule_owned(&mut st, 1.0);
        assert!(b.entries.iter().all(|e| !e.is_prefill), "cap holds before the threshold");
        // t=31: head has waited past starvation_age_s -> cap bypassed.
        let b = s.schedule_owned(&mut st, 31.0);
        assert!(
            b.entries.iter().any(|e| e.is_prefill),
            "starving head must be admitted despite the rate cap"
        );
        st.check_invariants().unwrap();
    }

    #[test]
    fn mid_tier_preempts_down_but_never_up() {
        // Small pool: completion's admission must evict batch, not chat.
        let mut st = four_class_state(16);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 512,
            watermark_blocks: 0,
            ..SchedulerConfig::default()
        });
        st.enqueue(req_of(Class(3), 30, 200, 64));
        let b = s.schedule_owned(&mut st, 0.0);
        apply(&mut st, &b);
        assert_eq!(*st.running(Class(3)), vec![30]);
        st.enqueue(req_of(Class(1), 11, 200, 2));
        let b = s.schedule_owned(&mut st, 0.1);
        assert!(b.entries.iter().any(|e| e.id == 11 && e.is_prefill));
        assert_eq!(st.preempted(Class(3)), &vec![30], "batch preempted by completion");
        st.check_invariants().unwrap();
    }
}
