//! The HyGen two-phase SLO-aware scheduler (§4.1, Alg. 1–2).
//!
//! Each engine iteration builds one hybrid batch under three budgets:
//!
//! * **latency** `t` — the profiled per-iteration latency budget (ms); the
//!   predictor charges every scheduling decision against it. `None`
//!   disables SLO-awareness (that is exactly the Sarathi++ baseline).
//! * **chunk** `c` — the Sarathi token budget per iteration.
//! * **memory** `m` — free KV blocks via the
//!   [`BlockManager`](super::block_manager::BlockManager).
//!
//! Phase 1 (online) schedules online decodes unconditionally and online
//! prefill chunks under `c`/`m`, preempting offline requests for memory.
//! Phase 2 (offline) pours the *residual* budgets into offline work:
//! resumed preempted requests first, then running offline, then new
//! requests drawn from the queue policy (FCFS / PSM / fair-PSM).
//!
//! The same struct, differently configured, implements every baseline in
//! the paper's evaluation — see [`SchedulerConfig`] and `baselines/`.

use super::batch::{Batch, BatchEntry, Features};
use super::predictor::LatencyPredictor;
use super::request::{Class, Phase, RequestId};
use super::state::EngineState;

/// How preempted offline requests are handled (InferCept's taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptionMode {
    /// Keep prefill/decode progress; only KV blocks are released
    /// (swap-to-host semantics). The paper's default.
    Preserve,
    /// Drop computed state; the request re-enters the offline queue and
    /// recomputes its prefill.
    Discard,
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Per-iteration latency budget in ms (from the SLO-aware profiler).
    /// `None` = SLO-unaware hybrid scheduling (Sarathi++).
    pub latency_budget_ms: Option<f64>,
    /// Token budget per iteration (Sarathi chunk size).
    pub chunk_tokens: usize,
    /// Max prefill tokens for one request in one iteration (the real
    /// engine's per-slot chunk bucket; `usize::MAX` to disable).
    pub max_chunk_per_request: usize,
    /// Max concurrently running requests (the real engine has 8 slots).
    pub max_running: usize,
    pub preemption: PreemptionMode,
    /// Schedule offline work at all (false = pure-online Sarathi).
    pub enable_offline: bool,
    /// HyGen* baseline: cap offline admissions at this rate (req/s).
    pub offline_qps_cap: Option<f64>,
    /// Blocks held back from admissions so running decodes can grow.
    pub watermark_blocks: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            latency_budget_ms: Some(50.0),
            chunk_tokens: 512,
            max_chunk_per_request: usize::MAX,
            max_running: 256,
            preemption: PreemptionMode::Preserve,
            enable_offline: true,
            offline_qps_cap: None,
            watermark_blocks: 8,
        }
    }
}

/// Simple token-bucket rate limiter (HyGen*'s fixed offline QPS).
#[derive(Debug, Clone)]
pub struct RateLimiter {
    rate: f64,
    tokens: f64,
    last: f64,
    burst: f64,
}

impl RateLimiter {
    pub fn new(rate: f64) -> RateLimiter {
        RateLimiter { rate, tokens: 1.0, last: 0.0, burst: 1.0_f64.max(rate) }
    }

    /// Try to consume one permit at time `now` (seconds).
    pub fn admit(&mut self, now: f64) -> bool {
        if now > self.last {
            self.tokens = (self.tokens + (now - self.last) * self.rate).min(self.burst);
            self.last = now;
        } else if now < self.last {
            // Non-monotonic clock (NTP step, cross-source timestamps):
            // re-anchor at the earlier time without granting retroactive
            // tokens, so refill resumes as the clock moves forward again
            // instead of being skipped forever.
            self.last = now;
        }
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Per-iteration scheduling statistics (observability + tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScheduleStats {
    pub preemptions: usize,
    pub online_stalls: usize,
    pub predicted_ms: f64,
}

pub struct HybridScheduler {
    pub cfg: SchedulerConfig,
    pub predictor: LatencyPredictor,
    offline_limiter: Option<RateLimiter>,
    pub last_stats: ScheduleStats,
    /// Reused id buffer for the per-phase passes (no per-iteration
    /// allocation once warm).
    scratch: Vec<RequestId>,
}

impl HybridScheduler {
    pub fn new(cfg: SchedulerConfig, predictor: LatencyPredictor) -> HybridScheduler {
        let offline_limiter = cfg.offline_qps_cap.map(RateLimiter::new);
        HybridScheduler {
            cfg,
            predictor,
            offline_limiter,
            last_stats: ScheduleStats::default(),
            scratch: Vec::new(),
        }
    }

    /// Snapshot the ids of `set` members currently in `phase` into the
    /// reused scratch buffer (callers put it back when done). The
    /// [`PhaseCounts`](super::state::PhaseCounts) census lets hot
    /// iterations skip phases with no candidates without scanning.
    fn take_phase_ids(
        &mut self,
        state: &EngineState,
        set: &super::runset::RunSet,
        phase: Phase,
    ) -> Vec<RequestId> {
        let mut ids = std::mem::take(&mut self.scratch);
        ids.clear();
        ids.extend(set.iter().filter(|&id| state.requests[&id].phase == phase));
        ids
    }

    /// Build the next iteration batch at time `now` (Alg. 2's two
    /// invocations of Alg. 1) into the caller-owned `out`, which is
    /// cleared first and reused across iterations — the engine's hot loop
    /// is allocation-free once `out` (and the internal scratch) is warm.
    /// Mutates `state`: admissions move queue requests into the running
    /// sets (with block allocation), and memory pressure may preempt
    /// offline requests.
    pub fn schedule(&mut self, state: &mut EngineState, now: f64, out: &mut Batch) {
        out.clear();
        let mut stats = ScheduleStats::default();
        let mut t = self.cfg.latency_budget_ms.unwrap_or(f64::INFINITY);
        if t.is_finite() {
            // Charge the empty-batch baseline (the regression bias) so the
            // sum of marginal costs telescopes to the full batch prediction
            // and `predicted_ms <= latency_budget_ms` holds exactly.
            t -= self.predictor.predict(&Features::default());
        }
        let mut c = self.cfg.chunk_tokens;
        let mut feats = Features::default();

        self.online_phase(state, out, &mut feats, &mut t, &mut c, &mut stats);
        if self.cfg.enable_offline {
            self.offline_phase(state, now, out, &mut feats, &mut t, &mut c);
        }
        stats.predicted_ms = self.predictor.predict(&feats);
        self.last_stats = stats;
    }

    /// Allocating convenience wrapper around [`HybridScheduler::schedule`]
    /// (tests and probes; the engine reuses its own scratch batch).
    pub fn schedule_owned(&mut self, state: &mut EngineState, now: f64) -> Batch {
        let mut out = Batch::new();
        self.schedule(state, now, &mut out);
        out
    }

    // ---------------------------------------------------------------- online

    fn online_phase(
        &mut self,
        state: &mut EngineState,
        batch: &mut Batch,
        feats: &mut Features,
        t: &mut f64,
        c: &mut usize,
        stats: &mut ScheduleStats,
    ) {
        let discard = self.cfg.preemption == PreemptionMode::Discard;

        // 1. Online decodes: scheduled regardless of latency budget
        //    (Alg. 1 line 8: "online" bypasses the `t_req <= t` check);
        //    memory pressure preempts offline requests.
        if state.counts.decode(Class::Online) > 0 {
            let ids = self.take_phase_ids(state, &state.running_online, Phase::Decode);
            for &id in &ids {
                let need = state.requests[&id].context_len() + 1;
                let mut ok = state.blocks.grow(id, need);
                while !ok {
                    if state.preempt_last_offline(discard).is_none() {
                        break;
                    }
                    stats.preemptions += 1;
                    ok = state.blocks.grow(id, need);
                }
                if !ok {
                    // No offline left to preempt and no memory: the decode
                    // stalls one iteration. (With online-only load this means
                    // the instance is over-committed.)
                    stats.online_stalls += 1;
                    continue;
                }
                let t_req = self.predictor.decode_cost(feats);
                *t -= t_req;
                feats.add_decode();
                batch.push(BatchEntry {
                    id,
                    class: Class::Online,
                    n_tokens: 1,
                    is_prefill: false,
                    predicted_ms: t_req,
                });
            }
            self.scratch = ids;
        }

        // 2. Online prefill continuations (already admitted, mid-prompt).
        if state.counts.prefill(Class::Online) > 0 {
            let ids = self.take_phase_ids(state, &state.running_online, Phase::Prefill);
            for &id in &ids {
                if *c == 0 {
                    break;
                }
                let want = state.requests[&id].prefill_remaining();
                let cap = want.min(self.cfg.max_chunk_per_request);
                // Memory already allocated at admission: pass unlimited mem.
                let (l, t_req) =
                    self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, cap);
                if l == 0 {
                    break;
                }
                *t -= t_req;
                *c -= l;
                feats.add_prefill(l);
                batch.push(BatchEntry {
                    id,
                    class: Class::Online,
                    n_tokens: l,
                    is_prefill: true,
                    predicted_ms: t_req,
                });
            }
            self.scratch = ids;
        }

        // 3. Online admissions from the FCFS queue.
        while *c > 0 && state.num_running() < self.cfg.max_running {
            let Some(next) = state.online_queue.peek() else { break };
            let prompt_len = next.prompt_len;
            // Memory: the full prompt KV must fit (chunked prefill still
            // writes every prompt token's KV), modulo prefix-cache hits.
            let mut free =
                state.blocks.free_tokens().saturating_sub(self.cfg.watermark_blocks * state.blocks.block_size());
            while free < prompt_len {
                if state.preempt_last_offline(discard).is_none() {
                    break;
                }
                stats.preemptions += 1;
                free = state
                    .blocks
                    .free_tokens()
                    .saturating_sub(self.cfg.watermark_blocks * state.blocks.block_size());
            }
            if free < prompt_len {
                stats.online_stalls += 1;
                break; // FCFS head-of-line: wait for memory
            }
            let mut req = state.online_queue.pop().expect("peeked");
            let chain = state.prompt_chain(&req);
            let cached = match state.blocks.allocate(req.id, prompt_len.max(1), &chain) {
                Some(cached) => cached,
                None => {
                    // racing watermark arithmetic; requeue and stop
                    state.online_queue.push_front(req);
                    break;
                }
            };
            // Prefix-cache hits skip prefill work, but at least one token
            // must be processed to produce the first logits.
            req.prefilled = cached.min(prompt_len.saturating_sub(1));
            let want = req.prefill_remaining().min(self.cfg.max_chunk_per_request);
            let (l, t_req) = self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, want);
            if l == 0 {
                // Latency/chunk budget exhausted: undo the admission.
                state.blocks.release(req.id);
                req.prefilled = 0;
                state.online_queue.push_front(req);
                break;
            }
            *t -= t_req;
            *c -= l;
            feats.add_prefill(l);
            req.phase = Phase::Prefill;
            batch.push(BatchEntry {
                id: req.id,
                class: Class::Online,
                n_tokens: l,
                is_prefill: true,
                predicted_ms: t_req,
            });
            state.insert_running(req);
        }
    }

    // --------------------------------------------------------------- offline

    fn offline_phase(
        &mut self,
        state: &mut EngineState,
        now: f64,
        batch: &mut Batch,
        feats: &mut Features,
        t: &mut f64,
        c: &mut usize,
    ) {
        let discard = self.cfg.preemption == PreemptionMode::Discard;
        // 1. Offline decodes — only within the residual latency budget
        //    (Alg. 3 lines 7-11; stop at the first that does not fit).
        if state.counts.decode(Class::Offline) > 0 {
            let ids = self.take_phase_ids(state, &state.running_offline, Phase::Decode);
            for &id in &ids {
                if !state.running_offline.contains(id) {
                    continue; // preempted below by an earlier decode's growth
                }
                let t_req = self.predictor.decode_cost(feats);
                if t_req > *t {
                    break;
                }
                let need = state.requests[&id].context_len() + 1;
                let mut ok = state.blocks.grow(id, need);
                while !ok {
                    // Self-preemption (vLLM-style): free the *newest* running
                    // offline request so older decodes keep making progress —
                    // without this, a full KV pool deadlocks pure-offline work.
                    match state.running_offline.last() {
                        Some(last) if last != id => {
                            state.preempt_last_offline(discard);
                            ok = state.blocks.grow(id, need);
                        }
                        _ => break,
                    }
                }
                if !ok {
                    break;
                }
                *t -= t_req;
                feats.add_decode();
                batch.push(BatchEntry {
                    id,
                    class: Class::Offline,
                    n_tokens: 1,
                    is_prefill: false,
                    predicted_ms: t_req,
                });
            }
            self.scratch = ids;
        }

        // 2. Offline prefill continuations, in preserved (DFS) order.
        if state.counts.prefill(Class::Offline) > 0 {
            let ids = self.take_phase_ids(state, &state.running_offline, Phase::Prefill);
            for &id in &ids {
                if *c == 0 || *t <= 0.0 {
                    break;
                }
                let want =
                    state.requests[&id].prefill_remaining().min(self.cfg.max_chunk_per_request);
                let (l, t_req) =
                    self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, want);
                if l == 0 {
                    break;
                }
                *t -= t_req;
                *c -= l;
                feats.add_prefill(l);
                batch.push(BatchEntry {
                    id,
                    class: Class::Offline,
                    n_tokens: l,
                    is_prefill: true,
                    predicted_ms: t_req,
                });
            }
            self.scratch = ids;
        }

        // 3. Resume preempted offline requests (FIFO — oldest progress
        //    first), re-allocating their context. Preserve semantics: no
        //    recompute; the request continues where it stopped.
        while let Some(&id) = state.preempted_offline.front() {
            if state.num_running() >= self.cfg.max_running || *t <= 0.0 {
                break;
            }
            let req = &state.requests[&id];
            let ctx = req.context_len().max(1);
            let chain = state.prompt_chain(req);
            if state.blocks.allocate(id, ctx, &chain).is_none() {
                break; // not enough memory yet
            }
            let resumed_phase = state.resume_front_preempted();
            // It also gets work this iteration if budget allows.
            if resumed_phase == Phase::Decode {
                let t_req = self.predictor.decode_cost(feats);
                let need = state.requests[&id].context_len() + 1;
                if t_req <= *t && state.blocks.grow(id, need) {
                    *t -= t_req;
                    feats.add_decode();
                    batch.push(BatchEntry {
                        id,
                        class: Class::Offline,
                        n_tokens: 1,
                        is_prefill: false,
                        predicted_ms: t_req,
                    });
                }
            } else {
                let want =
                    state.requests[&id].prefill_remaining().min(self.cfg.max_chunk_per_request);
                let (l, t_req) =
                    self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, want);
                if l > 0 {
                    *t -= t_req;
                    *c -= l;
                    feats.add_prefill(l);
                    batch.push(BatchEntry {
                        id,
                        class: Class::Offline,
                        n_tokens: l,
                        is_prefill: true,
                        predicted_ms: t_req,
                    });
                }
            }
        }

        // 4. New offline admissions in queue-policy order (PSM's DFS).
        while *c > 0 && *t > 0.0 && state.num_running() < self.cfg.max_running {
            let Some(next) = state.offline_queue.peek_next() else { break };
            let prompt_len = next.prompt_len;
            let free = state
                .blocks
                .free_tokens()
                .saturating_sub(self.cfg.watermark_blocks * state.blocks.block_size());
            if free < prompt_len {
                break; // offline waits; never preempts
            }
            // HyGen*'s admission rate cap.
            if let Some(lim) = &mut self.offline_limiter {
                if !lim.admit(now) {
                    break;
                }
            }
            let mut req = state.offline_queue.pop_next().expect("peeked");
            let chain = state.prompt_chain(&req);
            let cached = match state.blocks.allocate(req.id, prompt_len.max(1), &chain) {
                Some(cached) => cached,
                None => {
                    state.offline_queue.push(req);
                    state.offline_queue.reset_prefix_context();
                    break;
                }
            };
            // Prefix reuse: cache hits (real prompts) or the queue's
            // consecutive-LCP estimate (simulated prompts) skip work.
            let reuse = if state.prefix_caching {
                cached.max(req.shared_prefix_len.min(prompt_len))
            } else {
                0
            };
            req.prefilled = reuse.min(prompt_len.saturating_sub(1));
            let want = req.prefill_remaining().min(self.cfg.max_chunk_per_request);
            let (l, t_req) = self.predictor.max_prefill_tokens(feats, *t, *c, usize::MAX, want);
            if l == 0 {
                state.blocks.release(req.id);
                req.prefilled = 0;
                state.offline_queue.push(req);
                state.offline_queue.reset_prefix_context();
                break;
            }
            *t -= t_req;
            *c -= l;
            feats.add_prefill(l);
            req.phase = Phase::Prefill;
            batch.push(BatchEntry {
                id: req.id,
                class: Class::Offline,
                n_tokens: l,
                is_prefill: true,
                predicted_ms: t_req,
            });
            state.insert_running(req);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queues::OfflinePolicy;
    use crate::coordinator::request::Request;

    fn mk_state(blocks: usize) -> EngineState {
        EngineState::new(OfflinePolicy::Fcfs, blocks, 16, 0)
    }

    fn sched(cfg: SchedulerConfig) -> HybridScheduler {
        HybridScheduler::new(cfg, LatencyPredictor::default_seed())
    }

    fn online(id: RequestId, prompt: usize, out: usize) -> Request {
        Request::new(id, Class::Online, 0.0, prompt, out)
            .with_prompt((0..prompt as u32).map(|i| i + id as u32 * 1000).collect::<Vec<u32>>())
    }

    fn offline(id: RequestId, prompt: usize, out: usize) -> Request {
        Request::new(id, Class::Offline, 0.0, prompt, out)
            .with_prompt((0..prompt as u32).map(|i| i + id as u32 * 1000).collect::<Vec<u32>>())
    }

    /// Apply a batch the way the engine would (progress only; same
    /// semantics as `Engine::apply` — the chunk that completes the prompt
    /// also emits the first output token).
    fn apply(state: &mut EngineState, batch: &Batch) {
        let mut done: Vec<RequestId> = Vec::new();
        for e in &batch.entries {
            let finished = if e.is_prefill {
                state.advance_prefill(e.id, e.n_tokens) && state.advance_decode(e.id)
            } else {
                state.advance_decode(e.id)
            };
            if finished {
                done.push(e.id);
            }
        }
        for id in done {
            state.finish(id);
        }
    }

    #[test]
    fn online_prefill_then_decode_roundtrip() {
        let mut st = mk_state(256);
        let mut s = sched(SchedulerConfig::default());
        st.enqueue(online(1, 100, 2));
        let b = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b.len(), 1);
        assert!(b.entries[0].is_prefill);
        assert_eq!(b.entries[0].n_tokens, 100, "whole prompt fits the chunk budget");
        apply(&mut st, &b);
        assert_eq!(st.requests[&1].phase, Phase::Decode);
        let b2 = s.schedule_owned(&mut st, 0.1);
        assert_eq!(b2.len(), 1);
        assert!(!b2.entries[0].is_prefill);
        apply(&mut st, &b2);
        let b3 = s.schedule_owned(&mut st, 0.2);
        apply(&mut st, &b3);
        assert!(st.finished.iter().any(|r| r.id == 1));
        st.check_invariants().unwrap();
    }

    #[test]
    fn chunked_prefill_splits_long_prompt() {
        let mut st = mk_state(1024);
        let mut s = sched(SchedulerConfig {
            chunk_tokens: 128,
            latency_budget_ms: None,
            ..SchedulerConfig::default()
        });
        st.enqueue(online(1, 300, 1));
        let b1 = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b1.entries[0].n_tokens, 128);
        apply(&mut st, &b1);
        let b2 = s.schedule_owned(&mut st, 0.1);
        assert_eq!(b2.entries[0].n_tokens, 128);
        apply(&mut st, &b2);
        let b3 = s.schedule_owned(&mut st, 0.2);
        assert_eq!(b3.entries[0].n_tokens, 44);
        apply(&mut st, &b3);
        // Completing the prompt emits the first (and, with out=1, only)
        // output token, so the request finishes at the final chunk.
        assert!(st.finished.iter().any(|r| r.id == 1));
        st.check_invariants().unwrap();
    }

    #[test]
    fn offline_fills_residual_budget_only() {
        let mut st = mk_state(1024);
        // Tight latency budget: online prefill eats most of it.
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: Some(12.0),
            chunk_tokens: 4096,
            ..SchedulerConfig::default()
        });
        st.enqueue(online(1, 200, 4));
        st.enqueue(offline(10, 400, 4));
        let b = s.schedule_owned(&mut st, 0.0);
        let online_tokens: usize =
            b.entries.iter().filter(|e| e.class.is_online()).map(|e| e.n_tokens).sum();
        let offline_tokens: usize =
            b.entries.iter().filter(|e| !e.class.is_online()).map(|e| e.n_tokens).sum();
        assert_eq!(online_tokens, 200, "online gets its full prompt first");
        // Offline only gets what the residual latency allows — and the
        // predicted total must respect the budget.
        assert!(s.last_stats.predicted_ms <= 12.0 + 1e-6, "{}", s.last_stats.predicted_ms);
        assert!(offline_tokens < 400, "offline chunk must be throttled");
    }

    #[test]
    fn slo_unaware_mode_fills_chunk_budget() {
        let mut st = mk_state(1024);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None, // Sarathi++
            chunk_tokens: 512,
            ..SchedulerConfig::default()
        });
        st.enqueue(online(1, 200, 4));
        st.enqueue(offline(10, 400, 4));
        let b = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b.total_tokens(), 512, "chunk budget fully used when SLO-unaware");
    }

    #[test]
    fn disable_offline_is_pure_online() {
        let mut st = mk_state(1024);
        let mut s = sched(SchedulerConfig { enable_offline: false, ..Default::default() });
        st.enqueue(online(1, 50, 2));
        st.enqueue(offline(10, 50, 2));
        let b = s.schedule_owned(&mut st, 0.0);
        assert!(b.entries.iter().all(|e| e.class.is_online()));
        assert_eq!(st.offline_queue.len(), 1);
    }

    #[test]
    fn online_admission_preempts_offline_for_memory() {
        // 16 blocks * 16 tokens = 256 tokens of KV. Offline fills most.
        let mut st = mk_state(16);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 512,
            watermark_blocks: 0,
            ..SchedulerConfig::default()
        });
        st.enqueue(offline(10, 200, 64));
        let b = s.schedule_owned(&mut st, 0.0);
        apply(&mut st, &b);
        assert_eq!(st.running_offline, vec![10]);
        // Online request needs 200 tokens; only ~56 free -> preemption.
        st.enqueue(online(1, 200, 2));
        let b2 = s.schedule_owned(&mut st, 0.1);
        assert!(b2.entries.iter().any(|e| e.id == 1 && e.is_prefill));
        assert_eq!(s.last_stats.preemptions, 1);
        assert_eq!(st.preempted_offline, vec![10]);
        assert_eq!(st.requests[&10].prefilled, 200, "preserve keeps progress");
        st.check_invariants().unwrap();
    }

    #[test]
    fn preempted_offline_resumes_when_memory_frees() {
        let mut st = mk_state(16);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 512,
            watermark_blocks: 0,
            ..SchedulerConfig::default()
        });
        st.enqueue(offline(10, 200, 4));
        let b = s.schedule_owned(&mut st, 0.0);
        apply(&mut st, &b);
        st.enqueue(online(1, 200, 1));
        let b = s.schedule_owned(&mut st, 0.1);
        apply(&mut st, &b); // preempts 10, prefills 1
        let b = s.schedule_owned(&mut st, 0.2);
        apply(&mut st, &b); // 1 decodes once -> finished
        assert!(st.finished.iter().any(|r| r.id == 1));
        // Next iteration: 10 resumes with preserved progress.
        let b = s.schedule_owned(&mut st, 0.3);
        assert!(st.running_offline.contains(10));
        assert!(st.preempted_offline.is_empty());
        assert!(b.entries.iter().any(|e| e.id == 10));
        assert_eq!(st.requests[&10].prefilled, 200);
        st.check_invariants().unwrap();
    }

    #[test]
    fn discard_preemption_requeues_and_recomputes() {
        let mut st = mk_state(16);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 512,
            watermark_blocks: 0,
            preemption: PreemptionMode::Discard,
            ..SchedulerConfig::default()
        });
        st.enqueue(offline(10, 200, 4));
        let b = s.schedule_owned(&mut st, 0.0);
        apply(&mut st, &b);
        st.enqueue(online(1, 200, 2));
        let b = s.schedule_owned(&mut st, 0.1);
        apply(&mut st, &b);
        assert!(st.preempted_offline.is_empty());
        assert_eq!(st.offline_queue.len(), 1, "discarded -> requeued");
    }

    #[test]
    fn offline_qps_cap_limits_admissions() {
        let mut st = mk_state(4096);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 1 << 20,
            offline_qps_cap: Some(1.0), // 1 admission/s
            ..SchedulerConfig::default()
        });
        for i in 0..10 {
            st.enqueue(offline(10 + i, 32, 4));
        }
        let b = s.schedule_owned(&mut st, 0.0);
        let admissions = b.entries.iter().filter(|e| e.is_prefill).count();
        assert_eq!(admissions, 1, "token bucket starts with 1 permit");
        apply(&mut st, &b);
        // 5 seconds later: ~5 more permits accumulated (burst-capped at 1).
        let b2 = s.schedule_owned(&mut st, 5.0);
        let admissions2 = b2.entries.iter().filter(|e| e.is_prefill).count();
        assert_eq!(admissions2, 1, "burst cap 1 -> one admission per call");
    }

    #[test]
    fn max_running_bounds_admissions() {
        let mut st = mk_state(4096);
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: None,
            chunk_tokens: 1 << 20,
            max_running: 3,
            ..SchedulerConfig::default()
        });
        for i in 0..10 {
            st.enqueue(online(i, 16, 4));
        }
        let b = s.schedule_owned(&mut st, 0.0);
        assert_eq!(b.len(), 3);
        assert_eq!(st.num_running(), 3);
    }

    #[test]
    fn latency_budget_respected_by_prediction() {
        let mut st = mk_state(4096);
        let budget = 25.0;
        let mut s = sched(SchedulerConfig {
            latency_budget_ms: Some(budget),
            chunk_tokens: 1 << 20,
            ..SchedulerConfig::default()
        });
        for i in 0..50 {
            st.enqueue(offline(i, 512, 8));
        }
        let b = s.schedule_owned(&mut st, 0.0);
        assert!(!b.is_empty());
        assert!(
            s.last_stats.predicted_ms <= budget + 1e-6,
            "predicted {} > budget {budget}",
            s.last_stats.predicted_ms
        );
    }

    #[test]
    fn rate_limiter_basic() {
        let mut rl = RateLimiter::new(2.0);
        assert!(rl.admit(0.0));
        assert!(!rl.admit(0.0));
        assert!(rl.admit(0.5)); // 0.5s * 2/s = 1 token
        assert!(!rl.admit(0.5));
        assert!(rl.admit(10.0));
    }

    #[test]
    fn rate_limiter_tolerates_non_monotonic_clock() {
        let mut rl = RateLimiter::new(2.0);
        assert!(rl.admit(10.0)); // refilled to the burst cap (2) at t=10
        assert!(rl.admit(10.0)); // drain the bucket
        assert!(!rl.admit(10.0));
        // Clock steps backwards: no retroactive refill, but the anchor
        // must follow, otherwise refill is skipped forever.
        assert!(!rl.admit(4.0));
        assert!(rl.admit(4.5), "refill resumed after the backwards step");
        assert!(!rl.admit(4.5));
        assert!(rl.admit(5.0));
    }
}
