//! [`RunSet`] — an order-preserving indexed set of running request ids.
//!
//! The scheduler needs three things from the running sets that a plain
//! `Vec<RequestId>` cannot provide together at scale:
//!
//! * **stable order** — running offline requests keep their original DFS
//!   (prefix-sharing) order across iterations (Alg. 3), and online
//!   requests keep admission order;
//! * **O(1) membership** — the offline decode loop must detect ids that a
//!   self-preemption removed mid-pass (`Vec::contains` made one iteration
//!   O(running²));
//! * **O(1) removal** — `finish()` removes an arbitrary id per completed
//!   request (`Vec::retain` over both sets made a drain of n requests
//!   O(n²)).
//!
//! Implementation: a slab of doubly-linked nodes plus a
//! `HashMap<RequestId, slot>` index. Push/pop/remove/contains are O(1);
//! iteration is O(len) in insertion order. Freed slots are recycled so a
//! steady-state engine does not grow the slab.

use super::request::RequestId;
use std::collections::HashMap;

const NIL: usize = usize::MAX;

#[derive(Debug, Clone)]
struct Node {
    id: RequestId,
    prev: usize,
    next: usize,
}

/// Order-preserving set of request ids with O(1) insert/remove/contains.
#[derive(Debug, Clone)]
pub struct RunSet {
    slab: Vec<Node>,
    free: Vec<usize>,
    index: HashMap<RequestId, usize>,
    head: usize,
    tail: usize,
}

impl Default for RunSet {
    fn default() -> Self {
        RunSet::new()
    }
}

impl RunSet {
    pub fn new() -> RunSet {
        RunSet { slab: Vec::new(), free: Vec::new(), index: HashMap::new(), head: NIL, tail: NIL }
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn contains(&self, id: RequestId) -> bool {
        self.index.contains_key(&id)
    }

    /// First (oldest) id in order.
    pub fn front(&self) -> Option<RequestId> {
        (self.head != NIL).then(|| self.slab[self.head].id)
    }

    /// Last (newest) id in order.
    pub fn last(&self) -> Option<RequestId> {
        (self.tail != NIL).then(|| self.slab[self.tail].id)
    }

    /// Append `id`; ids are unique, pushing a present id is a logic error.
    pub fn push(&mut self, id: RequestId) {
        debug_assert!(!self.contains(id), "duplicate id {id} in RunSet");
        let node = Node { id, prev: self.tail, next: NIL };
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s] = node;
                s
            }
            None => {
                self.slab.push(node);
                self.slab.len() - 1
            }
        };
        if self.tail != NIL {
            self.slab[self.tail].next = slot;
        } else {
            self.head = slot;
        }
        self.tail = slot;
        self.index.insert(id, slot);
    }

    /// Remove and return the newest id (LIFO preemption order).
    pub fn pop(&mut self) -> Option<RequestId> {
        let id = self.last()?;
        self.remove(id);
        Some(id)
    }

    /// Remove `id` if present; returns whether it was a member.
    pub fn remove(&mut self, id: RequestId) -> bool {
        let Some(slot) = self.index.remove(&id) else { return false };
        let Node { prev, next, .. } = self.slab[slot];
        if prev != NIL {
            self.slab[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slab[next].prev = prev;
        } else {
            self.tail = prev;
        }
        self.free.push(slot);
        true
    }

    pub fn clear(&mut self) {
        self.slab.clear();
        self.free.clear();
        self.index.clear();
        self.head = NIL;
        self.tail = NIL;
    }

    /// Iterate ids in insertion order.
    pub fn iter(&self) -> Iter<'_> {
        Iter { set: self, at: self.head }
    }

    pub fn to_vec(&self) -> Vec<RequestId> {
        self.iter().collect()
    }
}

pub struct Iter<'a> {
    set: &'a RunSet,
    at: usize,
}

impl Iterator for Iter<'_> {
    type Item = RequestId;

    fn next(&mut self) -> Option<RequestId> {
        if self.at == NIL {
            return None;
        }
        let node = &self.set.slab[self.at];
        self.at = node.next;
        Some(node.id)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (0, Some(self.set.len()))
    }
}

impl<'a> IntoIterator for &'a RunSet {
    type Item = RequestId;
    type IntoIter = Iter<'a>;

    fn into_iter(self) -> Iter<'a> {
        self.iter()
    }
}

// Test-ergonomics: compare a RunSet against literal id sequences.
impl PartialEq<Vec<RequestId>> for RunSet {
    fn eq(&self, other: &Vec<RequestId>) -> bool {
        self.len() == other.len() && self.iter().eq(other.iter().copied())
    }
}

impl<const N: usize> PartialEq<[RequestId; N]> for RunSet {
    fn eq(&self, other: &[RequestId; N]) -> bool {
        self.len() == N && self.iter().eq(other.iter().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_preserves_order() {
        let mut s = RunSet::new();
        for id in [3, 1, 4, 1 + 4, 9] {
            s.push(id);
        }
        assert_eq!(s.to_vec(), vec![3, 1, 4, 5, 9]);
        assert_eq!(s.front(), Some(3));
        assert_eq!(s.last(), Some(9));
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn remove_middle_keeps_order_and_recycles_slots() {
        let mut s = RunSet::new();
        for id in 0..6 {
            s.push(id);
        }
        assert!(s.remove(3));
        assert!(!s.remove(3));
        assert_eq!(s.to_vec(), vec![0, 1, 2, 4, 5]);
        let slab_len = s.slab.len();
        s.push(100); // reuses the freed slot
        assert_eq!(s.slab.len(), slab_len);
        assert_eq!(s.to_vec(), vec![0, 1, 2, 4, 5, 100]);
    }

    #[test]
    fn pop_is_lifo() {
        let mut s = RunSet::new();
        s.push(1);
        s.push(2);
        s.push(3);
        assert_eq!(s.pop(), Some(3));
        assert_eq!(s.pop(), Some(2));
        assert_eq!(s.to_vec(), vec![1]);
        assert_eq!(s.pop(), Some(1));
        assert_eq!(s.pop(), None);
        assert!(s.is_empty());
    }

    #[test]
    fn remove_head_and_tail() {
        let mut s = RunSet::new();
        for id in [10, 20, 30] {
            s.push(id);
        }
        assert!(s.remove(10));
        assert_eq!(s.front(), Some(20));
        assert!(s.remove(30));
        assert_eq!(s.last(), Some(20));
        assert_eq!(s.to_vec(), vec![20]);
    }

    #[test]
    fn contains_and_eq_helpers() {
        let mut s = RunSet::new();
        s.push(7);
        s.push(8);
        assert!(s.contains(7));
        assert!(!s.contains(9));
        assert_eq!(s, vec![7, 8]);
        assert_eq!(s, [7, 8]);
        s.clear();
        assert_eq!(s, Vec::<RequestId>::new());
    }

    #[test]
    fn interleaved_ops_stay_consistent() {
        // Mini-fuzz against a Vec model.
        let mut s = RunSet::new();
        let mut model: Vec<RequestId> = Vec::new();
        let mut x = 0x9e3779b97f4a7c15u64;
        for step in 0..2000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let id = x % 64;
            match step % 3 {
                0 if !model.contains(&id) => {
                    s.push(id);
                    model.push(id);
                }
                1 => {
                    let was = model.iter().position(|&m| m == id);
                    assert_eq!(s.remove(id), was.is_some());
                    if let Some(p) = was {
                        model.remove(p);
                    }
                }
                _ => {
                    assert_eq!(s.pop(), model.pop());
                }
            }
            assert_eq!(s.to_vec(), model);
            assert_eq!(s.front(), model.first().copied());
            assert_eq!(s.last(), model.last().copied());
        }
    }
}
