//! Request model: SLO-class ids, lifecycle phases, SLO metrics, and
//! per-request progress the scheduler and engine share.

use std::sync::Arc;

/// Monotonic request identifier.
pub type RequestId = u64;

/// Shared empty prompt: every `Request::new` clones one static `Arc`
/// instead of allocating (trace replay admits thousands of requests per
/// second; prompts are shared with their `TraceEvent`, never copied).
pub fn empty_prompt() -> Arc<[u32]> {
    static EMPTY: std::sync::OnceLock<Arc<[u32]>> = std::sync::OnceLock::new();
    EMPTY.get_or_init(|| Vec::new().into()).clone()
}

/// Index into the session's SLO-class registry
/// ([`ClassRegistry`](crate::coordinator::classes::ClassRegistry)).
///
/// The paper's central dichotomy — latency-sensitive online vs
/// throughput-oriented offline — is the registry's compiled-in default:
/// index 0 is the flagship interactive class ([`ClassId::ONLINE`]) and
/// index 1 the harvest class ([`ClassId::OFFLINE`]). Every layer (queues,
/// scheduler tiers, census, metrics, cluster router) is *indexed* by this
/// id rather than matching on a two-variant enum, so new SLO classes are
/// a config change, not a refactor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClassId(pub u16);

/// Historical alias: most of the codebase spells the type `Class`.
pub type Class = ClassId;

impl ClassId {
    /// The flagship interactive class (registry index 0).
    pub const ONLINE: ClassId = ClassId(0);
    /// The default harvest class (registry index 1).
    pub const OFFLINE: ClassId = ClassId(1);

    /// Registry slot this id names.
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// True for the flagship interactive slot (registry index 0). With
    /// the default two-class registry this is exactly the paper's
    /// "online" class.
    pub fn is_online(self) -> bool {
        self == ClassId::ONLINE
    }
}

/// Request lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// In a queue, no prefill progress yet.
    Waiting,
    /// Partially prefilled (chunked prefill in flight).
    Prefill,
    /// Prefill complete; generating one token per scheduled iteration.
    Decode,
    /// Preempted with preserved state (re-admitted later).
    Preempted,
    /// Finished (all output tokens generated or budget exhausted).
    Finished,
}

/// The four statistical SLO metrics from the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SloMetric {
    MeanTtft,
    P99Ttft,
    MeanTbt,
    P99Tbt,
}

impl SloMetric {
    pub const ALL: [SloMetric; 4] =
        [SloMetric::MeanTtft, SloMetric::P99Ttft, SloMetric::MeanTbt, SloMetric::P99Tbt];

    pub fn name(self) -> &'static str {
        match self {
            SloMetric::MeanTtft => "mean_ttft",
            SloMetric::P99Ttft => "p99_ttft",
            SloMetric::MeanTbt => "mean_tbt",
            SloMetric::P99Tbt => "p99_tbt",
        }
    }

    pub fn parse(s: &str) -> Option<SloMetric> {
        match s {
            "mean_ttft" => Some(SloMetric::MeanTtft),
            "p99_ttft" => Some(SloMetric::P99Ttft),
            "mean_tbt" => Some(SloMetric::MeanTbt),
            "p99_tbt" => Some(SloMetric::P99Tbt),
            _ => None,
        }
    }

    pub fn is_ttft(self) -> bool {
        matches!(self, SloMetric::MeanTtft | SloMetric::P99Ttft)
    }
}

/// One SLO constraint: `metric` must stay at or below `limit_ms`.
///
/// In the paper's experiments limits are expressed as an *interference
/// tolerance ratio* over the pure-online baseline:
/// `limit = baseline * (1 + tolerance)` — see [`Slo::from_tolerance`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    pub metric: SloMetric,
    pub limit_ms: f64,
}

impl Slo {
    pub fn new(metric: SloMetric, limit_ms: f64) -> Slo {
        Slo { metric, limit_ms }
    }

    /// Build from a pure-online baseline measurement and a tolerance ratio
    /// (e.g. baseline 40 ms, tolerance 0.05 -> limit 42 ms).
    pub fn from_tolerance(metric: SloMetric, baseline_ms: f64, tolerance: f64) -> Slo {
        Slo { metric, limit_ms: baseline_ms * (1.0 + tolerance) }
    }
}

/// A request flowing through the system.
///
/// For the simulation backend `prompt` may be empty and only `prompt_len`
/// / `output_len` matter; the real PJRT engine carries actual token ids.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub class: Class,
    /// Arrival time in seconds (trace time for sim, engine-relative wall
    /// clock for the real path).
    pub arrival: f64,
    /// Prompt token ids (real engine), shared with the trace event that
    /// spawned the request (`Arc`: admission is a refcount bump, not a
    /// copy). Empty in pure simulation.
    pub prompt: Arc<[u32]>,
    /// Prompt length in tokens (== prompt.len() when prompt is real).
    pub prompt_len: usize,
    /// Number of output tokens to generate (sim: sampled from the trace;
    /// real engine: generation budget / until EOS).
    pub output_len: usize,
    /// Preemption priority: higher wins. Stamped from the class spec's
    /// `preempt_priority` at admission (`EngineState::enqueue`);
    /// `Request::new` seeds the classic 100/0 split for the default two
    /// classes.
    pub priority: u8,
    /// Tokens of this prompt reusable from the prefix cache at schedule
    /// time (set by the PSM policy; "deduct shared prefix" simulation).
    pub shared_prefix_len: usize,

    // ---- progress (owned by the engine/scheduler) ----
    pub phase: Phase,
    /// Prompt tokens prefilled so far (chunked prefill cursor).
    pub prefilled: usize,
    /// Output tokens generated so far.
    pub generated: usize,
    /// Times the request was preempted (fairness / starvation accounting).
    pub preemptions: u32,
    /// Generated token ids (real engine only).
    pub output_tokens: Vec<u32>,
}

impl Request {
    pub fn new(id: RequestId, class: Class, arrival: f64, prompt_len: usize, output_len: usize) -> Request {
        Request {
            id,
            class,
            arrival,
            prompt: empty_prompt(),
            prompt_len,
            output_len: output_len.max(1),
            priority: if class.is_online() { 100 } else { 0 },
            shared_prefix_len: 0,
            phase: Phase::Waiting,
            prefilled: 0,
            generated: 0,
            preemptions: 0,
            output_tokens: Vec::new(),
        }
    }

    pub fn with_prompt(mut self, prompt: impl Into<Arc<[u32]>>) -> Request {
        self.prompt = prompt.into();
        self.prompt_len = self.prompt.len();
        self
    }

    /// Prompt tokens still to prefill (after chunking and prefix reuse).
    pub fn prefill_remaining(&self) -> usize {
        self.prompt_len.saturating_sub(self.prefilled)
    }

    /// True once every prompt token is in the KV cache.
    pub fn prefill_done(&self) -> bool {
        self.prefilled >= self.prompt_len
    }

    /// Current sequence length (context held in KV cache).
    pub fn context_len(&self) -> usize {
        self.prefilled + self.generated
    }

    /// Total sequence length at completion.
    pub fn total_len(&self) -> usize {
        self.prompt_len + self.output_len
    }

    pub fn is_finished(&self) -> bool {
        self.phase == Phase::Finished
    }

    /// Advance the prefill cursor by a scheduled chunk of `n` tokens; flips
    /// to Decode when the prompt completes.
    pub fn advance_prefill(&mut self, n: usize) {
        debug_assert!(n <= self.prefill_remaining());
        self.prefilled += n;
        self.phase = if self.prefill_done() { Phase::Decode } else { Phase::Prefill };
    }

    /// Record one generated token; flips to Finished at the output budget.
    pub fn advance_decode(&mut self) {
        debug_assert!(self.prefill_done());
        self.generated += 1;
        if self.generated >= self.output_len {
            self.phase = Phase::Finished;
        }
    }

    /// Preempt with state preserved (paper's default preemption mechanism).
    pub fn preempt_preserve(&mut self) {
        self.preemptions += 1;
        self.phase = Phase::Preempted;
    }

    /// Preempt discarding computed state: prefill restarts from the shared
    /// prefix, generated tokens are lost (InferCept's "discard" class).
    pub fn preempt_discard(&mut self) {
        self.preemptions += 1;
        self.prefilled = 0;
        self.generated = 0;
        self.output_tokens.clear();
        self.phase = Phase::Waiting;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_prefill_then_decode_then_finish() {
        let mut r = Request::new(1, Class::ONLINE, 0.0, 10, 3);
        assert_eq!(r.phase, Phase::Waiting);
        assert_eq!(r.prefill_remaining(), 10);
        r.advance_prefill(6);
        assert_eq!(r.phase, Phase::Prefill);
        assert_eq!(r.prefill_remaining(), 4);
        r.advance_prefill(4);
        assert_eq!(r.phase, Phase::Decode);
        assert!(r.prefill_done());
        r.advance_decode();
        r.advance_decode();
        assert_eq!(r.phase, Phase::Decode);
        r.advance_decode();
        assert_eq!(r.phase, Phase::Finished);
        assert_eq!(r.context_len(), 13);
    }

    #[test]
    fn preempt_preserve_keeps_progress() {
        let mut r = Request::new(1, Class::OFFLINE, 0.0, 10, 5);
        r.advance_prefill(10);
        r.advance_decode();
        r.preempt_preserve();
        assert_eq!(r.phase, Phase::Preempted);
        assert_eq!(r.prefilled, 10);
        assert_eq!(r.generated, 1);
        assert_eq!(r.preemptions, 1);
    }

    #[test]
    fn preempt_discard_resets_progress() {
        let mut r = Request::new(1, Class::OFFLINE, 0.0, 10, 5);
        r.advance_prefill(10);
        r.advance_decode();
        r.preempt_discard();
        assert_eq!(r.phase, Phase::Waiting);
        assert_eq!(r.prefilled, 0);
        assert_eq!(r.generated, 0);
    }

    #[test]
    fn default_priorities() {
        assert_eq!(Request::new(1, Class::ONLINE, 0.0, 1, 1).priority, 100);
        assert_eq!(Request::new(2, Class::OFFLINE, 0.0, 1, 1).priority, 0);
    }

    #[test]
    fn slo_from_tolerance() {
        let s = Slo::from_tolerance(SloMetric::P99Tbt, 40.0, 0.10);
        assert!((s.limit_ms - 44.0).abs() < 1e-9);
    }

    #[test]
    fn slo_metric_roundtrip() {
        for m in SloMetric::ALL {
            assert_eq!(SloMetric::parse(m.name()), Some(m));
        }
        assert_eq!(SloMetric::parse("bogus"), None);
    }

    #[test]
    fn output_len_at_least_one() {
        assert_eq!(Request::new(1, Class::ONLINE, 0.0, 5, 0).output_len, 1);
    }

    #[test]
    fn prompts_are_shared_not_copied() {
        let prompt: Arc<[u32]> = vec![1, 2, 3].into();
        let r = Request::new(1, Class::ONLINE, 0.0, 0, 4).with_prompt(prompt.clone());
        assert_eq!(r.prompt_len, 3);
        assert!(Arc::ptr_eq(&r.prompt, &prompt), "admission must not copy the prompt");
        let fresh = Request::new(2, Class::OFFLINE, 0.0, 8, 1);
        assert!(fresh.prompt.is_empty());
    }
}
