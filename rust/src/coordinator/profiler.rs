//! SLO-aware profiler (§4.2): turns a *statistical* SLO (mean/P99
//! TTFT/TBT limit) into the per-iteration latency budget the scheduler
//! enforces.
//!
//! A naive budget (= the SLO limit itself) is wrong in both directions:
//! a mean-TBT SLO tolerates individual batches far above the limit, while
//! a P99 SLO with queueing effects can require budgets *below* it. The
//! profiler closes the gap empirically: it test-runs candidate budgets
//! against the (sampled) workload and binary-searches the largest budget
//! whose end-to-end report still meets the SLO — larger budget ⇒ more
//! offline co-location ⇒ more interference, so compliance is monotone in
//! the budget and binary search applies.
//!
//! The profiler is engine-agnostic: it takes an evaluation closure, so the
//! same code profiles against the simulator (fast, used by the figure
//! harnesses) or the real PJRT engine.

use super::metrics::Report;
use super::request::Slo;

#[derive(Debug, Clone)]
pub struct ProfilerConfig {
    /// Budget search range (ms).
    pub min_budget_ms: f64,
    pub max_budget_ms: f64,
    /// Binary-search refinement steps (each = one test run).
    pub steps: usize,
    /// Relative tolerance when comparing against the SLO limit.
    pub slack: f64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        ProfilerConfig { min_budget_ms: 1.0, max_budget_ms: 500.0, steps: 8, slack: 0.0 }
    }
}

#[derive(Debug, Clone)]
pub struct ProfileResult {
    /// The chosen per-iteration latency budget (ms).
    pub budget_ms: f64,
    /// The SLO metric achieved at that budget.
    pub achieved_ms: f64,
    /// Offline throughput at that budget (the profit of co-location).
    pub offline_tps: f64,
    /// Every (budget, metric, offline_tps) test run, for inspection.
    pub trials: Vec<(f64, f64, f64)>,
}

/// Binary-search the largest compliant latency budget.
///
/// `eval(budget_ms)` must run the hybrid workload with that budget and
/// return the resulting [`Report`].
pub fn profile_latency_budget<F: FnMut(f64) -> Report>(
    slo: &Slo,
    cfg: &ProfilerConfig,
    mut eval: F,
) -> ProfileResult {
    let limit = slo.limit_ms * (1.0 + cfg.slack);
    let mut trials = Vec::new();
    let mut run = |b: f64, trials: &mut Vec<(f64, f64, f64)>| -> (f64, f64) {
        let report = eval(b);
        // A budget too small to serve the online workload at all is a
        // violation, not vacuous compliance.
        let m = if report.online_finished == 0 {
            f64::INFINITY
        } else {
            report.metric(slo.metric)
        };
        trials.push((b, m, report.offline_tps));
        (m, report.offline_tps)
    };

    // Establish the bracket. The compliance region is an *interval*:
    // budgets too small to serve the online workload violate TTFT via
    // queueing, budgets too large violate via offline interference. Find
    // a compliant anchor first (geometric scan from the minimum), then
    // binary-search the interval's upper edge.
    let (mut lo_m, mut lo_tps) = run(cfg.min_budget_ms, &mut trials);
    let mut lo_budget = cfg.min_budget_ms;
    if lo_m > limit {
        let mut found = false;
        let mut b = cfg.min_budget_ms * 2.0;
        while b < cfg.max_budget_ms {
            let (m, tps) = run(b, &mut trials);
            if m <= limit {
                lo_budget = b;
                lo_m = m;
                lo_tps = tps;
                found = true;
                break;
            }
            b *= 2.0;
        }
        if !found {
            // Infeasible at every probed budget: report the least-bad
            // probe. `total_cmp` orders NaN (a degenerate eval — e.g. a
            // 0/0 latency ratio from an empty window — must not panic the
            // profiler; NaN sorts above every real violation and is never
            // picked while any finite probe exists).
            let best = trials
                .iter()
                .cloned()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .expect("the bracket scan recorded at least one trial");
            return ProfileResult {
                budget_ms: best.0,
                achieved_ms: best.1,
                offline_tps: best.2,
                trials,
            };
        }
    }
    let (hi_m, hi_tps) = run(cfg.max_budget_ms, &mut trials);
    if hi_m <= limit {
        // Even the max budget complies (light workload): use it.
        return ProfileResult {
            budget_ms: cfg.max_budget_ms,
            achieved_ms: hi_m,
            offline_tps: hi_tps,
            trials,
        };
    }

    let mut lo = lo_budget; // compliant
    let mut hi = cfg.max_budget_ms; // violating
    let mut best = (lo_budget, lo_m, lo_tps);
    for _ in 0..cfg.steps {
        let mid = 0.5 * (lo + hi);
        let (m, tps) = run(mid, &mut trials);
        if m <= limit {
            best = (mid, m, tps);
            lo = mid;
        } else {
            hi = mid;
        }
    }
    ProfileResult { budget_ms: best.0, achieved_ms: best.1, offline_tps: best.2, trials }
}

/// The Fig. 7 strawman: use the SLO limit itself as the batch budget.
pub fn naive_budget(slo: &Slo) -> f64 {
    slo.limit_ms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SloMetric;

    /// Synthetic monotone response: metric grows with budget; offline
    /// throughput too.
    fn fake_eval(budget: f64) -> Report {
        Report {
            mean_ttft_ms: 0.0,
            p50_ttft_ms: 0.0,
            p99_ttft_ms: 0.0,
            mean_tbt_ms: 10.0 + 0.5 * budget,
            p50_tbt_ms: 0.0,
            p99_tbt_ms: 0.0,
            online_finished: 1,
            offline_finished: 1,
            online_tps: 0.0,
            offline_tps: budget * 10.0,
            total_tps: 0.0,
            online_qps: 0.0,
            offline_qps: 0.0,
            duration_s: 1.0,
            batch_latency_hist: crate::obs::Histogram::new(),
            predictor_error: Vec::new(),
            classes: Vec::new(),
        }
    }

    #[test]
    fn finds_largest_compliant_budget() {
        // mean_tbt = 10 + 0.5 b <= 40  =>  b <= 60
        let slo = Slo::new(SloMetric::MeanTbt, 40.0);
        let cfg = ProfilerConfig { min_budget_ms: 1.0, max_budget_ms: 200.0, steps: 12, slack: 0.0 };
        let r = profile_latency_budget(&slo, &cfg, fake_eval);
        assert!((r.budget_ms - 60.0).abs() < 1.0, "budget {}", r.budget_ms);
        assert!(r.achieved_ms <= 40.0);
        assert!(r.trials.len() >= 10);
    }

    #[test]
    fn nan_producing_eval_does_not_panic() {
        // Degenerate sample set: the minimum-budget probe violates
        // finitely, and every larger probe reports NaN for the metric
        // (e.g. a 0/0 latency ratio from an empty measurement window).
        // NaN is never `<= limit`, so the bracket scan finds no compliant
        // anchor and the infeasible least-bad-probe path runs — which
        // used to panic in `partial_cmp(..).unwrap()`. With `total_cmp`,
        // NaN sorts above every finite violation and the finite probe is
        // reported.
        let slo = Slo::new(SloMetric::MeanTbt, 5.0);
        let cfg = ProfilerConfig { min_budget_ms: 1.0, max_budget_ms: 16.0, steps: 3, slack: 0.0 };
        let r = profile_latency_budget(&slo, &cfg, |budget| Report {
            mean_tbt_ms: if budget <= 1.0 { 50.0 } else { f64::NAN },
            ..fake_eval(budget)
        });
        assert_eq!(r.budget_ms, 1.0, "the finite probe wins over NaN ones");
        assert_eq!(r.achieved_ms, 50.0);
        assert!(r.trials.len() >= 2, "the geometric scan probed NaN budgets");
        // All-NaN evals must not panic either (NaN escapes the violation
        // check, so the search degenerates to the minimum budget).
        let r = profile_latency_budget(&slo, &cfg, |budget| Report {
            mean_tbt_ms: f64::NAN,
            ..fake_eval(budget)
        });
        assert!(r.achieved_ms.is_nan(), "honest report of a fully degenerate profile");
    }

    #[test]
    fn infeasible_slo_returns_min_budget() {
        let slo = Slo::new(SloMetric::MeanTbt, 5.0); // below the 10ms floor
        let r = profile_latency_budget(&slo, &ProfilerConfig::default(), fake_eval);
        assert_eq!(r.budget_ms, ProfilerConfig::default().min_budget_ms);
        assert!(r.achieved_ms > 5.0, "reports the violation honestly");
    }

    #[test]
    fn light_workload_returns_max_budget() {
        let slo = Slo::new(SloMetric::MeanTbt, 1e6);
        let cfg = ProfilerConfig::default();
        let r = profile_latency_budget(&slo, &cfg, fake_eval);
        assert_eq!(r.budget_ms, cfg.max_budget_ms);
        assert_eq!(r.trials.len(), 2, "bracket probes only");
    }

    #[test]
    fn budget_increases_with_looser_slo() {
        let cfg = ProfilerConfig { steps: 10, ..Default::default() };
        let tight =
            profile_latency_budget(&Slo::new(SloMetric::MeanTbt, 20.0), &cfg, fake_eval);
        let loose =
            profile_latency_budget(&Slo::new(SloMetric::MeanTbt, 60.0), &cfg, fake_eval);
        assert!(loose.budget_ms > tight.budget_ms);
        assert!(loose.offline_tps > tight.offline_tps, "looser SLO buys throughput");
    }

    #[test]
    fn naive_budget_is_the_limit() {
        assert_eq!(naive_budget(&Slo::new(SloMetric::P99Tbt, 33.0)), 33.0);
    }
}
