//! Fairness-extended PSM (§4.3, Alg. 4): starvation avoidance.
//!
//! Vanilla PSM can starve requests with little prefix-sharing potential —
//! a stream of "What is ..." arrivals keeps a lone "How to code" waiting
//! forever. The extension keeps, next to the prefix tree, a freshness-
//! ordered self-balancing tree (`BTreeMap` keyed by arrival), and draws
//! each next request from the prefix tree with probability `u` (the
//! *utility ratio*) or from the stalest end of the freshness tree with
//! probability `1-u`. A request scheduled from either structure is removed
//! from both, keeping them synchronized.
//!
//! With the N-class SLO registry, fairness composes per class: each
//! `longest-prefix` class runs its own `FairPsm` instance (independently
//! seeded — see `EngineState::with_registry`), intra-class starvation is
//! handled here, and *cross*-class starvation is the scheduler's job —
//! per-class admission rate caps plus the spec's `starvation_age_s`
//! override (the queue head bypasses its class rate cap once it has
//! waited past the threshold).

use super::psm::PrefixTree;
use super::request::RequestId;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Arrival-ordered index; `(arrival_ns, id)` keys make entries unique.
#[derive(Debug, Default)]
pub struct FreshnessTree {
    by_age: BTreeMap<(u64, RequestId), ()>,
    key_of: BTreeMap<RequestId, (u64, RequestId)>,
}

impl FreshnessTree {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, id: RequestId, arrival_s: f64) {
        let key = ((arrival_s.max(0.0) * 1e9) as u64, id);
        self.by_age.insert(key, ());
        self.key_of.insert(id, key);
    }

    /// The stalest (earliest-arrival) request.
    pub fn stalest(&self) -> Option<RequestId> {
        self.by_age.keys().next().map(|&(_, id)| id)
    }

    pub fn remove(&mut self, id: RequestId) -> bool {
        match self.key_of.remove(&id) {
            Some(key) => {
                self.by_age.remove(&key);
                true
            }
            None => false,
        }
    }

    pub fn len(&self) -> usize {
        self.by_age.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_age.is_empty()
    }
}

/// The combined structure behind the fairness-aware PSM policy.
#[derive(Debug)]
pub struct FairPsm {
    pub trie: PrefixTree,
    pub fresh: FreshnessTree,
    /// Probability of drawing from the prefix tree (1.0 = pure PSM,
    /// 0.0 = pure FCFS-by-age).
    pub utility_ratio: f64,
    rng: Rng,
    /// Cached draw so peek/pop agree (a peek must not re-flip the coin).
    pending: Option<RequestId>,
}

impl FairPsm {
    pub fn new(utility_ratio: f64, seed: u64) -> FairPsm {
        assert!((0.0..=1.0).contains(&utility_ratio));
        FairPsm {
            trie: PrefixTree::new(),
            fresh: FreshnessTree::new(),
            utility_ratio,
            rng: Rng::new(seed),
            pending: None,
        }
    }

    pub fn len(&self) -> usize {
        self.fresh.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fresh.is_empty()
    }

    pub fn insert(&mut self, id: RequestId, prompt: &[u32], arrival_s: f64) {
        self.trie.insert(id, prompt);
        self.fresh.insert(id, arrival_s);
        // A newly inserted request may precede the cached pick in DFS
        // order; drop the cache so the next peek re-draws.
        self.pending = None;
    }

    /// Next request under the utility-ratio policy, without removing it.
    pub fn peek_next(&mut self) -> Option<RequestId> {
        if let Some(id) = self.pending {
            return Some(id);
        }
        if self.is_empty() {
            return None;
        }
        let from_trie = self.rng.chance(self.utility_ratio);
        let id = if from_trie {
            self.trie.peek_next().or_else(|| self.fresh.stalest())
        } else {
            self.fresh.stalest().or_else(|| self.trie.peek_next())
        }?;
        self.pending = Some(id);
        Some(id)
    }

    /// Remove a request from both structures (after it was scheduled).
    pub fn remove(&mut self, id: RequestId) -> bool {
        if self.pending == Some(id) {
            self.pending = None;
        }
        let a = self.trie.remove(id);
        let b = self.fresh.remove(id);
        debug_assert_eq!(a, b, "structures out of sync for {id}");
        a
    }

    pub fn pop_next(&mut self) -> Option<RequestId> {
        let id = self.peek_next()?;
        self.remove(id);
        Some(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<u32> {
        s.bytes().map(|b| b as u32).collect()
    }

    #[test]
    fn freshness_orders_by_arrival() {
        let mut f = FreshnessTree::new();
        f.insert(1, 5.0);
        f.insert(2, 1.0);
        f.insert(3, 3.0);
        assert_eq!(f.stalest(), Some(2));
        assert!(f.remove(2));
        assert_eq!(f.stalest(), Some(3));
        assert!(!f.remove(2));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn freshness_ties_break_by_id() {
        let mut f = FreshnessTree::new();
        f.insert(9, 1.0);
        f.insert(4, 1.0);
        assert_eq!(f.stalest(), Some(4));
    }

    #[test]
    fn u1_is_pure_psm() {
        let mut p = FairPsm::new(1.0, 42);
        p.insert(1, &toks("zzz"), 0.0); // oldest but DFS-last
        p.insert(2, &toks("aaa"), 1.0);
        assert_eq!(p.pop_next(), Some(2), "u=1 always follows DFS order");
        assert_eq!(p.pop_next(), Some(1));
    }

    #[test]
    fn u0_is_pure_age_order() {
        let mut p = FairPsm::new(0.0, 42);
        p.insert(1, &toks("zzz"), 0.0);
        p.insert(2, &toks("aaa"), 1.0);
        assert_eq!(p.pop_next(), Some(1), "u=0 always picks stalest");
        assert_eq!(p.pop_next(), Some(2));
    }

    #[test]
    fn peek_is_stable_until_pop() {
        let mut p = FairPsm::new(0.5, 7);
        for i in 0..10u64 {
            p.insert(i, &toks(&format!("req {i}")), i as f64);
        }
        let a = p.peek_next();
        for _ in 0..20 {
            assert_eq!(p.peek_next(), a, "peek must not re-flip the coin");
        }
        assert_eq!(p.pop_next(), a);
    }

    #[test]
    fn starvation_bounded_with_mid_u() {
        // One loner vs a continuous stream of prefix-sharers: with u=0.5
        // the loner (always the stalest) must get scheduled long before the
        // stream drains.
        let mut p = FairPsm::new(0.5, 123);
        p.insert(0, &toks("How to code"), 0.0);
        for i in 1..200u64 {
            p.insert(i, &toks(&format!("What is topic {i}")), i as f64 * 0.01);
        }
        let mut popped_at = None;
        for step in 0..200 {
            let id = p.pop_next().unwrap();
            if id == 0 {
                popped_at = Some(step);
                break;
            }
        }
        let at = popped_at.expect("loner must be scheduled");
        assert!(at < 50, "loner waited {at} slots under u=0.5");
    }

    #[test]
    fn pure_psm_starves_the_loner() {
        // Control for the test above: with u=1.0 the loner goes last
        // ('H' < 'W' would actually put it first — use a DFS-last prompt).
        let mut p = FairPsm::new(1.0, 5);
        p.insert(0, &toks("zzz loner"), 0.0);
        for i in 1..50u64 {
            p.insert(i, &toks(&format!("aaa family {i}")), i as f64);
        }
        let mut order = Vec::new();
        while let Some(id) = p.pop_next() {
            order.push(id);
        }
        assert_eq!(*order.last().unwrap(), 0, "pure PSM schedules the loner dead last");
    }

    #[test]
    fn remove_keeps_structures_synced() {
        let mut p = FairPsm::new(0.5, 9);
        p.insert(1, &toks("a"), 0.0);
        p.insert(2, &toks("b"), 1.0);
        assert!(p.remove(1));
        assert!(!p.remove(1));
        assert_eq!(p.len(), 1);
        assert_eq!(p.trie.len(), 1);
        assert_eq!(p.fresh.len(), 1);
        assert_eq!(p.pop_next(), Some(2));
        assert!(p.is_empty());
    }
}
