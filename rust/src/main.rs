//! `hygen` — the launcher.
//!
//! Subcommands:
//! * `serve`            — start the real HTTP serving instance (PJRT engine)
//! * `run-trace`        — replay a synthetic workload in simulation, print the report
//! * `figures <id|all>` — regenerate the paper's evaluation figures (results/*.csv)
//! * `profile`          — SLO-aware profiler: derive the latency budget for an SLO
//! * `train-predictor`  — profile a cost model and fit/save the LR latency predictor
//! * `gen-trace`        — emit a synthetic trace CSV (azure | mooncake | datasets)
//! * `bench-sched`      — scheduling-overhead micro-bench; writes BENCH_sched.json
//! * `bench-replay`     — end-to-end replay throughput bench; writes BENCH_e2e.json
//! * `cluster-sim`      — multi-replica router comparison; writes
//!   artifacts/cluster_compare.csv
//! * `multi-slo`        — N-class SLO registry comparison on the 4-class
//!   trace; writes artifacts/multi_slo.csv
//! * `chaos`            — fault-injection comparison (kill/restart
//!   schedules per router policy); writes artifacts/chaos_compare.csv
//!   and fails if any cell loses a request
//! * `overload`         — open-loop QPS ramp through the serving admission
//!   ladder (429s, deadline 504s); writes artifacts/overload.csv and
//!   fails if any row's conservation ledger is off
//! * `trace-dump`       — replay one seeded faulted cluster run and dump
//!   the per-replica flight recorders as Perfetto-loadable Chrome trace
//!   JSON (artifacts/trace.json), byte-identical for a fixed seed
//! * `lint`             — in-repo static analysis over `rust/src`
//!   (determinism / alloc-free / panic-free / config-doc invariants);
//!   exits non-zero on any violation

use hygen::baselines::{SimSetup, System};
use hygen::cluster::router::RouterPolicy;
use hygen::config::ServeConfig;
use hygen::coordinator::predictor::LatencyPredictor;
use hygen::coordinator::queues::OfflinePolicy;
use hygen::coordinator::request::{Slo, SloMetric};
use hygen::engine::pjrt_backend::build_real_engine;
use hygen::experiments::{figures, hygen_profiled, online_baseline, Ctx};
use hygen::server::Server;
use hygen::sim::costmodel::CostModel;
use hygen::sim::profile_and_fit;
use hygen::util::alloc::CountingAlloc;
use hygen::util::cli::Args;
use hygen::workload::azure::{self, AzureTraceConfig};
use hygen::workload::datasets::{self, Dataset};
use hygen::workload::mooncake::{self, MooncakeTraceConfig};
use hygen::workload::trace::Trace;

const USAGE: &str = "\
hygen — elastic online/offline LLM request co-location (HyGen reproduction)

USAGE:
  hygen serve        [--config serve.json] [--bind ADDR] [--budget-ms N]
                     [--policy fcfs|psm|psm-fair] [--artifacts DIR]
                     [--replicas N]
                     [--router round-robin|jsq|slo-headroom|prefix-affinity]
                     [--drain-s N]
                     (requires a build with `--features pjrt` + `make artifacts`)
  hygen run-trace    [--system hygen|hygen-star|sarathi|sarathi++|sarathi-offline]
                     [--model NAME] [--online-qps N] [--offline-dataset arxiv|cnn|mmlu]
                     [--offline-n N] [--budget-ms N] [--policy P] [--duration S]
                     [--seed N]
  hygen figures      <1|3|4|...|17|all> [-j/--jobs N] [--out DIR] [--quick]
                     [--seed N]
                     (-j runs independent figure/sweep jobs on N worker
                     threads, default = all hardware threads; CSV output
                     is byte-identical for any -j)
  hygen profile      [--metric mean_tbt|p99_tbt|mean_ttft|p99_ttft]
                     [--tolerance R] [--model NAME] [--online-qps N] [--quick]
  hygen train-predictor [--model NAME] [--samples N] [--out FILE]
  hygen gen-trace    [--kind azure|mooncake|arxiv|cnn|mmlu] [--out FILE]
                     [--qps N] [--duration S] [--n N] [--seed N]
  hygen bench-sched  [--out FILE] [--quick] [--n N] [--seed N]
                     (10k-request mixed trace by default; --quick is the
                     few-hundred-request CI smoke shape)
  hygen bench-replay [--out FILE] [--prefix-out FILE] [--quick] [--seed N] [-j N]
                     (end-to-end mixed-trace replay at several scales +
                     the zero-allocation steady-decode probe with live
                     prefix-cache churn + the O(1) block-recycling probe
                     + the 0/50/90% shared-prefix shape sweep; writes
                     BENCH_e2e.json and the deterministic
                     BENCH_prefix.csv, and fails on regression ratios)
  hygen cluster-sim  [--out DIR] [--quick] [--seed N] [-j/--jobs N]
                     [--replicas 1,2,4,8] [--check] [--tbt-slo-ms N]
                     (replay the calibrated mixed trace AND the
                     Mooncake-style prefix-heavy trace against N
                     sim-backend replicas per router policy; writes
                     artifacts/cluster_compare.csv — incl. per-cell
                     prefix-cache hit-rate — byte-identical for a fixed
                     seed; --check enforces the slo-headroom-vs-
                     round-robin gate and the prefix-affinity-vs-
                     slo-headroom cache gate at 4 replicas)
  hygen multi-slo    [--out DIR] [--quick] [--seed N] [-j/--jobs N]
                     [--replicas 1,2,4]
                     (replay the calibrated 4-class trace — chat /
                     completion / summarize / batch — under the 2-class
                     and 4-class registries across replica counts; writes
                     artifacts/multi_slo.csv with per-tier SLO attainment
                     plus total throughput, byte-identical for a fixed
                     seed and any -j)
  hygen lint         [--root DIR]
                     (in-repo static analysis: determinism, alloc-free,
                     panic-free, and config-doc invariants over rust/src;
                     prints file:line diagnostics and exits non-zero on
                     any violation — see DESIGN.md \"Enforced invariants\")
  hygen chaos        [--out DIR] [--quick] [--seed N] [-j/--jobs N]
                     (replay the calibrated mixed trace against every
                     router policy under seeded random kill/restart
                     schedules next to a fault-free baseline; writes
                     artifacts/chaos_compare.csv — goodput, rerouted
                     TTFT penalty, migrations, 503s — byte-identical
                     for a fixed seed and any -j, and fails if any cell
                     loses or double-completes a request)
  hygen overload     [--out DIR] [--quick] [--seed N] [-j/--jobs N]
                     (ramp open-loop QPS past single-replica capacity
                     through the serving admission ladder — brown-out
                     429s, bounded queues, SLO-derived deadline 504s
                     cancelled in-engine; writes artifacts/overload.csv —
                     goodput vs offered load, per-class sheds, p99 TTFT —
                     byte-identical for a fixed seed and any -j, and
                     fails on any conservation-ledger imbalance)
  hygen trace-dump   [--out FILE] [--quick] [--seed N] [--schedule K]
                     (replay one seeded kill/restart cluster run — the
                     chaos recipe, slo-headroom router — and write every
                     replica's flight recorder as Perfetto-loadable
                     Chrome trace JSON; --schedule 0 replays the
                     fault-free baseline; output is byte-identical for a
                     fixed seed — load the file at https://ui.perfetto.dev)

MODELS: a100-llama2-7b (default), a40-qwen-14b, a40x4-yi-34b-tp2pp2,
        a100-mistral-7b, a5000-sheared-2.7b
";

/// Count heap allocations process-wide so `bench-replay` can enforce the
/// allocation-free steady-state contract with real numbers (one relaxed
/// atomic add per allocation; negligible for every other subcommand).
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("serve") => cmd_serve(&args),
        Some("run-trace") => cmd_run_trace(&args),
        Some("figures") => cmd_figures(&args),
        Some("profile") => cmd_profile(&args),
        Some("train-predictor") => cmd_train_predictor(&args),
        Some("gen-trace") => cmd_gen_trace(&args),
        Some("bench-sched") => cmd_bench_sched(&args),
        Some("bench-replay") => cmd_bench_replay(&args),
        Some("cluster-sim") => cmd_cluster_sim(&args),
        Some("multi-slo") => cmd_multi_slo(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("overload") => cmd_overload(&args),
        Some("trace-dump") => cmd_trace_dump(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from(args: &Args) -> Ctx {
    let mut ctx = if args.get_bool("quick") { Ctx::quick() } else { Ctx::default() };
    ctx.seed = args.get_u64("seed", ctx.seed);
    ctx.out_dir = args.get_or("out", &ctx.out_dir).to_string();
    ctx.jobs = args.get_usize_alias("jobs", "j", ctx.jobs).max(1);
    ctx
}

fn parse_model(args: &Args) -> anyhow::Result<CostModel> {
    let name = args.get_or("model", "a100-llama2-7b");
    CostModel::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown model '{name}'; see --help"))
}

fn parse_policy(args: &Args) -> anyhow::Result<OfflinePolicy> {
    let name = args.get_or("policy", "psm");
    let u = args.get_f64("utility-ratio", 0.9);
    OfflinePolicy::parse(name, u).ok_or_else(|| anyhow::anyhow!("unknown policy '{name}'"))
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ServeConfig::load(path)?,
        None => ServeConfig::default(),
    };
    if let Some(b) = args.get("bind") {
        cfg.bind = b.to_string();
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if args.get("budget-ms").is_some() {
        cfg.latency_budget_ms = Some(args.get_f64("budget-ms", 50.0));
    }
    if args.get("policy").is_some() {
        cfg.policy = parse_policy(args)?;
    }
    // Topology flags error on bad input instead of silently keeping the
    // default (same contract as the config-file path).
    if let Some(v) = args.get("replicas") {
        let n: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("--replicas expects a positive integer, got '{v}'"))?;
        anyhow::ensure!(n >= 1, "cluster needs at least one replica");
        cfg.cluster.replicas = n;
    }
    if let Some(name) = args.get("router") {
        cfg.cluster.router = RouterPolicy::parse(name)
            .ok_or_else(|| anyhow::anyhow!("unknown router '{name}'; see --help"))?;
    }
    if let Some(v) = args.get("drain-s") {
        let s: f64 = v
            .parse()
            .map_err(|_| anyhow::anyhow!("--drain-s expects a number of seconds, got '{v}'"))?;
        anyhow::ensure!(s.is_finite() && s >= 0.0, "--drain-s must be non-negative");
        cfg.cluster.drain_s = s;
    }
    println!("loading artifacts from {} ...", cfg.artifacts_dir);
    let registry = std::sync::Arc::new(cfg.classes.clone());
    let server = {
        let factories: Vec<_> = (0..cfg.cluster.replicas)
            .map(|i| {
                let cfg = cfg.clone();
                let registry = std::sync::Arc::clone(&registry);
                move || -> anyhow::Result<_> {
                    let mut engine = build_real_engine(
                        &cfg.artifacts_dir,
                        cfg.latency_budget_ms,
                        cfg.policy,
                        registry,
                        cfg.seed + i as u64,
                    )?;
                    engine
                        .state
                        .recorder
                        .configure(cfg.cluster.trace_capacity, cfg.cluster.trace_enabled);
                    engine.state.blocks.set_eviction_policy(cfg.cluster.kv_eviction);
                    println!(
                        "replica {i} ready: {} slots, max chunk {}, max request len {}",
                        engine.backend.nslots(),
                        engine.backend.max_chunk(),
                        engine.backend.max_request_len()
                    );
                    Ok(engine)
                }
            })
            .collect();
        Server::start_cluster_with_registry(
            &cfg.bind,
            factories,
            cfg.cluster.build_router(),
            cfg.http_workers,
            std::time::Duration::from_secs_f64(cfg.cluster.drain_s),
            std::sync::Arc::clone(&registry),
            cfg.cluster.supervisor_config(),
            cfg.cluster.overload_config(),
        )?
    };
    println!(
        "hygen serving on http://{} with {} replica(s), router {}  \
         (POST /v1/completions, GET /metrics)",
        server.addr,
        server.replicas,
        cfg.cluster.router.name()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_run_trace(args: &Args) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let policy = parse_policy(args)?;
    let seed = args.get_u64("seed", 0);
    let duration = args.get_f64("duration", 300.0);
    let online = azure::generate(
        &AzureTraceConfig {
            duration_s: duration,
            mean_qps: args.get_f64("online-qps", 2.0),
            ..Default::default()
        },
        seed,
    );
    let dataset = Dataset::parse(args.get_or("offline-dataset", "arxiv"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let offline = datasets::generate(dataset, args.get_usize("offline-n", 1500), seed);
    let workload = online.merged(offline);

    let system = match args.get_or("system", "hygen") {
        "sarathi" => System::Sarathi,
        "sarathi++" | "sarathi-pp" => System::SarathiPlusPlus,
        "sarathi-offline" => System::SarathiOffline { chunk_tokens: 1024 },
        "hygen-star" => System::HyGenStar { offline_qps: args.get_f64("offline-qps-cap", 1.0) },
        "hygen" => System::HyGen { latency_budget_ms: args.get_f64("budget-ms", 40.0) },
        other => anyhow::bail!("unknown system '{other}'"),
    };
    let setup = SimSetup::new(model).with_policy(policy).with_seed(seed);
    println!("running {} on {} ({} events) ...", system.name(), setup.model.name, workload.len());
    let r = setup.run(system, &workload, duration * 1.5)?;
    println!("{}", r.report.to_json().to_pretty());
    println!(
        "iterations={} sched_overhead_total={:?} ({:.1} µs/iter)",
        r.iterations,
        r.sched_overhead,
        r.sched_overhead.as_secs_f64() * 1e6 / r.iterations.max(1) as f64
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let ctx = ctx_from(args);
    figures::run(&ctx, which)
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args);
    let model = parse_model(args)?;
    let metric = SloMetric::parse(args.get_or("metric", "p99_tbt"))
        .ok_or_else(|| anyhow::anyhow!("bad metric"))?;
    let tol = args.get_f64("tolerance", 0.1);
    let setup = SimSetup::new(model).with_seed(ctx.seed);
    let online = azure::generate(
        &AzureTraceConfig {
            duration_s: ctx.trace_s,
            mean_qps: args.get_f64("online-qps", 2.0),
            ..Default::default()
        },
        ctx.seed,
    );
    let offline = datasets::generate(Dataset::ArxivSummarization, 2000, ctx.seed);
    let base = online_baseline(&setup, &online, &ctx)?;
    let slo = Slo::from_tolerance(metric, base.metric(metric), tol);
    println!(
        "baseline {} = {:.2} ms; SLO limit = {:.2} ms (tolerance {:.0}%)",
        metric.name(),
        base.metric(metric),
        slo.limit_ms,
        tol * 100.0
    );
    let workload = online.merged(offline);
    let (prof, report) = hygen_profiled(&setup, &workload, &slo, &ctx)?;
    println!("profiled latency budget: {:.2} ms", prof.budget_ms);
    println!("achieved {} = {:.2} ms; offline tps = {:.1}", metric.name(), report.metric(metric), report.offline_tps);
    println!("trials:");
    for (b, m, tps) in &prof.trials {
        println!("  budget {b:>8.2} ms -> {} {m:>8.2} ms, offline {tps:>8.1} tok/s", metric.name());
    }
    Ok(())
}

fn cmd_train_predictor(args: &Args) -> anyhow::Result<()> {
    let model = parse_model(args)?;
    let n = args.get_usize("samples", 80_000);
    let t0 = std::time::Instant::now();
    let (predictor, _samples, mape) = profile_and_fit(&model, args.get_u64("seed", 0), n);
    println!(
        "profiled {} with {} samples in {:?}; held-out MAPE {:.2}%",
        model.name,
        n,
        t0.elapsed(),
        mape
    );
    let out = args.get_or("out", "predictor.json");
    predictor.save(out)?;
    println!("saved {out}: coef {:?}", predictor.coef);
    let _ = LatencyPredictor::load(out)?;
    Ok(())
}

fn cmd_bench_sched(args: &Args) -> anyhow::Result<()> {
    use hygen::experiments::bench_sched::{self, BenchConfig};
    let mut cfg = if args.get_bool("quick") { BenchConfig::quick() } else { BenchConfig::full() };
    cfg.n_requests = args.get_usize("n", cfg.n_requests);
    cfg.seed = args.get_u64("seed", cfg.seed);
    let out = args.get_or("out", "BENCH_sched.json");
    let outcome = bench_sched::run_and_save(&cfg, out)?;
    // A super-linear hot path makes the largest-vs-smallest per-entry (or
    // churn per-op) cost ratio grow toward the size ratio, while a linear
    // one keeps both ~flat (constant terms even pull them below 1). Gate
    // well under the quadratic signal but well above noise. Sensitivity
    // scales with the probe sizes: the full 100→5000 shape resolves even
    // small per-entry O(n) terms; the --quick shape (50→400) is mainly a
    // pipeline smoke test and only trips on gross regressions.
    let size_ratio = cfg.scaling_sizes.last().copied().unwrap_or(1) as f64
        / cfg.scaling_sizes.first().copied().unwrap_or(1).max(1) as f64;
    let threshold = (size_ratio / 4.0).max(4.0);
    for (name, ratio) in
        [("per-entry", outcome.ns_per_entry_ratio), ("preempt/resume churn", outcome.churn_ratio)]
    {
        anyhow::ensure!(
            ratio < threshold,
            "{name} scheduling cost grew {ratio:.1}x from n={} to n={} (threshold {threshold:.1}) — super-linear hot path",
            cfg.scaling_sizes.first().copied().unwrap_or(0),
            cfg.scaling_sizes.last().copied().unwrap_or(0),
        );
    }
    Ok(())
}

fn cmd_bench_replay(args: &Args) -> anyhow::Result<()> {
    use hygen::experiments::bench_replay::{self, ReplayConfig};
    let mut cfg = if args.get_bool("quick") { ReplayConfig::quick() } else { ReplayConfig::full() };
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.jobs = args.get_usize_alias("jobs", "j", cfg.jobs).max(1);
    let out = args.get_or("out", "BENCH_e2e.json");
    let prefix_out = args.get_or("prefix-out", "BENCH_prefix.csv");
    let outcome = bench_replay::run_and_save(&cfg, out, prefix_out)?;
    // All regression gates (linear replay cost across scales; zero-alloc
    // steady decode with live cache churn — enforceable here because this
    // binary registers `ALLOC`; O(1) block recycling; prefix-sweep
    // hit-rate monotonicity).
    bench_replay::check_gates(&outcome)
}

fn cmd_cluster_sim(args: &Args) -> anyhow::Result<()> {
    use hygen::experiments::cluster_sim::{self, ClusterSimConfig};
    let mut cfg =
        if args.get_bool("quick") { ClusterSimConfig::quick() } else { ClusterSimConfig::full() };
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.jobs = args.get_usize_alias("jobs", "j", cfg.jobs).max(1);
    if let Some(list) = args.get("replicas") {
        cfg.replica_counts = list
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<Vec<_>, _>>()
            .map_err(|_| anyhow::anyhow!("--replicas expects a comma list like 1,2,4,8"))?;
        anyhow::ensure!(
            cfg.replica_counts.iter().all(|&n| n >= 1),
            "replica counts must be >= 1"
        );
    }
    let out_dir = args.get_or("out", "artifacts");
    let outcomes = cluster_sim::run_and_save(&cfg, out_dir)?;
    if args.get_bool("check") {
        // The measured acceptance gate: SLO-headroom routing must match
        // or beat round-robin on total throughput at 4 replicas (or the
        // largest count actually in the grid) while keeping online p99
        // TBT within the configured SLO scale (default: 2x the
        // per-iteration latency budget).
        let at = if cfg.replica_counts.contains(&4) {
            4
        } else {
            cfg.replica_counts.iter().copied().max().unwrap_or(1)
        };
        let tbt_slo = args.get_f64("tbt-slo-ms", cfg.latency_budget_ms * 2.0);
        cluster_sim::check_slo_headroom_wins(&outcomes, at, tbt_slo)?;
        println!(
            "check passed: slo-headroom >= round-robin at {at} replicas \
             (p99 TBT within {tbt_slo:.0} ms)"
        );
        // The prefix-cache acceptance gate: on the Mooncake-style
        // prefix workload, affinity routing must match-or-beat
        // slo-headroom on aggregate cache hit-rate at equal SLO
        // attainment.
        cluster_sim::check_prefix_affinity_wins(&outcomes, at, tbt_slo)?;
        println!(
            "check passed: prefix-affinity cache hit-rate >= slo-headroom at {at} replicas \
             on the mooncake-prefix workload (equal attainment, p99 TBT within {tbt_slo:.0} ms)"
        );
    }
    Ok(())
}

fn cmd_multi_slo(args: &Args) -> anyhow::Result<()> {
    use hygen::experiments::multi_slo::{self, MultiSloConfig};
    let mut cfg =
        if args.get_bool("quick") { MultiSloConfig::quick() } else { MultiSloConfig::full() };
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.jobs = args.get_usize_alias("jobs", "j", cfg.jobs).max(1);
    if let Some(list) = args.get("replicas") {
        cfg.replica_counts = list
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<Vec<_>, _>>()
            .map_err(|_| anyhow::anyhow!("--replicas expects a comma list like 1,2,4"))?;
        anyhow::ensure!(
            cfg.replica_counts.iter().all(|&n| n >= 1),
            "replica counts must be >= 1"
        );
    }
    let out_dir = args.get_or("out", "artifacts");
    let outcomes = multi_slo::run_and_save(&cfg, out_dir)?;
    // Sanity headline: the 4-class registry must actually serve every
    // interactive class at the largest replica count.
    if let Some(best) = outcomes
        .iter()
        .filter(|o| o.config_name == "4-class")
        .max_by_key(|o| o.replicas)
    {
        for c in best.registry.ids() {
            let spec = best.registry.spec(c);
            if !spec.elastic() {
                anyhow::ensure!(
                    best.result.aggregate.classes[c.index()].finished > 0,
                    "interactive class '{}' finished nothing at {} replicas",
                    spec.name,
                    best.replicas
                );
            }
        }
    }
    Ok(())
}

fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    use hygen::experiments::chaos::{self, ChaosConfig};
    let mut cfg = if args.get_bool("quick") { ChaosConfig::quick() } else { ChaosConfig::full() };
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.jobs = args.get_usize_alias("jobs", "j", cfg.jobs).max(1);
    let out_dir = args.get_or("out", "artifacts");
    // `run_and_save` already enforces the zero-loss conservation gate —
    // a lost (or double-completed) request in any cell is a hard error,
    // not an opt-in check.
    let outcomes = chaos::run_and_save(&cfg, out_dir)?;
    let faulted = outcomes.iter().filter(|o| o.schedule > 0).count();
    println!(
        "chaos gate passed: 0 lost across {} cells ({} faulted)",
        outcomes.len(),
        faulted
    );
    Ok(())
}

fn cmd_overload(args: &Args) -> anyhow::Result<()> {
    use hygen::experiments::overload::{self, OverloadExpConfig};
    let mut cfg =
        if args.get_bool("quick") { OverloadExpConfig::quick() } else { OverloadExpConfig::full() };
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.jobs = args.get_usize_alias("jobs", "j", cfg.jobs).max(1);
    let out_dir = args.get_or("out", "artifacts");
    // `run_and_save` already enforces the conservation gate — an
    // unbalanced admission or exit ledger in any row is a hard error.
    let outcomes = overload::run_and_save(&cfg, out_dir)?;
    let shed: usize = outcomes.iter().map(|o| o.rejected_429 + o.timed_out_504).sum();
    println!(
        "overload gate passed: ledger balanced across {} offered rates ({} shed/timed out)",
        outcomes.len(),
        shed
    );
    Ok(())
}

fn cmd_trace_dump(args: &Args) -> anyhow::Result<()> {
    use hygen::experiments::trace_dump::{self, TraceDumpConfig};
    let mut cfg =
        if args.get_bool("quick") { TraceDumpConfig::quick() } else { TraceDumpConfig::full() };
    cfg.chaos.seed = args.get_u64("seed", cfg.chaos.seed);
    cfg.schedule = args.get_usize("schedule", cfg.schedule);
    let out = args.get_or("out", "artifacts/trace.json");
    trace_dump::run_and_save(&cfg, out)
}

fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    use hygen::analysis;
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => analysis::find_repo_root(std::path::Path::new("."))
            .ok_or_else(|| anyhow::anyhow!("could not locate repo root (rust/src); use --root"))?,
    };
    let report = analysis::lint_repo(&root)?;
    for d in &report.diagnostics {
        println!("{d}");
    }
    if report.is_clean() {
        println!("lint: clean ({} files scanned)", report.files_scanned);
        Ok(())
    } else {
        anyhow::bail!(
            "lint: {} violation(s) across {} scanned file(s)",
            report.diagnostics.len(),
            report.files_scanned
        )
    }
}

fn cmd_gen_trace(args: &Args) -> anyhow::Result<()> {
    let seed = args.get_u64("seed", 0);
    let duration = args.get_f64("duration", 3600.0);
    let kind = args.get_or("kind", "azure");
    let trace: Trace = match kind {
        "azure" => azure::generate(
            &AzureTraceConfig {
                duration_s: duration,
                mean_qps: args.get_f64("qps", 2.0),
                ..Default::default()
            },
            seed,
        ),
        "mooncake" => mooncake::generate(
            &MooncakeTraceConfig {
                duration_s: duration,
                mean_qps: args.get_f64("qps", 1.2),
                ..Default::default()
            },
            seed,
        ),
        other => {
            let d = Dataset::parse(other).ok_or_else(|| anyhow::anyhow!("unknown kind"))?;
            datasets::generate(d, args.get_usize("n", 1000), seed)
        }
    };
    let out = args.get_or("out", "trace.csv");
    trace.save(out)?;
    println!("wrote {} events to {out} (mean qps {:.2})", trace.len(), trace.mean_qps());
    Ok(())
}
