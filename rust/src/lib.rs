//! # HyGen — elastic online/offline LLM request co-location
//!
//! Reproduction of *HyGen: Efficient LLM Serving via Elastic Online-Offline
//! Request Co-location* (Sun, Wang, Lai; cs.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: an N-class
//!   **SLO-class registry** (the paper's online/offline dichotomy is its
//!   two-class default) with per-class queues, the SLO-aware tier-loop
//!   scheduler (higher tiers charge the latency budget first, lower
//!   tiers drink the residual, preemption flows down-tier only), the
//!   linear-regression latency predictor, the SLO-aware profiler,
//!   prefix-sharing-maximizing offline scheduling with a fairness
//!   extension, and a paged KV block manager.
//! * **Layer 2** — a JAX step function (mixed chunked-prefill/decode batch
//!   over a slotted KV cache) AOT-lowered to HLO text at build time
//!   (`python/compile/`); loaded and executed here via the PJRT C API
//!   ([`runtime`]). Python never runs on the request path.
//! * **Layer 1** — a Pallas online-softmax attention kernel inside that
//!   step function (`python/compile/kernels/`).
//!
//! Two interchangeable execution backends drive the *same* scheduler:
//! [`engine::pjrt_backend::PjrtBackend`] executes the real AOT artifacts on
//! the PJRT CPU client (behind the `pjrt` cargo feature, which pulls in
//! the `xla` crate), and [`sim::SimBackend`] — the default — is a
//! calibrated discrete-event cost model used to regenerate the paper's
//! evaluation at A100/A40/A5000 scale (see DESIGN.md for the substitution
//! table).
//!
//! Above the single engine sits the [`cluster`] layer: N replicas behind
//! a routing policy (round-robin / join-shortest-queue / SLO-headroom)
//! with elastic placement of the shared offline backlog — `hygen serve
//! --replicas N --router <policy>` for the threaded front end and
//! `hygen cluster-sim` for the deterministic policy comparison.
//!
//! Entry points: the `hygen` binary (`serve`, `run-trace`, `figures`
//! — with `-j` parallel experiment execution —, `profile`,
//! `train-predictor`, `bench-sched`, `bench-replay`, `cluster-sim`,
//! `multi-slo` subcommands), the `examples/`, and the bench targets
//! under `rust/benches/`.

pub mod analysis;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;
