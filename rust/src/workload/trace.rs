//! Trace model: a sequence of timestamped requests with length metadata,
//! plus CSV persistence so generated workloads can be inspected, diffed,
//! and replayed exactly.

use crate::coordinator::request::{empty_prompt, Class};
use std::sync::Arc;

/// One trace record (the unit both generators and the engine replay).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    pub class: Class,
    pub prompt_len: usize,
    pub output_len: usize,
    /// Prompt token ids; generators synthesize these so PSM/prefix caching
    /// operate on real token content even in simulation. `Arc`-shared with
    /// every `Request` admitted from this event (replay never copies it).
    pub prompt: Arc<[u32]>,
}

#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Events sorted by arrival. Treat as read-only after construction:
    /// the per-class counts below are computed once in [`Trace::new`].
    pub events: Vec<TraceEvent>,
    /// Events per class id (dense; index = class).
    n_by_class: Vec<usize>,
}

impl Trace {
    pub fn new(mut events: Vec<TraceEvent>) -> Trace {
        events.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let mut n_by_class = Vec::new();
        for e in &events {
            let i = e.class.index();
            if i >= n_by_class.len() {
                n_by_class.resize(i + 1, 0);
            }
            n_by_class[i] += 1;
        }
        Trace { events, n_by_class }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events of one class (precomputed — the replay loops' admission
    /// lookahead and the bench trace stats read these counts every replay
    /// instead of rescanning the event list).
    pub fn num_of(&self, class: Class) -> usize {
        self.n_by_class.get(class.index()).copied().unwrap_or(0)
    }

    /// Flagship-class (class 0) events in the trace.
    pub fn num_online(&self) -> usize {
        self.num_of(Class::ONLINE)
    }

    /// Events of every class beyond the flagship.
    pub fn num_offline(&self) -> usize {
        self.events.len() - self.num_online()
    }

    pub fn duration_s(&self) -> f64 {
        self.events.last().map(|e| e.arrival_s).unwrap_or(0.0)
    }

    /// Merge two traces (e.g. an online trace with an offline backlog).
    pub fn merged(mut self, other: Trace) -> Trace {
        self.events.extend(other.events);
        Trace::new(self.events)
    }

    /// Mean arrival rate over the trace span (req/s).
    pub fn mean_qps(&self) -> f64 {
        if self.events.len() < 2 {
            return 0.0;
        }
        self.events.len() as f64 / self.duration_s().max(1e-9)
    }

    /// Random subsample to a target QPS, preserving timestamps — the
    /// paper's "sample T*Q requests over T seconds" methodology (§5.1).
    pub fn sample_to_qps(&self, qps: f64, rng: &mut crate::util::rng::Rng) -> Trace {
        let keep = (qps / self.mean_qps()).min(1.0);
        let events =
            self.events.iter().filter(|_| rng.chance(keep)).cloned().collect::<Vec<_>>();
        Trace::new(events)
    }

    // ---- CSV persistence (arrival,class,prompt_len,output_len) ----
    // Prompt token ids are regenerated from lengths on load (seeded), so
    // traces stay compact; exact-token replay uses the in-memory form.

    pub fn to_csv(&self) -> String {
        let mut out = String::from("arrival_s,class,prompt_len,output_len\n");
        for e in &self.events {
            // Classic names for the default two classes; higher class ids
            // serialize positionally ("class2", ...) so N-class traces
            // survive the round trip without a registry in scope.
            let class: std::borrow::Cow<'_, str> = match e.class {
                Class::ONLINE => "online".into(),
                Class::OFFLINE => "offline".into(),
                c => format!("class{}", c.index()).into(),
            };
            out.push_str(&format!(
                "{:.6},{},{},{}\n",
                e.arrival_s, class, e.prompt_len, e.output_len
            ));
        }
        out
    }

    pub fn from_csv(text: &str) -> anyhow::Result<Trace> {
        let mut events = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header / blanks
            }
            let parts: Vec<&str> = line.split(',').collect();
            if parts.len() != 4 {
                anyhow::bail!("line {}: expected 4 fields, got {}", i + 1, parts.len());
            }
            let class = match parts[1] {
                "online" => Class::ONLINE,
                "offline" => Class::OFFLINE,
                other => match other.strip_prefix("class").and_then(|n| n.parse::<u16>().ok()) {
                    Some(n) => Class(n),
                    None => anyhow::bail!("line {}: bad class '{other}'", i + 1),
                },
            };
            events.push(TraceEvent {
                arrival_s: parts[0].parse()?,
                class,
                prompt_len: parts[2].parse()?,
                output_len: parts[3].parse()?,
                prompt: empty_prompt(),
            });
        }
        Ok(Trace::new(events))
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }

    pub fn load(path: &str) -> anyhow::Result<Trace> {
        Trace::from_csv(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ev(t: f64, class: Class, p: usize, o: usize) -> TraceEvent {
        TraceEvent { arrival_s: t, class, prompt_len: p, output_len: o, prompt: Vec::new().into() }
    }

    #[test]
    fn new_sorts_by_arrival() {
        let tr = Trace::new(vec![
            ev(2.0, Class::ONLINE, 10, 5),
            ev(1.0, Class::OFFLINE, 20, 5),
        ]);
        assert_eq!(tr.events[0].arrival_s, 1.0);
        assert_eq!(tr.duration_s(), 2.0);
    }

    #[test]
    fn per_class_counts_precomputed() {
        let tr = Trace::new(vec![
            ev(0.0, Class::ONLINE, 1, 1),
            ev(1.0, Class::OFFLINE, 1, 1),
            ev(2.0, Class::ONLINE, 1, 1),
        ]);
        assert_eq!(tr.num_online(), 2);
        assert_eq!(tr.num_offline(), 1);
        let merged = tr.merged(Trace::new(vec![ev(0.5, Class::OFFLINE, 1, 1)]));
        assert_eq!(merged.num_online(), 2);
        assert_eq!(merged.num_offline(), 2);
        assert_eq!(Trace::default().num_online(), 0);
        assert_eq!(Trace::default().num_of(Class(3)), 0);
        // N-class counts are dense by class id.
        let multi = Trace::new(vec![
            ev(0.0, Class(0), 1, 1),
            ev(0.1, Class(2), 1, 1),
            ev(0.2, Class(3), 1, 1),
            ev(0.3, Class(3), 1, 1),
        ]);
        assert_eq!(multi.num_of(Class(2)), 1);
        assert_eq!(multi.num_of(Class(3)), 2);
        assert_eq!(multi.num_of(Class(1)), 0);
        assert_eq!(multi.num_offline(), 3);
    }

    #[test]
    fn csv_roundtrips_higher_class_ids() {
        let tr = Trace::new(vec![ev(0.5, Class(2), 16, 4), ev(1.0, Class(3), 8, 2)]);
        let parsed = Trace::from_csv(&tr.to_csv()).unwrap();
        assert_eq!(parsed.events[0].class, Class(2));
        assert_eq!(parsed.events[1].class, Class(3));
        assert!(tr.to_csv().contains("class2"));
    }

    #[test]
    fn merged_interleaves() {
        let a = Trace::new(vec![ev(1.0, Class::ONLINE, 1, 1), ev(3.0, Class::ONLINE, 1, 1)]);
        let b = Trace::new(vec![ev(2.0, Class::OFFLINE, 1, 1)]);
        let m = a.merged(b);
        assert_eq!(m.len(), 3);
        assert!(m.events.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn csv_roundtrip() {
        let tr = Trace::new(vec![
            ev(0.5, Class::ONLINE, 128, 64),
            ev(1.25, Class::OFFLINE, 4096, 512),
        ]);
        let parsed = Trace::from_csv(&tr.to_csv()).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed.events[1].prompt_len, 4096);
        assert_eq!(parsed.events[0].class, Class::ONLINE);
    }

    #[test]
    fn csv_rejects_garbage() {
        assert!(Trace::from_csv("arrival\n1,online\n").is_err());
        assert!(Trace::from_csv("h\n1.0,middleware,5,5\n").is_err());
    }

    #[test]
    fn sample_to_qps_reduces_rate() {
        let events: Vec<TraceEvent> =
            (0..1000).map(|i| ev(i as f64 * 0.1, Class::ONLINE, 10, 10)).collect();
        let tr = Trace::new(events);
        assert!((tr.mean_qps() - 10.0).abs() < 0.2);
        let mut rng = Rng::new(0);
        let sampled = tr.sample_to_qps(2.0, &mut rng);
        let q = sampled.mean_qps();
        assert!((q - 2.0).abs() < 0.6, "sampled qps {q}");
    }
}
