//! Workload substrate: trace model + generators standing in for the
//! paper's datasets (DESIGN.md substitution table).
//!
//! * [`azure`] — Azure-LLM-inference-2023-like conversation trace
//!   (diurnal envelope + minute-scale bursts, ≥3× rate swings).
//! * [`mooncake`] — Mooncake-like trace (burstier, heavier-tailed).
//! * [`datasets`] — offline request sets modelled on arXiv-summarization,
//!   CNN/DailyMail and MMLU (length distributions + shared-prefix
//!   structure driving PSM).
//! * [`trace`] — the trace record type + CSV persistence.

pub mod azure;
pub mod datasets;
pub mod mooncake;
pub mod trace;
