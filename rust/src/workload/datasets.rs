//! Offline dataset models: synthetic stand-ins for the paper's offline
//! workloads with the two properties PSM and the throughput analysis
//! depend on — the *length distribution* and the *shared-prefix structure*.
//!
//! * **arXiv summarization** (Cohan et al.): long documents (median ≈ 3k
//!   tokens, heavy tail, capped), short summaries; a shared instruction
//!   preamble ("Summarize the following article: ...") of ~30 tokens.
//! * **CNN/DailyMail**: medium articles (median ≈ 780 tokens), highlights
//!   of ~60 tokens, same-style shared preamble.
//! * **MMLU**: short multiple-choice questions (~100-300 tokens) drawn
//!   from 57 subjects; all questions of a subject share a long few-shot
//!   template prefix (hundreds of tokens) — the prefix-sharing-heavy
//!   workload of Fig. 6.
//!
//! Prompts carry real synthetic token ids: a family/template prefix
//! (identical ids for the same family) followed by unique body tokens, so
//! the PSM trie, the block-manager prefix cache, and the consecutive-LCP
//! accounting all operate exactly as they would on tokenized text.

use super::trace::{Trace, TraceEvent};
use crate::coordinator::request::Class;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    ArxivSummarization,
    CnnDailyMail,
    Mmlu,
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Dataset> {
        match s {
            "arxiv" | "arxiv-summarization" => Some(Dataset::ArxivSummarization),
            "cnn" | "cnn-dailymail" => Some(Dataset::CnnDailyMail),
            "mmlu" => Some(Dataset::Mmlu),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::ArxivSummarization => "arxiv-summarization",
            Dataset::CnnDailyMail => "cnn-dailymail",
            Dataset::Mmlu => "mmlu",
        }
    }

    fn params(&self) -> DatasetParams {
        match self {
            Dataset::ArxivSummarization => DatasetParams {
                prompt_mu: 8.0, // ~3000 tokens median
                prompt_sigma: 0.6,
                output_mu: 5.2, // ~180-token summaries
                output_sigma: 0.4,
                max_prompt: 7000,
                max_output: 600,
                families: 1, // one shared instruction preamble
                family_prefix_tokens: 32,
            },
            Dataset::CnnDailyMail => DatasetParams {
                prompt_mu: 6.66, // ~780 tokens median
                prompt_sigma: 0.45,
                output_mu: 4.1, // ~60-token highlights
                output_sigma: 0.35,
                max_prompt: 2500,
                max_output: 200,
                families: 1,
                family_prefix_tokens: 24,
            },
            Dataset::Mmlu => DatasetParams {
                prompt_mu: 5.0, // ~150-token questions
                prompt_sigma: 0.35,
                output_mu: 0.7, // a few tokens (the answer letter + expl.)
                output_sigma: 0.5,
                max_prompt: 600,
                max_output: 16,
                families: 57, // subjects, each with a few-shot template
                family_prefix_tokens: 320,
            },
        }
    }
}

struct DatasetParams {
    prompt_mu: f64,
    prompt_sigma: f64,
    output_mu: f64,
    output_sigma: f64,
    max_prompt: usize,
    max_output: usize,
    families: usize,
    family_prefix_tokens: usize,
}

/// Generate `n` offline requests, all available at time 0 (the paper's
/// offline backlog model: Batch-API-style jobs queued up front). Arrival
/// order interleaves families — exactly the situation PSM reorders.
pub fn generate(dataset: Dataset, n: usize, seed: u64) -> Trace {
    generate_arrivals(dataset, n, 0.0, seed)
}

/// Like [`generate`] but spreading arrivals uniformly over `span_s`
/// seconds (for experiments with a trickling offline feed).
pub fn generate_arrivals(dataset: Dataset, n: usize, span_s: f64, seed: u64) -> Trace {
    let p = dataset.params();
    let mut rng = Rng::new(seed ^ (dataset.name().len() as u64).rotate_left(40));
    let mut events = Vec::with_capacity(n);
    // Unique-token space per dataset, away from online ids.
    let mut uniq: u32 = 1 << 28;
    for i in 0..n {
        let family = rng.range_usize(0, p.families);
        let prompt_len = (rng.lognormal(p.prompt_mu, p.prompt_sigma) as usize)
            .clamp(p.family_prefix_tokens + 4, p.max_prompt);
        let output_len =
            (rng.lognormal(p.output_mu, p.output_sigma) as usize).clamp(1, p.max_output);
        let mut prompt = Vec::with_capacity(prompt_len);
        // family template prefix: identical ids within a family
        for k in 0..p.family_prefix_tokens.min(prompt_len) {
            prompt.push((family as u32) << 16 | (k as u32 & 0xFFFF) | (1 << 30));
        }
        // unique body
        while prompt.len() < prompt_len {
            prompt.push(uniq);
            uniq = uniq.wrapping_add(1);
        }
        let arrival_s = if span_s > 0.0 { span_s * (i as f64 / n as f64) } else { 0.0 };
        events.push(TraceEvent {
            arrival_s,
            class: Class::OFFLINE,
            prompt_len,
            output_len,
            prompt: prompt.into(),
        });
    }
    Trace::new(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::psm::lcp;

    #[test]
    fn arxiv_is_long_cnn_medium_mmlu_short() {
        let mean_prompt = |d: Dataset| {
            let tr = generate(d, 2000, 0);
            tr.events.iter().map(|e| e.prompt_len as f64).sum::<f64>() / tr.len() as f64
        };
        let arxiv = mean_prompt(Dataset::ArxivSummarization);
        let cnn = mean_prompt(Dataset::CnnDailyMail);
        let mmlu = mean_prompt(Dataset::Mmlu);
        assert!(arxiv > cnn && cnn > mmlu, "{arxiv} > {cnn} > {mmlu}");
        assert!(arxiv > 2000.0, "arxiv docs are long: {arxiv}");
        assert!(mmlu < 600.0, "mmlu questions are short: {mmlu}");
    }

    #[test]
    fn mmlu_same_family_shares_long_prefix() {
        let tr = generate(Dataset::Mmlu, 500, 1);
        // find two requests of the same subject
        let mut by_family: std::collections::HashMap<u32, Vec<usize>> = Default::default();
        for (i, e) in tr.events.iter().enumerate() {
            by_family.entry(e.prompt[0]).or_default().push(i);
        }
        let family = by_family.values().find(|v| v.len() >= 2).expect("families repeat");
        let a = &tr.events[family[0]].prompt;
        let b = &tr.events[family[1]].prompt;
        assert_eq!(lcp(a, b), 320, "full few-shot template shared");
        // different families share nothing
        let other = by_family
            .iter()
            .find(|(k, v)| **k != tr.events[family[0]].prompt[0] && !v.is_empty());
        if let Some((_, v)) = other {
            assert_eq!(lcp(a, &tr.events[v[0]].prompt), 0);
        }
    }

    #[test]
    fn arxiv_shares_instruction_preamble_only() {
        let tr = generate(Dataset::ArxivSummarization, 50, 2);
        let a = &tr.events[0].prompt;
        let b = &tr.events[1].prompt;
        assert_eq!(lcp(a, b), 32, "common instruction preamble");
    }

    #[test]
    fn output_lengths_positive_and_capped() {
        for d in [Dataset::ArxivSummarization, Dataset::CnnDailyMail, Dataset::Mmlu] {
            let tr = generate(d, 500, 3);
            assert!(tr.events.iter().all(|e| e.output_len >= 1));
            assert!(tr.events.iter().all(|e| e.prompt.len() == e.prompt_len));
        }
    }

    #[test]
    fn arrivals_spread_over_span() {
        let tr = generate_arrivals(Dataset::CnnDailyMail, 100, 50.0, 4);
        assert_eq!(tr.events[0].arrival_s, 0.0);
        assert!(tr.duration_s() > 40.0);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Dataset::parse("arxiv"), Some(Dataset::ArxivSummarization));
        assert_eq!(Dataset::parse("cnn-dailymail"), Some(Dataset::CnnDailyMail));
        assert_eq!(Dataset::parse("mmlu"), Some(Dataset::Mmlu));
        assert_eq!(Dataset::parse("wikipedia"), None);
    }
}
