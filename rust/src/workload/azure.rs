//! Azure-LLM-inference-2023-like online trace generator.
//!
//! The real one-hour conversation trace (Patel et al., Splitwise) is not
//! distributable here, so we synthesize a trace reproducing its published
//! shape (the properties the scheduler is sensitive to — Fig. 1 / §3.2):
//!
//! * a slow diurnal-style envelope over the hour,
//! * minute-scale bursts: rate can swing ≥3× within a couple of minutes
//!   (modelled by a log-normal modulating process resampled per window),
//! * Poisson arrivals within each window,
//! * conversation-style lengths: log-normal prompts (median ≈ 1k tokens,
//!   long tail) and shorter log-normal outputs (median ≈ 120-200).

use super::trace::{Trace, TraceEvent};
use crate::coordinator::request::Class;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct AzureTraceConfig {
    /// Trace span in seconds (the paper uses a one-hour trace).
    pub duration_s: f64,
    /// Target mean request rate (the paper samples to a QPS that suits the
    /// hardware, §5.1).
    pub mean_qps: f64,
    /// Burst modulation window (rate is re-drawn each window).
    pub burst_window_s: f64,
    /// Log-normal sigma of the burst modulation (0.45 gives ~3x swings).
    pub burst_sigma: f64,
    /// Diurnal envelope amplitude in [0, 1).
    pub diurnal_amplitude: f64,
    /// Prompt length log-normal (mu, sigma) in ln-tokens.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// Output length log-normal (mu, sigma).
    pub output_mu: f64,
    pub output_sigma: f64,
    /// Hard caps keeping lengths inside the engine's context budget.
    pub max_prompt: usize,
    pub max_output: usize,
}

impl Default for AzureTraceConfig {
    fn default() -> Self {
        AzureTraceConfig {
            duration_s: 3600.0,
            mean_qps: 2.0,
            burst_window_s: 30.0,
            burst_sigma: 0.45,
            diurnal_amplitude: 0.35,
            prompt_mu: 6.9,    // e^6.9 ~ 1000 tokens median
            prompt_sigma: 0.8, // heavy tail up to several k
            output_mu: 5.0,    // ~150 tokens median
            output_sigma: 0.7,
            max_prompt: 6000,
            max_output: 1500,
        }
    }
}

impl AzureTraceConfig {
    /// Scaled-down variant for the real (CPU PJRT) engine: tiny context.
    pub fn tiny() -> AzureTraceConfig {
        AzureTraceConfig {
            duration_s: 30.0,
            mean_qps: 2.0,
            burst_window_s: 5.0,
            prompt_mu: 3.4, // ~30 tokens
            prompt_sigma: 0.5,
            output_mu: 2.0, // ~8 tokens
            output_sigma: 0.4,
            max_prompt: 120,
            max_output: 32,
            ..Default::default()
        }
    }
}

/// Generate the online trace. Prompts get synthetic token ids (unique per
/// request — conversations rarely share long prefixes, unlike the offline
/// datasets).
pub fn generate(cfg: &AzureTraceConfig, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0xA2u64.rotate_left(32));
    let mut events = Vec::new();
    let mut t = 0.0f64;
    let mut window_end = 0.0f64;
    let mut rate = cfg.mean_qps;
    // Normalize the log-normal modulation so the mean stays ~mean_qps.
    let ln_mean_correction = (-0.5 * cfg.burst_sigma * cfg.burst_sigma).exp();
    let mut uniq: u32 = 1 << 20; // token-id space distinct from datasets
    while t < cfg.duration_s {
        if t >= window_end {
            // diurnal envelope (one slow sinusoid across the span)
            let phase = 2.0 * std::f64::consts::PI * (t / cfg.duration_s);
            let envelope = 1.0 + cfg.diurnal_amplitude * phase.sin();
            let burst = rng.lognormal(0.0, cfg.burst_sigma) * ln_mean_correction;
            rate = (cfg.mean_qps * envelope * burst).max(0.02);
            window_end = t + cfg.burst_window_s;
        }
        t += rng.exp(rate);
        if t >= cfg.duration_s {
            break;
        }
        let prompt_len =
            (rng.lognormal(cfg.prompt_mu, cfg.prompt_sigma) as usize).clamp(4, cfg.max_prompt);
        let output_len =
            (rng.lognormal(cfg.output_mu, cfg.output_sigma) as usize).clamp(1, cfg.max_output);
        // unique prompt tokens (no accidental prefix sharing online)
        let prompt: Vec<u32> = (0..prompt_len as u32).map(|i| uniq.wrapping_add(i)).collect();
        uniq = uniq.wrapping_add(prompt_len as u32 + 17);
        events.push(TraceEvent {
            arrival_s: t,
            class: Class::ONLINE,
            prompt_len,
            output_len,
            prompt: prompt.into(),
        });
    }
    Trace::new(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::WindowSeries;

    #[test]
    fn mean_rate_close_to_target() {
        let cfg = AzureTraceConfig { duration_s: 3600.0, mean_qps: 2.0, ..Default::default() };
        let tr = generate(&cfg, 0);
        let qps = tr.len() as f64 / cfg.duration_s;
        assert!((qps - 2.0).abs() < 0.5, "qps={qps}");
    }

    #[test]
    fn bursts_reach_3x_within_minutes() {
        // The Fig. 1 property: minute-window rates vary >= 3x.
        let cfg = AzureTraceConfig::default();
        let tr = generate(&cfg, 1);
        let mut w = WindowSeries::new(120.0);
        for e in &tr.events {
            w.record(e.arrival_s, 1.0);
        }
        let rates = w.rates();
        let max = rates.iter().cloned().fold(0.0, f64::max);
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min.max(1e-9) >= 3.0, "burstiness {}", max / min);
    }

    #[test]
    fn lengths_within_caps_and_plausible() {
        let cfg = AzureTraceConfig::default();
        let tr = generate(&cfg, 2);
        assert!(tr.len() > 1000);
        let mean_prompt: f64 =
            tr.events.iter().map(|e| e.prompt_len as f64).sum::<f64>() / tr.len() as f64;
        assert!(mean_prompt > 400.0 && mean_prompt < 3000.0, "mean prompt {mean_prompt}");
        assert!(tr.events.iter().all(|e| e.prompt_len <= cfg.max_prompt));
        assert!(tr.events.iter().all(|e| e.output_len <= cfg.max_output && e.output_len >= 1));
        assert!(tr.events.iter().all(|e| e.prompt.len() == e.prompt_len));
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = AzureTraceConfig::tiny();
        let a = generate(&cfg, 42);
        let b = generate(&cfg, 42);
        assert_eq!(a.events, b.events);
        let c = generate(&cfg, 43);
        assert_ne!(a.events.len(), 0);
        assert!(a.events != c.events);
    }

    #[test]
    fn tiny_profile_fits_small_context() {
        let tr = generate(&AzureTraceConfig::tiny(), 3);
        assert!(tr.events.iter().all(|e| e.prompt_len + e.output_len <= 160));
    }
}
