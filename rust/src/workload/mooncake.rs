//! Mooncake-trace-like online workload (Qin et al., Kimi's serving trace).
//!
//! Relative to the Azure conversation trace, the published Mooncake trace
//! shows (Fig. 13 of the paper): much longer prompts (KV-centric workload,
//! many tens of k context — capped here to the simulated engines' budget),
//! shorter outputs, and *spikier* arrivals (request storms on ten-minute
//! scales). We model it as a gamma-modulated Poisson process with a
//! heavier burst tail plus occasional storm windows.
//!
//! Mooncake's other signature property is **prefix reuse**: most requests
//! open with one of a small set of shared system/context templates, which
//! is exactly what the KV prefix cache (and the `prefix-affinity` router)
//! exploit. A configurable share of generated requests therefore draws its
//! opening `prefix_len` tokens from a per-group deterministic template —
//! same group ⇒ byte-identical opening tokens ⇒ identical full-block hash
//! chains, the `synthetic_chain` sharing semantics carried by real prompt
//! content. Prefix decisions come from a *separate* RNG stream, so
//! arrival times and length distributions are bit-identical across
//! `prefix_share` settings (and to pre-prefix versions of this
//! generator).

use super::trace::{Trace, TraceEvent};
use crate::coordinator::request::Class;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct MooncakeTraceConfig {
    pub duration_s: f64,
    pub mean_qps: f64,
    pub burst_window_s: f64,
    /// Gamma shape for rate modulation (smaller = spikier). 1.2 gives the
    /// pronounced trough/storm alternation of Fig. 13.
    pub gamma_shape: f64,
    /// Probability a window is a storm (rate multiplied by `storm_boost`).
    pub storm_prob: f64,
    pub storm_boost: f64,
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub output_mu: f64,
    pub output_sigma: f64,
    pub max_prompt: usize,
    pub max_output: usize,
    /// Fraction of requests opening with a shared group template
    /// (Mooncake-style system-prompt reuse). 0 = all-unique prompts.
    pub prefix_share: f64,
    /// Number of distinct shared templates in rotation.
    pub prefix_groups: usize,
    /// Length (tokens) of each shared template; clamped to the prompt.
    /// Keep it a multiple of the engines' block size for full-block reuse.
    pub prefix_len: usize,
}

impl Default for MooncakeTraceConfig {
    fn default() -> Self {
        MooncakeTraceConfig {
            duration_s: 3600.0,
            mean_qps: 1.2,
            burst_window_s: 60.0,
            gamma_shape: 1.2,
            storm_prob: 0.04,
            storm_boost: 4.0,
            prompt_mu: 7.6, // ~2000 tokens median: long-context workload
            prompt_sigma: 0.9,
            output_mu: 4.3, // ~75 tokens median
            output_sigma: 0.6,
            max_prompt: 8000,
            max_output: 800,
            prefix_share: 0.5,
            prefix_groups: 12,
            prefix_len: 1024,
        }
    }
}

/// Token `i` of group `g`'s shared template: deterministic, and disjoint
/// from the `uniq`-counter tail tokens (templates set the top bit; the
/// tail counter starts at `1 << 24` and wraps far below it).
fn template_token(group: usize, i: usize) -> u32 {
    let mix = ((group as u32) << 20).wrapping_add((i as u32).wrapping_mul(0x9E37_79B9));
    0x8000_0000 | (mix & 0x7FFF_FFFF)
}

pub fn generate(cfg: &MooncakeTraceConfig, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0x3A00Cu64.rotate_left(24));
    // Prefix-group decisions draw from their own stream so the arrival /
    // length streams above are untouched by `prefix_share` (and identical
    // to the pre-prefix generator for any setting).
    let mut content = Rng::new(seed ^ 0xC0DE_5EEDu64.rotate_left(32));
    let mut events = Vec::new();
    let mut t = 0.0f64;
    let mut window_end = 0.0f64;
    let mut rate = cfg.mean_qps;
    let mut uniq: u32 = 1 << 24;
    while t < cfg.duration_s {
        if t >= window_end {
            // gamma-modulated base rate, mean 1
            let g = rng.gamma(cfg.gamma_shape, 1.0 / cfg.gamma_shape);
            let storm = if rng.chance(cfg.storm_prob) { cfg.storm_boost } else { 1.0 };
            rate = (cfg.mean_qps * g * storm).max(0.01);
            window_end = t + cfg.burst_window_s;
        }
        t += rng.exp(rate);
        if t >= cfg.duration_s {
            break;
        }
        let prompt_len =
            (rng.lognormal(cfg.prompt_mu, cfg.prompt_sigma) as usize).clamp(8, cfg.max_prompt);
        let output_len =
            (rng.lognormal(cfg.output_mu, cfg.output_sigma) as usize).clamp(1, cfg.max_output);
        // Shared-template opening: `shared` tokens of group identity, the
        // tail from the per-request unique counter. Groups are drawn even
        // for non-sharing requests to keep the content stream aligned.
        let group = if cfg.prefix_groups > 0 { content.range_usize(0, cfg.prefix_groups) } else { 0 };
        let shared = if cfg.prefix_share > 0.0
            && cfg.prefix_groups > 0
            && content.chance(cfg.prefix_share)
        {
            cfg.prefix_len.min(prompt_len)
        } else {
            0
        };
        let prompt: Vec<u32> = (0..prompt_len)
            .map(|i| {
                if i < shared {
                    template_token(group, i)
                } else {
                    uniq.wrapping_add(i as u32)
                }
            })
            .collect();
        uniq = uniq.wrapping_add(prompt_len as u32 + 29);
        events.push(TraceEvent {
            arrival_s: t,
            class: Class::ONLINE,
            prompt_len,
            output_len,
            prompt: prompt.into(),
        });
    }
    Trace::new(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::WindowSeries;
    use crate::workload::azure::{self, AzureTraceConfig};

    #[test]
    fn mean_rate_roughly_target() {
        let cfg = MooncakeTraceConfig::default();
        let tr = generate(&cfg, 0);
        let qps = tr.len() as f64 / cfg.duration_s;
        assert!(qps > 0.5 * cfg.mean_qps && qps < 2.0 * cfg.mean_qps, "qps {qps}");
    }

    #[test]
    fn spikier_than_azure() {
        // Fig. 13 vs Fig. 1: Mooncake's windowed rates are burstier.
        let mk = generate(&MooncakeTraceConfig::default(), 1);
        let az = azure::generate(&AzureTraceConfig::default(), 1);
        let burst = |tr: &Trace| {
            let mut w = WindowSeries::new(120.0);
            for e in &tr.events {
                w.record(e.arrival_s, 1.0);
            }
            w.burstiness()
        };
        assert!(burst(&mk) > burst(&az), "mooncake {} vs azure {}", burst(&mk), burst(&az));
    }

    #[test]
    fn prompts_longer_outputs_shorter_than_azure() {
        let mk = generate(&MooncakeTraceConfig::default(), 2);
        let az = azure::generate(&AzureTraceConfig::default(), 2);
        let mean = |tr: &Trace, f: fn(&TraceEvent) -> usize| {
            tr.events.iter().map(|e| f(e) as f64).sum::<f64>() / tr.len() as f64
        };
        assert!(mean(&mk, |e| e.prompt_len) > mean(&az, |e| e.prompt_len));
        assert!(mean(&mk, |e| e.output_len) < mean(&az, |e| e.output_len));
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = MooncakeTraceConfig { duration_s: 120.0, ..Default::default() };
        assert_eq!(generate(&cfg, 9).events, generate(&cfg, 9).events);
    }

    #[test]
    fn shared_prefixes_carry_real_block_identity() {
        use crate::coordinator::block_manager::chain_hashes;
        let cfg = MooncakeTraceConfig { duration_s: 1200.0, ..Default::default() };
        let tr = generate(&cfg, 3);
        // Root-block hashes repeat across requests of the same group —
        // the prefix cache can actually hit on replay.
        let mut roots: Vec<u64> =
            tr.events.iter().filter_map(|e| chain_hashes(&e.prompt, 16).first().copied()).collect();
        let total = roots.len();
        roots.sort_unstable();
        roots.dedup();
        assert!(
            roots.len() < total,
            "no shared root blocks in {total} requests — prefix families missing"
        );
        assert!(
            roots.len() <= total - total / 4,
            "sharing too rare: {} distinct roots in {total}",
            roots.len()
        );
        // With sharing disabled every root is unique (the old behaviour).
        let cold =
            generate(&MooncakeTraceConfig { prefix_share: 0.0, ..cfg.clone() }, 3);
        let mut cold_roots: Vec<u64> = cold
            .events
            .iter()
            .filter_map(|e| chain_hashes(&e.prompt, 16).first().copied())
            .collect();
        let n = cold_roots.len();
        cold_roots.sort_unstable();
        cold_roots.dedup();
        assert_eq!(cold_roots.len(), n, "prefix_share 0 keeps prompts all-unique");
    }

    #[test]
    fn prefix_share_leaves_arrival_and_length_streams_unchanged() {
        let cfg = MooncakeTraceConfig { duration_s: 600.0, ..Default::default() };
        let warm = generate(&MooncakeTraceConfig { prefix_share: 0.9, ..cfg.clone() }, 5);
        let cold = generate(&MooncakeTraceConfig { prefix_share: 0.0, ..cfg.clone() }, 5);
        assert_eq!(warm.len(), cold.len());
        for (w, c) in warm.events.iter().zip(cold.events.iter()) {
            assert_eq!(w.arrival_s, c.arrival_s, "arrival stream must not depend on sharing");
            assert_eq!(w.prompt_len, c.prompt_len);
            assert_eq!(w.output_len, c.output_len);
        }
    }
}
